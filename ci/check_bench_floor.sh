#!/usr/bin/env bash
# Hot-path regression gate: fail when the sim_throughput smoke run's
# steps/s falls below a checked-in floor.
#
# The gated metric is `steps_per_second` of the `saturated_32rps`
# scenario in BENCH_sim.json — simulated decode steps per wall second,
# the most step-dense scenario, so an accidental per-step allocation or
# rescan shows up here first. (The gate used to track
# `events_per_second`; the decode-leap engine collapses step events into
# leaps by design, so events/s stopped being a stable perf metric —
# `steps_simulated` is bit-identical across leap modes and survives.)
#
# When the paired `saturated_32rps_no_leap` reference row is present,
# the script also prints the leap-on/leap-off steps/s ratio — the leap
# engine's acceptance metric (informational, not gated: it tracks
# machine-dependent event/step timing ratios). Likewise, when the
# `par_8dec_64rps` / `par_8dec_64rps_no_par` pair is present, it prints
# the within-run parallelism speedup (ISSUE 7) — also informational,
# since it scales with the runner's core count. The paired
# `fleet_4grp_diurnal` rows (ISSUE 8) get the same treatment: the
# 4-group lockstep fleet's leap speedup is printed, never gated. So do
# the paired `hetero_offload_16rps` rows (ISSUE 9): the standalone-
# executor cost plane's leap speedup is printed, never gated. And the
# paired `fleet_4grp_crash` rows (ISSUE 10): the fault-tolerant fleet's
# (health-aware routing + failover + overload shedding) leap speedup is
# printed, never gated.
#
# To help the ratchet protocol along, the gate also prints a suggested
# floor (20% of the measured saturated_32rps steps/s) — copy it into
# ci/sim_bench_floor.txt when ratcheting from a CI artifact.
#
# Floor calibration protocol (EXPERIMENTS.md §Perf):
#   * the floor lives in ci/sim_bench_floor.txt and is deliberately set
#     well below the recorded runner-class numbers (so runner variance
#     never false-positives) but close enough to catch an
#     order-of-magnitude hot-path regression;
#   * for an intentional recalibration (e.g. the cost model gets richer),
#     override with SIM_BENCH_FLOOR in the workflow env for the PR that
#     moves it, and update the checked-in floor in the same PR.
#
# Usage: check_bench_floor.sh [BENCH_sim.json]
set -euo pipefail

json="${1:-BENCH_sim.json}"
script_dir="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
floor="${SIM_BENCH_FLOOR:-$(tr -d '[:space:]' < "$script_dir/sim_bench_floor.txt")}"

if [[ ! -f "$json" ]]; then
    echo "bench gate: $json not found (did the bench step run?)" >&2
    exit 1
fi

python3 - "$json" "$floor" <<'PY'
import json, sys

path, floor = sys.argv[1], float(sys.argv[2])
with open(path) as f:
    rows = json.load(f)
sps = None
ref_sps = None
par_sps = None
par_ref_sps = None
fleet_sps = None
fleet_ref_sps = None
hetero_sps = None
hetero_ref_sps = None
crash_sps = None
crash_ref_sps = None
for row in rows:
    if row.get("bench") == "sim_throughput/saturated_32rps":
        sps = float(row["steps_per_second"])
    elif row.get("bench") == "sim_throughput/saturated_32rps_no_leap":
        ref_sps = float(row.get("steps_per_second", 0.0))
    elif row.get("bench") == "sim_throughput/par_8dec_64rps":
        par_sps = float(row.get("steps_per_second", 0.0))
    elif row.get("bench") == "sim_throughput/par_8dec_64rps_no_par":
        par_ref_sps = float(row.get("steps_per_second", 0.0))
    elif row.get("bench") == "sim_throughput/fleet_4grp_diurnal":
        fleet_sps = float(row.get("steps_per_second", 0.0))
    elif row.get("bench") == "sim_throughput/fleet_4grp_diurnal_no_leap":
        fleet_ref_sps = float(row.get("steps_per_second", 0.0))
    elif row.get("bench") == "sim_throughput/hetero_offload_16rps":
        hetero_sps = float(row.get("steps_per_second", 0.0))
    elif row.get("bench") == "sim_throughput/hetero_offload_16rps_no_leap":
        hetero_ref_sps = float(row.get("steps_per_second", 0.0))
    elif row.get("bench") == "sim_throughput/fleet_4grp_crash":
        crash_sps = float(row.get("steps_per_second", 0.0))
    elif row.get("bench") == "sim_throughput/fleet_4grp_crash_no_leap":
        crash_ref_sps = float(row.get("steps_per_second", 0.0))
if sps is None:
    print(f"bench gate: saturated_32rps row missing from {path}", file=sys.stderr)
    sys.exit(1)
print(f"bench gate: saturated_32rps steps/s = {sps:.0f} (floor = {floor:.0f})")
if ref_sps:
    print(
        f"bench gate: leap speedup = {sps / ref_sps:.2f}x "
        f"(leap-off reference = {ref_sps:.0f} steps/s)"
    )
if par_sps and par_ref_sps:
    print(
        f"bench gate: par speedup (8 decode instances) = "
        f"{par_sps / par_ref_sps:.2f}x "
        f"(inline reference = {par_ref_sps:.0f} steps/s)"
    )
if fleet_sps and fleet_ref_sps:
    print(
        f"bench gate: fleet leap speedup (4-group diurnal) = "
        f"{fleet_sps / fleet_ref_sps:.2f}x "
        f"(leap-off reference = {fleet_ref_sps:.0f} steps/s)"
    )
if hetero_sps and hetero_ref_sps:
    print(
        f"bench gate: hetero leap speedup (standalone executor) = "
        f"{hetero_sps / hetero_ref_sps:.2f}x "
        f"(leap-off reference = {hetero_ref_sps:.0f} steps/s)"
    )
if crash_sps and crash_ref_sps:
    print(
        f"bench gate: fault-tolerant fleet leap speedup (4-group crash) = "
        f"{crash_sps / crash_ref_sps:.2f}x "
        f"(leap-off reference = {crash_ref_sps:.0f} steps/s)"
    )
print(f"bench gate: suggested ratchet floor = {0.2 * sps:.0f} (20% of measured)")
if sps >= floor:
    print("bench gate: PASS")
else:
    print(
        f"bench gate: FAIL — steps/s {sps:.0f} below floor {floor:.0f}. "
        "If this regression is intentional, recalibrate per the protocol "
        "in ci/check_bench_floor.sh.",
        file=sys.stderr,
    )
    sys.exit(1)
PY
