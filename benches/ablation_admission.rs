//! Ablation (DESIGN.md §6.3): Algorithm 1's load-aware admission vs the
//! naive fixed-ratio policy, and the printed-vs-strict C1 variant.

use adrenaline::config::{ModelSpec, OffloadPolicy};
use adrenaline::sim::{run_ratio_sweep_with, ClusterSim, ExecMode, SimConfig};
use adrenaline::util::bench::{figure_row, Bench};
use adrenaline::workload::WorkloadKind;

fn main() {
    let m = ModelSpec::llama2_7b();
    let rate = 24.0;

    // Load-aware (Algorithm 1 as printed) and the strict-C1 variant.
    let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
    cfg.duration_s = 120.0;
    let la = ClusterSim::new(cfg).run();
    figure_row("ablation_admission", "load_aware_tput", 0.0, la.throughput);
    figure_row("ablation_admission", "load_aware_tpot_s", 0.0, la.tpot.map(|s| s.mean).unwrap_or(f64::NAN));
    figure_row("ablation_admission", "load_aware_offl_frac", 0.0, la.offloaded_fraction);

    let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
    cfg.duration_s = 120.0;
    cfg.serving.offload = OffloadPolicy::LoadAwareStrict;
    let strict = ClusterSim::new(cfg).run();
    figure_row("ablation_admission", "strict_tput", 0.0, strict.throughput);
    figure_row("ablation_admission", "strict_offl_frac", 0.0, strict.offloaded_fraction);

    // Naive fixed ratios (what an operator would hand-tune offline).
    let pts = run_ratio_sweep_with(
        m,
        WorkloadKind::ShareGpt,
        rate,
        &[0.3, 0.5, 0.7, 0.9],
        120.0,
        ExecMode::Parallel,
    );
    let mut best = f64::MIN;
    for (ratio, r) in &pts {
        figure_row("ablation_admission", "fixed_tput", *ratio, r.throughput);
        best = best.max(r.throughput);
    }
    figure_row(
        "ablation_admission",
        "load_aware_vs_best_fixed",
        0.0,
        la.throughput / best,
    );

    // Over-offloading hurts: the 0.9 point should trail the best.
    let worst = pts.iter().find(|(r, _)| *r == 0.9).map(|(_, r)| r.throughput).unwrap();
    figure_row("ablation_admission", "overshoot_penalty_0.9", 0.9, worst / best);

    Bench::new(1, 3).run("ablation_admission/load_aware_run", || {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
        cfg.duration_s = 120.0;
        cfg.serving.offload = OffloadPolicy::LoadAware;
        let _ = ClusterSim::new(cfg).run();
    });
}
