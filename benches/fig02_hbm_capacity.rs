//! Bench + data for Figs 2/16: HBM capacity utilization of the prefill and
//! decode instances, baseline vs Adrenaline, from full simulation runs.

use adrenaline::config::ModelSpec;
use adrenaline::sim::{ClusterSim, SimConfig};
use adrenaline::util::bench::{figure_row, Bench};
use adrenaline::workload::WorkloadKind;

fn main() {
    let m = ModelSpec::llama2_7b();
    for (name, on) in [("vllm", false), ("adrenaline", true)] {
        let mut cfg = if on {
            SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0)
        } else {
            SimConfig::baseline(m, WorkloadKind::ShareGpt, 24.0)
        };
        cfg.duration_s = 120.0;
        let r = ClusterSim::new(cfg).run();
        figure_row("fig2", &format!("{name}_prefill_capacity_mean"), 0.0, r.prefill_hbm_capacity_util);
        figure_row(
            "fig2",
            &format!("{name}_prefill_capacity_peak"),
            0.0,
            r.prefill_occupancy.max_value().unwrap_or(0.0),
        );
        figure_row(
            "fig2",
            &format!("{name}_decode_occupancy_peak"),
            0.0,
            r.decode_occupancy.max_value().unwrap_or(0.0),
        );
    }

    // Bench the simulation run itself at this configuration.
    Bench::new(1, 5).run("fig02/sim_sharegpt_24rps_120s", || {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0);
        cfg.duration_s = 120.0;
        let _ = ClusterSim::new(cfg).run();
    });
}
