//! Within-run parallelism scaling (ISSUE 7): simulated decode steps per
//! wall second as a function of the pricing worker count, on a fixed
//! saturated 8-decode-instance scenario.
//!
//! One row per `ServingConfig::par_workers` setting in 1, 2, 4, 8
//! (total pricing concurrency including the sim thread; 1 ≡ the inline
//! `no_par` path). Every setting must simulate the identical step count
//! — worker count picks concurrency, never results (the bit-identity
//! contract is pinned by `rust/tests/par_run.rs`; this bench asserts the
//! cheap scalar as a smoke check) — so steps/s compares cleanly across
//! rows. Written to `BENCH_par.json` (override: env `BENCH_PAR_JSON`)
//! and uploaded as a CI artifact so the scaling curve is tracked across
//! PRs. Absolute speedups depend on the runner's core count (CI runners
//! may cap the thread budget well below 8): the rows carry the measured
//! budget context (`available_parallelism`) so curves from different
//! machines are comparable.
//!
//! CI smoke knobs shared with `sim_throughput`: `SIM_BENCH_ITERS` and
//! `SIM_BENCH_DURATION_S`.

use std::collections::BTreeMap;

use adrenaline::config::ModelSpec;
use adrenaline::sim::{par_config, ClusterSim, SimConfig, SimReport};
use adrenaline::util::bench::{figure_row, Bench, BenchStats};
use adrenaline::util::json::Json;
use adrenaline::workload::WorkloadKind;

const N_DECODE: u32 = 8;
const RATE_RPS: f64 = 64.0;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn run_workers(
    m: ModelSpec,
    par_workers: usize,
    duration: f64,
    iters: usize,
) -> (BenchStats, SimReport) {
    let label = format!("par_scaling/workers_{par_workers}");
    let mut last: Option<SimReport> = None;
    let stats = Bench::new(1, iters).run(&label, || {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, RATE_RPS);
        cfg.duration_s = duration;
        cfg.cluster.n_decode = N_DECODE;
        cfg.serving.par_workers = par_workers;
        last = Some(ClusterSim::new(cfg).run());
    });
    (stats, last.expect("bench ran at least once"))
}

fn main() {
    let m = ModelSpec::llama2_7b();
    let iters = env_usize("SIM_BENCH_ITERS", 5);
    let duration = env_f64("SIM_BENCH_DURATION_S", 60.0);
    let hw = par_config().hw_threads;
    let mut rows: Vec<Json> = Vec::new();
    let mut baseline_sps: Option<f64> = None;
    let mut baseline_steps: Option<u64> = None;

    for par_workers in [1usize, 2, 4, 8] {
        let (stats, report) = run_workers(m, par_workers, duration, iters);
        if let Some(steps) = baseline_steps {
            assert_eq!(
                report.steps_simulated, steps,
                "worker count must never change simulated results"
            );
        } else {
            baseline_steps = Some(report.steps_simulated);
        }
        let sps = report.steps_simulated as f64 / stats.p50_s;
        let base = *baseline_sps.get_or_insert(sps);
        let speedup = if base > 0.0 { sps / base } else { 1.0 };
        figure_row("par_scaling", "steps_per_second", par_workers as f64, sps);
        figure_row("par_scaling", "speedup_vs_1_worker", par_workers as f64, speedup);
        let mut o = BTreeMap::new();
        o.insert("bench".into(), Json::Str(format!("par_scaling/workers_{par_workers}")));
        o.insert("par_workers".into(), Json::Num(par_workers as f64));
        o.insert("n_decode".into(), Json::Num(N_DECODE as f64));
        o.insert("rate_rps".into(), Json::Num(RATE_RPS));
        o.insert("duration_s".into(), Json::Num(duration));
        o.insert("hw_threads".into(), Json::Num(hw as f64));
        o.insert("iters".into(), Json::Num(stats.iters as f64));
        o.insert("p50_wall_s".into(), Json::Num(stats.p50_s));
        o.insert("mean_wall_s".into(), Json::Num(stats.mean_s));
        o.insert("steps_simulated".into(), Json::Num(report.steps_simulated as f64));
        o.insert("steps_per_second".into(), Json::Num(sps));
        o.insert("speedup_vs_1_worker".into(), Json::Num(speedup));
        o.insert("finished".into(), Json::Num(report.finished as f64));
        rows.push(Json::Obj(o));
    }

    let path = std::env::var("BENCH_PAR_JSON").unwrap_or_else(|_| "BENCH_par.json".into());
    let payload = format!("{}\n", Json::Arr(rows));
    match std::fs::write(&path, payload) {
        Ok(()) => println!("bench rows written to {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
