//! Simulator performance: steps/s, events/s and simulated-vs-wall time
//! ratio — the L3 substrate must stay fast enough that figure sweeps are
//! interactive.
//!
//! Besides the human-readable `bench ...` / `figure=sim_perf ...` lines,
//! this bench writes a machine-readable `BENCH_sim.json` (path override:
//! env `BENCH_SIM_JSON`) so the hot-path numbers are tracked across PRs.
//!
//! Since the steady-state decode-leap engine (EXPERIMENTS.md §Perf
//! "Decode leaping"), every scenario runs **paired**: once with leaping
//! (the default) and once with `ServingConfig::no_leap` (the per-step
//! reference). Leaping collapses `events_processed` by design, so
//! events/s is no longer a stable perf metric — the leap-robust metric
//! is `steps_per_second` (`SimReport::steps_simulated`, identical in
//! both modes, divided by p50 wall time), which is what the CI floor
//! gate (`ci/check_bench_floor.sh`) tracks. The leap-on row also carries
//! `leap_speedup_steps_per_s` (leap-on steps/s over its paired leap-off
//! row) — the acceptance metric for the leap engine.
//!
//! CI smoke knobs: `SIM_BENCH_ITERS` (sample iterations, default 5) and
//! `SIM_BENCH_DURATION_S` (simulated trace seconds, default 120).

use std::collections::BTreeMap;

use adrenaline::config::{
    AutoscaleConfig, DeviceProfile, DeviceProfiles, DeviceRole, FaultConfig, FaultKind,
    FleetConfig, GpuSpec, ModelSpec, OverloadConfig, RouterPolicy, ScriptedFault,
};
use adrenaline::sim::{ClusterSim, FleetReport, FleetSim, SimConfig, SimReport};
use adrenaline::util::bench::{figure_row, Bench, BenchStats};
use adrenaline::util::json::Json;
use adrenaline::workload::{ArrivalPattern, WorkloadKind};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[allow(clippy::too_many_arguments)]
fn row(
    name: &str,
    rate: f64,
    duration_s: f64,
    leap: bool,
    stats: &BenchStats,
    report: &SimReport,
    leap_speedup: Option<f64>,
) -> Json {
    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str(format!("sim_throughput/{name}")));
    o.insert("rate_rps".into(), Json::Num(rate));
    o.insert("duration_s".into(), Json::Num(duration_s));
    o.insert("leap".into(), Json::Bool(leap));
    o.insert("iters".into(), Json::Num(stats.iters as f64));
    o.insert("p50_wall_s".into(), Json::Num(stats.p50_s));
    o.insert("mean_wall_s".into(), Json::Num(stats.mean_s));
    // Numerator is the configured trace duration (the seed metric's
    // definition), NOT sim_end_s (which includes the post-trace drain and
    // would inflate the ratio against pre-overhaul baselines).
    o.insert(
        "sim_seconds_per_wall_second".into(),
        Json::Num(duration_s / stats.p50_s),
    );
    o.insert("sim_end_s".into(), Json::Num(report.sim_end_s));
    // The leap-robust hot-path metric (the CI floor gate's target):
    // simulated decode steps per wall second. `steps_simulated` is
    // bit-identical across leap modes, so this compares cleanly.
    o.insert(
        "steps_per_second".into(),
        Json::Num(report.steps_simulated as f64 / stats.p50_s),
    );
    o.insert("steps_simulated".into(), Json::Num(report.steps_simulated as f64));
    if let Some(s) = leap_speedup {
        o.insert("leap_speedup_steps_per_s".into(), Json::Num(s));
    }
    // events/s collapses under leaping by design; kept for continuity.
    o.insert(
        "events_per_second".into(),
        Json::Num(report.events_processed as f64 / stats.p50_s),
    );
    o.insert("events".into(), Json::Num(report.events_processed as f64));
    o.insert("finished".into(), Json::Num(report.finished as f64));
    // Executable-grid padding efficiency (bucketed cost plane): requested
    // vs padding-wasted batch slots and their ratio. All zero under
    // ADRENALINE_EXACT_COSTS=1.
    o.insert("graph_selections".into(), Json::Num(report.graph_selections as f64));
    o.insert("graph_used_slots".into(), Json::Num(report.graph_used_slots as f64));
    o.insert("graph_padded_slots".into(), Json::Num(report.graph_padded_slots as f64));
    o.insert(
        "graph_padding_overhead".into(),
        Json::Num(report.graph_padding_overhead),
    );
    Json::Obj(o)
}

/// Insert an extra key into a row object (the par rows carry fields the
/// shared `row` builder does not know about).
fn patch(mut j: Json, key: &str, val: Json) -> Json {
    if let Json::Obj(ref mut o) = j {
        o.insert(key.into(), val);
    }
    j
}

/// Run one scenario in one leap mode; returns (stats, last report).
/// `customize` is the scenario's config hook (topology, fault plane, …).
#[allow(clippy::too_many_arguments)]
fn run_mode(
    m: ModelSpec,
    workload: WorkloadKind,
    name: &str,
    rate: f64,
    duration: f64,
    iters: usize,
    no_leap: bool,
    customize: fn(&mut SimConfig),
) -> (BenchStats, SimReport) {
    let label = if no_leap {
        format!("sim_throughput/{name}_no_leap")
    } else {
        format!("sim_throughput/{name}")
    };
    let mut last: Option<SimReport> = None;
    let stats = Bench::new(1, iters).run(&label, || {
        let mut cfg = SimConfig::paper_default(m, workload, rate);
        cfg.duration_s = duration;
        cfg.serving.no_leap = no_leap;
        customize(&mut cfg);
        last = Some(ClusterSim::new(cfg).run());
    });
    (stats, last.expect("bench ran at least once"))
}

/// Run one within-run-parallelism scenario in one par mode (leaping on
/// in both — epochs only exist on the leap path); returns (stats, last
/// report).
fn run_par_mode(
    m: ModelSpec,
    name: &str,
    n_decode: u32,
    rate: f64,
    duration: f64,
    iters: usize,
    no_par: bool,
) -> (BenchStats, SimReport) {
    let label = if no_par {
        format!("sim_throughput/{name}_no_par")
    } else {
        format!("sim_throughput/{name}")
    };
    let mut last: Option<SimReport> = None;
    let stats = Bench::new(1, iters).run(&label, || {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
        cfg.duration_s = duration;
        cfg.cluster.n_decode = n_decode;
        cfg.serving.no_par = no_par;
        last = Some(ClusterSim::new(cfg).run());
    });
    (stats, last.expect("bench ran at least once"))
}

/// Run a fleet scenario (4 routed groups, diurnal trace, autoscaled
/// prefill pools) in one leap mode; returns (stats, last report).
/// `customize` is the scenario's config hook, applied on top of the
/// shared fleet base (fault plane, overload knobs, …).
fn run_fleet_mode(
    m: ModelSpec,
    name: &str,
    rate: f64,
    duration: f64,
    iters: usize,
    no_leap: bool,
    customize: fn(&mut SimConfig),
) -> (BenchStats, FleetReport) {
    let label = if no_leap {
        format!("sim_throughput/{name}_no_leap")
    } else {
        format!("sim_throughput/{name}")
    };
    let mut last: Option<FleetReport> = None;
    let stats = Bench::new(1, iters).run(&label, || {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
        cfg.duration_s = duration;
        cfg.serving.no_leap = no_leap;
        cfg.arrivals = ArrivalPattern::Diurnal { period_s: 40.0, depth: 0.8 };
        cfg.cluster.n_prefill = 3;
        cfg.serving.fleet = Some(FleetConfig {
            groups: 4,
            router: RouterPolicy::RoundRobin,
            autoscale: Some(AutoscaleConfig {
                min_prefill: 1,
                max_prefill: 3,
                ..AutoscaleConfig::default()
            }),
            ..FleetConfig::default()
        });
        customize(&mut cfg);
        last = Some(FleetSim::new(cfg).run());
    });
    (stats, last.expect("bench ran at least once"))
}

/// Fleet analogue of `row`: the leap-robust metrics the fleet report
/// aggregates, plus the fleet-only counters.
fn fleet_row(
    name: &str,
    rate: f64,
    duration_s: f64,
    leap: bool,
    stats: &BenchStats,
    report: &FleetReport,
    leap_speedup: Option<f64>,
) -> Json {
    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str(format!("sim_throughput/{name}")));
    o.insert("rate_rps".into(), Json::Num(rate));
    o.insert("duration_s".into(), Json::Num(duration_s));
    o.insert("leap".into(), Json::Bool(leap));
    o.insert("iters".into(), Json::Num(stats.iters as f64));
    o.insert("p50_wall_s".into(), Json::Num(stats.p50_s));
    o.insert("mean_wall_s".into(), Json::Num(stats.mean_s));
    o.insert(
        "sim_seconds_per_wall_second".into(),
        Json::Num(duration_s / stats.p50_s),
    );
    o.insert(
        "steps_per_second".into(),
        Json::Num(report.steps_simulated as f64 / stats.p50_s),
    );
    o.insert("steps_simulated".into(), Json::Num(report.steps_simulated as f64));
    if let Some(s) = leap_speedup {
        o.insert("leap_speedup_steps_per_s".into(), Json::Num(s));
    }
    o.insert(
        "events_per_second".into(),
        Json::Num(report.events_processed as f64 / stats.p50_s),
    );
    o.insert("events".into(), Json::Num(report.events_processed as f64));
    o.insert("finished".into(), Json::Num(report.finished as f64));
    o.insert("groups".into(), Json::Num(report.groups.len() as f64));
    o.insert("scale_events".into(), Json::Num(report.scale_events as f64));
    o.insert("fleet_goodput_tok_s".into(), Json::Num(report.fleet_goodput));
    // Fault-tolerance counters (ISSUE 10); all zero on the plain fleet
    // scenario, kept in every row so the schema stays uniform.
    o.insert("requests_shed".into(), Json::Num(report.requests_shed as f64));
    o.insert(
        "requests_failed_over".into(),
        Json::Num(report.requests_failed_over as f64),
    );
    o.insert("retries".into(), Json::Num(report.retries as f64));
    o.insert("router_reroutes".into(), Json::Num(report.router_reroutes as f64));
    Json::Obj(o)
}

fn main() {
    let m = ModelSpec::llama2_7b();
    let iters = env_usize("SIM_BENCH_ITERS", 5);
    let duration = env_f64("SIM_BENCH_DURATION_S", 120.0);
    let mut rows: Vec<Json> = Vec::new();

    let noop: fn(&mut SimConfig) = |_| {};
    // Fault-plane row (ISSUE 6): the saturated trace with a scripted
    // mid-run prefill crash on a two-prefill cluster. Informational —
    // the CI floor gate (`ci/check_bench_floor.sh`) reads only
    // `saturated_32rps` — but it tracks the fault plane's hot-path cost
    // across PRs, and the paired-mode `steps_simulated` assert below
    // doubles as the leap/fault composition check in the bench.
    let fault_crash: fn(&mut SimConfig) = |cfg| {
        cfg.cluster.n_prefill = 2;
        cfg.serving.fault = Some(FaultConfig {
            script: vec![ScriptedFault {
                kind: FaultKind::PrefillCrash,
                instance: 0,
                at_s: 40.0,
                down_s: 10.0,
                group: None,
            }],
            ..FaultConfig::default()
        });
    };

    // Heterogeneous-offload row (ISSUE 9): offloaded KV on a standalone
    // memory-rich H20-class executor instead of the colocated SM share.
    // Informational like the fault row — the CI floor gate still reads
    // only `saturated_32rps` — but it tracks the per-device cost plane's
    // hot-path cost across PRs.
    let hetero_offload: fn(&mut SimConfig) = |cfg| {
        cfg.cluster.profiles = Some(DeviceProfiles {
            executor: Some(DeviceProfile::whole(GpuSpec::h20_96g(), DeviceRole::Executor)),
            ..DeviceProfiles::default()
        });
    };

    let scenarios = [
        ("light_4rps", WorkloadKind::ShareGpt, 4.0, iters, noop),
        ("saturated_32rps", WorkloadKind::ShareGpt, 32.0, iters, noop),
        // OpenThoughts generates ~10x the decode steps per request.
        ("openthoughts_2rps", WorkloadKind::OpenThoughts, 2.0, iters.min(3), noop),
        ("saturated_32rps_fault_crash", WorkloadKind::ShareGpt, 32.0, iters, fault_crash),
        ("hetero_offload_16rps", WorkloadKind::ShareGpt, 16.0, iters, hetero_offload),
    ];
    for (name, workload, rate, iters, customize) in scenarios {
        // Reference first so the paired leap-on row can carry the ratio.
        // The per-step reference only feeds the informational speedup
        // ratio (the gate reads the leap row), so it gets a capped
        // iteration count — it is the slow side of the pair by design.
        let ref_iters = iters.clamp(1, 2);
        let (ref_stats, ref_report) =
            run_mode(m, workload, name, rate, duration, ref_iters, true, customize);
        let (leap_stats, leap_report) =
            run_mode(m, workload, name, rate, duration, iters, false, customize);
        assert_eq!(
            leap_report.steps_simulated,
            ref_report.steps_simulated,
            "leap and reference must simulate identical step counts"
        );
        let ref_sps = ref_report.steps_simulated as f64 / ref_stats.p50_s;
        let leap_sps = leap_report.steps_simulated as f64 / leap_stats.p50_s;
        let speedup = if ref_sps > 0.0 { leap_sps / ref_sps } else { 1.0 };
        figure_row(
            "sim_perf",
            &format!("{name}_sim_seconds_per_wall_second"),
            rate,
            duration / leap_stats.p50_s,
        );
        figure_row("sim_perf", &format!("{name}_steps_per_second"), rate, leap_sps);
        figure_row("sim_perf", &format!("{name}_steps_per_second_no_leap"), rate, ref_sps);
        figure_row("sim_perf", &format!("{name}_leap_speedup"), rate, speedup);
        rows.push(row(name, rate, duration, true, &leap_stats, &leap_report, Some(speedup)));
        rows.push(row(
            &format!("{name}_no_leap"),
            rate,
            duration,
            false,
            &ref_stats,
            &ref_report,
            None,
        ));
    }

    // Within-run parallelism rows (ISSUE 7): paired par-on/par-off runs
    // at 1, 2 and 8 decode instances, load scaled with the topology so
    // every instance stays saturated. Both sides leap (epochs only exist
    // on the leap path) and are bit-identical by rust/tests/par_run.rs,
    // so `steps_simulated` compares cleanly; the par-on row carries
    // `par_speedup_steps_per_s` — the acceptance metric for the epoch
    // engine. The 1-instance row pins the no-regression side: epochs
    // never fire there, so its speedup should sit at ~1.0. Speedups are
    // informational (they depend on the runner's core count); the CI
    // floor gate still reads only `saturated_32rps`.
    let par_scenarios: [(&str, u32, f64); 3] = [
        ("par_1dec_8rps", 1, 8.0),
        ("par_2dec_16rps", 2, 16.0),
        ("par_8dec_64rps", 8, 64.0),
    ];
    for (name, n_decode, rate) in par_scenarios {
        // Inline reference first so the paired par-on row carries the
        // ratio; it is the slow side, so its iterations are capped.
        let ref_iters = iters.clamp(1, 2);
        let (ref_stats, ref_report) =
            run_par_mode(m, name, n_decode, rate, duration, ref_iters, true);
        let (par_stats, par_report) =
            run_par_mode(m, name, n_decode, rate, duration, iters, false);
        assert_eq!(
            par_report.steps_simulated,
            ref_report.steps_simulated,
            "par and no_par must simulate identical step counts"
        );
        let ref_sps = ref_report.steps_simulated as f64 / ref_stats.p50_s;
        let par_sps = par_report.steps_simulated as f64 / par_stats.p50_s;
        let speedup = if ref_sps > 0.0 { par_sps / ref_sps } else { 1.0 };
        figure_row("sim_perf", &format!("{name}_steps_per_second"), rate, par_sps);
        figure_row("sim_perf", &format!("{name}_steps_per_second_no_par"), rate, ref_sps);
        figure_row("sim_perf", &format!("{name}_par_speedup"), rate, speedup);
        let on = row(name, rate, duration, true, &par_stats, &par_report, None);
        let on = patch(on, "n_decode", Json::Num(n_decode as f64));
        let on = patch(on, "par", Json::Bool(true));
        rows.push(patch(on, "par_speedup_steps_per_s", Json::Num(speedup)));
        let off = row(
            &format!("{name}_no_par"),
            rate,
            duration,
            true,
            &ref_stats,
            &ref_report,
            None,
        );
        let off = patch(off, "n_decode", Json::Num(n_decode as f64));
        rows.push(patch(off, "par", Json::Bool(false)));
    }

    // Fleet rows (ISSUE 8 + ISSUE 10): a 4-group diurnal fleet with
    // per-group prefill-pool autoscaling, paired leap-on/off like every
    // scenario — once plain, once with the fault-tolerance plane armed
    // (`fleet_4grp_crash`: scripted group-0 prefill crash, health-aware
    // routing, cross-group failover, overload admission control).
    // Informational — the CI floor gate still reads only
    // `saturated_32rps` — but the `steps_simulated` asserts double as
    // the leap/fleet/autoscale and leap/failover/overload composition
    // checks in the bench.
    let fleet_noop: fn(&mut SimConfig) = |_| {};
    let fleet_crash: fn(&mut SimConfig) = |cfg| {
        cfg.serving.fault = Some(FaultConfig {
            script: vec![ScriptedFault {
                kind: FaultKind::PrefillCrash,
                instance: 0,
                at_s: 40.0,
                down_s: 20.0,
                group: Some(0),
            }],
            ..FaultConfig::default()
        });
        if let Some(fleet) = cfg.serving.fleet.as_mut() {
            fleet.overload = Some(OverloadConfig::default());
        }
    };
    let fleet_scenarios: [(&str, fn(&mut SimConfig)); 2] =
        [("fleet_4grp_diurnal", fleet_noop), ("fleet_4grp_crash", fleet_crash)];
    for (name, customize) in fleet_scenarios {
        let rate = 64.0;
        let ref_iters = iters.clamp(1, 2);
        let (ref_stats, ref_report) =
            run_fleet_mode(m, name, rate, duration, ref_iters, true, customize);
        let (leap_stats, leap_report) =
            run_fleet_mode(m, name, rate, duration, iters, false, customize);
        assert_eq!(
            leap_report.steps_simulated,
            ref_report.steps_simulated,
            "fleet leap and reference must simulate identical step counts"
        );
        let ref_sps = ref_report.steps_simulated as f64 / ref_stats.p50_s;
        let leap_sps = leap_report.steps_simulated as f64 / leap_stats.p50_s;
        let speedup = if ref_sps > 0.0 { leap_sps / ref_sps } else { 1.0 };
        figure_row(
            "sim_perf",
            &format!("{name}_sim_seconds_per_wall_second"),
            rate,
            duration / leap_stats.p50_s,
        );
        figure_row("sim_perf", &format!("{name}_steps_per_second"), rate, leap_sps);
        figure_row("sim_perf", &format!("{name}_steps_per_second_no_leap"), rate, ref_sps);
        figure_row("sim_perf", &format!("{name}_leap_speedup"), rate, speedup);
        rows.push(fleet_row(name, rate, duration, true, &leap_stats, &leap_report, Some(speedup)));
        rows.push(fleet_row(
            &format!("{name}_no_leap"),
            rate,
            duration,
            false,
            &ref_stats,
            &ref_report,
            None,
        ));
    }

    let path = std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_sim.json".into());
    let payload = format!("{}\n", Json::Arr(rows));
    match std::fs::write(&path, payload) {
        Ok(()) => println!("bench rows written to {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
