//! Simulator performance: events/s and simulated-vs-wall time ratio — the
//! L3 substrate must stay fast enough that figure sweeps are interactive.

use adrenaline::config::ModelSpec;
use adrenaline::sim::{ClusterSim, SimConfig};
use adrenaline::util::bench::{figure_row, Bench};
use adrenaline::workload::WorkloadKind;

fn main() {
    let m = ModelSpec::llama2_7b();

    for (name, rate, dur) in [("light_4rps", 4.0, 120.0), ("saturated_32rps", 32.0, 120.0)] {
        let mut tokens = 0usize;
        let stats = Bench::new(1, 5).run(&format!("sim_throughput/{name}"), || {
            let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
            cfg.duration_s = dur;
            let r = ClusterSim::new(cfg).run();
            tokens = r.finished;
        });
        figure_row(
            "sim_perf",
            &format!("{name}_sim_seconds_per_wall_second"),
            rate,
            dur / stats.p50_s,
        );
    }

    // OpenThoughts generates ~10x the decode steps per request.
    Bench::new(1, 3).run("sim_throughput/openthoughts_2rps_120s", || {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::OpenThoughts, 2.0);
        cfg.duration_s = 120.0;
        let _ = ClusterSim::new(cfg).run();
    });
}
