//! Simulator performance: events/s and simulated-vs-wall time ratio — the
//! L3 substrate must stay fast enough that figure sweeps are interactive.
//!
//! Besides the human-readable `bench ...` / `figure=sim_perf ...` lines,
//! this bench writes a machine-readable `BENCH_sim.json` (path override:
//! env `BENCH_SIM_JSON`) so the hot-path numbers are tracked across PRs —
//! the acceptance bar for the §Perf overhaul is
//! `saturated_32rps.sim_seconds_per_wall_second` improving ≥ 5× over the
//! pre-overhaul baseline (see EXPERIMENTS.md §Perf).
//!
//! CI smoke knobs: `SIM_BENCH_ITERS` (sample iterations, default 5) and
//! `SIM_BENCH_DURATION_S` (simulated trace seconds, default 120).

use std::collections::BTreeMap;

use adrenaline::config::ModelSpec;
use adrenaline::sim::{ClusterSim, SimConfig, SimReport};
use adrenaline::util::bench::{figure_row, Bench, BenchStats};
use adrenaline::util::json::Json;
use adrenaline::workload::WorkloadKind;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn row(
    name: &str,
    rate: f64,
    duration_s: f64,
    stats: &BenchStats,
    report: &SimReport,
) -> Json {
    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str(format!("sim_throughput/{name}")));
    o.insert("rate_rps".into(), Json::Num(rate));
    o.insert("duration_s".into(), Json::Num(duration_s));
    o.insert("iters".into(), Json::Num(stats.iters as f64));
    o.insert("p50_wall_s".into(), Json::Num(stats.p50_s));
    o.insert("mean_wall_s".into(), Json::Num(stats.mean_s));
    // Numerator is the configured trace duration (the seed metric's
    // definition), NOT sim_end_s (which includes the post-trace drain and
    // would inflate the ratio against pre-overhaul baselines).
    o.insert(
        "sim_seconds_per_wall_second".into(),
        Json::Num(duration_s / stats.p50_s),
    );
    o.insert("sim_end_s".into(), Json::Num(report.sim_end_s));
    o.insert(
        "events_per_second".into(),
        Json::Num(report.events_processed as f64 / stats.p50_s),
    );
    o.insert("events".into(), Json::Num(report.events_processed as f64));
    o.insert("finished".into(), Json::Num(report.finished as f64));
    // Executable-grid padding efficiency (bucketed cost plane): requested
    // vs padding-wasted batch slots and their ratio. All zero under
    // ADRENALINE_EXACT_COSTS=1.
    o.insert("graph_selections".into(), Json::Num(report.graph_selections as f64));
    o.insert("graph_used_slots".into(), Json::Num(report.graph_used_slots as f64));
    o.insert("graph_padded_slots".into(), Json::Num(report.graph_padded_slots as f64));
    o.insert(
        "graph_padding_overhead".into(),
        Json::Num(report.graph_padding_overhead),
    );
    Json::Obj(o)
}

fn main() {
    let m = ModelSpec::llama2_7b();
    let iters = env_usize("SIM_BENCH_ITERS", 5);
    let duration = env_f64("SIM_BENCH_DURATION_S", 120.0);
    let mut rows: Vec<Json> = Vec::new();

    for (name, rate) in [("light_4rps", 4.0), ("saturated_32rps", 32.0)] {
        let mut last: Option<SimReport> = None;
        let stats = Bench::new(1, iters).run(&format!("sim_throughput/{name}"), || {
            let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
            cfg.duration_s = duration;
            last = Some(ClusterSim::new(cfg).run());
        });
        let report = last.expect("bench ran at least once");
        figure_row(
            "sim_perf",
            &format!("{name}_sim_seconds_per_wall_second"),
            rate,
            duration / stats.p50_s,
        );
        figure_row(
            "sim_perf",
            &format!("{name}_events_per_second"),
            rate,
            report.events_processed as f64 / stats.p50_s,
        );
        rows.push(row(name, rate, duration, &stats, &report));
    }

    // OpenThoughts generates ~10x the decode steps per request.
    {
        let rate = 2.0;
        let mut last: Option<SimReport> = None;
        let stats =
            Bench::new(1, iters.min(3)).run("sim_throughput/openthoughts_2rps", || {
                let mut cfg = SimConfig::paper_default(m, WorkloadKind::OpenThoughts, rate);
                cfg.duration_s = duration;
                last = Some(ClusterSim::new(cfg).run());
            });
        let report = last.expect("bench ran at least once");
        rows.push(row("openthoughts_2rps", rate, duration, &stats, &report));
    }

    let path = std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_sim.json".into());
    let payload = format!("{}\n", Json::Arr(rows));
    match std::fs::write(&path, payload) {
        Ok(()) => println!("bench rows written to {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
