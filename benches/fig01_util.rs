//! Bench + data for Fig 1: resource utilization of the disaggregated
//! prefill (HBM bandwidth) and decode (compute) phases.

use adrenaline::config::{GpuSpec, ModelSpec};
use adrenaline::gpu_model::{KernelKind, PhaseKernels, Roofline};
use adrenaline::util::bench::{black_box, figure_row, Bench};

fn main() {
    let rl = Roofline::whole(GpuSpec::a100_80g());
    let pk = PhaseKernels::new(ModelSpec::llama2_7b());

    // Data series.
    for p in [256u64, 512, 1024, 2048, 4096] {
        let mut cost = pk.prefill_cost(KernelKind::QkvProj, p);
        for k in [KernelKind::Attention, KernelKind::OutProj, KernelKind::Ffn] {
            cost = cost.add(&pk.prefill_cost(k, p));
        }
        figure_row("fig1a", "prefill_hbm_bw_util", p as f64, rl.bw_utilization(cost));
    }
    for b in [1u64, 8, 16, 32, 64, 80, 128] {
        let mut cost = pk.decode_cost(KernelKind::QkvProj, b, b * 1024);
        for k in [KernelKind::Attention, KernelKind::OutProj, KernelKind::Ffn] {
            cost = cost.add(&pk.decode_cost(k, b, b * 1024));
        }
        figure_row("fig1b", "decode_compute_util", b as f64, rl.compute_utilization(cost));
    }

    // Microbench of the cost-model evaluation itself (it sits inside the
    // simulator's per-step hot loop).
    Bench::new(10, 100).run("fig01/cost_model_full_step_eval", || {
        for b in 1..=64u64 {
            let mut cost = pk.decode_cost(KernelKind::QkvProj, b, b * 1024);
            for k in [KernelKind::Attention, KernelKind::OutProj, KernelKind::Ffn] {
                cost = cost.add(&pk.decode_cost(k, b, b * 1024));
            }
            black_box(rl.compute_utilization(cost));
        }
    });
}
