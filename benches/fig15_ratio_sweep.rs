//! Bench + data for Figs 15/17: fixed offload-ratio sweep — throughput
//! inflection and the resource-utilization panels.

use adrenaline::config::ModelSpec;
use adrenaline::sim::{run_ratio_sweep_with, ExecMode};
use adrenaline::util::bench::{figure_row, Bench};
use adrenaline::workload::WorkloadKind;

fn main() {
    let ratios = [0.0, 0.2, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];
    for m in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b()] {
        let rate = if m.name == "llama2-7b" { 24.0 } else { 16.0 };
        let pts = run_ratio_sweep_with(
            m,
            WorkloadKind::ShareGpt,
            rate,
            &ratios,
            120.0,
            ExecMode::Parallel,
        );
        for (ratio, r) in &pts {
            figure_row("fig15", &format!("{}_tput", m.name), *ratio, r.throughput);
            figure_row(
                "fig15",
                &format!("{}_tpot_s", m.name),
                *ratio,
                r.tpot.map(|s| s.mean).unwrap_or(f64::NAN),
            );
            figure_row("fig17a", &format!("{}_prefill_bw", m.name), *ratio, r.prefill_hbm_bw_util);
            figure_row(
                "fig17b",
                &format!("{}_decode_compute", m.name),
                *ratio,
                r.decode_compute_util,
            );
        }
    }

    Bench::new(1, 3).run("fig15/ratio_point_sharegpt_7b", || {
        let _ = run_ratio_sweep_with(
            ModelSpec::llama2_7b(),
            WorkloadKind::ShareGpt,
            24.0,
            &[0.7],
            120.0,
            ExecMode::Parallel,
        );
    });
}
