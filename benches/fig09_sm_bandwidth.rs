//! Bench + data for Figs 9/10: the MPS SM-partition curves — superlinear
//! attention bandwidth and sublinear prefill slowdown.

use adrenaline::config::{GpuSpec, ModelSpec};
use adrenaline::gpu_model::{bw_frac_of_sm_frac, prefill_slowdown, PrefillKernelTimes, Roofline};
use adrenaline::util::bench::{black_box, figure_row, Bench};

fn main() {
    for i in 1..=10 {
        let s = i as f64 / 10.0;
        figure_row("fig9", "bw_frac", s, bw_frac_of_sm_frac(s));
        if i >= 2 {
            figure_row("fig10", "norm_prefill_tput", s, 1.0 / prefill_slowdown(s));
        }
    }
    figure_row("fig9", "anchor_20pct_sms (paper: 0.60)", 0.2, bw_frac_of_sm_frac(0.2));

    let rl = Roofline::whole(GpuSpec::a100_80g());
    let m = ModelSpec::llama2_7b();
    Bench::new(10, 200).run("fig09/partitioned_prefill_time_eval", || {
        for i in 1..=10 {
            let s = i as f64 / 10.0;
            let base = PrefillKernelTimes::compute(&rl, &m, 2048).total();
            black_box(base * prefill_slowdown(s));
        }
    });
}
