//! L3 hot-path bench on the REAL serving stack (needs `make artifacts`):
//! decode-step latency for the fused fast path, the split layer-loop path,
//! and the path with attention offloaded to the executor thread — the
//! numbers behind EXPERIMENTS.md §Perf.
//!
//! The *simulator* hot path has its own bench (`sim_throughput`, tracked
//! in BENCH_sim.json); EXPERIMENTS.md §Perf records both baselines and
//! the memoization/bucketing scheme the simulator path relies on.

use adrenaline::config::ServingConfig;
use adrenaline::engine::Server;
use adrenaline::runtime::Manifest;
use adrenaline::util::bench::{figure_row, Bench};
use adrenaline::workload::{TraceGenerator, WorkloadKind};

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("decode_hot_path: skipping (run `make artifacts`)");
        return;
    }

    for (name, force_offload, fused) in [
        ("fused_local", Some(false), true),
        ("split_local", Some(false), false),
        ("offloaded", Some(true), true),
    ] {
        let mut server = Server::start(&dir, ServingConfig::default()).expect("server");
        server.set_fused_fast_path(fused);
        let mut gen = TraceGenerator::new(WorkloadKind::Fixed { prompt: 16, output: 24 }, 100.0, 5);
        let reqs = gen.take(4);
        let reqs = gen.with_tokens(reqs, 256);

        let stats = Bench::new(1, 8).run(&format!("decode_hot_path/{name}_b4_24steps"), || {
            let report = server.run_requests(&reqs, force_offload).expect("serve");
            assert_eq!(report.completions.len(), 4);
        });
        // Per-decode-step time: 24 steps of batch 4 per run (first token
        // comes from prefill).
        figure_row("perf_l3", &format!("{name}_step_ms"), 4.0, stats.p50_s / 23.0 * 1e3);
    }
}
