//! Ablation bench (DESIGN.md §6.2): the 2-D executable-bucket cache —
//! selection cost and padding overhead vs bucket-interval configuration,
//! the AOT analogue of the paper's 2-D CUDA-graph storage/overhead
//! trade-off (§3.2.2).
//!
//! Besides the human-readable `figure=graph_bucket` rows, the bench
//! writes machine-readable padding-efficiency rows (used vs padded slots
//! per grid configuration) to `BENCH_graph_bucket.json` (path override:
//! env `BENCH_GRAPH_BUCKET_JSON`) so bucket-interval choices are tracked
//! across PRs alongside `BENCH_sim.json`.

use std::collections::BTreeMap;

use adrenaline::coordinator::GraphCache;
use adrenaline::util::bench::{black_box, figure_row, Bench};
use adrenaline::util::json::Json;
use adrenaline::util::rng::Rng;

/// One grid configuration's padding-efficiency row.
fn efficiency_row(name: &str, g: &GraphCache) -> Json {
    let s = g.stats();
    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str(format!("graph_bucket/{name}")));
    o.insert("grid_size".into(), Json::Num(g.grid_size() as f64));
    o.insert("selections".into(), Json::Num(s.selections as f64));
    o.insert("used_slots".into(), Json::Num(s.used_slots as f64));
    o.insert("padded_slots".into(), Json::Num(s.padded_slots as f64));
    o.insert("padding_overhead".into(), Json::Num(g.padding_overhead()));
    Json::Obj(o)
}

fn main() {
    let mut rows: Vec<Json> = Vec::new();

    // Padding overhead vs grid granularity, under a realistic mixed load.
    let grids: &[(&str, Vec<usize>)] = &[
        ("pow2", vec![1, 2, 4, 8, 16, 32, 64, 128, 256]),
        ("coarse", vec![1, 8, 64, 256]),
        ("exact16", (1..=256).step_by(16).collect()),
        ("dense", (1..=256).collect()),
    ];
    for (name, buckets) in grids {
        let mut g = GraphCache::new(buckets, buckets, None);
        let mut rng = Rng::seed_from_u64(7);
        for _ in 0..100_000 {
            let local = rng.range_usize(1, 200);
            let offl = rng.range_usize(0, 120);
            let _ = g.select(local, offl);
        }
        figure_row("graph_bucket", &format!("{name}_grid_size"), 0.0, g.grid_size() as f64);
        figure_row("graph_bucket", &format!("{name}_padding_overhead"), 0.0, g.padding_overhead());
        rows.push(efficiency_row(name, &g));
    }

    // Interval-limited grid (the paper's configurable cap).
    let full: Vec<usize> = (1..=256).collect();
    for limit in [32usize, 128, 1024] {
        let mut g = GraphCache::new(&full, &full, Some(limit));
        let mut rng = Rng::seed_from_u64(7);
        for _ in 0..100_000 {
            let _ = g.select(rng.range_usize(1, 200), rng.range_usize(0, 120));
        }
        figure_row(
            "graph_bucket",
            &format!("limit{limit}_padding_overhead"),
            limit as f64,
            g.padding_overhead(),
        );
        rows.push(efficiency_row(&format!("limit{limit}"), &g));
    }

    // Selection hot-path cost (runs once per decode step per instance).
    let mut g = GraphCache::new(&[1, 2, 4, 8, 16, 32, 64, 128, 256], &[1, 2, 4, 8, 16, 32, 64, 128], None);
    let mut rng = Rng::seed_from_u64(3);
    Bench::new(10, 100).run("graph_bucket/select_10k", || {
        for _ in 0..10_000 {
            black_box(g.select(rng.range_usize(1, 250), rng.range_usize(0, 120)));
        }
    });
    rows.push(efficiency_row("select_10k", &g));

    let path = std::env::var("BENCH_GRAPH_BUCKET_JSON")
        .unwrap_or_else(|_| "BENCH_graph_bucket.json".into());
    let payload = format!("{}\n", Json::Arr(rows));
    match std::fs::write(&path, payload) {
        Ok(()) => println!("bench rows written to {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
