//! Bench + data for Fig 11: the end-to-end ShareGPT + Llama-2 7B
//! request-rate sweep, vLLM baseline vs Adrenaline (all four panels).

use adrenaline::sim::{run_e2e_with, E2eConfig, ExecMode};
use adrenaline::util::bench::{figure_row, Bench};

fn main() {
    let cfg = E2eConfig {
        rates: vec![8.0, 12.0, 16.0, 20.0, 24.0, 28.0],
        duration_s: 120.0,
        ..E2eConfig::fig11()
    };
    let pts = run_e2e_with(&cfg, ExecMode::Parallel);
    for p in &pts {
        figure_row("fig11a", &format!("{}_ttft_s", p.system), p.rate, p.ttft_mean_s);
        figure_row("fig11b", &format!("{}_tpot_s", p.system), p.rate, p.tpot_mean_s);
        figure_row("fig11c", &format!("{}_p99_tpot_s", p.system), p.rate, p.tpot_p99_s);
        figure_row("fig11d", &format!("{}_tput_tok_s", p.system), p.rate, p.throughput_tok_s);
    }
    // Headline ratio at the saturating point.
    let b = pts.iter().find(|p| p.rate == 24.0 && p.system == "vllm").unwrap();
    let a = pts.iter().find(|p| p.rate == 24.0 && p.system == "adrenaline").unwrap();
    figure_row(
        "fig11d",
        "speedup_at_saturation (paper: up to 1.47x)",
        24.0,
        a.throughput_tok_s / b.throughput_tok_s,
    );

    // Bench one sweep point end-to-end.
    Bench::new(1, 5).run("fig11/e2e_pair_at_24rps_120s", || {
        let cfg = E2eConfig { rates: vec![24.0], duration_s: 120.0, ..E2eConfig::fig11() };
        let _ = run_e2e_with(&cfg, ExecMode::Parallel);
    });
}
