//! Bench + data for Fig 3: decode attention's share of per-layer execution
//! time vs batch size (seq 1K). Paper anchor: 69.5% at batch 80.

use adrenaline::config::{GpuSpec, ModelSpec};
use adrenaline::gpu_model::{DecodeKernelTimes, Roofline};
use adrenaline::util::bench::{black_box, figure_row, Bench};

fn main() {
    let rl = Roofline::whole(GpuSpec::a100_80g());
    let m = ModelSpec::llama2_7b();
    for b in [1u64, 8, 16, 32, 48, 64, 80, 96, 128] {
        let t = DecodeKernelTimes::compute(&rl, &m, b, b * 1024);
        figure_row("fig3", "attention_share", b as f64, t.attention_share());
    }
    let anchor = DecodeKernelTimes::compute(&rl, &m, 80, 80 * 1024).attention_share();
    figure_row("fig3", "paper_anchor_b80 (paper: 0.695)", 80.0, anchor);

    Bench::new(10, 200).run("fig03/decode_kernel_times_batch_sweep", || {
        for b in [1u64, 8, 32, 80, 128] {
            black_box(DecodeKernelTimes::compute(&rl, &m, b, b * 1024).total());
        }
    });
}
