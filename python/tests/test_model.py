"""L2 model correctness: split pieces vs fused path vs pure reference.

The split/fused equivalence is the property that makes attention
disaggregation *exact* (not an approximation): driving the layer loop from
outside (as the Rust coordinator does) must produce bit-comparable results
to the fused decode artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.ref import decode_attention_ref

CFG = M.TINY
WEIGHTS = M.init_weights(CFG, seed=0)
RNG = np.random.default_rng(7)


def split_decode_step(tokens, positions, k_cache, v_cache, offload_split=None):
    """Drive the decode step exactly like the Rust coordinator: embed ->
    per-layer (pre -> [split] attention [merge] -> post) -> head.

    offload_split: if given, rows [offload_split:] run attention in a
    *separate kernel call* (the offloaded sub-batch).
    """
    b = tokens.shape[0]
    seq_lens = positions + 1
    (hidden,) = M.embed(tokens, WEIGHTS["embedding"])
    k_news, v_news = [], []
    for l in range(CFG.n_layers):
        lw = {n: WEIGHTS[f"layers.{l}.{n}"] for n in M.LAYER_WEIGHT_NAMES}
        q, k_new, v_new = M.layer_pre(
            CFG, hidden, positions, lw["ln_attn"], lw["wq"], lw["wk"], lw["wv"]
        )
        bidx = jnp.arange(b)
        k_cache = k_cache.at[l, bidx, positions].set(k_new)
        v_cache = v_cache.at[l, bidx, positions].set(v_new)
        if offload_split is None:
            (attn_out,) = M.attention(CFG, q, k_cache[l], v_cache[l], seq_lens)
        else:
            s = offload_split
            (local,) = M.attention(CFG, q[:s], k_cache[l, :s], v_cache[l, :s], seq_lens[:s])
            (remote,) = M.attention(CFG, q[s:], k_cache[l, s:], v_cache[l, s:], seq_lens[s:])
            attn_out = jnp.concatenate([local, remote], axis=0)
        (hidden,) = M.layer_post(
            CFG, hidden, attn_out,
            lw["wo"], lw["ln_ffn"], lw["w_gate"], lw["w_up"], lw["w_down"],
        )
        k_news.append(k_new)
        v_news.append(v_new)
    next_tok, logits = M.head(CFG, hidden, WEIGHTS["ln_final"], WEIGHTS["embedding"])
    return next_tok, jnp.stack(k_news), jnp.stack(v_news), logits


def random_state(b):
    L, s, h, dh = CFG.n_layers, CFG.max_seq_len, CFG.n_heads, CFG.head_dim
    k_cache = jnp.asarray(RNG.standard_normal((L, b, s, h, dh)), jnp.float32) * 0.3
    v_cache = jnp.asarray(RNG.standard_normal((L, b, s, h, dh)), jnp.float32) * 0.3
    tokens = jnp.asarray(RNG.integers(0, CFG.vocab_size, b), jnp.int32)
    positions = jnp.asarray(RNG.integers(1, s - 1, b), jnp.int32)
    return tokens, positions, k_cache, v_cache


@pytest.mark.parametrize("b", [1, 2, 4, 8])
def test_fused_equals_split(b):
    tokens, positions, k_cache, v_cache = random_state(b)
    sw = M.stacked_layer_weights(CFG, WEIGHTS)
    tok_f, kn_f, vn_f = M.decode_fused(
        CFG, tokens, positions, k_cache, v_cache,
        WEIGHTS["embedding"], WEIGHTS["ln_final"], *sw,
    )
    tok_s, kn_s, vn_s, _ = split_decode_step(tokens, positions, k_cache, v_cache)
    np.testing.assert_array_equal(tok_f, tok_s)
    np.testing.assert_allclose(kn_f, kn_s, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(vn_f, vn_s, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("split", [1, 2, 3])
def test_offloaded_split_is_exact(split):
    """Attention offloading partitions the batch; results must be identical
    to the unsplit step (modulo float reassociation: none here — same kernel,
    same per-row math)."""
    b = 4
    tokens, positions, k_cache, v_cache = random_state(b)
    tok_a, _, _, logits_a = split_decode_step(tokens, positions, k_cache, v_cache)
    tok_b, _, _, logits_b = split_decode_step(
        tokens, positions, k_cache, v_cache, offload_split=split
    )
    np.testing.assert_array_equal(tok_a, tok_b)
    np.testing.assert_allclose(logits_a, logits_b, rtol=1e-5, atol=1e-5)


def test_prefill_first_token_matches_reference():
    prompt = [int(t) for t in RNG.integers(0, CFG.vocab_size, 24)]
    sw = M.stacked_layer_weights(CFG, WEIGHTS)
    p = 32  # bucket
    toks = jnp.zeros((1, p), jnp.int32).at[0, : len(prompt)].set(jnp.asarray(prompt))
    plens = jnp.asarray([len(prompt)], jnp.int32)
    first, k_cache, v_cache = M.prefill(
        CFG, toks, plens, WEIGHTS["embedding"], WEIGHTS["ln_final"], *sw
    )
    ref = M.reference_generate(CFG, WEIGHTS, prompt, 1)
    assert int(first[0]) == ref[0]
    assert k_cache.shape == (CFG.n_layers, 1, p, CFG.n_heads, CFG.head_dim)


@pytest.mark.parametrize("plen,bucket", [(5, 16), (16, 16), (30, 32), (100, 128)])
def test_prefill_bucket_padding_irrelevant(plen, bucket):
    """Padding tokens beyond prompt_len must not affect the first token or
    the valid KV prefix."""
    prompt = [int(t) for t in RNG.integers(0, CFG.vocab_size, plen)]
    sw = M.stacked_layer_weights(CFG, WEIGHTS)
    base = jnp.zeros((1, bucket), jnp.int32).at[0, :plen].set(jnp.asarray(prompt))
    junk = base.at[0, plen:].set(jnp.asarray(RNG.integers(0, CFG.vocab_size, bucket - plen), jnp.int32)) if bucket > plen else base
    plens = jnp.asarray([plen], jnp.int32)
    args = (plens, WEIGHTS["embedding"], WEIGHTS["ln_final"], *sw)
    f1, k1, v1 = M.prefill(CFG, base, *args)
    f2, k2, v2 = M.prefill(CFG, junk, *args)
    assert int(f1[0]) == int(f2[0])
    np.testing.assert_allclose(k1[:, :, :plen], k2[:, :, :plen], rtol=1e-5, atol=1e-6)


def test_generate_chain_fused_matches_reference():
    """Multi-step greedy decode through the fused artifact path equals the
    pure-jnp reference generation."""
    prompt = [3, 250, 17, 42, 99, 7, 123, 8]
    n_steps = 12
    ref_toks = M.reference_generate(CFG, WEIGHTS, prompt, n_steps)

    sw = M.stacked_layer_weights(CFG, WEIGHTS)
    p = 16
    toks = jnp.zeros((1, p), jnp.int32).at[0, : len(prompt)].set(jnp.asarray(prompt))
    plens = jnp.asarray([len(prompt)], jnp.int32)
    first, k_pref, v_pref = M.prefill(
        CFG, toks, plens, WEIGHTS["embedding"], WEIGHTS["ln_final"], *sw
    )
    got = [int(first[0])]

    # Move prefill KV into a max_seq_len cache (what the Rust KV pool does).
    L, s, h, dh = CFG.n_layers, CFG.max_seq_len, CFG.n_heads, CFG.head_dim
    k_cache = jnp.zeros((L, 1, s, h, dh), jnp.float32).at[:, :, :p].set(k_pref)
    v_cache = jnp.zeros((L, 1, s, h, dh), jnp.float32).at[:, :, :p].set(v_pref)

    tok = int(first[0])
    for step in range(n_steps - 1):
        pos = len(prompt) + step
        nxt, k_new, v_new = M.decode_fused(
            CFG,
            jnp.asarray([tok], jnp.int32),
            jnp.asarray([pos], jnp.int32),
            k_cache, v_cache,
            WEIGHTS["embedding"], WEIGHTS["ln_final"], *sw,
        )
        k_cache = k_cache.at[:, 0, pos].set(k_new[:, 0])
        v_cache = v_cache.at[:, 0, pos].set(v_new[:, 0])
        tok = int(nxt[0])
        got.append(tok)
    assert got == ref_toks


def test_rope_position_zero_is_identity():
    x = jnp.asarray(RNG.standard_normal((2, 4, 16)), jnp.float32)
    pos = jnp.zeros((2,), jnp.int32)
    np.testing.assert_allclose(M.rope(x, pos, CFG.rope_theta), x, rtol=1e-6, atol=1e-6)


def test_rope_preserves_norm():
    x = jnp.asarray(RNG.standard_normal((3, 4, 16)), jnp.float32)
    pos = jnp.asarray([0, 5, 100], jnp.int32)
    y = M.rope(x, pos, CFG.rope_theta)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
    )


def test_rms_norm_scale_invariance():
    x = jnp.asarray(RNG.standard_normal((4, 64)), jnp.float32)
    g = jnp.ones((64,), jnp.float32)
    y1 = M.rms_norm(x, g, 1e-5)
    y2 = M.rms_norm(x * 10.0, g, 1e-5)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


def test_attention_wrapper_matches_ref():
    b, s = 4, CFG.max_seq_len
    q = jnp.asarray(RNG.standard_normal((b, CFG.n_heads, CFG.head_dim)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, CFG.n_heads, CFG.head_dim)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, CFG.n_heads, CFG.head_dim)), jnp.float32)
    lens = jnp.asarray([1, 20, 77, 128], jnp.int32)
    (out,) = M.attention(CFG, q, k, v, lens)
    ref = decode_attention_ref(q, k, v, lens).reshape(b, CFG.d_model)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_weights_deterministic():
    w1 = M.init_weights(CFG, seed=0)
    w2 = M.init_weights(CFG, seed=0)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])
    w3 = M.init_weights(CFG, seed=1)
    assert float(jnp.max(jnp.abs(w1["wq" if "wq" in w1 else "layers.0.wq"] - w3["layers.0.wq"]))) > 0 or True
    assert not np.array_equal(np.asarray(w1["layers.0.wq"]), np.asarray(w3["layers.0.wq"]))
