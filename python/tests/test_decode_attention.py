"""Pallas decode-attention kernel vs the pure-jnp oracle.

This is the CORE L1 correctness signal: the exact kernel that both the
decode engine and the attention executor run (as part of attn_b*.hlo.txt)
must match `ref.decode_attention_ref` for every shape/length combination.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention
from compile.kernels.ref import decode_attention_ref, merge_attention_ref

RNG = np.random.default_rng(1234)


def make_inputs(b, s, h, d, dtype=jnp.float32, rng=RNG):
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    lens = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    return q, k, v, lens


@pytest.mark.parametrize("b", [1, 2, 4, 8])
@pytest.mark.parametrize("s", [32, 128])
def test_matches_ref_basic(b, s):
    q, k, v, lens = make_inputs(b, s, h=4, d=16)
    out = decode_attention(q, k, v, lens)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_seq_len_one():
    """A decode step always has >= 1 valid KV entry; the degenerate case is
    attention over exactly the current token => output == v[:, 0]."""
    q, k, v, _ = make_inputs(3, 64, 4, 16)
    lens = jnp.ones((3,), jnp.int32)
    out = decode_attention(q, k, v, lens)
    np.testing.assert_allclose(out, v[:, 0], rtol=1e-5, atol=1e-6)


def test_full_cache():
    q, k, v, _ = make_inputs(2, 128, 4, 16)
    lens = jnp.full((2,), 128, jnp.int32)
    out = decode_attention(q, k, v, lens)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_padding_is_ignored():
    """Garbage in padded KV positions must not change the result."""
    q, k, v, _ = make_inputs(2, 64, 4, 16)
    lens = jnp.asarray([10, 33], jnp.int32)
    out1 = decode_attention(q, k, v, lens)
    k2 = k.at[0, 10:].set(1e6).at[1, 33:].set(-1e6)
    v2 = v.at[0, 10:].set(1e6).at[1, 33:].set(-1e6)
    out2 = decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_block_size_invariance():
    """Online-softmax chunking must not affect the math."""
    q, k, v, lens = make_inputs(4, 128, 4, 16)
    outs = [decode_attention(q, k, v, lens, block_s=bs) for bs in (8, 16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


def test_bfloat16_tolerance():
    q, k, v, lens = make_inputs(2, 64, 4, 16, dtype=jnp.bfloat16)
    out = decode_attention(q, k, v, lens)
    ref = decode_attention_ref(q, k, v, lens)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=3e-2, atol=3e-2
    )


def test_batch_rows_independent():
    """Each batch row's output depends only on its own q/kv/len — the property
    that makes attention disaggregation across sub-batches valid at all."""
    q, k, v, lens = make_inputs(4, 64, 4, 16)
    full = decode_attention(q, k, v, lens)
    for i in range(4):
        solo = decode_attention(q[i : i + 1], k[i : i + 1], v[i : i + 1], lens[i : i + 1])
        np.testing.assert_allclose(full[i], solo[0], rtol=1e-5, atol=1e-6)


def test_split_batch_equals_full_batch():
    """Local/offloaded sub-batch split (the serving system's core move) is a
    pure partition: running rows in two kernel calls == one call."""
    q, k, v, lens = make_inputs(8, 128, 4, 16)
    full = decode_attention(q, k, v, lens)
    a = decode_attention(q[:3], k[:3], v[:3], lens[:3])
    b = decode_attention(q[3:], k[3:], v[3:], lens[3:])
    np.testing.assert_allclose(jnp.concatenate([a, b]), full, rtol=1e-5, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 8),
    s=st.sampled_from([16, 32, 64, 128, 160]),
    h=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(b, s, h, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v, lens = make_inputs(b, s, h, d, rng=rng)
    out = decode_attention(q, k, v, lens)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    b=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_dtypes(dtype, b, seed):
    rng = np.random.default_rng(seed)
    q, k, v, lens = make_inputs(b, 64, 4, 16, dtype=dtype, rng=rng)
    out = decode_attention(q, k, v, lens)
    ref = decode_attention_ref(q, k, v, lens)
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol
    )
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


def test_merge_ref_is_exact_split():
    """Flash-decoding split-KV merge: attending over [0, s1) and [s1, s)
    separately then merging == attending over [0, s)."""
    b, s, h, d = 2, 64, 4, 16
    q, k, v, _ = make_inputs(b, s, h, d)
    lens = jnp.full((b,), s, jnp.int32)
    full = decode_attention_ref(q, k, v, lens)

    def part(ks, vs):
        sl = jnp.full((b,), ks.shape[1], jnp.int32)
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
        scores = jnp.einsum("bhd,bshd->bhs", q, ks) * scale
        m = jnp.max(scores, axis=-1)
        p = jnp.exp(scores - m[..., None])
        l = jnp.sum(p, axis=-1)
        out = jnp.einsum("bhs,bshd->bhd", p / l[..., None], vs)
        return out, m + jnp.log(l)

    s1 = 24
    oa, la = part(k[:, :s1], v[:, :s1])
    ob, lb = part(k[:, s1:], v[:, s1:])
    merged = merge_attention_ref(oa, la, ob, lb)
    np.testing.assert_allclose(merged, full, rtol=1e-5, atol=1e-5)
