"""L1 perf-model invariants: the structural properties the kernel's block
configuration must satisfy on real TPU hardware."""

import pytest

from compile.kernels.perf_model import (
    DecodeKernelConfig,
    llama7b_config,
    tiny_model_config,
    HBM_BW,
    PEAK_BF16_FLOPS,
    VMEM_BYTES,
)


def test_tiny_config_fits_vmem_easily():
    cfg = tiny_model_config()
    assert cfg.vmem_fraction() < 0.01, "tiny model uses <1% of VMEM"


def test_7b_config_pipelines_in_vmem():
    cfg = llama7b_config()
    # Double-buffered KV staging must leave plenty of VMEM for the rest of
    # the layer (the practical budget is ~50%).
    assert cfg.vmem_fraction() < 0.5, f"fraction = {cfg.vmem_fraction():.3f}"
    assert cfg.vmem_double_buffered() > cfg.vmem_per_stage()


def test_decode_attention_memory_bound_at_all_context_lengths():
    # The paper's core premise (Figs 3/9): decode attention is memory-bound
    # — that's exactly why offloading it to idle bandwidth works.
    cfg = llama7b_config()
    for seq in [128, 1024, 4096]:
        assert cfg.memory_bound(seq), f"seq {seq} must be memory-bound"
        # Intensity is constant in seq (both flops and bytes are linear).
        assert cfg.arithmetic_intensity(seq) == pytest.approx(
            cfg.arithmetic_intensity(128)
        )


def test_intensity_well_below_ridge():
    cfg = llama7b_config()
    ridge = PEAK_BF16_FLOPS / HBM_BW
    assert cfg.arithmetic_intensity(1024) < ridge / 50, (
        "decode attention sits far left of the roofline ridge"
    )


def test_mxu_tiling_improves_with_head_dim_and_batch():
    small = DecodeKernelConfig(batch=1, n_heads=4, head_dim=16, max_seq=128, block_s=32)
    big = llama7b_config()
    assert big.estimated_mxu_utilization() > small.estimated_mxu_utilization()
    c, o = big.mxu_tiles()
    assert c == 1.0, "7B head_dim 128 fills the contracting MXU axis"
    assert o == 1.0, "batch*heads >= 128 fills the output axis"


def test_block_s_tradeoff():
    # Larger KV blocks stage more VMEM but don't change intensity.
    small = llama7b_config(block_s=64)
    mid = llama7b_config(block_s=128)
    large = llama7b_config(block_s=512)
    assert large.vmem_double_buffered() > small.vmem_double_buffered()
    assert large.arithmetic_intensity(1024) == small.arithmetic_intensity(1024)
    # The design constraint the default BLOCK_S=128 encodes: with all 32
    # heads staged per batch element, 128-token blocks pipeline within the
    # VMEM budget but 512-token blocks do NOT — the block sweep's finding.
    assert mid.vmem_double_buffered() < VMEM_BYTES / 2
    assert large.vmem_double_buffered() > VMEM_BYTES / 2
