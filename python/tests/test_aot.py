"""AOT pipeline: weights serialization round-trip, manifest consistency,
and HLO-text artifact sanity (parseable structure, right entry shapes)."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_weights_roundtrip(tmp_path):
    w = M.init_weights(M.TINY, seed=3)
    path = tmp_path / "w.bin"
    aot.save_weights(path, w)
    back = aot.load_weights(path)
    assert set(back) == set(w)
    for name in w:
        np.testing.assert_array_equal(np.asarray(w[name], np.float32), back[name])


def test_weights_format_header(tmp_path):
    w = {"a": jnp.ones((2, 3), jnp.float32)}
    path = tmp_path / "w.bin"
    aot.save_weights(path, w)
    data = path.read_bytes()
    assert data[:4] == b"ADRW"
    # version 1, count 1
    assert int.from_bytes(data[4:8], "little") == 1
    assert int.from_bytes(data[8:12], "little") == 1


def test_artifact_specs_cover_all_buckets():
    specs = aot.artifact_specs(M.TINY)
    for b in aot.BATCH_BUCKETS:
        for stem in ("embed", "layer_pre", "attn", "layer_post", "head", "decode_fused"):
            assert f"{stem}_b{b}" in specs
    for p in aot.PROMPT_BUCKETS:
        assert f"prefill_p{p}" in specs


def test_lowering_produces_hlo_text():
    specs = aot.artifact_specs(M.TINY)
    fn, args = specs["attn_b1"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "ENTRY" in text
    assert "HloModule" in text


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
class TestBuiltArtifacts:
    def test_manifest_matches_specs(self):
        manifest = json.loads((ART / "manifest.json").read_text())
        specs = aot.artifact_specs(M.TINY)
        assert set(manifest["artifacts"]) == set(specs)
        assert manifest["batch_buckets"] == list(aot.BATCH_BUCKETS)
        assert manifest["prompt_buckets"] == list(aot.PROMPT_BUCKETS)
        mc = manifest["model"]
        assert mc["d_model"] == M.TINY.d_model
        assert mc["n_layers"] == M.TINY.n_layers
        assert mc["max_seq_len"] == M.TINY.max_seq_len

    def test_all_artifacts_exist_and_parse(self):
        manifest = json.loads((ART / "manifest.json").read_text())
        for name in manifest["artifacts"]:
            text = (ART / f"{name}.hlo.txt").read_text()
            assert "ENTRY" in text, name
            assert "HloModule" in text, name

    def test_weights_bin_loadable(self):
        w = aot.load_weights(ART / "weights.bin")
        assert "embedding" in w and "ln_final" in w
        for l in range(M.TINY.n_layers):
            for n in M.LAYER_WEIGHT_NAMES:
                assert f"layers.{l}.{n}" in w
        assert w["embedding"].shape == (M.TINY.vocab_size, M.TINY.d_model)

    def test_weights_match_seeded_init(self):
        manifest = json.loads((ART / "manifest.json").read_text())
        w_disk = aot.load_weights(ART / "weights.bin")
        w_init = M.init_weights(M.TINY, seed=manifest["seed"])
        for name in w_init:
            np.testing.assert_array_equal(
                w_disk[name], np.asarray(w_init[name], np.float32)
            )

    def test_incremental_build_is_noop(self, capsys):
        aot.build(ART)  # manifest exists + same inventory -> no rebuild
        out = capsys.readouterr().out
        assert "up to date" in out
