"""Pallas prefill (causal flash) attention kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.prefill_attention import prefill_attention
from compile.kernels.ref import prefill_attention_ref

RNG = np.random.default_rng(42)


def make_inputs(b, p, h, d, dtype=jnp.float32, rng=RNG):
    q = jnp.asarray(rng.standard_normal((b, p, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, p, h, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, p, h, d)), dtype)
    lens = jnp.asarray(rng.integers(1, p + 1, size=b), jnp.int32)
    return q, k, v, lens


def assert_valid_rows_close(out, ref, lens, rtol=1e-5, atol=1e-5):
    """Compare only rows < prompt_len (padded rows are defined-but-garbage)."""
    for b in range(out.shape[0]):
        L = int(lens[b])
        np.testing.assert_allclose(out[b, :L], ref[b, :L], rtol=rtol, atol=atol)
        assert bool(jnp.all(jnp.isfinite(out[b].astype(jnp.float32))))


@pytest.mark.parametrize("p", [16, 32, 64, 128])
def test_matches_ref(p):
    q, k, v, lens = make_inputs(2, p, 4, 16)
    out = prefill_attention(q, k, v, lens)
    ref = prefill_attention_ref(q, k, v, lens)
    assert_valid_rows_close(out, ref, lens)


def test_full_prompts():
    q, k, v, _ = make_inputs(3, 64, 4, 16)
    lens = jnp.full((3,), 64, jnp.int32)
    out = prefill_attention(q, k, v, lens)
    ref = prefill_attention_ref(q, k, v, lens)
    assert_valid_rows_close(out, ref, lens)


def test_causality():
    """Changing future tokens must not change earlier rows."""
    q, k, v, _ = make_inputs(1, 32, 2, 8)
    lens = jnp.asarray([32], jnp.int32)
    out1 = prefill_attention(q, k, v, lens)
    k2 = k.at[0, 20:].add(3.0)
    v2 = v.at[0, 20:].add(-2.0)
    out2 = prefill_attention(q, k2, v2, lens)
    np.testing.assert_allclose(out1[0, :20], out2[0, :20], rtol=1e-5, atol=1e-6)
    # ... and the later rows DO change (the mask isn't over-wide).
    assert float(jnp.max(jnp.abs(out1[0, 20:] - out2[0, 20:]))) > 1e-3


def test_first_row_attends_only_self():
    q, k, v, _ = make_inputs(2, 16, 4, 16)
    lens = jnp.full((2,), 16, jnp.int32)
    out = prefill_attention(q, k, v, lens)
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-6)


def test_block_shape_invariance():
    q, k, v, lens = make_inputs(2, 128, 4, 16)
    outs = [
        prefill_attention(q, k, v, lens, block_q=bq, block_k=bk)
        for bq, bk in ((16, 16), (32, 32), (64, 32), (32, 64), (128, 128))
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


def test_prompt_len_one():
    q, k, v, _ = make_inputs(2, 32, 4, 16)
    lens = jnp.ones((2,), jnp.int32)
    out = prefill_attention(q, k, v, lens)
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    p=st.sampled_from([16, 32, 64, 128]),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(b, p, h, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v, lens = make_inputs(b, p, h, d, rng=rng)
    out = prefill_attention(q, k, v, lens)
    ref = prefill_attention_ref(q, k, v, lens)
    assert_valid_rows_close(out, ref, lens, rtol=2e-5, atol=2e-5)


def test_bfloat16():
    q, k, v, lens = make_inputs(2, 32, 4, 16, dtype=jnp.bfloat16)
    out = prefill_attention(q, k, v, lens)
    ref = prefill_attention_ref(q, k, v, lens)
    assert out.dtype == jnp.bfloat16
    for b in range(2):
        L = int(lens[b])
        np.testing.assert_allclose(
            out[b, :L].astype(jnp.float32),
            ref[b, :L].astype(jnp.float32),
            rtol=4e-2, atol=4e-2,
        )
