"""Cross-kernel consistency: the prefill and decode attention kernels must
agree — token i's attention output computed causally during prefill equals
a decode-attention query at position i over the same KV prefix. This is the
property that lets a PD-disaggregated system hand prefill-produced KV to
the decode phase (or to the attention executor) without re-computation."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention
from compile.kernels.prefill_attention import prefill_attention
from compile import model as M

RNG = np.random.default_rng(99)
CFG = M.TINY


@pytest.mark.parametrize("p,i", [(16, 0), (16, 15), (32, 17), (64, 63)])
def test_decode_matches_prefill_row(p, i):
    h, d = 4, 16
    q = jnp.asarray(RNG.standard_normal((1, p, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, p, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, p, h, d)), jnp.float32)
    lens = jnp.asarray([p], jnp.int32)
    pref = prefill_attention(q, k, v, lens)  # [1, P, H, D]

    # Decode view: query token i against KV[0..i] (padded cache).
    s = 128
    kc = jnp.zeros((1, s, h, d), jnp.float32).at[:, :p].set(k)
    vc = jnp.zeros((1, s, h, d), jnp.float32).at[:, :p].set(v)
    dec = decode_attention(q[:, i], kc, vc, jnp.asarray([i + 1], jnp.int32))
    np.testing.assert_allclose(dec[0], pref[0, i], rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    p=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_every_row_consistent(p, seed):
    rng = np.random.default_rng(seed)
    h, d = 2, 8
    q = jnp.asarray(rng.standard_normal((1, p, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, p, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, p, h, d)), jnp.float32)
    pref = prefill_attention(q, k, v, jnp.asarray([p], jnp.int32))
    i = int(rng.integers(0, p))
    kc = jnp.zeros((1, 128, h, d), jnp.float32).at[:, :p].set(k)
    vc = jnp.zeros((1, 128, h, d), jnp.float32).at[:, :p].set(v)
    dec = decode_attention(q[:, i], kc, vc, jnp.asarray([i + 1], jnp.int32))
    np.testing.assert_allclose(dec[0], pref[0, i], rtol=3e-5, atol=3e-5)


def test_layer_pre_kv_matches_prefill_kv():
    """The KV rows layer_pre produces for a token at position p must equal
    the prefill pass's KV at that position (RoPE phases aligned) — this is
    what makes recompute-free decode after prefill correct."""
    w = M.init_weights(CFG, seed=0)
    sw = M.stacked_layer_weights(CFG, w)
    prompt = [int(t) for t in RNG.integers(0, CFG.vocab_size, 12)]
    toks = jnp.zeros((1, 16), jnp.int32).at[0, : len(prompt)].set(jnp.asarray(prompt))
    plens = jnp.asarray([len(prompt)], jnp.int32)
    _first, k_pref, v_pref = M.prefill(CFG, toks, plens, w["embedding"], w["ln_final"], *sw)

    # Recompute layer-0 KV for each prompt position via layer_pre on the
    # embedded token (layer 0's input hidden is just the embedding).
    (hidden,) = M.embed(jnp.asarray(prompt, jnp.int32), w["embedding"])
    positions = jnp.arange(len(prompt), dtype=jnp.int32)
    lw = {n: w[f"layers.0.{n}"] for n in M.LAYER_WEIGHT_NAMES}
    _q, k_new, v_new = M.layer_pre(
        CFG, hidden, positions, lw["ln_attn"], lw["wq"], lw["wk"], lw["wv"]
    )
    np.testing.assert_allclose(k_new, k_pref[0, 0, : len(prompt)], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v_new, v_pref[0, 0, : len(prompt)], rtol=1e-5, atol=1e-5)


def test_reference_generations_file_consistent():
    """The artifact the Rust e2e tests consume must replay exactly."""
    import json
    import pathlib

    art = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    path = art / "reference_generations.json"
    if not path.exists():
        pytest.skip("run `make artifacts` first")
    manifest = json.loads((art / "manifest.json").read_text())
    w = M.init_weights(CFG, seed=manifest["seed"])
    cases = json.loads(path.read_text())
    assert len(cases) >= 4
    # Replay the shortest case fully.
    case = min(cases, key=lambda c: len(c["prompt"]) + len(c["expected"]))
    got = M.reference_generate(CFG, w, case["prompt"], len(case["expected"]))
    assert got == case["expected"]
