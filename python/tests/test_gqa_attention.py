"""GQA decode-attention kernel vs its KV-head-expansion oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention
from compile.kernels.gqa_decode_attention import (
    gqa_decode_attention,
    gqa_decode_attention_ref,
)

RNG = np.random.default_rng(321)


def make_inputs(b, s, hq, hkv, d, rng=RNG):
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    return q, k, v, lens


@pytest.mark.parametrize("hq,hkv", [(8, 2), (8, 4), (4, 1), (4, 4)])
def test_matches_ref(hq, hkv):
    q, k, v, lens = make_inputs(2, 64, hq, hkv, 16)
    out = gqa_decode_attention(q, k, v, lens)
    ref = gqa_decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_group_of_one_equals_mha_kernel():
    """Hq == Hkv degenerates to the MHA decode kernel exactly."""
    q, k, v, lens = make_inputs(3, 64, 4, 4, 16)
    gqa = gqa_decode_attention(q, k, v, lens)
    mha = decode_attention(q, k, v, lens)
    np.testing.assert_allclose(gqa, mha, rtol=1e-6, atol=1e-6)


def test_query_heads_in_group_share_kv():
    """With identical q rows inside a group, outputs must be identical —
    they read the same KV head."""
    b, s, hkv, d, group = 1, 32, 2, 8, 3
    hq = hkv * group
    q1 = jnp.asarray(RNG.standard_normal((b, hkv, 1, d)), jnp.float32)
    q = jnp.broadcast_to(q1, (b, hkv, group, d)).reshape(b, hq, d)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    lens = jnp.asarray([s], jnp.int32)
    out = gqa_decode_attention(q, k, v, lens).reshape(b, hkv, group, d)
    for g in range(1, group):
        np.testing.assert_allclose(out[:, :, g], out[:, :, 0], rtol=1e-6, atol=1e-6)


def test_padding_ignored():
    q, k, v, _ = make_inputs(2, 64, 8, 2, 16)
    lens = jnp.asarray([5, 40], jnp.int32)
    out1 = gqa_decode_attention(q, k, v, lens)
    k2 = k.at[0, 5:].set(1e6).at[1, 40:].set(-1e6)
    v2 = v.at[0, 5:].set(1e6).at[1, 40:].set(-1e6)
    out2 = gqa_decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_block_size_invariance():
    q, k, v, lens = make_inputs(2, 128, 8, 2, 16)
    outs = [gqa_decode_attention(q, k, v, lens, block_s=bs) for bs in (16, 32, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    s=st.sampled_from([16, 64, 128]),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(b, s, hkv, group, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v, lens = make_inputs(b, s, hkv * group, hkv, d, rng=rng)
    out = gqa_decode_attention(q, k, v, lens)
    ref = gqa_decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_kv_bytes_savings_property():
    """The serving-economics point: GQA's KV cache is `group`x smaller per
    token — the input tensors themselves demonstrate it."""
    _, k_mha, _, _ = make_inputs(1, 64, 8, 8, 16)
    _, k_gqa, _, _ = make_inputs(1, 64, 8, 2, 16)
    assert k_mha.size == 4 * k_gqa.size
