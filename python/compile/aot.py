"""AOT pipeline: lower every (function, bucket) pair to HLO text + weights.

This is the single build-time Python entrypoint (`make artifacts`). It emits
into artifacts/:

    manifest.json            model config + artifact/bucket inventory
    weights.bin              all weight tensors (custom ADRW format, f32 LE)
    <name>.hlo.txt           one HLO-text module per artifact

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` crate) rejects; the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Python never runs at serve time — the Rust binary is self-contained once
this script has produced artifacts/.
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

# Batch buckets for the decode-step artifacts — the first dimension of the
# paper's 2-D CUDA-graph grid (C_d x C_o). The Rust graph cache picks the
# smallest (local, offload) bucket pair covering a step's two sub-batches.
BATCH_BUCKETS = (1, 2, 4, 8)
# Prompt-length buckets for the prefill artifact.
PROMPT_BUCKETS = (16, 32, 64, 128)

WEIGHTS_MAGIC = b"ADRW"
WEIGHTS_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def save_weights(path: pathlib.Path, weights: dict[str, jnp.ndarray]) -> None:
    """ADRW format: magic, version u32, count u32, then per tensor:
    name_len u16 + name bytes, ndim u8, dims u32*, f32 LE data."""
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<II", WEIGHTS_VERSION, len(weights)))
        for name in sorted(weights):
            arr = np.asarray(weights[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            f.write(arr.astype("<f4").tobytes())


def load_weights(path: pathlib.Path) -> dict[str, np.ndarray]:
    """Inverse of save_weights (used by round-trip tests)."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == WEIGHTS_MAGIC, "bad magic"
    version, count = struct.unpack_from("<II", data, 4)
    assert version == WEIGHTS_VERSION
    off = 12
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off : off + nlen].decode()
        off += nlen
        (ndim,) = struct.unpack_from("<B", data, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        n = int(np.prod(shape)) if ndim else 1
        arr = np.frombuffer(data, dtype="<f4", count=n, offset=off).reshape(shape)
        off += 4 * n
        out[name] = arr
    return out


# ---------------------------------------------------------------------------
# Artifact definitions: name -> (function, example-arg shapes)
# ---------------------------------------------------------------------------


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def artifact_specs(cfg: M.ModelConfig) -> dict[str, tuple]:
    """All (name -> (fn, arg_specs)) pairs to lower."""
    d, h, dh, f, v, s, L = (
        cfg.d_model,
        cfg.n_heads,
        cfg.head_dim,
        cfg.ffn_hidden,
        cfg.vocab_size,
        cfg.max_seq_len,
        cfg.n_layers,
    )
    lw_specs = [  # per-layer weights, order = M.LAYER_WEIGHT_NAMES
        f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d),
        f32(d), f32(d, f), f32(d, f), f32(f, d),
    ]
    stacked_lw_specs = [
        jax.ShapeDtypeStruct((L, *spec.shape), spec.dtype) for spec in lw_specs
    ]
    specs: dict[str, tuple] = {}
    for b in BATCH_BUCKETS:
        specs[f"embed_b{b}"] = (M.embed, [i32(b), f32(v, d)])
        specs[f"layer_pre_b{b}"] = (
            functools.partial(M.layer_pre, cfg),
            [f32(b, d), i32(b), *lw_specs[:4]],
        )
        specs[f"attn_b{b}"] = (
            functools.partial(M.attention, cfg),
            [f32(b, h, dh), f32(b, s, h, dh), f32(b, s, h, dh), i32(b)],
        )
        specs[f"layer_post_b{b}"] = (
            functools.partial(M.layer_post, cfg),
            [f32(b, d), f32(b, d), *lw_specs[4:]],
        )
        specs[f"head_b{b}"] = (
            functools.partial(M.head, cfg),
            [f32(b, d), f32(d), f32(v, d)],
        )
        specs[f"decode_fused_b{b}"] = (
            functools.partial(M.decode_fused, cfg),
            [
                i32(b), i32(b),
                f32(L, b, s, h, dh), f32(L, b, s, h, dh),
                f32(v, d), f32(d),
                *stacked_lw_specs,
            ],
        )
    for p in PROMPT_BUCKETS:
        specs[f"prefill_p{p}"] = (
            functools.partial(M.prefill, cfg),
            [i32(1, p), i32(1), f32(v, d), f32(d), *stacked_lw_specs],
        )
    return specs


def build(out_dir: pathlib.Path, seed: int = 0, force: bool = False) -> None:
    cfg = M.TINY
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"

    specs = artifact_specs(cfg)
    if manifest_path.exists() and not force:
        # Incremental: only rebuild if the inventory changed (make handles
        # source-file staleness).
        existing = json.loads(manifest_path.read_text())
        if set(existing.get("artifacts", [])) == set(specs) and (
            out_dir / "weights.bin"
        ).exists():
            print(f"artifacts up to date in {out_dir}")
            return

    weights = M.init_weights(cfg, seed=seed)
    save_weights(out_dir / "weights.bin", weights)
    print(f"wrote weights.bin ({len(weights)} tensors)")

    # Reference greedy generations: the Rust integration tests replay these
    # prompts through the full serving stack (with and without attention
    # offloading) and require token-exact agreement with the pure-jnp
    # oracle — the strongest cross-layer correctness signal we have.
    import numpy as _np

    rng = _np.random.default_rng(seed + 1)
    refs = []
    for plen, steps in [(5, 12), (16, 10), (31, 8), (64, 6)]:
        prompt = [int(t) for t in rng.integers(0, cfg.vocab_size, plen)]
        toks = M.reference_generate(cfg, weights, prompt, steps)
        refs.append({"prompt": prompt, "expected": toks})
    (out_dir / "reference_generations.json").write_text(json.dumps(refs))
    print(f"wrote reference_generations.json ({len(refs)} cases)")

    for name, (fn, arg_specs) in specs.items():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        (out_dir / f"{name}.hlo.txt").write_text(text)
        print(f"wrote {name}.hlo.txt ({len(text)} chars)")

    manifest = {
        "model": {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "ffn_hidden": cfg.ffn_hidden,
            "max_seq_len": cfg.max_seq_len,
            "rope_theta": cfg.rope_theta,
            "rms_eps": cfg.rms_eps,
        },
        "seed": seed,
        "batch_buckets": list(BATCH_BUCKETS),
        "prompt_buckets": list(PROMPT_BUCKETS),
        "layer_weight_names": list(M.LAYER_WEIGHT_NAMES),
        "global_weight_names": list(M.GLOBAL_WEIGHT_NAMES),
        "artifacts": sorted(specs),
    }
    manifest_path.write_text(json.dumps(manifest, indent=2))
    print(f"wrote manifest.json ({len(specs)} artifacts)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    build(pathlib.Path(args.out).resolve(), seed=args.seed, force=args.force)


if __name__ == "__main__":
    main()
