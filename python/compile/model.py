"""L2: a Llama-architecture transformer, split at Adrenaline's boundaries.

The forward pass is deliberately factored the way the paper disaggregates
it, so the Rust coordinator (L3) can drive the per-layer loop and route the
attention sub-batches:

    embed        : token ids              -> hidden
    layer_pre    : RMSNorm + QKV proj + RoPE        (per layer, weights as params)
    attention    : decode_attention Pallas kernel   (THE offloadable unit)
    layer_post   : O proj + residual + RMSNorm + SwiGLU FFN + residual
    head         : final RMSNorm + tied-embedding logits + greedy argmax
    prefill      : the whole prompt pass fused (scan over layers), emitting
                   the first token plus the populated KV cache
    decode_fused : the whole decode step fused — the no-offload fast path
                   (ablation baseline; also how a vanilla PD system decodes)

Weights are *parameters*, not baked constants: one lowered artifact per
(function, batch-bucket) serves every layer; Rust passes the per-layer
weight literals. All math in f32 (CPU PJRT).

The model config here must stay in lock-step with rust/src/config/model.rs
(TINY consts) and the manifest emitted by aot.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels.decode_attention import decode_attention
from compile.kernels.prefill_attention import prefill_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dimensions of the served model (the tiny CPU-path model by default)."""

    vocab_size: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    head_dim: int = 16
    ffn_hidden: int = 128
    max_seq_len: int = 128
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    def __post_init__(self) -> None:
        assert self.n_heads * self.head_dim == self.d_model


TINY = ModelConfig()

# Layer-weight tensor names, in the order artifacts take them as parameters.
LAYER_WEIGHT_NAMES = (
    "ln_attn",  # [D]
    "wq",  # [D, D]
    "wk",  # [D, D]
    "wv",  # [D, D]
    "wo",  # [D, D]
    "ln_ffn",  # [D]
    "w_gate",  # [D, F]
    "w_up",  # [D, F]
    "w_down",  # [F, D]
)
GLOBAL_WEIGHT_NAMES = (
    "embedding",  # [V, D]
    "ln_final",  # [D]
)


def init_weights(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Deterministic small-scale weights (the model is random, not trained —
    the serving system's correctness doesn't depend on sensible text)."""
    key = jax.random.PRNGKey(seed)
    d, f, v = cfg.d_model, cfg.ffn_hidden, cfg.vocab_size
    shapes = {
        "ln_attn": (d,),
        "wq": (d, d),
        "wk": (d, d),
        "wv": (d, d),
        "wo": (d, d),
        "ln_ffn": (d,),
        "w_gate": (d, f),
        "w_up": (d, f),
        "w_down": (f, d),
    }
    weights: dict[str, jnp.ndarray] = {}
    key, sub = jax.random.split(key)
    weights["embedding"] = jax.random.normal(sub, (v, d), jnp.float32) * 0.08
    weights["ln_final"] = jnp.ones((d,), jnp.float32)
    for layer in range(cfg.n_layers):
        for name, shape in shapes.items():
            full = f"layers.{layer}.{name}"
            if name.startswith("ln_"):
                weights[full] = jnp.ones(shape, jnp.float32)
            else:
                key, sub = jax.random.split(key)
                fan_in = shape[0]
                weights[full] = jax.random.normal(sub, shape, jnp.float32) * (
                    0.8 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
                )
    return weights


def layer_weights(weights: dict[str, jnp.ndarray], layer: int) -> list[jnp.ndarray]:
    return [weights[f"layers.{layer}.{n}"] for n in LAYER_WEIGHT_NAMES]


def stacked_layer_weights(
    cfg: ModelConfig, weights: dict[str, jnp.ndarray]
) -> list[jnp.ndarray]:
    """Stack each layer weight along a leading L axis (for scan-based paths)."""
    return [
        jnp.stack([weights[f"layers.{l}.{n}"] for l in range(cfg.n_layers)])
        for n in LAYER_WEIGHT_NAMES
    ]


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [..., H, D]; positions: x.shape[:-2]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)  # [half]
    ang = positions[..., None, None].astype(jnp.float32) * freq  # [..., 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


# ---------------------------------------------------------------------------
# Disaggregated decode-step pieces (each becomes one artifact per bucket)
# ---------------------------------------------------------------------------


def embed(tokens: jnp.ndarray, embedding: jnp.ndarray):
    """tokens [B] int32 -> hidden [B, D]."""
    return (jnp.take(embedding, tokens, axis=0),)


def layer_pre(
    cfg: ModelConfig,
    hidden: jnp.ndarray,  # [B, D]
    positions: jnp.ndarray,  # [B] int32 (0-based position of this token)
    ln_attn, wq, wk, wv,  # layer weights (subset)
):
    """RMSNorm + QKV projection + RoPE -> q, k, v each [B, H, Dh].

    k/v are the *new* cache entries for this step; L3 writes them into its
    KV pool at `positions` before (or while) running attention.
    """
    b = hidden.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    x = rms_norm(hidden, ln_attn, cfg.rms_eps)
    q = (x @ wq).reshape(b, h, dh)
    k = (x @ wk).reshape(b, h, dh)
    v = (x @ wv).reshape(b, h, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(
    cfg: ModelConfig,
    q: jnp.ndarray,  # [B, H, Dh]
    k_cache: jnp.ndarray,  # [B, S, H, Dh]
    v_cache: jnp.ndarray,  # [B, S, H, Dh]
    seq_lens: jnp.ndarray,  # [B] int32
):
    """The offloadable unit: the Pallas decode-attention kernel, flattened
    back to [B, D] for the O projection."""
    b = q.shape[0]
    out = decode_attention(q, k_cache, v_cache, seq_lens)
    return (out.reshape(b, cfg.d_model),)


def layer_post(
    cfg: ModelConfig,
    hidden: jnp.ndarray,  # [B, D] residual stream input to the layer
    attn_out: jnp.ndarray,  # [B, D] merged attention output
    wo, ln_ffn, w_gate, w_up, w_down,
):
    """O projection + residual + FFN block -> next hidden [B, D]."""
    hidden = hidden + attn_out @ wo
    x = rms_norm(hidden, ln_ffn, cfg.rms_eps)
    hidden = hidden + swiglu(x, w_gate, w_up, w_down)
    return (hidden,)


def head(cfg: ModelConfig, hidden: jnp.ndarray, ln_final, embedding):
    """Final norm + tied-embedding logits + greedy next token."""
    x = rms_norm(hidden, ln_final, cfg.rms_eps)
    logits = x @ embedding.T  # [B, V]
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, logits


# ---------------------------------------------------------------------------
# Fused paths
# ---------------------------------------------------------------------------


def decode_fused(
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [B] int32
    positions: jnp.ndarray,  # [B] int32 position of this token
    k_cache: jnp.ndarray,  # [L, B, S, H, Dh]
    v_cache: jnp.ndarray,  # [L, B, S, H, Dh]
    embedding, ln_final,
    *stacked_lw,  # 9 tensors, each [L, ...]
):
    """Whole decode step in one artifact — the no-offload fast path.

    Returns (next_token [B], k_new [L,B,H,Dh], v_new [L,B,H,Dh]); L3 writes
    k_new/v_new into its KV pool (the artifact does NOT return the whole
    cache, keeping the output transfer small).
    """
    b = tokens.shape[0]
    (hidden,) = embed(tokens, embedding)
    seq_lens = positions + 1
    bidx = jnp.arange(b)

    def step(hidden, per_layer):
        kc, vc, (ln_attn, wq, wk, wv, wo, ln_ffn, w_gate, w_up, w_down) = per_layer
        q, k_new, v_new = layer_pre(cfg, hidden, positions, ln_attn, wq, wk, wv)
        kc = kc.at[bidx, positions].set(k_new)
        vc = vc.at[bidx, positions].set(v_new)
        (attn_out,) = attention(cfg, q, kc, vc, seq_lens)
        (hidden,) = layer_post(
            cfg, hidden, attn_out, wo, ln_ffn, w_gate, w_up, w_down
        )
        return hidden, (k_new, v_new)

    hidden, (k_news, v_news) = jax.lax.scan(
        step, hidden, (k_cache, v_cache, tuple(stacked_lw))
    )
    next_tok, _logits = head(cfg, hidden, ln_final, embedding)
    return next_tok, k_news, v_news


def prefill(
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [B, P] int32, padded with 0
    prompt_lens: jnp.ndarray,  # [B] int32
    embedding, ln_final,
    *stacked_lw,  # 9 tensors, each [L, ...]
):
    """Full prefill pass: first output token + populated KV cache.

    Returns (first_token [B], k_cache [L,B,P,H,Dh], v_cache [L,B,P,H,Dh]).
    """
    b, p = tokens.shape
    h, dh = cfg.n_heads, cfg.head_dim
    hidden = jnp.take(embedding, tokens, axis=0)  # [B, P, D]
    positions = jnp.broadcast_to(jnp.arange(p, dtype=jnp.int32), (b, p))

    def step(hidden, lw):
        ln_attn, wq, wk, wv, wo, ln_ffn, w_gate, w_up, w_down = lw
        x = rms_norm(hidden, ln_attn, cfg.rms_eps)
        q = (x @ wq).reshape(b, p, h, dh)
        k = (x @ wk).reshape(b, p, h, dh)
        v = (x @ wv).reshape(b, p, h, dh)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        attn = prefill_attention(q, k, v, prompt_lens)  # [B, P, H, Dh]
        hidden = hidden + attn.reshape(b, p, cfg.d_model) @ wo
        x = rms_norm(hidden, ln_ffn, cfg.rms_eps)
        hidden = hidden + swiglu(x, w_gate, w_up, w_down)
        return hidden, (k, v)

    hidden, (k_cache, v_cache) = jax.lax.scan(step, hidden, tuple(stacked_lw))
    # Last *valid* token's hidden state produces the first output token.
    last = jnp.maximum(prompt_lens - 1, 0)  # [B]
    final_hidden = hidden[jnp.arange(b), last]  # [B, D]
    first_tok, _logits = head(cfg, final_hidden, ln_final, embedding)
    return first_tok, k_cache, v_cache


# ---------------------------------------------------------------------------
# Pure-jnp reference decode (oracle for the full pipeline, incl. fused/split
# equivalence). Mirrors decode_fused but uses ref attention math.
# ---------------------------------------------------------------------------


def reference_generate(
    cfg: ModelConfig,
    weights: dict[str, jnp.ndarray],
    prompt: list[int],
    n_steps: int,
) -> list[int]:
    """Greedy generation with plain-python orchestration and jnp math only.

    Slow; used by tests as the end-to-end ground truth for the Rust serving
    path (same prompt => identical greedy tokens).
    """
    from compile.kernels.ref import decode_attention_ref, prefill_attention_ref

    emb = weights["embedding"]
    ln_f = weights["ln_final"]
    p = len(prompt)
    toks = jnp.asarray(prompt, jnp.int32)[None, :]  # [1, P]
    hidden = jnp.take(emb, toks, axis=0)
    positions = jnp.arange(p, dtype=jnp.int32)[None, :]
    plens = jnp.asarray([p], jnp.int32)

    k_caches, v_caches = [], []
    for l in range(cfg.n_layers):
        lw = {n: weights[f"layers.{l}.{n}"] for n in LAYER_WEIGHT_NAMES}
        x = rms_norm(hidden, lw["ln_attn"], cfg.rms_eps)
        q = (x @ lw["wq"]).reshape(1, p, cfg.n_heads, cfg.head_dim)
        k = (x @ lw["wk"]).reshape(1, p, cfg.n_heads, cfg.head_dim)
        v = (x @ lw["wv"]).reshape(1, p, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        attn = prefill_attention_ref(q, k, v, plens)
        hidden = hidden + attn.reshape(1, p, cfg.d_model) @ lw["wo"]
        x = rms_norm(hidden, lw["ln_ffn"], cfg.rms_eps)
        hidden = hidden + swiglu(x, lw["w_gate"], lw["w_up"], lw["w_down"])
        # Pad cache to max_seq_len.
        pad = cfg.max_seq_len - p
        k_caches.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
        v_caches.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))

    x = rms_norm(hidden[:, p - 1], ln_f, cfg.rms_eps)
    tok = int(jnp.argmax(x @ emb.T, axis=-1)[0])
    out = [tok]

    for step in range(n_steps - 1):
        pos = p + step
        if pos >= cfg.max_seq_len:
            break
        hid = jnp.take(emb, jnp.asarray([tok], jnp.int32), axis=0)  # [1, D]
        posarr = jnp.asarray([pos], jnp.int32)
        slens = jnp.asarray([pos + 1], jnp.int32)
        for l in range(cfg.n_layers):
            lw = {n: weights[f"layers.{l}.{n}"] for n in LAYER_WEIGHT_NAMES}
            q, k_new, v_new = layer_pre(
                cfg, hid, posarr, lw["ln_attn"], lw["wq"], lw["wk"], lw["wv"]
            )
            k_caches[l] = k_caches[l].at[0, pos].set(k_new[0])
            v_caches[l] = v_caches[l].at[0, pos].set(v_new[0])
            attn_out = decode_attention_ref(q, k_caches[l], v_caches[l], slens)
            attn_out = attn_out.reshape(1, cfg.d_model)
            (hid,) = layer_post(
                cfg, hid, attn_out,
                lw["wo"], lw["ln_ffn"], lw["w_gate"], lw["w_up"], lw["w_down"],
            )
        x = rms_norm(hid, ln_f, cfg.rms_eps)
        tok = int(jnp.argmax(x @ emb.T, axis=-1)[0])
        out.append(tok)
    return out
