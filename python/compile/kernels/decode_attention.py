"""L1 Pallas kernel: decode-phase attention over a padded KV cache.

This is the paper's offloadable unit — the memory-bound attention kernel
that Adrenaline disaggregates from the decoding instance and ships to the
attention executor colocated with the prefill instance. The exact same
lowered artifact is executed by BOTH the decode engine (local sub-batch)
and the attention executor (offloaded sub-batch); only the batch bucket
differs.

Structure (TPU adaptation of GPU flash-decoding, see DESIGN.md
§Hardware-Adaptation):

  * grid over the batch dimension — one program per request;
  * the KV sequence is streamed in BLOCK_S chunks (the HBM→VMEM schedule
    a CUDA kernel would express with threadblocks / cp.async);
  * an online-softmax running state (max, sum, acc) carried across chunks
    in f32 — the flash-decoding split-K reduction;
  * padding positions masked via iota-vs-seq_len comparison.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO. Real-TPU VMEM/MXU estimates
are recorded in DESIGN.md §9.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# KV chunk streamed per online-softmax step. On a real TPU this bounds the
# per-stage VMEM footprint: BLOCK_S * H * D * 4B (+ the running state),
# double-buffered by the pipeline.
DEFAULT_BLOCK_S = 32

_NEG_INF = -1e30  # finite "minus infinity": keeps padded-row math NaN-free


def _decode_attn_kernel(
    len_ref,  # [1] int32 in SMEM-style prefetch position (valid KV length)
    q_ref,  # [H, D]
    k_ref,  # [S, H, D]
    v_ref,  # [S, H, D]
    o_ref,  # [H, D]
    *,
    block_s: int,
):
    h, d = q_ref.shape
    s = k_ref.shape[0]
    seq_len = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))

    q = q_ref[...].astype(jnp.float32) * scale  # [H, D]

    n_blocks = pl.cdiv(s, block_s)

    def body(blk, carry):
        m_prev, l_prev, acc_prev = carry  # [H,1], [H,1], [H,D]
        start = blk * block_s
        k_blk = pl.load(k_ref, (pl.dslice(start, block_s), slice(None), slice(None)))
        v_blk = pl.load(v_ref, (pl.dslice(start, block_s), slice(None), slice(None)))
        k_blk = k_blk.astype(jnp.float32)  # [block_s, H, D]
        v_blk = v_blk.astype(jnp.float32)

        # scores[h, j] = q[h, :] . k_blk[j, h, :]
        scores = jnp.einsum("hd,jhd->hj", q, k_blk)  # [H, block_s]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
        mask = pos < seq_len  # [1, block_s]
        scores = jnp.where(mask, scores, _NEG_INF)

        m_blk = jnp.max(scores, axis=-1, keepdims=True)  # [H, 1]
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(scores - m_new)  # [H, block_s]
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)  # rescale of the old accumulator
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc_prev * alpha + jnp.einsum("hj,jhd->hd", p, v_blk)
        return m_new, l_new, acc_new

    m0 = jnp.full((h, 1), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((h, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((h, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))

    # seq_len >= 1 is a caller invariant (the current token's KV is always
    # written before attention), so l > 0.
    o_ref[...] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(
    q: jnp.ndarray,  # [B, H, D]
    k_cache: jnp.ndarray,  # [B, S, H, D]
    v_cache: jnp.ndarray,  # [B, S, H, D]
    seq_lens: jnp.ndarray,  # [B] int32
    *,
    block_s: int = DEFAULT_BLOCK_S,
) -> jnp.ndarray:  # [B, H, D]
    """Decode attention: one query token per request against its KV cache."""
    b, h, d = q.shape
    s = k_cache.shape[1]
    block_s = min(block_s, s)
    kernel = functools.partial(_decode_attn_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),  # seq_lens
            pl.BlockSpec((None, h, d), lambda i: (i, 0, 0)),  # q
            pl.BlockSpec((None, s, h, d), lambda i: (i, 0, 0, 0)),  # k
            pl.BlockSpec((None, s, h, d), lambda i: (i, 0, 0, 0)),  # v
        ],
        out_specs=pl.BlockSpec((None, h, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,
    )(seq_lens, q, k_cache, v_cache)
