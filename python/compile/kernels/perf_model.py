"""L1 performance model: VMEM footprint and MXU/roofline estimates for the
Pallas kernels' block configurations.

interpret=True wallclock on CPU is *not* a TPU proxy (DESIGN.md §9), so the
kernel optimization loop is structural: pick block shapes whose staged VMEM
footprint pipelines cleanly and whose contractions map onto the MXU, and
verify the arithmetic-intensity regime matches the paper's premises (the
decode kernel must stay memory-bound — that's what makes it offloadable).

Used by python/tests/test_perf_model.py and the numbers quoted in
EXPERIMENTS.md §Perf / DESIGN.md §9.
"""

from __future__ import annotations

import dataclasses

# TPU v4-ish reference numbers (per core), for ratio estimates only.
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128
HBM_BW = 1.2e12  # B/s
PEAK_BF16_FLOPS = 137.5e12


@dataclasses.dataclass(frozen=True)
class DecodeKernelConfig:
    """One decode-attention kernel instantiation."""

    batch: int
    n_heads: int
    head_dim: int
    max_seq: int
    block_s: int
    dtype_bytes: int = 4  # f32 on the CPU path; 2 on real TPU

    def vmem_per_stage(self) -> int:
        """Bytes staged in VMEM per grid step (one batch element):
        q + one (K, V) block + online-softmax state + accumulator."""
        hd = self.n_heads * self.head_dim
        q = hd * self.dtype_bytes
        kv_block = 2 * self.block_s * hd * self.dtype_bytes
        # m, l: [H, 1] f32; acc: [H, D] f32 (state is always f32).
        state = self.n_heads * (1 + 1 + self.head_dim) * 4
        return q + kv_block + state

    def vmem_double_buffered(self) -> int:
        """Pipelined footprint: two in-flight KV blocks."""
        hd = self.n_heads * self.head_dim
        return self.vmem_per_stage() + 2 * self.block_s * hd * self.dtype_bytes

    def vmem_fraction(self) -> float:
        return self.vmem_double_buffered() / VMEM_BYTES

    def flops(self, seq_len: int) -> float:
        """q·K^T + p·V over `seq_len` tokens, all heads."""
        return 4.0 * seq_len * self.n_heads * self.head_dim

    def hbm_bytes(self, seq_len: int) -> float:
        """KV traffic dominates: K and V read once."""
        return 2.0 * seq_len * self.n_heads * self.head_dim * self.dtype_bytes

    def arithmetic_intensity(self, seq_len: int) -> float:
        return self.flops(seq_len) / self.hbm_bytes(seq_len)

    def memory_bound(self, seq_len: int) -> bool:
        """The paper's premise: decode attention sits far left of the TPU
        roofline ridge (ridge ≈ PEAK/HBM_BW ≈ 115 FLOP/B)."""
        return self.arithmetic_intensity(seq_len) < PEAK_BF16_FLOPS / HBM_BW

    def mxu_tiles(self) -> tuple[float, float]:
        """How the two contractions tile onto the 128x128 MXU:
        (contracting-dim fill, output-dim fill), each in (0, 1]."""
        contracting = min(self.head_dim / MXU_DIM, 1.0)
        # Batched heads fold into the non-contracting axis.
        output = min(self.batch * self.n_heads / MXU_DIM, 1.0)
        return contracting, output

    def estimated_mxu_utilization(self) -> float:
        """Upper bound from tile fill alone (the memory-bound ceiling is
        far lower — see memory_bound)."""
        c, o = self.mxu_tiles()
        return c * o


def tiny_model_config(block_s: int = 32) -> DecodeKernelConfig:
    return DecodeKernelConfig(batch=8, n_heads=4, head_dim=16, max_seq=128, block_s=block_s)


def llama7b_config(block_s: int = 128) -> DecodeKernelConfig:
    return DecodeKernelConfig(
        batch=64, n_heads=32, head_dim=128, max_seq=4096, block_s=block_s, dtype_bytes=2
    )


def report(cfg: DecodeKernelConfig, seq_len: int) -> dict:
    return {
        "vmem_per_stage_bytes": cfg.vmem_per_stage(),
        "vmem_double_buffered_bytes": cfg.vmem_double_buffered(),
        "vmem_fraction": cfg.vmem_fraction(),
        "arithmetic_intensity": cfg.arithmetic_intensity(seq_len),
        "memory_bound": cfg.memory_bound(seq_len),
        "mxu_tile_fill": cfg.mxu_tiles(),
        "mxu_utilization_bound": cfg.estimated_mxu_utilization(),
    }


if __name__ == "__main__":
    import json

    print("tiny (CPU path):", json.dumps(report(tiny_model_config(), 128), indent=2))
    print("llama-2 7B shape:", json.dumps(report(llama7b_config(), 1024), indent=2))
