"""L1 extension: grouped-query-attention (GQA) decode kernel.

The paper evaluates MHA models (Llama-2 7B/13B), but every serving
framework built on this technique must also handle GQA (Llama-3, Mistral,
Qwen): fewer KV heads than query heads means a *smaller* KV cache and a
*higher* arithmetic intensity per KV byte — which shifts the paper's
offloading arithmetic (the attention kernel stays memory-bound, but
`OB_mem`'s per-token KV cost drops by the group factor).

Same structure as decode_attention.py (grid over batch, online softmax,
BLOCK_S-chunked KV streaming); the query heads are grouped so every KV
head's block is loaded once and shared by its `group` query heads — the
TPU analogue of GQA's warp-level KV reuse on GPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 32
_NEG_INF = -1e30


def _gqa_decode_kernel(
    len_ref,  # [1] int32
    q_ref,  # [Hq, D]
    k_ref,  # [S, Hkv, D]
    v_ref,  # [S, Hkv, D]
    o_ref,  # [Hq, D]
    *,
    block_s: int,
    group: int,
):
    hq, d = q_ref.shape
    s, hkv, _ = k_ref.shape
    seq_len = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))

    # Fold query heads into (Hkv, group, D): every KV head serves `group`
    # query heads from one loaded block.
    q = q_ref[...].astype(jnp.float32).reshape(hkv, group, d) * scale

    n_blocks = pl.cdiv(s, block_s)

    def body(blk, carry):
        m_prev, l_prev, acc_prev = carry  # [Hkv, G, 1], [Hkv, G, 1], [Hkv, G, D]
        start = blk * block_s
        k_blk = pl.load(k_ref, (pl.dslice(start, block_s), slice(None), slice(None)))
        v_blk = pl.load(v_ref, (pl.dslice(start, block_s), slice(None), slice(None)))
        k_blk = k_blk.astype(jnp.float32)  # [block_s, Hkv, D]
        v_blk = v_blk.astype(jnp.float32)

        # scores[h, g, j] = q[h, g, :] . k_blk[j, h, :]
        scores = jnp.einsum("hgd,jhd->hgj", q, k_blk)  # [Hkv, G, block_s]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_s), 2)
        mask = pos < seq_len
        scores = jnp.where(mask, scores, _NEG_INF)

        m_blk = jnp.max(scores, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(scores - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc_prev * alpha + jnp.einsum("hgj,jhd->hgd", p, v_blk)
        return m_new, l_new, acc_new

    m0 = jnp.full((hkv, group, 1), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((hkv, group, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((hkv, group, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))

    o_ref[...] = (acc / l).reshape(hq, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s",))
def gqa_decode_attention(
    q: jnp.ndarray,  # [B, Hq, D]
    k_cache: jnp.ndarray,  # [B, S, Hkv, D]
    v_cache: jnp.ndarray,  # [B, S, Hkv, D]
    seq_lens: jnp.ndarray,  # [B] int32
    *,
    block_s: int = DEFAULT_BLOCK_S,
) -> jnp.ndarray:  # [B, Hq, D]
    """GQA decode attention: Hq query heads share Hkv KV heads."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0, f"query heads {hq} must be a multiple of kv heads {hkv}"
    group = hq // hkv
    block_s = min(block_s, s)
    kernel = functools.partial(_gqa_decode_kernel, block_s=block_s, group=group)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((None, hq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, hkv, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((None, s, hkv, d), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, hq, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=True,
    )(seq_lens, q, k_cache, v_cache)


def gqa_decode_attention_ref(
    q: jnp.ndarray,  # [B, Hq, D]
    k_cache: jnp.ndarray,  # [B, S, Hkv, D]
    v_cache: jnp.ndarray,  # [B, S, Hkv, D]
    seq_lens: jnp.ndarray,  # [B] int32
) -> jnp.ndarray:
    """Oracle: expand KV heads to query heads, then plain masked softmax."""
    b, hq, d = q.shape
    hkv = k_cache.shape[2]
    group = hq // hkv
    k_full = jnp.repeat(k_cache, group, axis=2)  # [B, S, Hq, D]
    v_full = jnp.repeat(v_cache, group, axis=2)
    from compile.kernels.ref import decode_attention_ref

    return decode_attention_ref(q, k_full, v_full, seq_lens)
