"""L1 Pallas kernel: prefill-phase causal (flash) attention.

The compute-bound half of the paper's workload split: all prompt tokens
attend causally in parallel. Flash-attention structure adapted for TPU
(DESIGN.md §Hardware-Adaptation):

  * grid over (batch, head, query-row block) — the threadblock tiling of
    the CUDA original becomes BlockSpec index maps;
  * KV streamed in BLOCK_K chunks with an online-softmax running state;
  * the causal structure prunes KV chunks entirely above the diagonal
    (chunk start > query-block end ⇒ skipped by the fori_loop bound);
  * padded keys (j >= prompt_len) masked; padded query rows forced to
    attend to position 0 so outputs stay finite (callers discard them).

interpret=True for CPU-PJRT execution (see decode_attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32

_NEG_INF = -1e30


def _prefill_attn_kernel(
    len_ref,  # [1] int32 (valid prompt length for this batch element)
    q_ref,  # [BLOCK_Q, D]
    k_ref,  # [P, D]
    v_ref,  # [P, D]
    o_ref,  # [BLOCK_Q, D]
    *,
    block_q: int,
    block_k: int,
    p_total: int,
):
    d = q_ref.shape[-1]
    qblk = pl.program_id(2)
    prompt_len = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))

    q = q_ref[...].astype(jnp.float32) * scale  # [BLOCK_Q, D]
    q_pos = qblk * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    # Causality: query row i only sees keys j <= i, so KV chunks strictly
    # beyond this query block's last row are pruned from the loop bound.
    n_kv_blocks = jnp.minimum(
        pl.cdiv(p_total, block_k),
        pl.cdiv((qblk + 1) * block_q, block_k),
    )

    def body(blk, carry):
        m_prev, l_prev, acc_prev = carry
        start = blk * block_k
        k_blk = pl.load(k_ref, (pl.dslice(start, block_k), slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.dslice(start, block_k), slice(None))).astype(jnp.float32)

        scores = q @ k_blk.T  # [BLOCK_Q, BLOCK_K]
        k_pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = (k_pos <= q_pos) & (k_pos < prompt_len)
        # Keep j == 0 open for every row: padded/degenerate rows stay finite.
        mask = mask | (k_pos == 0)
        scores = jnp.where(mask, scores, _NEG_INF)

        m_blk = jnp.max(scores, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(scores - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc_prev * alpha + p @ v_blk
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, 1), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv_blocks, body, (m0, l0, acc0))

    o_ref[...] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def prefill_attention(
    q: jnp.ndarray,  # [B, P, H, D]
    k: jnp.ndarray,  # [B, P, H, D]
    v: jnp.ndarray,  # [B, P, H, D]
    prompt_lens: jnp.ndarray,  # [B] int32
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:  # [B, P, H, D]
    """Causal prefill attention over a padded prompt batch."""
    b, p, h, d = q.shape
    block_q = min(block_q, p)
    block_k = min(block_k, p)
    kernel = functools.partial(
        _prefill_attn_kernel, block_q=block_q, block_k=block_k, p_total=p
    )
    grid = (b, h, pl.cdiv(p, block_q))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, qi: (bi,)),  # prompt_lens
            pl.BlockSpec((None, block_q, None, d), lambda bi, hi, qi: (bi, qi, hi, 0)),
            pl.BlockSpec((None, p, None, d), lambda bi, hi, qi: (bi, 0, hi, 0)),
            pl.BlockSpec((None, p, None, d), lambda bi, hi, qi: (bi, 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, block_q, None, d), lambda bi, hi, qi: (bi, qi, hi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, p, h, d), q.dtype),
        interpret=True,
    )(prompt_lens, q, k, v)
