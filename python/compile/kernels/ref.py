"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest/hypothesis suites compare against:
straightforward, numerically-stable softmax attention with explicit masking,
written with no regard for performance. Anything the Pallas kernels (or the
lowered HLO artifacts) produce must match these within tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,  # [B, H, D]
    k_cache: jnp.ndarray,  # [B, S, H, D]
    v_cache: jnp.ndarray,  # [B, S, H, D]
    seq_lens: jnp.ndarray,  # [B] int32, number of valid KV entries per request
) -> jnp.ndarray:  # [B, H, D]
    """Single-token decode attention over a (padded) KV cache.

    Positions >= seq_lens[b] are padding and must not contribute.
    """
    b, s, h, d = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    # scores[b, h, s] = q[b, h, :] . k_cache[b, s, h, :]
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k_cache.astype(jnp.float32))
    scores = scores * scale
    mask = jnp.arange(s)[None, None, :] < seq_lens[:, None, None]  # [B, 1, S]
    scores = jnp.where(mask, scores, -jnp.inf)
    # Stable softmax; rows with zero valid entries are undefined — callers
    # must pass seq_lens >= 1 (a decode step always has at least the token
    # written in this step).
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    attn = p / denom
    out = jnp.einsum("bhs,bshd->bhd", attn, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def prefill_attention_ref(
    q: jnp.ndarray,  # [B, P, H, D]
    k: jnp.ndarray,  # [B, P, H, D]
    v: jnp.ndarray,  # [B, P, H, D]
    prompt_lens: jnp.ndarray,  # [B] int32, valid prompt length per request
) -> jnp.ndarray:  # [B, P, H, D]
    """Causal self-attention over a (padded) prompt batch.

    Token i attends to tokens j <= i, and only where j < prompt_lens[b].
    Rows beyond prompt_lens produce garbage that callers discard, but they
    must still be finite (we force them to attend to position 0).
    """
    b, p, h, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    scores = jnp.einsum("bihd,bjhd->bhij", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    i = jnp.arange(p)[:, None]
    j = jnp.arange(p)[None, :]
    causal = j <= i  # [P, P]
    valid = jnp.arange(p)[None, :] < prompt_lens[:, None]  # [B, P] (keys)
    mask = causal[None, None, :, :] & valid[:, None, None, :]
    # Guarantee every row has at least one unmasked entry (j == 0) so padded
    # rows stay finite.
    mask = mask.at[:, :, :, 0].set(True)
    scores = jnp.where(mask, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)
    pexp = jnp.exp(scores - m)
    pexp = jnp.where(mask, pexp, 0.0)
    denom = jnp.sum(pexp, axis=-1, keepdims=True)
    attn = pexp / denom
    out = jnp.einsum("bhij,bjhd->bihd", attn, v.astype(jnp.float32))
    return out.astype(q.dtype)


def merge_attention_ref(
    out_a: jnp.ndarray,  # [B, H, D] partial attention output over KV range A
    lse_a: jnp.ndarray,  # [B, H] log-sum-exp of range A
    out_b: jnp.ndarray,  # [B, H, D]
    lse_b: jnp.ndarray,  # [B, H]
) -> jnp.ndarray:
    """Flash-decoding split-KV merge: combine two partial softmax results.

    Used to validate the kernel's online-softmax chunk merge and (in the
    serving system) the local/offloaded attention output merge semantics.
    """
    m = jnp.maximum(lse_a, lse_b)
    wa = jnp.exp(lse_a - m)[..., None]
    wb = jnp.exp(lse_b - m)[..., None]
    return (out_a * wa + out_b * wb) / (wa + wb)
