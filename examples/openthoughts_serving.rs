//! OpenThoughts (reasoning) serving at A100 scale (Figs 13/14): long
//! chain-of-thought outputs exhaust the decode instance's KV pool, forcing
//! vLLM-style preemption; Adrenaline absorbs the KV growth in the prefill
//! instances' spare HBM.
//!
//!     cargo run --release --example openthoughts_serving

use adrenaline::config::ModelSpec;
use adrenaline::sim::{run_e2e_with, E2eConfig, ExecMode};

fn main() {
    for (label, cfg) in [
        ("Fig 13: OpenThoughts + Llama-2 7B", E2eConfig::fig13()),
        ("Fig 14: OpenThoughts + Llama-2 13B", E2eConfig { model: ModelSpec::llama2_13b(), ..E2eConfig::fig13() }),
    ] {
        println!("== {label} ==\n");
        println!(
            "{:>6} {:>12} {:>12} {:>12} {:>12} {:>14} {:>8}",
            "rate", "system", "TTFT(s)", "TPOT(ms)", "P99(ms)", "tput(tok/s)", "preempt"
        );
        let pts = run_e2e_with(&cfg, ExecMode::Parallel);
        for p in &pts {
            println!(
                "{:>6.1} {:>12} {:>12.3} {:>12.2} {:>12.2} {:>14.0} {:>8}",
                p.rate,
                p.system,
                p.ttft_mean_s,
                p.tpot_mean_s * 1e3,
                p.tpot_p99_s * 1e3,
                p.throughput_tok_s,
                p.preemptions
            );
        }

        // Paper anchors: 26.9–29.5% mean-TPOT reduction (7B), 1.60–1.66x
        // throughput, large P99 cuts from preemption mitigation.
        let mut tpot_cut = 0.0f64;
        let mut tput_up = 0.0f64;
        for &rate in &cfg.rates {
            let b = pts.iter().find(|p| p.rate == rate && p.system == "vllm").unwrap();
            let a = pts.iter().find(|p| p.rate == rate && p.system == "adrenaline").unwrap();
            if b.tpot_mean_s > 0.0 {
                tpot_cut = tpot_cut.max(1.0 - a.tpot_mean_s / b.tpot_mean_s);
            }
            if b.throughput_tok_s > 0.0 {
                tput_up = tput_up.max(a.throughput_tok_s / b.throughput_tok_s);
            }
        }
        println!(
            "\nmax mean-TPOT reduction: {:.1}%   max throughput speedup: {:.2}x\n",
            tpot_cut * 100.0,
            tput_up
        );
    }
}
