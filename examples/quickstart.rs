//! Quickstart — the END-TO-END driver: load the AOT-compiled tiny-Llama
//! artifacts, stand up the full disaggregated serving stack (proxy,
//! prefill instance with colocated attention executor on its own thread,
//! decode engine), serve a batch of requests with Algorithm-1 offloading,
//! and report latency/throughput.
//!
//! Everything here is the REAL request path: PJRT executables compiled
//! from the Pallas/JAX artifacts, per-layer attention disaggregation over
//! channels, exact token-level results (see rust/tests/e2e_serving.rs for
//! the oracle check). Python is not involved.
//!
//!     make artifacts && cargo run --release --example quickstart

use adrenaline::config::ServingConfig;
use adrenaline::engine::Server;
use adrenaline::runtime::Manifest;
use adrenaline::workload::{TraceGenerator, WorkloadKind};

fn main() -> adrenaline::Result<()> {
    let dir = Manifest::default_dir();
    println!("== Adrenaline quickstart ==");
    println!("artifacts: {}", dir.display());

    // 1) Stand up the stack. Each instance thread owns its own PJRT CPU
    //    client — the process analogue of the paper's separate GPU pools.
    //    The builder validates the knob combination up front (a bad grid
    //    or contradictory engine switches fail here, not mid-serve);
    //    builder defaults equal `ServingConfig::default()`.
    let serving = ServingConfig::builder().build()?;
    let t0 = std::time::Instant::now();
    let mut server = Server::start(&dir, serving)?;
    println!("stack up in {:.2}s (artifact grid compiled on both instances)", t0.elapsed().as_secs_f64());

    // 2) A small chatbot-like workload, clipped to the tiny model's
    //    128-token context.
    let mut gen =
        TraceGenerator::new(WorkloadKind::ShareGpt, 8.0, 2024).with_clip((4, 48), (2, 40));
    let reqs = gen.take(12);
    let reqs = gen.with_tokens(reqs, 256);

    // 3) Serve. The proxy's Algorithm 1 decides which requests' decode
    //    attention is disaggregated to the prefill instance.
    let report = server.run_requests(&reqs, None)?;

    println!("\n-- completions --");
    for c in &report.completions {
        println!(
            "request {:>2}  attention={}  {:>2} tokens  head: {:?}",
            c.id,
            if c.offloaded { "offloaded" } else { "local   " },
            c.tokens.len(),
            &c.tokens[..c.tokens.len().min(6)]
        );
    }

    let ttft = report.metrics.ttft_stats().expect("requests ran");
    let tpot = report.metrics.tpot_stats().expect("tokens decoded");
    let total_tokens = report.metrics.total_output_tokens();
    println!("\n-- report --");
    println!("requests          {}", report.completions.len());
    println!("offloaded         {}", report.offloaded_requests);
    println!("decode steps      {} ({} fused fast-path)", report.decode_steps, report.fused_steps);
    println!("TTFT   mean {:>8.2} ms   p99 {:>8.2} ms", ttft.mean * 1e3, ttft.p99 * 1e3);
    println!("TPOT   mean {:>8.2} ms   p99 {:>8.2} ms", tpot.mean * 1e3, tpot.p99 * 1e3);
    println!(
        "output throughput {:.1} tok/s over {:.2}s wall",
        total_tokens as f64 / report.wall_s,
        report.wall_s
    );
    println!("\nAll three layers composed: Pallas kernel -> JAX artifact -> Rust coordinator.");
    Ok(())
}
