//! ShareGPT serving at A100 scale (Fig 11): sweep request rates for the
//! vLLM-style PD-disaggregation baseline and Adrenaline, print the four
//! panels (TTFT / TPOT / P99 TPOT / output throughput).
//!
//!     cargo run --release --example sharegpt_serving

use adrenaline::sim::{run_e2e_with, E2eConfig, ExecMode};

fn main() {
    let cfg = E2eConfig {
        // This testbed's saturating range (the paper's stack saturates
        // near 4 req/s; our roofline decode steps are faster, so the
        // crossover lands at higher rates — shapes, not absolutes).
        rates: vec![8.0, 12.0, 16.0, 20.0, 24.0, 28.0],
        duration_s: 120.0,
        ..E2eConfig::fig11()
    };
    println!("== Fig 11: ShareGPT + Llama-2 7B (one prefill + one decode A100) ==\n");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>14} {:>8} {:>9}",
        "rate", "system", "TTFT(s)", "TPOT(ms)", "P99(ms)", "tput(tok/s)", "preempt", "offload"
    );
    let pts = run_e2e_with(&cfg, ExecMode::Parallel);
    for p in &pts {
        println!(
            "{:>6.1} {:>12} {:>12.3} {:>12.2} {:>12.2} {:>14.0} {:>8} {:>9.2}",
            p.rate,
            p.system,
            p.ttft_mean_s,
            p.tpot_mean_s * 1e3,
            p.tpot_p99_s * 1e3,
            p.throughput_tok_s,
            p.preemptions,
            p.offloaded_fraction
        );
    }

    // Headline: the paper reports up to 1.47x output-token throughput for
    // 7B ShareGPT. Print our measured max speedup across the sweep.
    let mut best = 0.0f64;
    for rate in cfg.rates {
        let b = pts.iter().find(|p| p.rate == rate && p.system == "vllm").unwrap();
        let a = pts.iter().find(|p| p.rate == rate && p.system == "adrenaline").unwrap();
        best = best.max(a.throughput_tok_s / b.throughput_tok_s);
    }
    println!("\nmax throughput speedup across sweep: {best:.2}x (paper: up to 1.47x)");
}
