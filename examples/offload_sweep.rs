//! Offload-ratio selection (Figs 15/17): sweep fixed offload ratios and
//! show the inflection the paper's load-aware scheduler finds
//! automatically, plus the resource-utilization panels.
//!
//!     cargo run --release --example offload_sweep

use adrenaline::config::{ClusterSpec, ModelSpec, SloConfig};
use adrenaline::coordinator::OffloadBounds;
use adrenaline::sim::{run_ratio_sweep_with, ExecMode};
use adrenaline::workload::WorkloadKind;

fn main() {
    let model = ModelSpec::llama2_7b();
    let rate = 24.0;
    let ratios = [0.0, 0.2, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];
    println!("== Fig 15: ShareGPT + Llama-2 7B, fixed offload-ratio sweep (rate {rate}/s) ==\n");
    println!(
        "{:>7} {:>14} {:>12} {:>12} {:>14} {:>14} {:>8}",
        "ratio", "tput(tok/s)", "TPOT(ms)", "TTFT(s)", "prefill-bw", "decode-comp", "preempt"
    );
    let pts = run_ratio_sweep_with(
        model,
        WorkloadKind::ShareGpt,
        rate,
        &ratios,
        120.0,
        ExecMode::Parallel,
    );
    let mut best = (0.0, 0.0);
    for (ratio, r) in &pts {
        println!(
            "{:>7.1} {:>14.0} {:>12.2} {:>12.3} {:>14.3} {:>14.3} {:>8}",
            ratio,
            r.throughput,
            r.tpot.map(|s| s.mean * 1e3).unwrap_or(f64::NAN),
            r.ttft.map(|s| s.mean).unwrap_or(f64::NAN),
            r.prefill_hbm_bw_util,
            r.decode_compute_util,
            r.preemptions
        );
        if r.throughput > best.1 {
            best = (*ratio, r.throughput);
        }
    }
    println!(
        "\nthroughput inflection at ratio {:.1} (paper: ~0.7 for ShareGPT; beyond it the \
         executor's attention time exceeds the local overlap window)",
        best.0
    );

    // What Algorithm 1 derives analytically (the automatic alternative to
    // this offline sweep):
    let b = OffloadBounds::compute(&ClusterSpec::paper_default(), &model, &SloConfig::default(), 1024);
    println!(
        "load-aware bound: OB_mem={:.2} OB_comp={:.2} -> OB={:.2} (offloaded:local token ratio)",
        b.ob_mem,
        b.ob_comp(),
        b.ob()
    );
}
