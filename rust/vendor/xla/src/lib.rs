//! Compile-only stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links the XLA extension's PJRT CPU client, which is not
//! available in this offline build environment. This stub keeps the exact
//! API surface `runtime/engine.rs` consumes so the crate builds and the
//! simulator / analytical paths (which never touch PJRT) run normally.
//!
//! Behavior contract:
//! * [`Literal`] is fully functional host-side (shape/size-checked
//!   construction from untyped bytes, typed readback) — unit tests over
//!   literal plumbing pass against the stub.
//! * Everything that would require the PJRT runtime
//!   ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`], executable
//!   execution) returns [`Error::Unavailable`] with a pointer here. The
//!   serving entry points already gate on `make artifacts` having run, so
//!   tests and benches skip rather than fail.

use std::fmt;

/// Errors surfaced by the stub.
#[derive(Debug)]
pub enum Error {
    /// The operation needs the real PJRT runtime.
    Unavailable(&'static str),
    /// Host-side literal plumbing was misused.
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what} requires the real PJRT runtime; this build uses the \
                 vendored `xla` stub (rust/vendor/xla)"
            ),
            Error::Literal(msg) => write!(f, "literal error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the engines use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(&self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Sealed-ish marker for element types readable out of a [`Literal`].
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_ne_bytes4(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_ne_bytes4(b: [u8; 4]) -> Self {
        f32::from_ne_bytes(b)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_ne_bytes4(b: [u8; 4]) -> Self {
        i32::from_ne_bytes(b)
    }
}

/// A host-side tensor value (shape + raw bytes).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Build a literal straight from shaped bytes (single-copy upload in
    /// the real crate; here a plain size-checked copy).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        let expect = numel * ty.byte_size();
        if expect != data.len() {
            return Err(Error::Literal(format!(
                "shape {dims:?} ({ty:?}) wants {expect} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Typed readback of the literal's contents.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error::Literal(format!(
                "literal is {:?}, asked for {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_ne_bytes4([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Split a tuple literal into its elements. Tuple literals only come
    /// out of executable execution, which the stub cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("tuple literal readback"))
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<Self> {
        Err(Error::Unavailable("HLO parsing"))
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("buffer readback"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("executable execution"))
    }
}

/// The process-level PJRT client.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("the PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("executable compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        let err =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &[0u8; 8])
                .unwrap_err();
        assert!(err.to_string().contains("wants 12 bytes"));
    }

    #[test]
    fn literal_dtype_mismatch_rejected() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[0u8; 4]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![0]);
    }

    #[test]
    fn runtime_paths_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"), "got: {msg}");
    }
}
