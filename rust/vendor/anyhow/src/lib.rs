//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The container this repository builds in has no crate registry, so the
//! tiny subset of `anyhow` the codebase uses is implemented here:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion to exist without overlapping
//! impls.

use std::fmt;

/// A type-erased error: any `std::error::Error + Send + Sync` or an ad-hoc
/// message built by [`anyhow!`].
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

impl Error {
    /// Wrap a concrete error.
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }

    /// Build an error from a display-able message.
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display,
    {
        Error(Box::new(MessageError(message.to_string())))
    }

    /// Macro plumbing for `anyhow!` (kept separate from `msg` so the
    /// macros expand to a single concrete call).
    #[doc(hidden)]
    pub fn from_message(message: String) -> Self {
        Error(Box::new(MessageError(message)))
    }

    /// The root cause as a `std::error::Error` trait object.
    pub fn root_cause(&self) -> &(dyn std::error::Error + 'static) {
        let mut cause: &(dyn std::error::Error + 'static) = &*self.0;
        while let Some(next) = cause.source() {
            cause = next;
        }
        cause
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Ad-hoc message error (what `anyhow!("...")` produces).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for MessageError {}

/// Construct an [`Error`] from a message, a format string, or another
/// display-able value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::from_message(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::from_message(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::from_message(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn macros_build_messages() {
        let key = "decode_buckets";
        let e = anyhow!("bad bucket in {key}");
        assert_eq!(e.to_string(), "bad bucket in decode_buckets");
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(e.to_string(), "1 + 2");
        let e = anyhow!(io_err());
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("lucky numbers rejected");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(7).unwrap_err().to_string().contains("lucky"));
    }

    #[test]
    fn debug_includes_message() {
        let e = Error::msg("top level");
        assert!(format!("{e:?}").contains("top level"));
        assert_eq!(e.root_cause().to_string(), "top level");
    }
}
