//! Fleet × faults cross-matrix (ISSUE 10).
//!
//! PR 6 built the per-group fault plane and PR 8 the fleet layer; this
//! suite pins their composition — the fleet health plane, cross-group
//! failover, and overload admission control — from four directions:
//!
//! * **Structural inertness** — `FleetConfig::overload` armed with an
//!   unreachable budget never sheds, never retries, and leaves every
//!   routing decision and request count exactly as the plain PR 8/9
//!   fleet produced them, under every router policy.
//! * **Graceful ≥ naive** — on a scripted group-0 prefill crash, the
//!   health-aware fleet (masked routing + failover + shedding) beats the
//!   health-blind baseline on shed-aware goodput: strictly for the
//!   pre-partitioned policies (round-robin, session-sticky), whose naive
//!   runs strand every post-crash arrival assigned to the dead group,
//!   and no worse for least-loaded.
//! * **Conservation** — no request is ever lost: exports equal
//!   re-injections, `finished + shed` accounts for every arrival, and
//!   every group's token ledger stays conserved through export/inject.
//! * **Engine composition** — the faulted health-aware fleet replays
//!   bit-identically across decode-leap and within-run-parallelism
//!   modes (CI re-runs this suite under `ADRENALINE_NO_LEAP=1` and
//!   `ADRENALINE_NO_PAR=1`).

use adrenaline::config::{
    FaultConfig, FaultKind, FleetConfig, ModelSpec, OverloadConfig, RouterPolicy, ScriptedFault,
};
use adrenaline::metrics::{LatencyStats, Timeline};
use adrenaline::sim::{parallel_map, FleetReport, FleetSim, SimConfig, SimReport};
use adrenaline::workload::WorkloadKind;

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn assert_timeline_eq(name: &str, a: &Timeline, b: &Timeline) {
    assert_eq!(a.len(), b.len(), "{name}: timeline lengths differ");
    for (i, (pa, pb)) in a.points().iter().zip(b.points()).enumerate() {
        assert!(
            feq(pa.0, pb.0) && feq(pa.1, pb.1),
            "{name}[{i}]: {pa:?} vs {pb:?}"
        );
    }
}

fn assert_stats_eq(name: &str, a: &Option<LatencyStats>, b: &Option<LatencyStats>) {
    match (a, b) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count, "{name} count");
            assert!(feq(x.mean, y.mean), "{name} mean: {} vs {}", x.mean, y.mean);
            assert!(feq(x.p50, y.p50), "{name} p50");
            assert!(feq(x.p99, y.p99), "{name} p99");
            assert!(feq(x.max, y.max), "{name} max");
        }
        (None, None) => {}
        _ => panic!("{name} presence differs"),
    }
}

/// Full per-group bitwise equality, fault/export fields included. Both
/// sides of every pairing here take the same engine path, so even
/// `events_processed` must match.
fn assert_group_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.tokens_conserved, b.tokens_conserved);
    assert_eq!(a.steps_simulated, b.steps_simulated, "step counts must agree");
    assert_eq!(a.events_processed, b.events_processed, "event counts must agree");
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert_eq!(a.requests_slo_met, b.requests_slo_met);
    assert_eq!(a.slo_met_tokens, b.slo_met_tokens);
    assert!(feq(a.sim_end_s, b.sim_end_s), "{} vs {}", a.sim_end_s, b.sim_end_s);
    assert_stats_eq("ttft", &a.ttft, &b.ttft);
    assert_stats_eq("tpot", &a.tpot, &b.tpot);
    assert_timeline_eq("decode_occupancy", &a.decode_occupancy, &b.decode_occupancy);
    assert_timeline_eq("batch_size", &a.batch_size, &b.batch_size);
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.requests_recovered, b.requests_recovered);
    assert_eq!(a.recompute_tokens_replayed, b.recompute_tokens_replayed);
    assert_eq!(a.requests_exported, b.requests_exported);
    assert!(feq(a.degraded_time_s, b.degraded_time_s));
    assert_timeline_eq("health", &a.health_timeline, &b.health_timeline);
}

/// Leap-contract variant: identical physics, `events_processed` allowed
/// to shrink on the leap side `a`.
fn assert_group_leap_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.tokens_conserved, b.tokens_conserved);
    assert_eq!(a.steps_simulated, b.steps_simulated, "step counts must agree");
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert_eq!(a.requests_slo_met, b.requests_slo_met);
    assert_eq!(a.slo_met_tokens, b.slo_met_tokens);
    assert!(feq(a.sim_end_s, b.sim_end_s), "{} vs {}", a.sim_end_s, b.sim_end_s);
    assert_stats_eq("ttft", &a.ttft, &b.ttft);
    assert_stats_eq("tpot", &a.tpot, &b.tpot);
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.requests_recovered, b.requests_recovered);
    assert_eq!(a.requests_exported, b.requests_exported);
    assert_timeline_eq("health", &a.health_timeline, &b.health_timeline);
    assert!(
        a.events_processed <= b.events_processed,
        "leaping must never add events: {} vs {}",
        a.events_processed,
        b.events_processed
    );
}

/// The fleet-level fault counters and availability timelines must agree
/// across engine modes too.
fn assert_fleet_fault_fields_eq(a: &FleetReport, b: &FleetReport) {
    assert_eq!(a.router_decisions, b.router_decisions);
    assert_eq!(a.router_reroutes, b.router_reroutes);
    assert_eq!(a.requests_shed, b.requests_shed);
    assert_eq!(a.requests_failed_over, b.requests_failed_over);
    assert_eq!(a.retries, b.retries);
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert!(feq(a.fleet_slo_attainment, b.fleet_slo_attainment));
    assert!(feq(a.fleet_goodput_shed_aware, b.fleet_goodput_shed_aware));
    assert_eq!(a.availability.len(), b.availability.len());
    for (i, (ta, tb)) in a.availability.iter().zip(&b.availability).enumerate() {
        assert_timeline_eq(&format!("availability[{i}]"), ta, tb);
    }
}

fn base_cfg(rate: f64, duration_s: f64) -> SimConfig {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, rate);
    cfg.duration_s = duration_s;
    cfg
}

/// Kill group 0's entire (single-instance) prefill pool at `at_s` for
/// `down_s` seconds.
fn group0_crash(at_s: f64, down_s: f64, health_aware: bool) -> FaultConfig {
    FaultConfig {
        script: vec![ScriptedFault {
            kind: FaultKind::PrefillCrash,
            instance: 0,
            at_s,
            down_s,
            group: Some(0),
        }],
        health_aware,
        ..FaultConfig::default()
    }
}

const POLICIES: [RouterPolicy; 3] =
    [RouterPolicy::RoundRobin, RouterPolicy::SessionSticky, RouterPolicy::LeastLoaded];

#[test]
fn unreachable_overload_budget_is_inert_under_every_policy() {
    // An armed admission controller whose budget can never be exceeded
    // must change nothing observable: no sheds, no retries, identical
    // routing and request counts vs the plain fleet — even though it
    // forces the pre-partitioned policies onto the lockstep path.
    for router in POLICIES {
        let mut plain_cfg = base_cfg(12.0, 25.0);
        plain_cfg.serving.fleet =
            Some(FleetConfig { groups: 2, router, ..FleetConfig::default() });
        let mut armed_cfg = plain_cfg.clone();
        armed_cfg.serving.fleet = Some(FleetConfig {
            groups: 2,
            router,
            overload: Some(OverloadConfig { ttft_budget_s: 1e12, ..OverloadConfig::default() }),
            ..FleetConfig::default()
        });
        let plain = FleetSim::new(plain_cfg).run();
        let armed = FleetSim::new(armed_cfg.clone()).run();
        // The plain fleet predates the fault plane: all new counters stay
        // zeroed (the `overload: None` inertness contract).
        assert_eq!(plain.requests_shed, 0, "{}", router.name());
        assert_eq!(plain.requests_failed_over, 0);
        assert_eq!(plain.retries, 0);
        assert_eq!(plain.router_reroutes, 0);
        assert!(plain.availability.is_empty());
        // The unreachable budget admits everything, first try.
        assert_eq!(armed.requests_shed, 0, "{}", router.name());
        assert_eq!(armed.retries, 0);
        assert_eq!(armed.requests_failed_over, 0);
        assert_eq!(armed.router_reroutes, 0);
        // Routing and request accounting are unperturbed. (Physics are
        // not bitwise-comparable for the static policies — the lockstep
        // build prices offload bounds from the shared trace rather than
        // each partition slice — but every placement decision is.)
        assert_eq!(armed.router_decisions, plain.router_decisions, "{}", router.name());
        assert_eq!(armed.arrived, plain.arrived);
        assert_eq!(armed.finished, plain.finished);
        assert_eq!(armed.finished, armed.arrived, "everything must drain");
        for (ga, gb) in armed.groups.iter().zip(&plain.groups) {
            assert_eq!(ga.arrived, gb.arrived);
            assert_eq!(ga.finished, gb.finished);
            assert!(ga.tokens_conserved && gb.tokens_conserved);
        }
        // And the armed path replays bit-identically run over run.
        let mut runs: Vec<FleetReport> =
            parallel_map(2, |_| FleetSim::new(armed_cfg.clone()).run());
        let rb = runs.pop().expect("two runs");
        let ra = runs.pop().expect("two runs");
        assert_fleet_fault_fields_eq(&ra, &rb);
        for (ga, gb) in ra.groups.iter().zip(&rb.groups) {
            assert_group_identical(ga, gb);
        }
    }
}

#[test]
fn group_crash_graceful_beats_naive_under_every_policy() {
    // Scripted group-0 prefill crash at t=10s that outlives the 40s
    // arrival window (recovery at t=70s). The naive baseline keeps its
    // health-blind routing — the pre-partitioned policies strand every
    // post-crash group-0 arrival until recovery, a guaranteed TTFT-SLO
    // miss. The graceful fleet masks the dead group, fails its queue
    // over, and sheds what no group can serve in budget.
    for router in POLICIES {
        let mut naive_cfg = base_cfg(12.0, 40.0);
        naive_cfg.serving.fault = Some(group0_crash(10.0, 60.0, false));
        naive_cfg.serving.fleet =
            Some(FleetConfig { groups: 2, router, ..FleetConfig::default() });
        let mut graceful_cfg = naive_cfg.clone();
        graceful_cfg.serving.fault = Some(group0_crash(10.0, 60.0, true));
        graceful_cfg.serving.fleet = Some(FleetConfig {
            groups: 2,
            router,
            overload: Some(OverloadConfig::default()),
            ..FleetConfig::default()
        });
        let naive = FleetSim::new(naive_cfg).run();
        let graceful = FleetSim::new(graceful_cfg).run();

        // The scoped script fires in group 0 only, in both modes.
        assert_eq!(naive.groups[0].faults_injected, 1, "{}", router.name());
        assert_eq!(naive.groups[1].faults_injected, 0);
        assert_eq!(graceful.groups[0].faults_injected, 1);
        assert_eq!(graceful.groups[1].faults_injected, 0);

        // Naive never sheds or fails over; it still drains everything
        // eventually (recovery fires after close, during the drain).
        assert_eq!(naive.requests_shed + naive.requests_failed_over, 0);
        assert_eq!(naive.finished, naive.arrived, "{}: naive must drain", router.name());

        // Graceful conservation: every arrival is finished or shed, every
        // export was re-injected exactly once, tokens conserved per group.
        assert_eq!(
            graceful.finished + graceful.requests_shed as usize,
            graceful.arrived,
            "{}: finished + shed must cover every offered request",
            router.name()
        );
        assert_eq!(
            graceful.groups.iter().map(|g| g.requests_exported).sum::<u64>(),
            graceful.requests_failed_over,
            "exports must equal re-injections"
        );
        for g in graceful.groups.iter().chain(&naive.groups) {
            assert!(g.tokens_conserved, "{}: token ledger must survive failover", router.name());
        }
        assert_eq!(naive.arrived, graceful.arrived, "same offered trace");

        // Availability: the graceful lockstep saw group 0 go down and
        // stay down through the close; group 1 stayed up throughout.
        assert_eq!(graceful.availability.len(), 2);
        let g0 = graceful.availability[0].points();
        assert_eq!(g0.first().map(|p| p.1), Some(1.0), "group 0 starts up");
        assert_eq!(g0.last().map(|p| p.1), Some(0.0), "group 0 is down at close");
        assert!(
            graceful.availability[1].points().iter().all(|&(_, v)| v == 1.0),
            "group 1 never stalls"
        );
        assert!(naive.availability.is_empty(), "naive runs record no health plane");

        // The headline comparison, on the window-free shed-aware goodput.
        // Round-robin and session-sticky strand ~40% of the trace in the
        // naive run — graceful is strictly better. Least-loaded's naive
        // baseline already dodges the dead group via live headroom, so
        // only no-worse is guaranteed there.
        match router {
            RouterPolicy::RoundRobin | RouterPolicy::SessionSticky => {
                assert!(
                    graceful.fleet_goodput_shed_aware > naive.fleet_goodput_shed_aware,
                    "{}: graceful {} must strictly beat naive {}",
                    router.name(),
                    graceful.fleet_goodput_shed_aware,
                    naive.fleet_goodput_shed_aware
                );
                assert!(
                    graceful.fleet_slo_attainment > naive.fleet_slo_attainment,
                    "{}: attainment {} vs {}",
                    router.name(),
                    graceful.fleet_slo_attainment,
                    naive.fleet_slo_attainment
                );
                assert!(
                    graceful.router_reroutes > 0,
                    "{}: post-crash arrivals must divert off the dead group",
                    router.name()
                );
            }
            RouterPolicy::LeastLoaded => {
                assert!(
                    graceful.fleet_goodput_shed_aware >= naive.fleet_goodput_shed_aware,
                    "least_loaded: graceful {} must be no worse than naive {}",
                    graceful.fleet_goodput_shed_aware,
                    naive.fleet_goodput_shed_aware
                );
            }
        }
    }
}

#[test]
fn faulted_health_aware_fleet_is_leap_and_par_safe() {
    // The full graceful stack — masking, failover, admission control —
    // rides the same fence/pump/inject surface as PR 8's lockstep, so it
    // must stay bit-identical across both engines (the acceptance gate;
    // CI re-runs this suite with each engine forced off).
    let mk = |no_leap: bool, no_par: bool| {
        let mut cfg = base_cfg(16.0, 30.0);
        cfg.serving.no_leap = no_leap;
        cfg.serving.no_par = no_par;
        cfg.serving.fault = Some(group0_crash(8.0, 40.0, true));
        cfg.serving.fleet = Some(FleetConfig {
            groups: 2,
            router: RouterPolicy::RoundRobin,
            overload: Some(OverloadConfig {
                ttft_budget_s: 0.5,
                max_retries: 2,
                retry_backoff_s: 0.1,
                retry_backoff_cap_s: 0.4,
            }),
            ..FleetConfig::default()
        });
        cfg
    };
    let on = FleetSim::new(mk(false, false)).run();
    let no_leap = FleetSim::new(mk(true, false)).run();
    let no_par = FleetSim::new(mk(false, true)).run();
    assert!(on.finished > 0);
    assert!(on.requests_failed_over > 0, "the crash must actually trigger failover");
    assert_fleet_fault_fields_eq(&on, &no_par);
    assert_fleet_fault_fields_eq(&on, &no_leap);
    for (ga, gb) in on.groups.iter().zip(&no_leap.groups) {
        assert_group_leap_identical(ga, gb);
    }
    for (ga, gb) in on.groups.iter().zip(&no_par.groups) {
        assert_group_identical(ga, gb);
    }
}

#[test]
fn saturating_overload_sheds_retries_and_keeps_the_books() {
    // A trace far past fleet capacity against a tight TTFT budget: the
    // admission controller must actually shed, every shed request must
    // stay in the attainment denominator, and the whole thing must
    // replay deterministically.
    let mut cfg = base_cfg(48.0, 30.0);
    cfg.serving.fleet = Some(FleetConfig {
        groups: 2,
        router: RouterPolicy::LeastLoaded,
        overload: Some(OverloadConfig {
            ttft_budget_s: 0.05,
            max_retries: 1,
            retry_backoff_s: 0.1,
            retry_backoff_cap_s: 0.2,
        }),
        ..FleetConfig::default()
    });
    let mut runs: Vec<FleetReport> = parallel_map(2, |_| FleetSim::new(cfg.clone()).run());
    let b = runs.pop().expect("two runs");
    let a = runs.pop().expect("two runs");
    assert!(a.requests_shed > 0, "a saturating trace against 50ms must shed");
    assert!(a.retries > 0, "rejected arrivals must get their retry");
    assert!(a.finished > 0, "admitted work still finishes");
    assert_eq!(
        a.finished + a.requests_shed as usize,
        a.arrived,
        "finished + shed must cover every offered request"
    );
    assert_eq!(
        a.router_decisions.iter().sum::<u64>() + a.requests_shed,
        a.arrived as u64,
        "every arrival either routed or shed — never both, never neither"
    );
    // Shed requests drag pooled attainment below the finished-only
    // fraction: they are misses, not non-events.
    let met: usize = a.groups.iter().map(|g| g.requests_slo_met).sum();
    let finished_only = met as f64 / a.finished as f64;
    assert!(
        a.fleet_slo_attainment < finished_only,
        "shed requests must count against attainment: {} !< {}",
        a.fleet_slo_attainment,
        finished_only
    );
    assert_fleet_fault_fields_eq(&a, &b);
    for (ga, gb) in a.groups.iter().zip(&b.groups) {
        assert_group_identical(ga, gb);
    }
}
