//! End-to-end integration tests over the real artifacts (`make artifacts`
//! first): the full Rust serving stack — proxy, prefill instance with
//! colocated attention executor, decode engine with per-layer attention
//! disaggregation — must reproduce the pure-jnp oracle's greedy tokens
//! exactly, with and without offloading.
//!
//! This is the repository's strongest correctness claim: attention
//! disaggregation is *exact*, so serving output is bit-identical whether a
//! request's attention runs on the decode instance or on the remote
//! executor.

use std::path::PathBuf;

use adrenaline::config::{OffloadPolicy, ServingConfig};
use adrenaline::engine::Server;
use adrenaline::util::json::Json;
use adrenaline::workload::Request;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.json").exists()
}

/// The reference prompts + expected greedy tokens written by aot.py.
fn reference_cases() -> Vec<(Vec<u32>, Vec<i32>)> {
    let text = std::fs::read_to_string(artifact_dir().join("reference_generations.json"))
        .expect("reference_generations.json (run `make artifacts`)");
    let v = Json::parse(&text).unwrap();
    v.as_arr()
        .unwrap()
        .iter()
        .map(|case| {
            let prompt = case
                .get("prompt")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_u64().unwrap() as u32)
                .collect();
            let expected = case
                .get("expected")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_u64().unwrap() as i32)
                .collect();
            (prompt, expected)
        })
        .collect()
}

fn requests_from_cases(cases: &[(Vec<u32>, Vec<i32>)]) -> Vec<Request> {
    cases
        .iter()
        .enumerate()
        .map(|(i, (prompt, expected))| {
            let mut r = Request::new(i as u64, 0.0, prompt.len(), expected.len());
            r.prompt_tokens = prompt.clone();
            r
        })
        .collect()
}

fn check_against_reference(
    cases: &[(Vec<u32>, Vec<i32>)],
    completions: &[adrenaline::engine::Completion],
) {
    assert_eq!(completions.len(), cases.len());
    for c in completions {
        let (_, expected) = &cases[c.id as usize];
        assert_eq!(
            &c.tokens, expected,
            "request {} (offloaded={}) diverged from the jnp oracle",
            c.id, c.offloaded
        );
    }
}

#[test]
fn serving_matches_oracle_all_local() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases);
    let mut server = Server::start(&artifact_dir(), ServingConfig::baseline()).unwrap();
    let report = server.run_requests(&reqs, Some(false)).unwrap();
    assert_eq!(report.offloaded_requests, 0);
    check_against_reference(&cases, &report.completions);
}

#[test]
fn serving_matches_oracle_all_offloaded() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases);
    let mut server = Server::start(&artifact_dir(), ServingConfig::default()).unwrap();
    let report = server.run_requests(&reqs, Some(true)).unwrap();
    assert_eq!(report.offloaded_requests, reqs.len());
    assert_eq!(report.fused_steps, 0, "offloaded batches cannot take the fused path");
    check_against_reference(&cases, &report.completions);
}

#[test]
fn serving_matches_oracle_split_path_without_offload() {
    // Ablation: the layer-loop split path (fused fast path disabled) must
    // agree token-for-token with both the fused path and the oracle.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases);
    let mut server = Server::start(&artifact_dir(), ServingConfig::baseline()).unwrap();
    server.set_fused_fast_path(false);
    let report = server.run_requests(&reqs, Some(false)).unwrap();
    assert_eq!(report.fused_steps, 0);
    check_against_reference(&cases, &report.completions);
}

#[test]
fn serving_matches_oracle_mixed_policy() {
    // Algorithm 1 decides per request; whatever mix it picks, every output
    // stream must still match the oracle.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases);
    let cfg = ServingConfig {
        offload: OffloadPolicy::FixedRatio(0.5),
        ..ServingConfig::default()
    };
    let mut server = Server::start(&artifact_dir(), cfg).unwrap();
    let report = server.run_requests(&reqs, None).unwrap();
    assert!(report.offloaded_requests > 0, "ratio 0.5 over 4 requests must offload some");
    assert!(report.offloaded_requests < reqs.len());
    check_against_reference(&cases, &report.completions);
}

#[test]
fn runtime_warmup_compiles_full_grid() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = adrenaline::runtime::ModelRuntime::load(&artifact_dir()).unwrap();
    let n = rt.warmup().unwrap();
    assert_eq!(n, rt.manifest.batch_buckets.len() * 6 + rt.manifest.prompt_buckets.len());
    assert_eq!(rt.compiled_count(), n);
}

#[test]
fn prefill_bucket_selection_and_first_token_stability() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = adrenaline::runtime::ModelRuntime::load(&artifact_dir()).unwrap();
    // Same prompt through two different buckets (padding) must give the
    // same first token and the same valid KV prefix.
    let prompt: Vec<i32> = (0..10).map(|i| (i * 7) % 256).collect();
    let out16 = rt.prefill(&prompt).unwrap();
    assert_eq!(out16.bucket, 16);
    // Force a larger bucket by padding the prompt conceptually: re-run via
    // a longer prompt that lands in the next bucket and compare nothing —
    // instead check determinism of the same call.
    let out16b = rt.prefill(&prompt).unwrap();
    assert_eq!(out16.first_token, out16b.first_token);
    assert_eq!(out16.k_cache, out16b.k_cache);
}

#[test]
fn executor_failure_recovers_with_local_recompute() {
    // Failure injection (DESIGN.md §7): kill the prefill-instance thread
    // while offloaded requests are in flight. The server must re-prefill
    // them locally (recompute) and still produce the oracle's exact
    // tokens, then keep serving new requests in degraded local-only mode.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases);
    let mut server = Server::start(&artifact_dir(), ServingConfig::default()).unwrap();

    // Kill the executor BEFORE serving: prefill + offload must both fall
    // back to the decode instance. (Mid-flight failure is exercised below.)
    server.kill_executor();
    assert!(!server.executor_alive());
    let report = server.run_requests(&reqs, Some(true)).unwrap();
    assert_eq!(report.offloaded_requests, 0, "degraded mode serves locally");
    check_against_reference(&cases, &report.completions);
}

#[test]
fn executor_failure_mid_flight_recovers() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases);

    // Run a first offloaded batch to get the executor warm, then kill it
    // and serve again: the stale server state must not corrupt results.
    let mut server = Server::start(&artifact_dir(), ServingConfig::default()).unwrap();
    let r1 = server.run_requests(&reqs, Some(true)).unwrap();
    check_against_reference(&cases, &r1.completions);
    server.kill_executor();
    let r2 = server.run_requests(&reqs, Some(true)).unwrap();
    assert_eq!(r2.offloaded_requests, 0);
    check_against_reference(&cases, &r2.completions);
}

#[test]
fn executor_failure_arm_recomputes_offloaded_requests() {
    // The `RecoveryPlan` arm proper (engine/recovery.rs): the executor
    // dies *between* decode steps while offloaded KV is resident, so the
    // next step fails mid-flight. The server must classify the batch,
    // re-prefill each offloaded request locally from prompt + the tokens
    // generated so far, count them in `recoveries`, finish the run in
    // degraded local-only mode — and still emit the oracle's exact
    // streams, because recompute-prefill of the extended prompt is
    // bit-identical to the decode step it replaces.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases);
    let mut server = Server::start(&artifact_dir(), ServingConfig::default()).unwrap();
    server.fail_executor_after_steps = Some(2);
    let report = server.run_requests(&reqs, Some(true)).unwrap();
    assert!(!server.executor_alive(), "injected failure must stick");
    assert!(
        server.recoveries > 0,
        "the failure arm must have recomputed at least one offloaded request"
    );
    assert_eq!(report.offloaded_requests, reqs.len(), "all were admitted offloaded");
    check_against_reference(&cases, &report.completions);
}

#[test]
fn kv_capacity_limits_respected() {
    // Small KV budgets: offloaded requests overflow the executor pool and
    // fall back to local; the local pool serializes admissions. Everything
    // still completes oracle-exact.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases);
    let total_reserve: usize =
        reqs.iter().map(|r| (r.prompt_len + r.output_len).min(128)).sum();

    // Executor pool fits only ~half the reservations.
    let cfg = ServingConfig {
        executor_kv_capacity_tokens: Some(total_reserve / 2),
        ..ServingConfig::default()
    };
    let mut server = Server::start(&artifact_dir(), cfg).unwrap();
    let report = server.run_requests(&reqs, Some(true)).unwrap();
    assert!(
        report.offloaded_requests < reqs.len(),
        "executor capacity must force some local fallbacks"
    );
    assert!(report.offloaded_requests >= 1);
    check_against_reference(&cases, &report.completions);

    // Local pool fits ~one request at a time: admissions serialize.
    let biggest = reqs.iter().map(|r| r.prompt_len + r.output_len).max().unwrap();
    let cfg = ServingConfig {
        decode_kv_capacity_tokens: Some(biggest + 8),
        ..ServingConfig::baseline()
    };
    let mut server = Server::start(&artifact_dir(), cfg).unwrap();
    let report = server.run_requests(&reqs, Some(false)).unwrap();
    check_against_reference(&cases, &report.completions);
}

#[test]
fn oversized_request_rejected_cleanly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cases = reference_cases();
    let reqs = requests_from_cases(&cases[..1].to_vec());
    let cfg = ServingConfig {
        decode_kv_capacity_tokens: Some(4), // smaller than any request
        ..ServingConfig::baseline()
    };
    let mut server = Server::start(&artifact_dir(), cfg).unwrap();
    let err = server.run_requests(&reqs, Some(false)).unwrap_err();
    assert!(err.to_string().contains("exceeds the decode KV capacity"), "{err}");
}
