//! Within-run parallelism — the bit-identity contract (ISSUE 7).
//!
//! Scheduling passes on multi-decode topologies run the epoch engine:
//! every starting decode instance's step series is priced concurrently
//! on a worker pool, other instances' pending *provably clean* step
//! ends are absorbed into the epoch as lanes (strict (time, seq)
//! queue-prefix rule — see `ClusterSim::run_epoch`), and everything is
//! committed through a deterministic merge. The contract is
//! the strongest the house style has, and *stricter* than the leap
//! engine's: a parallel run's `SimReport` must be bit-identical to the
//! `ServingConfig::no_par` / `ADRENALINE_NO_PAR=1` inline run —
//! **including `events_processed`** (the two modes execute the same
//! epoch code; only the thread that prices each series differs) — and
//! bit-identical except `events_processed` to the
//! `ADRENALINE_NO_LEAP=1` per-step reference (collapsing events is the
//! point).
//!
//! The scenario matrix leans on many-instance topologies (2, 4 and 8
//! decode instances) because that is where epochs actually fire, and
//! deliberately includes every shared structure the merge must replay
//! in exact serial event order: B_TPOT estimator EMAs (bounds
//! feedback), duty-cycle decay and executor busy time (offloaded rows),
//! rebalance migrations (dense queued events truncating epochs),
//! preemption churn under tiny pools (horizon exhaustion mid-epoch),
//! fault windows (straggler multipliers re-synced into the pricer
//! clones), and the exact cost plane (no grid, pure roofline pricing).
//! CI re-runs the sim suites under `ADRENALINE_NO_PAR=1` and under the
//! combined `ADRENALINE_NO_PAR=1 ADRENALINE_NO_LEAP=1` so every
//! engine combination stays green.

use adrenaline::config::{
    BoundsFeedbackConfig, FaultConfig, FaultKind, ModelSpec, RebalanceConfig, ScriptedFault,
};
use adrenaline::metrics::{LatencyStats, Timeline};
use adrenaline::sim::{parallel_map, ClusterSim, SimConfig, SimReport};
use adrenaline::workload::{ArrivalPattern, WorkloadKind};

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn assert_timeline_eq(name: &str, a: &Timeline, b: &Timeline) {
    assert_eq!(a.len(), b.len(), "{name}: timeline lengths differ");
    for (i, (pa, pb)) in a.points().iter().zip(b.points()).enumerate() {
        assert!(
            feq(pa.0, pb.0) && feq(pa.1, pb.1),
            "{name}[{i}]: {pa:?} vs {pb:?}"
        );
    }
}

fn assert_stats_eq(name: &str, a: &Option<LatencyStats>, b: &Option<LatencyStats>) {
    match (a, b) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count, "{name} count");
            assert!(feq(x.mean, y.mean), "{name} mean: {} vs {}", x.mean, y.mean);
            assert!(feq(x.p50, y.p50), "{name} p50");
            assert!(feq(x.p99, y.p99), "{name} p99");
            assert!(feq(x.max, y.max), "{name} max");
        }
        (None, None) => {}
        _ => panic!("{name} presence differs"),
    }
}

/// Everything in the report except `events_processed` must match bit
/// for bit (the leap/epoch engines collapse events; callers that expect
/// even the event counts to tie assert that separately).
fn assert_bit_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.req_preemptions_total, b.req_preemptions_total);
    assert_eq!(a.tokens_conserved, b.tokens_conserved);
    assert_eq!(a.steps_simulated, b.steps_simulated, "step counts must agree");
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.prefill_hbm_capacity_util, b.prefill_hbm_capacity_util));
    assert!(feq(a.prefill_hbm_bw_util, b.prefill_hbm_bw_util));
    assert!(feq(a.executor_bw_util, b.executor_bw_util));
    assert!(feq(a.executor_duty, b.executor_duty));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    assert!(feq(a.ttft_slo_attainment, b.ttft_slo_attainment));
    assert!(feq(a.tpot_slo_attainment, b.tpot_slo_attainment));
    assert!(feq(a.sim_end_s, b.sim_end_s), "{} vs {}", a.sim_end_s, b.sim_end_s);
    assert_stats_eq("ttft", &a.ttft, &b.ttft);
    assert_stats_eq("tpot", &a.tpot, &b.tpot);
    match (&a.window, &b.window) {
        (Some(x), Some(y)) => {
            assert!(feq(x.start, y.start) && feq(x.end, y.end), "window bounds");
            assert_eq!(x.saturated, y.saturated);
        }
        (None, None) => {}
        _ => panic!("stable-window presence differs"),
    }
    assert_timeline_eq("decode_occupancy", &a.decode_occupancy, &b.decode_occupancy);
    assert_timeline_eq("prefill_occupancy", &a.prefill_occupancy, &b.prefill_occupancy);
    assert_timeline_eq("batch_size", &a.batch_size, &b.batch_size);
    assert_eq!(a.exact_costs, b.exact_costs);
    assert_eq!(a.graph_selections, b.graph_selections);
    assert_eq!(a.graph_used_slots, b.graph_used_slots);
    assert_eq!(a.graph_padded_slots, b.graph_padded_slots);
    assert!(feq(a.graph_padding_overhead, b.graph_padding_overhead));
    assert_eq!(a.graph_bucket_hits, b.graph_bucket_hits);
    assert_eq!(a.migrations_total, b.migrations_total);
    assert_eq!(a.migrations_to_offload, b.migrations_to_offload);
    assert_eq!(a.migrations_to_local, b.migrations_to_local);
    assert_eq!(a.migration_tokens_moved, b.migration_tokens_moved);
    assert_timeline_eq("offloaded_frac", &a.offloaded_frac_timeline, &b.offloaded_frac_timeline);
    assert_timeline_eq(
        "prefill_pressure",
        &a.prefill_pressure_timeline,
        &b.prefill_pressure_timeline,
    );
    assert_eq!(a.metadata_residual, b.metadata_residual);
    assert_timeline_eq("b_tpot", &a.b_tpot_timeline, &b.b_tpot_timeline);
    assert_timeline_eq("ob", &a.ob_timeline, &b.ob_timeline);
    assert_eq!(a.bounds_refreshes, b.bounds_refreshes);
    assert_eq!(a.b_tpot_observations, b.b_tpot_observations);
    assert_eq!(a.decision_counts, b.decision_counts);
    assert_eq!(a.decision_counts_rerouted, b.decision_counts_rerouted);
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.requests_recovered, b.requests_recovered);
    assert_eq!(a.recompute_tokens_replayed, b.recompute_tokens_replayed);
    assert_eq!(a.transfer_retries, b.transfer_retries);
    assert!(feq(a.degraded_time_s, b.degraded_time_s));
    assert_timeline_eq("health", &a.health_timeline, &b.health_timeline);
}

/// Run `cfg` with parallel epoch pricing on and off; returns
/// (parallel, inline). Leaping stays at the config's setting (default
/// on — epochs only exist on the leap path).
fn par_pair(cfg: &SimConfig) -> (SimReport, SimReport) {
    let mut on = cfg.clone();
    on.serving.no_par = false;
    let mut off = cfg.clone();
    off.serving.no_par = true;
    let mut runs: Vec<SimReport> = parallel_map(2, |i| {
        ClusterSim::new(if i == 0 { on.clone() } else { off.clone() }).run()
    });
    let off = runs.pop().expect("two runs");
    let on = runs.pop().expect("two runs");
    (on, off)
}

/// The par/no-par contract: the two modes run the same epoch code, so
/// even the event counts must tie exactly.
fn assert_par_identical(on: &SimReport, off: &SimReport) {
    assert_bit_identical(on, off);
    assert_eq!(
        on.events_processed, off.events_processed,
        "par and no_par execute the same epoch schedule"
    );
}

/// A saturated many-instance scenario: the epoch engine's home turf.
fn many_instance_cfg(n_decode: u32, rate: f64) -> SimConfig {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, rate);
    cfg.duration_s = 40.0;
    cfg.cluster.n_decode = n_decode;
    cfg
}

#[test]
fn two_instance_par_bit_identity() {
    let (on, off) = par_pair(&many_instance_cfg(2, 8.0));
    assert!(on.finished > 0);
    assert_par_identical(&on, &off);
}

#[test]
fn four_instance_par_bit_identity() {
    let (on, off) = par_pair(&many_instance_cfg(4, 16.0));
    assert!(on.finished > 0);
    assert_par_identical(&on, &off);
}

#[test]
fn eight_instance_par_bit_identity() {
    let (on, off) = par_pair(&many_instance_cfg(8, 32.0));
    assert!(on.finished > 0);
    assert_par_identical(&on, &off);
}

#[test]
fn single_instance_never_epochs() {
    // One decode instance never enters the epoch engine (the
    // `decode.len() >= 2` gate): par and no_par are trivially the same
    // run, and neither may perturb the solo leap path.
    let (on, off) = par_pair(&many_instance_cfg(1, 4.0));
    assert!(on.finished > 0);
    assert_par_identical(&on, &off);
}

#[test]
fn par_matches_per_step_reference() {
    // Three-way anchor: the parallel run must also match the per-step
    // no-leap reference bit for bit (except collapsed events) — the
    // epoch merge replays exactly the serial handler sequence.
    let cfg = many_instance_cfg(4, 16.0);
    let mut par = cfg.clone();
    par.serving.no_par = false;
    let mut reference = cfg.clone();
    reference.serving.no_leap = true;
    let mut runs: Vec<SimReport> = parallel_map(2, |i| {
        ClusterSim::new(if i == 0 { par.clone() } else { reference.clone() }).run()
    });
    let reference = runs.pop().expect("two runs");
    let par = runs.pop().expect("two runs");
    assert!(par.finished > 0);
    assert_bit_identical(&par, &reference);
    assert!(
        par.events_processed <= reference.events_processed,
        "epochs must never add events: {} vs {}",
        par.events_processed,
        reference.events_processed
    );
}

#[test]
fn worker_count_is_unobservable() {
    // `par_workers` picks concurrency, never results: 1 (≡ inline), 2,
    // 3 and a saturating request must all produce one bit-identical
    // report.
    let cfg = many_instance_cfg(4, 16.0);
    let reports: Vec<SimReport> = parallel_map(4, |i| {
        let mut c = cfg.clone();
        c.serving.par_workers = [1, 2, 3, 64][i];
        ClusterSim::new(c).run()
    });
    for r in &reports[1..] {
        assert_par_identical(r, &reports[0]);
    }
}

#[test]
fn bounds_feedback_par_bit_identity() {
    // Per-step B_TPOT EMA observations are the most order-sensitive
    // shared state the merge replays: any cross-instance reordering of
    // step starts diverges the estimator and everything downstream.
    let mut cfg = many_instance_cfg(4, 20.0);
    cfg.duration_s = 45.0;
    cfg.arrivals = ArrivalPattern::Diurnal { period_s: 40.0, depth: 0.8 };
    cfg.cluster.n_prefill = 2;
    cfg.serving.bounds_feedback = Some(BoundsFeedbackConfig::default());
    let (on, off) = par_pair(&cfg);
    assert!(on.b_tpot_observations > 0, "the estimator must observe steps");
    assert_par_identical(&on, &off);
}

#[test]
fn rebalance_churn_par_bit_identity() {
    // Rebalance ticks and migration completions land between epochs and
    // truncate them; migrations also move rows across instances so the
    // starter sets keep changing.
    let mut cfg = many_instance_cfg(4, 24.0);
    cfg.duration_s = 45.0;
    cfg.arrivals = ArrivalPattern::Bursty { period_s: 30.0, duty: 0.25, mult: 3.0 };
    cfg.serving.rebalance = Some(RebalanceConfig::default());
    let (on, off) = par_pair(&cfg);
    assert!(on.finished > 0);
    assert_par_identical(&on, &off);
}

#[test]
fn preemption_churn_par_bit_identity() {
    // Tiny pools: epoch horizons exhaust mid-window (the merge's
    // stop-and-truncate path) and the shared executor-pool bound across
    // starters is what keeps overflow preemptions on evented steps.
    let mut cfg =
        SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::OpenThoughts, 2.0);
    cfg.duration_s = 20.0;
    cfg.cluster.n_decode = 2;
    cfg.serving.decode_kv_capacity_tokens = Some(16 * 1024);
    cfg.serving.executor_kv_capacity_tokens = Some(8 * 1024);
    let (on, off) = par_pair(&cfg);
    assert!(on.preemptions > 0, "tiny pools must preempt");
    assert!(on.tokens_conserved);
    assert_par_identical(&on, &off);
}

#[test]
fn fault_straggler_par_bit_identity() {
    // Straggler windows mutate the authoritative cost plane's slowdown
    // multipliers mid-run; the pricer clones re-sync at every epoch, so
    // pre-, intra- and post-window epochs all price identically to the
    // inline path. A decode crash also exercises the down-instance
    // filter in the epoch starter scan.
    let mut cfg = many_instance_cfg(4, 16.0);
    cfg.duration_s = 45.0;
    cfg.serving.fault = Some(FaultConfig {
        script: vec![
            ScriptedFault { kind: FaultKind::Straggler, instance: 0, at_s: 8.0, down_s: 12.0, group: None },
            ScriptedFault { kind: FaultKind::DecodeCrash, instance: 1, at_s: 20.0, down_s: 6.0, group: None },
        ],
        straggler_factor: 2.5,
        ..FaultConfig::default()
    });
    let (on, off) = par_pair(&cfg);
    assert!(on.faults_injected >= 2);
    assert_par_identical(&on, &off);
}

#[test]
fn exact_costs_par_bit_identity() {
    // The exact (pre-bucketing) cost plane: no grid selections to
    // replay, pure roofline pricing on the clones.
    let mut cfg = many_instance_cfg(4, 16.0);
    cfg.serving.exact_costs = true;
    let (on, off) = par_pair(&cfg);
    assert!(on.exact_costs && on.finished > 0);
    assert_eq!(on.graph_selections, 0);
    assert_par_identical(&on, &off);
}

#[test]
fn epochs_still_collapse_events() {
    // The perf claim behind the engine: a saturated 8-instance run must
    // still process far fewer events than the per-step reference (the
    // epoch merge commits interior steps inline, exactly like the solo
    // leap does — and absorption keeps the window open past other
    // instances' pending clean step ends, which at saturation would
    // otherwise fence every epoch to a single step). Under
    // ADRENALINE_NO_LEAP=1 both runs are the reference and the counts
    // legitimately tie.
    let cfg = many_instance_cfg(8, 32.0);
    let mut leap = cfg.clone();
    leap.serving.no_leap = false;
    let mut reference = cfg.clone();
    reference.serving.no_leap = true;
    let mut runs: Vec<SimReport> = parallel_map(2, |i| {
        ClusterSim::new(if i == 0 { leap.clone() } else { reference.clone() }).run()
    });
    let reference = runs.pop().expect("two runs");
    let leap = runs.pop().expect("two runs");
    assert_eq!(leap.steps_simulated, reference.steps_simulated);
    let env_forced = adrenaline::sim::engine_env().no_leap;
    if env_forced {
        assert_eq!(leap.events_processed, reference.events_processed);
    } else {
        assert!(
            (leap.events_processed as f64) < reference.events_processed as f64 * 0.7,
            "multi-instance runs must still collapse events: {} vs {}",
            leap.events_processed,
            reference.events_processed
        );
    }
}

#[test]
fn property_par_bit_identity_random_configs() {
    // Random topologies (1–6 decode instances), rates, seeds, pool
    // budgets and durations: the epoch horizon must never commit a
    // finish, an overflow, or a queued-event interleaving inline, and
    // the merge must replay every interleaving the serial reference
    // produces — any divergence fails the paired comparison.
    adrenaline::util::prop::check("par_run_bit_identity", 5, |rng| {
        let model = ModelSpec::llama2_7b();
        let workload = if rng.range_usize(0, 2) == 0 {
            WorkloadKind::ShareGpt
        } else {
            WorkloadKind::OpenThoughts
        };
        let mut cfg = SimConfig::paper_default(model, workload, 2.0 + rng.f64() * 14.0);
        cfg.duration_s = 10.0 + rng.f64() * 10.0;
        cfg.seed = rng.next_u64();
        cfg.cluster.n_decode = 1 + rng.range_usize(0, 6) as u32;
        if rng.range_usize(0, 2) == 0 {
            let dec = 12 * 1024 + rng.range_usize(0, 32 * 1024);
            let exe = 8 * 1024 + rng.range_usize(0, 16 * 1024);
            cfg.serving.decode_kv_capacity_tokens = Some(dec);
            cfg.serving.executor_kv_capacity_tokens = Some(exe);
        }
        let (on, off) = par_pair(&cfg);
        assert_par_identical(&on, &off);
    });
}
