//! Fleet layer contracts (ISSUE 8).
//!
//! The fleet is an *aggregation* layer: it must add routing and
//! autoscaling without perturbing the per-group physics. The contracts
//! pin that from both ends:
//!
//! * **Structural inertness** — `fleet: None` and a one-group fleet are
//!   bit-identical to a bare [`ClusterSim`] run (same trace generator,
//!   same report, bit for bit).
//! * **Determinism** — every router policy (including the lockstep
//!   least-loaded co-simulation) replays bit-identically run over run.
//! * **Engine composition** — the decode-leap and within-run-parallelism
//!   engines stay bit-identical through the lockstep fence/pump/inject
//!   surface (CI re-runs this suite under `ADRENALINE_NO_LEAP=1` and
//!   `ADRENALINE_NO_PAR=1`).
//! * **Autoscaler safety** — unreachable thresholds never act (physics
//!   match a fixed pool), and aggressive scale-down drains never lose a
//!   request.

use adrenaline::config::{AutoscaleConfig, FleetConfig, ModelSpec, RouterPolicy};
use adrenaline::metrics::{LatencyStats, Timeline};
use adrenaline::sim::{parallel_map, ClusterSim, FleetReport, FleetSim, SimConfig, SimReport};
use adrenaline::workload::{ArrivalPattern, WorkloadKind};

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn assert_timeline_eq(name: &str, a: &Timeline, b: &Timeline) {
    assert_eq!(a.len(), b.len(), "{name}: timeline lengths differ");
    for (i, (pa, pb)) in a.points().iter().zip(b.points()).enumerate() {
        assert!(
            feq(pa.0, pb.0) && feq(pa.1, pb.1),
            "{name}[{i}]: {pa:?} vs {pb:?}"
        );
    }
}

fn assert_stats_eq(name: &str, a: &Option<LatencyStats>, b: &Option<LatencyStats>) {
    match (a, b) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count, "{name} count");
            assert!(feq(x.mean, y.mean), "{name} mean: {} vs {}", x.mean, y.mean);
            assert!(feq(x.p50, y.p50), "{name} p50");
            assert!(feq(x.p99, y.p99), "{name} p99");
            assert!(feq(x.max, y.max), "{name} max");
        }
        (None, None) => {}
        _ => panic!("{name} presence differs"),
    }
}

/// Full-report bitwise equality (`step_leap.rs` house style). Unlike the
/// leap contract there is no allowed difference here: both sides of
/// every pairing in this suite take the same engine path, so even
/// `events_processed` must match.
fn assert_report_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.req_preemptions_total, b.req_preemptions_total);
    assert_eq!(a.tokens_conserved, b.tokens_conserved);
    assert_eq!(a.steps_simulated, b.steps_simulated, "step counts must agree");
    assert_eq!(a.events_processed, b.events_processed, "event counts must agree");
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.prefill_hbm_capacity_util, b.prefill_hbm_capacity_util));
    assert!(feq(a.prefill_hbm_bw_util, b.prefill_hbm_bw_util));
    assert!(feq(a.executor_bw_util, b.executor_bw_util));
    assert!(feq(a.executor_duty, b.executor_duty));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    assert!(feq(a.ttft_slo_attainment, b.ttft_slo_attainment));
    assert!(feq(a.tpot_slo_attainment, b.tpot_slo_attainment));
    assert!(feq(a.sim_end_s, b.sim_end_s), "{} vs {}", a.sim_end_s, b.sim_end_s);
    assert_stats_eq("ttft", &a.ttft, &b.ttft);
    assert_stats_eq("tpot", &a.tpot, &b.tpot);
    assert_timeline_eq("decode_occupancy", &a.decode_occupancy, &b.decode_occupancy);
    assert_timeline_eq("prefill_occupancy", &a.prefill_occupancy, &b.prefill_occupancy);
    assert_timeline_eq("batch_size", &a.batch_size, &b.batch_size);
    assert_eq!(a.graph_selections, b.graph_selections);
    assert_eq!(a.graph_used_slots, b.graph_used_slots);
    assert_eq!(a.graph_padded_slots, b.graph_padded_slots);
    assert_eq!(a.migrations_total, b.migrations_total);
    assert_eq!(a.migration_tokens_moved, b.migration_tokens_moved);
    assert_eq!(a.bounds_refreshes, b.bounds_refreshes);
    assert_eq!(a.b_tpot_observations, b.b_tpot_observations);
    assert_eq!(a.decision_counts, b.decision_counts);
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.requests_recovered, b.requests_recovered);
    assert!(feq(a.degraded_time_s, b.degraded_time_s));
    assert_timeline_eq("health", &a.health_timeline, &b.health_timeline);
    assert_timeline_eq("prefill_pool", &a.prefill_pool_timeline, &b.prefill_pool_timeline);
    assert_eq!(a.scale_ups, b.scale_ups);
    assert_eq!(a.scale_downs, b.scale_downs);
}

/// Leap contract variant: bit-identical physics, `events_processed`
/// allowed to shrink on the leap side `a`.
fn assert_leap_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.tokens_conserved, b.tokens_conserved);
    assert_eq!(a.steps_simulated, b.steps_simulated, "step counts must agree");
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.sim_end_s, b.sim_end_s), "{} vs {}", a.sim_end_s, b.sim_end_s);
    assert_stats_eq("ttft", &a.ttft, &b.ttft);
    assert_stats_eq("tpot", &a.tpot, &b.tpot);
    assert_timeline_eq("decode_occupancy", &a.decode_occupancy, &b.decode_occupancy);
    assert_timeline_eq("batch_size", &a.batch_size, &b.batch_size);
    assert_timeline_eq("prefill_pool", &a.prefill_pool_timeline, &b.prefill_pool_timeline);
    assert_eq!(a.scale_ups, b.scale_ups);
    assert_eq!(a.scale_downs, b.scale_downs);
    assert!(
        a.events_processed <= b.events_processed,
        "leaping must never add events: {} vs {}",
        a.events_processed,
        b.events_processed
    );
}

fn base_cfg(rate: f64, duration_s: f64) -> SimConfig {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, rate);
    cfg.duration_s = duration_s;
    cfg
}

/// A fleet run and a bare [`ClusterSim`] run over the same config must
/// produce the same single-group report, bit for bit: `ClusterSim::new`
/// and the fleet's shared-trace generation are the same code path.
fn assert_fleet_matches_bare(fleet: &FleetReport, bare: &SimReport) {
    assert_eq!(fleet.groups.len(), 1);
    assert_report_identical(&fleet.groups[0], bare);
    assert!(feq(fleet.fleet_throughput, bare.throughput));
    assert!(feq(fleet.fleet_goodput, bare.goodput));
    assert_stats_eq("fleet_ttft", &fleet.fleet_ttft, &bare.ttft);
    assert_stats_eq("fleet_tpot", &fleet.fleet_tpot, &bare.tpot);
    assert_eq!(fleet.arrived, bare.arrived);
    assert_eq!(fleet.finished, bare.finished);
    assert_eq!(fleet.steps_simulated, bare.steps_simulated);
    assert_eq!(fleet.events_processed, bare.events_processed);
    assert_eq!(fleet.scale_events, 0);
    assert!(fleet.fleet_size_timeline.is_empty(), "no autoscaler, no pool timeline");
    assert_eq!(fleet.router_decisions, vec![bare.arrived as u64]);
}

#[test]
fn fleet_none_is_bit_identical_to_bare_sim() {
    // `fleet: None` resolves to the default one-group round-robin fleet;
    // the acceptance gate says it must be structurally inert.
    let cfg = base_cfg(8.0, 30.0);
    assert!(cfg.serving.fleet.is_none(), "paper default must not enable the fleet layer");
    let fleet = FleetSim::new(cfg.clone()).run();
    let bare = ClusterSim::new(cfg).run();
    assert!(bare.finished > 0);
    assert_fleet_matches_bare(&fleet, &bare);
}

#[test]
fn one_group_fleet_is_bit_identical_to_bare_sim_under_every_policy() {
    // With one group every policy routes everything to group 0, so the
    // policy must be unobservable in the report.
    for router in [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::SessionSticky]
    {
        let mut cfg = base_cfg(8.0, 30.0);
        cfg.serving.fleet = Some(FleetConfig { groups: 1, router, ..FleetConfig::default() });
        let fleet = FleetSim::new(cfg.clone()).run();
        cfg.serving.fleet = None;
        let bare = ClusterSim::new(cfg).run();
        assert!(bare.finished > 0);
        assert_fleet_matches_bare(&fleet, &bare);
    }
}

#[test]
fn every_router_policy_replays_deterministically() {
    // Same config, two runs, bit-identical fleet reports — including the
    // least-loaded lockstep co-simulation, whose routing depends on live
    // headroom reads at every arrival instant.
    for router in [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::SessionSticky]
    {
        let mut cfg = base_cfg(24.0, 20.0);
        cfg.arrivals = ArrivalPattern::Bursty { period_s: 10.0, duty: 0.25, mult: 3.0 };
        cfg.serving.fleet = Some(FleetConfig { groups: 3, router, ..FleetConfig::default() });
        let mut runs: Vec<FleetReport> =
            parallel_map(2, |_| FleetSim::new(cfg.clone()).run());
        let b = runs.pop().expect("two runs");
        let a = runs.pop().expect("two runs");
        assert!(a.finished > 0, "{}: trace must finish work", router.name());
        assert_eq!(a.router_decisions, b.router_decisions, "{} routing", router.name());
        assert_eq!(
            a.router_decisions.iter().sum::<u64>(),
            a.arrived as u64,
            "{}: every arrival routes exactly once",
            router.name()
        );
        for (ga, gb) in a.groups.iter().zip(&b.groups) {
            assert_report_identical(ga, gb);
        }
    }
}

#[test]
fn lockstep_least_loaded_is_leap_and_par_safe() {
    // The lockstep fence/pump/inject surface must compose with both
    // engines: the fence pins the leap horizon at each injection instant,
    // so leap-on, leap-off and par-off runs all agree bit for bit —
    // including the routing itself (identical headroom reads).
    let mk = |no_leap: bool, no_par: bool| {
        let mut cfg = base_cfg(24.0, 25.0);
        cfg.arrivals = ArrivalPattern::Diurnal { period_s: 15.0, depth: 0.8 };
        cfg.serving.no_leap = no_leap;
        cfg.serving.no_par = no_par;
        cfg.serving.fleet = Some(FleetConfig {
            groups: 2,
            router: RouterPolicy::LeastLoaded,
            ..FleetConfig::default()
        });
        cfg
    };
    let on = FleetSim::new(mk(false, false)).run();
    let no_leap = FleetSim::new(mk(true, false)).run();
    let no_par = FleetSim::new(mk(false, true)).run();
    assert!(on.finished > 0);
    assert_eq!(on.router_decisions, no_leap.router_decisions, "leap must not change routing");
    assert_eq!(on.router_decisions, no_par.router_decisions, "par must not change routing");
    assert!(
        on.router_decisions.iter().all(|&n| n > 0),
        "least-loaded must spread a saturating trace: {:?}",
        on.router_decisions
    );
    for (ga, gb) in on.groups.iter().zip(&no_leap.groups) {
        assert_leap_identical(ga, gb);
    }
    for (ga, gb) in on.groups.iter().zip(&no_par.groups) {
        assert_report_identical(ga, gb);
    }
}

#[test]
fn unreachable_thresholds_keep_the_pool_pinned() {
    // An autoscaler that can never fire must not perturb the physics:
    // same arrivals, same finishes, same step series and latency stats
    // as a fixed pool. (Tick events do land in the queue, so
    // `events_processed` legitimately differs — everything physical must
    // not.)
    let autoscale = AutoscaleConfig {
        min_prefill: 2,
        max_prefill: 2,
        initial_prefill: None,
        scale_up_pressure: 1e9,
        scale_down_pressure: -1.0,
        ..AutoscaleConfig::default()
    };
    let mut cfg = base_cfg(48.0, 30.0);
    cfg.cluster.n_prefill = 2;
    cfg.serving.fleet = Some(FleetConfig {
        groups: 2,
        router: RouterPolicy::RoundRobin,
        autoscale: Some(autoscale),
        ..FleetConfig::default()
    });
    let with = FleetSim::new(cfg.clone()).run();
    cfg.serving.fleet =
        Some(FleetConfig { groups: 2, router: RouterPolicy::RoundRobin, ..FleetConfig::default() });
    let without = FleetSim::new(cfg).run();
    assert!(with.finished > 0);
    assert_eq!(with.scale_events, 0, "unreachable thresholds must never act");
    assert_eq!(with.arrived, without.arrived);
    assert_eq!(with.finished, without.finished);
    assert_eq!(with.steps_simulated, without.steps_simulated);
    assert_stats_eq("ttft", &with.fleet_ttft, &without.fleet_ttft);
    assert_stats_eq("tpot", &with.fleet_tpot, &without.fleet_tpot);
    for (ga, gb) in with.groups.iter().zip(&without.groups) {
        // Per-request physics are identical; the run-end clock is not
        // (the final idle tick extends it by up to `tick_s`), so the
        // window-based rates compare only when the stable window — a
        // pure function of the identical per-step timelines — exists.
        assert_eq!(ga.arrived, gb.arrived);
        assert_eq!(ga.finished, gb.finished);
        assert_eq!(ga.steps_simulated, gb.steps_simulated);
        assert_stats_eq("group ttft", &ga.ttft, &gb.ttft);
        assert_stats_eq("group tpot", &ga.tpot, &gb.tpot);
        assert_timeline_eq("decode_occupancy", &ga.decode_occupancy, &gb.decode_occupancy);
        assert_timeline_eq("batch_size", &ga.batch_size, &gb.batch_size);
        match (&ga.window, &gb.window) {
            (Some(x), Some(y)) => {
                assert!(feq(x.start, y.start) && feq(x.end, y.end), "window bounds");
                assert!(feq(ga.throughput, gb.throughput));
                assert!(feq(ga.goodput, gb.goodput));
            }
            (None, None) => {}
            _ => panic!("stable-window presence differs"),
        }
    }
    // The pinned pool's timeline exists and never moves off 2 per group
    // (4 fleet-wide).
    assert!(!with.fleet_size_timeline.is_empty());
    assert!(
        with.fleet_size_timeline.points().iter().all(|&(_, v)| v == 4.0),
        "pool must stay pinned at the floor=ceiling size"
    );
    assert!(without.fleet_size_timeline.is_empty());
}

#[test]
fn aggressive_scale_down_drains_without_losing_requests() {
    // Thresholds rigged so the pool always wants to shrink: the scaler
    // must drain victims through the health plane — requests already
    // queued on a draining instance still complete — and land every
    // request, with token conservation intact in every group.
    let autoscale = AutoscaleConfig {
        min_prefill: 1,
        max_prefill: 3,
        initial_prefill: Some(3),
        scale_up_pressure: 1e9,
        scale_down_pressure: 1e9, // always satisfied => shrink to the floor
        sustain_s: 0.5,
        cooldown_s: 1.0,
        tick_s: 0.25,
    };
    let mut cfg = base_cfg(16.0, 30.0);
    cfg.cluster.n_prefill = 3;
    cfg.serving.fleet = Some(FleetConfig {
        groups: 2,
        router: RouterPolicy::RoundRobin,
        autoscale: Some(autoscale),
        ..FleetConfig::default()
    });
    let r = FleetSim::new(cfg).run();
    assert!(r.arrived > 0);
    assert_eq!(r.finished, r.arrived, "drains must not lose requests");
    assert!(r.scale_events >= 2, "both groups must shrink: {}", r.scale_events);
    for g in &r.groups {
        assert!(g.tokens_conserved, "drain must conserve tokens");
        assert!(g.scale_downs >= 1);
        assert_eq!(g.scale_ups, 0, "scale-up threshold is unreachable");
    }
    // The fleet pool timeline starts at the full 6 (3 per group) and
    // shrinks toward the floor.
    let pts = r.fleet_size_timeline.points();
    assert!(!pts.is_empty());
    assert_eq!(pts[0].1, 6.0, "pools start at initial_prefill");
    let min = pts.iter().map(|&(_, v)| v).fold(f64::INFINITY, f64::min);
    assert!(min < 6.0, "the pool must actually shrink");
}

#[test]
fn autoscaler_tracks_a_diurnal_wave() {
    // The acceptance-gate scenario shape: a diurnal trace against pools
    // that start at the floor. Peaks must pull the pool up; the timeline
    // must move in both directions across the run.
    let autoscale = AutoscaleConfig {
        min_prefill: 1,
        max_prefill: 3,
        initial_prefill: None,
        scale_up_pressure: 0.2,
        scale_down_pressure: 0.05,
        sustain_s: 1.0,
        cooldown_s: 2.0,
        tick_s: 0.25,
    };
    let mut cfg = base_cfg(32.0, 40.0);
    cfg.arrivals = ArrivalPattern::Diurnal { period_s: 20.0, depth: 0.9 };
    cfg.cluster.n_prefill = 3;
    cfg.serving.fleet = Some(FleetConfig {
        groups: 2,
        router: RouterPolicy::RoundRobin,
        autoscale: Some(autoscale),
        ..FleetConfig::default()
    });
    let r = FleetSim::new(cfg).run();
    assert!(r.finished > 0);
    let ups: u64 = r.groups.iter().map(|g| g.scale_ups).sum();
    assert!(ups >= 1, "diurnal peaks must trigger scale-ups");
    let pts = r.fleet_size_timeline.points();
    let max = pts.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max);
    let min = pts.iter().map(|&(_, v)| v).fold(f64::INFINITY, f64::min);
    assert!(max > min, "the pool must move with the wave: min={min} max={max}");
    assert_eq!(pts[0].1, 2.0, "pools start at the floor (1 per group)");
}
