//! Runtime offload rebalancer — behavioral contract (ISSUE 3).
//!
//! The static bit-identity contract ("`LoadAware`/`Disabled` without
//! rebalancing behave exactly as before the rebalancer existed") is pinned
//! from three sides:
//!
//! * structurally: `ServingConfig::rebalance = None` schedules no ticks
//!   and runs no migration code (`static_runs_never_migrate` in
//!   `sim::cluster`), and the refactored seams are pinned bit-for-bit at
//!   the unit level — the Poisson arrival path consumes the RNG exactly
//!   like the pre-pattern generator
//!   (`poisson_default_matches_legacy_sampling_exactly`) and
//!   `CostModel::kv_transfer_time` reproduces the old inline transfer
//!   formula (`kv_transfer_time_matches_legacy_inline_formula`);
//! * behaviorally: [`ticks_without_migrations_are_inert`] shows that even
//!   *with* the controller ticking, a zero-migration budget leaves every
//!   simulated metric bit-identical to the static run — the ticks only
//!   observe, they never perturb.
//!
//! The dynamic contract on a bursty trace: migrations happen, token
//! accounting and proxy metadata survive them, runs stay deterministic,
//! and throughput is at least the static `LoadAware` baseline's.

use adrenaline::config::{ModelSpec, RebalanceConfig};
use adrenaline::sim::{parallel_map, ClusterSim, SimConfig, SimReport};
use adrenaline::workload::{ArrivalPattern, WorkloadKind};

/// The §Scenarios burst trace: 3x the mean rate for a quarter of each
/// 30 s cycle, troughs compensating so the offered load stays 24 req/s.
const BURSTY: ArrivalPattern = ArrivalPattern::Bursty { period_s: 30.0, duty: 0.25, mult: 3.0 };

fn bursty_cfg(rebalance: Option<RebalanceConfig>) -> SimConfig {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 24.0);
    cfg.duration_s = 120.0;
    cfg.arrivals = BURSTY;
    cfg.serving.rebalance = rebalance;
    cfg
}

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

/// A ticking controller with a zero migration budget must leave every
/// simulated quantity bit-identical to the static run: the rebalancer
/// observes the system, it never perturbs it except by migrating.
#[test]
fn ticks_without_migrations_are_inert() {
    let mut short = bursty_cfg(None);
    short.duration_s = 60.0;
    let frozen = RebalanceConfig { max_migrations_per_interval: 0, ..Default::default() };
    let mut ticking = bursty_cfg(Some(frozen));
    ticking.duration_s = 60.0;

    let runs: Vec<SimReport> = parallel_map(2, |i| {
        ClusterSim::new(if i == 0 { short.clone() } else { ticking.clone() }).run()
    });
    let (a, b) = (&runs[0], &runs[1]);
    assert_eq!(b.migrations_total, 0);
    assert!(!b.prefill_pressure_timeline.is_empty(), "the controller did tick");

    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    // (sim_end_s and the end-normalized utilization means are NOT
    // compared: the final tick legitimately advances the clock up to one
    // interval past the last finish.)
    match (&a.ttft, &b.ttft) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count);
            assert!(feq(x.mean, y.mean) && feq(x.p50, y.p50) && feq(x.p99, y.p99));
        }
        (None, None) => {}
        _ => panic!("ttft presence differs"),
    }
    match (&a.tpot, &b.tpot) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count);
            assert!(feq(x.mean, y.mean) && feq(x.p50, y.p50) && feq(x.p99, y.p99));
        }
        (None, None) => {}
        _ => panic!("tpot presence differs"),
    }
    assert_eq!(a.decode_occupancy.points(), b.decode_occupancy.points());
    assert_eq!(a.batch_size.points(), b.batch_size.points());
    assert_eq!(a.graph_selections, b.graph_selections);
    assert_eq!(a.graph_bucket_hits, b.graph_bucket_hits);
    // The only allowed difference: the tick events themselves.
    assert!(b.events_processed > a.events_processed);
}

/// The acceptance bar: on the bursty trace the dynamic rebalancer
/// migrates (offloading more whenever troughs leave OB headroom the
/// admission-time split can't reach) and overall throughput is at least
/// the static `LoadAware` baseline's.
#[test]
fn dynamic_rebalancing_beats_static_on_bursty_trace() {
    let cfgs = [bursty_cfg(None), bursty_cfg(Some(RebalanceConfig::default()))];
    let runs: Vec<SimReport> = parallel_map(2, |i| ClusterSim::new(cfgs[i].clone()).run());
    let (stat, dyn_) = (&runs[0], &runs[1]);

    assert_eq!(stat.migrations_total, 0);
    assert!(dyn_.migrations_total > 0, "the controller must act on this trace");
    assert!(dyn_.migrations_to_offload > 0, "troughs leave OB headroom to claim");
    assert!(dyn_.tokens_conserved, "migrations must not corrupt token accounting");
    assert_eq!(dyn_.preemptions, dyn_.req_preemptions_total);
    assert!(dyn_.migration_tokens_moved > 0, "token movement must be recorded");
    assert!(
        dyn_.throughput >= stat.throughput * 0.99,
        "dynamic {} must not lose to static {}",
        dyn_.throughput,
        stat.throughput
    );
    if dyn_.finished == dyn_.arrived {
        assert_eq!(dyn_.metadata_residual, 0, "proxy metadata must drain");
    }
}

/// The burst signal itself: the prefill-pressure samples must cross both
/// edges of the default hysteresis band (0.25 / 0.75), and the offloaded
/// fraction must actually move in response — the tracking the `rebalance`
/// figure group charts.
#[test]
fn pressure_spans_the_band_and_fraction_responds() {
    let r = ClusterSim::new(bursty_cfg(Some(RebalanceConfig::default()))).run();
    let pressure = &r.prefill_pressure_timeline;
    assert!(!pressure.is_empty());
    let pmax = pressure.max_value().unwrap();
    let pmin = pressure.min_value().unwrap();
    assert!(pmax >= 0.75, "bursts must push pressure past the band, got {pmax}");
    assert!(pmin <= 0.25, "troughs must drain below the band, got {pmin}");

    let frac = &r.offloaded_frac_timeline;
    assert_eq!(frac.len(), pressure.len(), "tick samples stay aligned");
    let fmax = frac.max_value().unwrap();
    let fmin = frac.min_value().unwrap();
    assert!(fmax - fmin > 0.2, "offloaded fraction must move, range {}", fmax - fmin);
}

/// With a tight executor pool, prefill bursts block offloaded prompts at
/// dispatch; the controller must reclaim (offloaded → local) to unblock
/// them — both migration directions fire, and accounting survives.
#[test]
fn tight_executor_pool_forces_reclaim_migrations() {
    let mut cfg = bursty_cfg(Some(RebalanceConfig::default()));
    cfg.serving.executor_kv_capacity_tokens = Some(32 * 1024);
    let r = ClusterSim::new(cfg).run();
    assert!(r.finished > 0);
    assert!(r.migrations_to_local > 0, "blocked dispatch must trigger reclaim");
    assert!(r.migrations_to_offload > 0, "troughs must still refill the pool");
    assert!(r.tokens_conserved);
    assert_eq!(r.preemptions, r.req_preemptions_total);
    if r.finished == r.arrived {
        assert_eq!(r.metadata_residual, 0);
    }
}

/// Migration churn on top of preemption churn (tiny pools, long outputs):
/// the two recovery paths must compose without corrupting accounting.
#[test]
fn rebalancing_composes_with_preemption_churn() {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::OpenThoughts, 1.0);
    cfg.duration_s = 20.0;
    cfg.arrivals = ArrivalPattern::Bursty { period_s: 8.0, duty: 0.25, mult: 3.0 };
    cfg.serving.decode_kv_capacity_tokens = Some(16 * 1024);
    cfg.serving.executor_kv_capacity_tokens = Some(16 * 1024);
    cfg.serving.rebalance = Some(RebalanceConfig::default());
    let r = ClusterSim::new(cfg).run();
    assert!(r.preemptions > 0, "tiny pools must preempt");
    assert!(r.tokens_conserved, "accounting must survive preempt+migrate churn");
    assert_eq!(r.preemptions, r.req_preemptions_total);
    assert!(r.finished > 0);
}

/// Rebalancing runs stay seed-deterministic, migrations included.
#[test]
fn rebalancing_is_deterministic_given_seed() {
    let mut cfg = bursty_cfg(Some(RebalanceConfig::default()));
    cfg.duration_s = 45.0;
    let a = ClusterSim::new(cfg.clone()).run();
    let b = ClusterSim::new(cfg).run();
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.migrations_total, b.migrations_total);
    assert_eq!(a.migrations_to_offload, b.migrations_to_offload);
    assert_eq!(a.migrations_to_local, b.migrations_to_local);
    assert_eq!(a.migration_tokens_moved, b.migration_tokens_moved);
    assert_eq!(a.finished, b.finished);
    assert!(feq(a.throughput, b.throughput));
    assert_eq!(a.offloaded_frac_timeline.points(), b.offloaded_frac_timeline.points());
    assert_eq!(a.prefill_pressure_timeline.points(), b.prefill_pressure_timeline.points());
}

/// The diurnal pattern drives the same machinery more gently: the sim
/// runs, conserves, and (with rebalancing) keeps metadata consistent.
#[test]
fn diurnal_trace_runs_clean_with_rebalancing() {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 12.0);
    cfg.duration_s = 60.0;
    cfg.arrivals = ArrivalPattern::Diurnal { period_s: 40.0, depth: 0.8 };
    cfg.serving.rebalance = Some(RebalanceConfig::default());
    let r = ClusterSim::new(cfg).run();
    assert!(r.finished > 0);
    assert!(r.tokens_conserved);
    assert!(!r.prefill_pressure_timeline.is_empty());
    if r.finished == r.arrived {
        assert_eq!(r.metadata_residual, 0);
    }
}
