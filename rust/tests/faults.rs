//! Fault-injection plane (ISSUE 6) — crashes, retries, degradation.
//!
//! Four contracts pinned here:
//!
//! 1. **No request is lost.** Any fault schedule — scripted crashes,
//!    stochastic MTBF/MTTR chains, flaky transfers, stragglers — must
//!    still drain every request with exact token accounting (the
//!    recompute/re-route recovery paths, plus the debug-build proxy
//!    `used_token` vs sim `kv_tokens` lock-step checks armed in every
//!    run below).
//! 2. **Leap bit-identity with faults enabled.** Faults are ordinary
//!    queued events, so PR 5's strict next-event horizon must fence
//!    them with no new machinery: a leap run's `SimReport` matches the
//!    `ServingConfig::no_leap` reference bit for bit on everything but
//!    `events_processed`, across the fault scenario matrix. CI re-runs
//!    this suite under `ADRENALINE_NO_LEAP=1` so both modes stay green.
//! 3. **A no-op `FaultConfig` changes observation, not physics.** Arming
//!    the plane with nothing to inject adds heartbeat events and the
//!    health timeline — every step, token, preemption, migration and
//!    routing decision stays identical to `fault: None`.
//! 4. **Graceful beats naive.** Health-aware degradation (mask crashed
//!    instances out of routing, keep executor-resident KV on a decode
//!    crash) must dominate the naive baseline (`health_aware: false`)
//!    on crash traces — higher drain throughput, less recompute replay.

use adrenaline::config::{FaultConfig, FaultKind, ModelSpec, ScriptedFault};
use adrenaline::metrics::{LatencyStats, Timeline};
use adrenaline::sim::{parallel_map, ClusterSim, SimConfig, SimReport};
use adrenaline::workload::WorkloadKind;

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn assert_timeline_eq(name: &str, a: &Timeline, b: &Timeline) {
    assert_eq!(a.len(), b.len(), "{name}: timeline lengths differ");
    for (i, (pa, pb)) in a.points().iter().zip(b.points()).enumerate() {
        assert!(
            feq(pa.0, pb.0) && feq(pa.1, pb.1),
            "{name}[{i}]: {pa:?} vs {pb:?}"
        );
    }
}

fn assert_stats_eq(name: &str, a: &Option<LatencyStats>, b: &Option<LatencyStats>) {
    match (a, b) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count, "{name} count");
            assert!(feq(x.mean, y.mean), "{name} mean: {} vs {}", x.mean, y.mean);
            assert!(feq(x.p50, y.p50), "{name} p50");
            assert!(feq(x.p99, y.p99), "{name} p99");
            assert!(feq(x.max, y.max), "{name} max");
        }
        (None, None) => {}
        _ => panic!("{name} presence differs"),
    }
}

/// Run `cfg` with leaping on and off; returns (leap, reference).
fn leap_pair(cfg: &SimConfig) -> (SimReport, SimReport) {
    let mut on = cfg.clone();
    on.serving.no_leap = false;
    let mut off = cfg.clone();
    off.serving.no_leap = true;
    let mut runs: Vec<SimReport> = parallel_map(2, |i| {
        ClusterSim::new(if i == 0 { on.clone() } else { off.clone() }).run()
    });
    let off = runs.pop().expect("two runs");
    let on = runs.pop().expect("two runs");
    (on, off)
}

/// Everything in the report except `events_processed` must match bit for
/// bit between the leap run `a` and the per-step reference `b` — the
/// step_leap.rs contract, fault fields included.
fn assert_bit_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.req_preemptions_total, b.req_preemptions_total);
    assert_eq!(a.tokens_conserved, b.tokens_conserved);
    assert_eq!(a.steps_simulated, b.steps_simulated, "step counts must agree");
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.prefill_hbm_capacity_util, b.prefill_hbm_capacity_util));
    assert!(feq(a.prefill_hbm_bw_util, b.prefill_hbm_bw_util));
    assert!(feq(a.executor_duty, b.executor_duty));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    assert!(feq(a.ttft_slo_attainment, b.ttft_slo_attainment));
    assert!(feq(a.tpot_slo_attainment, b.tpot_slo_attainment));
    assert!(feq(a.sim_end_s, b.sim_end_s), "{} vs {}", a.sim_end_s, b.sim_end_s);
    assert_stats_eq("ttft", &a.ttft, &b.ttft);
    assert_stats_eq("tpot", &a.tpot, &b.tpot);
    assert_timeline_eq("decode_occupancy", &a.decode_occupancy, &b.decode_occupancy);
    assert_timeline_eq("prefill_occupancy", &a.prefill_occupancy, &b.prefill_occupancy);
    assert_timeline_eq("batch_size", &a.batch_size, &b.batch_size);
    assert_eq!(a.migrations_total, b.migrations_total);
    assert_eq!(a.migration_tokens_moved, b.migration_tokens_moved);
    assert_eq!(a.metadata_residual, b.metadata_residual);
    assert_eq!(a.decision_counts, b.decision_counts);
    assert_eq!(a.decision_counts_rerouted, b.decision_counts_rerouted);
    // Fault plane: schedules, recoveries, retry chains and health
    // sampling must replay identically through leaps.
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.requests_recovered, b.requests_recovered);
    assert_eq!(a.recompute_tokens_replayed, b.recompute_tokens_replayed);
    assert_eq!(a.transfer_retries, b.transfer_retries);
    assert!(feq(a.degraded_time_s, b.degraded_time_s));
    assert_timeline_eq("health", &a.health_timeline, &b.health_timeline);
    assert!(
        a.events_processed <= b.events_processed,
        "leaping must never add events: {} vs {}",
        a.events_processed,
        b.events_processed
    );
}

fn base_cfg(rate: f64, duration: f64) -> SimConfig {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, rate);
    cfg.duration_s = duration;
    cfg
}

#[test]
fn scripted_crash_matrix_leap_bit_identity() {
    // All three fault kinds in one run: a prefill crash (offloaded
    // residents recompute), a decode crash (offloaded victims re-route),
    // and a straggler window (slowdown inside leaps) — every recovery
    // path exercised under leaping.
    let mut cfg = base_cfg(4.0, 50.0);
    cfg.cluster.n_prefill = 2;
    cfg.cluster.n_decode = 2;
    cfg.serving.fault = Some(FaultConfig {
        script: vec![
            ScriptedFault { kind: FaultKind::PrefillCrash, instance: 0, at_s: 12.0, down_s: 6.0, group: None },
            ScriptedFault { kind: FaultKind::DecodeCrash, instance: 1, at_s: 20.0, down_s: 5.0, group: None },
            ScriptedFault { kind: FaultKind::Straggler, instance: 1, at_s: 30.0, down_s: 8.0, group: None },
        ],
        ..FaultConfig::default()
    });
    let (on, off) = leap_pair(&cfg);
    assert_eq!(on.faults_injected, 3);
    assert_eq!(on.finished, on.arrived, "no request may be lost");
    assert!(on.tokens_conserved);
    assert!(on.degraded_time_s > 0.0);
    assert_bit_identical(&on, &off);
}

#[test]
fn stochastic_mtbf_leap_bit_identity() {
    // Seeded MTBF/MTTR chains on both instance classes: failures keep
    // firing for the whole run and every schedule draw must replay
    // identically whether or not decode steps leap between them.
    let mut cfg = base_cfg(2.0, 45.0);
    cfg.cluster.n_prefill = 2;
    cfg.serving.fault = Some(FaultConfig {
        prefill_mtbf_s: Some(12.0),
        prefill_mttr_s: 2.0,
        decode_mtbf_s: Some(18.0),
        decode_mttr_s: 2.0,
        ..FaultConfig::default()
    });
    let (on, off) = leap_pair(&cfg);
    assert!(on.faults_injected > 0, "MTBF 12 s over 45 s must fire");
    assert_eq!(on.finished, on.arrived, "no request may be lost");
    assert!(on.tokens_conserved);
    assert_bit_identical(&on, &off);
}

#[test]
fn transfer_failure_leap_bit_identity() {
    // Flaky KV links: retry chains (exponential backoff) interleave with
    // leaps, and exhausted retries fall back to recompute.
    let mut cfg = base_cfg(2.0, 40.0);
    cfg.serving.fault = Some(FaultConfig {
        transfer_fail_prob: 0.4,
        transfer_max_retries: 2,
        transfer_backoff_s: 0.02,
        ..FaultConfig::default()
    });
    let (on, off) = leap_pair(&cfg);
    assert!(on.transfer_retries > 0, "p=0.4 over 40 s must retry");
    assert_eq!(on.finished, on.arrived, "no request may be lost");
    assert!(on.tokens_conserved);
    assert_bit_identical(&on, &off);
}

#[test]
fn noop_fault_config_changes_observation_not_physics() {
    // An armed-but-empty fault plane adds heartbeat events and the
    // health timeline; everything the requests experience is identical.
    // (Event-clock-derived readouts — `sim_end_s`, the report-time
    // occupancy closing sample — may trail by up to one heartbeat, since
    // the final tick pops after the last finish.)
    let plain_cfg = base_cfg(2.0, 40.0);
    let mut armed_cfg = plain_cfg.clone();
    armed_cfg.serving.fault = Some(FaultConfig::default());
    let plain = ClusterSim::new(plain_cfg).run();
    let armed = ClusterSim::new(armed_cfg).run();

    assert_eq!(armed.faults_injected, 0);
    assert_eq!(armed.requests_recovered, 0);
    assert_eq!(armed.recompute_tokens_replayed, 0);
    assert_eq!(armed.transfer_retries, 0);
    assert!(feq(armed.degraded_time_s, 0.0));

    assert_eq!(armed.arrived, plain.arrived);
    assert_eq!(armed.finished, plain.finished);
    assert_eq!(armed.preemptions, plain.preemptions);
    assert_eq!(armed.req_preemptions_total, plain.req_preemptions_total);
    assert_eq!(armed.tokens_conserved, plain.tokens_conserved);
    assert_eq!(armed.steps_simulated, plain.steps_simulated);
    assert!(feq(armed.offloaded_fraction, plain.offloaded_fraction));
    assert_stats_eq("ttft", &armed.ttft, &plain.ttft);
    assert_stats_eq("tpot", &armed.tpot, &plain.tpot);
    assert_timeline_eq("decode_occupancy", &armed.decode_occupancy, &plain.decode_occupancy);
    assert_timeline_eq("batch_size", &armed.batch_size, &plain.batch_size);
    assert_eq!(armed.migrations_total, plain.migrations_total);
    assert_eq!(armed.decision_counts, plain.decision_counts);
    assert_eq!(armed.decision_counts_rerouted, plain.decision_counts_rerouted);
    assert_eq!(armed.metadata_residual, plain.metadata_residual);

    // The additions: heartbeat events and the (all-healthy) timeline.
    assert!(armed.events_processed > plain.events_processed);
    assert!(plain.health_timeline.is_empty());
    assert!(!armed.health_timeline.is_empty());
    assert!(feq(armed.health_timeline.min_value().expect("sampled"), 1.0));
    let hb = FaultConfig::default().heartbeat_s;
    assert!(armed.sim_end_s >= plain.sim_end_s - 1e-9);
    assert!(
        armed.sim_end_s <= plain.sim_end_s + hb + 1e-9,
        "trailing heartbeat bounded by one interval: {} vs {}",
        armed.sim_end_s,
        plain.sim_end_s
    );
}

#[test]
fn graceful_degradation_beats_naive_on_prefill_crash() {
    // Two prefill instances, one crashes across the trace's tail. Naive
    // keeps round-robining arrivals onto the corpse — that cohort stalls
    // until recovery at t=65, well past the last arrival, and stretches
    // the drain. Graceful masks the instance at the next heartbeat and
    // pushes everything through the survivor. Same work, same physics —
    // graceful must drain at least as fast.
    let mut cfg = base_cfg(6.0, 60.0);
    cfg.cluster.n_prefill = 2;
    let script = vec![ScriptedFault {
        kind: FaultKind::PrefillCrash,
        instance: 0,
        at_s: 45.0,
        down_s: 20.0,
        group: None,
    }];
    let mut g_cfg = cfg.clone();
    g_cfg.serving.fault =
        Some(FaultConfig { script: clone_script(&script), health_aware: true, ..FaultConfig::default() });
    let mut n_cfg = cfg;
    n_cfg.serving.fault =
        Some(FaultConfig { script, health_aware: false, ..FaultConfig::default() });
    let mut runs: Vec<SimReport> = parallel_map(2, |i| {
        ClusterSim::new(if i == 0 { g_cfg.clone() } else { n_cfg.clone() }).run()
    });
    let naive = runs.pop().expect("two runs");
    let graceful = runs.pop().expect("two runs");

    assert_eq!(graceful.finished, graceful.arrived, "graceful must drain");
    assert_eq!(naive.finished, naive.arrived, "naive stalls but must not lose");
    assert!(graceful.faults_injected == 1 && naive.faults_injected == 1);
    // Requests-per-second over the drain: the throughput pin (window
    // detection is not comparable across such different degradation
    // shapes, drain rate is).
    let g_rate = graceful.finished as f64 / graceful.sim_end_s;
    let n_rate = naive.finished as f64 / naive.sim_end_s;
    assert!(
        g_rate >= n_rate,
        "graceful must sustain >= naive throughput: {g_rate} vs {n_rate} req/s"
    );
    // The stalled-on-the-corpse cohort shows up in naive's tail latency.
    let g_ttft = graceful.ttft.as_ref().expect("finished requests").p99;
    let n_ttft = naive.ttft.as_ref().expect("finished requests").p99;
    assert!(
        g_ttft <= n_ttft,
        "graceful must not worsen tail TTFT: {g_ttft} vs {n_ttft}"
    );
}

fn clone_script(s: &[ScriptedFault]) -> Vec<ScriptedFault> {
    s.to_vec()
}

#[test]
fn graceful_decode_crash_keeps_offloaded_kv() {
    // Offloaded victims' KV lives in executor HBM and survives a decode
    // crash: graceful re-routes them with residency intact, naive
    // replays every victim from scratch.
    let mut cfg = base_cfg(4.0, 50.0);
    cfg.cluster.n_decode = 2;
    let script = vec![ScriptedFault {
        kind: FaultKind::DecodeCrash,
        instance: 0,
        at_s: 20.0,
        down_s: 6.0,
        group: None,
    }];
    let mut g_cfg = cfg.clone();
    g_cfg.serving.fault =
        Some(FaultConfig { script: clone_script(&script), health_aware: true, ..FaultConfig::default() });
    let mut n_cfg = cfg;
    n_cfg.serving.fault =
        Some(FaultConfig { script, health_aware: false, ..FaultConfig::default() });
    let graceful = ClusterSim::new(g_cfg).run();
    let naive = ClusterSim::new(n_cfg).run();

    assert_eq!(graceful.finished, graceful.arrived);
    assert_eq!(naive.finished, naive.arrived);
    assert!(graceful.tokens_conserved && naive.tokens_conserved);
    assert!(graceful.requests_recovered > 0, "the crash must strike live work");
    assert!(
        naive.recompute_tokens_replayed > 0,
        "naive must replay its victims"
    );
    assert!(
        graceful.recompute_tokens_replayed < naive.recompute_tokens_replayed,
        "keeping executor-resident KV must save replay: {} vs {}",
        graceful.recompute_tokens_replayed,
        naive.recompute_tokens_replayed
    );
}

#[test]
fn fault_runs_are_deterministic() {
    // Same seed, same schedule — stochastic chains, retry draws and
    // recovery interleavings included.
    let mut cfg = base_cfg(2.0, 35.0);
    cfg.cluster.n_prefill = 2;
    cfg.serving.fault = Some(FaultConfig {
        prefill_mtbf_s: Some(10.0),
        prefill_mttr_s: 2.0,
        transfer_fail_prob: 0.2,
        ..FaultConfig::default()
    });
    let a = ClusterSim::new(cfg.clone()).run();
    let b = ClusterSim::new(cfg).run();
    assert_eq!(a.events_processed, b.events_processed);
    assert_bit_identical(&a, &b);
}

#[test]
fn property_no_request_lost_under_random_fault_schedules() {
    // Random topologies x random scripts x random stochastic chains x
    // random link flakiness: every schedule must drain every request
    // with exact token accounting (and, in debug builds, with the
    // aggregate/proxy-token invariants armed on every step start).
    adrenaline::util::prop::check("faults_no_request_lost", 5, |rng| {
        let mut cfg = base_cfg(0.5 + rng.f64() * 1.5, 15.0 + rng.f64() * 10.0);
        cfg.seed = rng.next_u64();
        cfg.cluster.n_prefill = 1 + rng.range_usize(0, 2) as u32;
        cfg.cluster.n_decode = 1 + rng.range_usize(0, 2) as u32;
        let mut fc = FaultConfig::default();
        for _ in 0..(1 + rng.range_usize(0, 3)) {
            let kind = match rng.range_usize(0, 3) {
                0 => FaultKind::PrefillCrash,
                1 => FaultKind::DecodeCrash,
                _ => FaultKind::Straggler,
            };
            let limit = match kind {
                FaultKind::DecodeCrash => cfg.cluster.n_decode as usize,
                _ => cfg.cluster.n_prefill as usize,
            };
            fc.script.push(ScriptedFault {
                kind,
                instance: rng.range_usize(0, limit),
                at_s: 2.0 + rng.f64() * (cfg.duration_s - 4.0),
                down_s: 1.0 + rng.f64() * 8.0,
                group: None,
            });
        }
        if rng.range_usize(0, 2) == 0 {
            fc.transfer_fail_prob = rng.f64() * 0.5;
        }
        if rng.range_usize(0, 2) == 0 {
            fc.prefill_mtbf_s = Some(10.0 + rng.f64() * 20.0);
            fc.prefill_mttr_s = 1.0 + rng.f64() * 3.0;
        }
        if rng.range_usize(0, 2) == 0 {
            fc.decode_mtbf_s = Some(10.0 + rng.f64() * 20.0);
            fc.decode_mttr_s = 1.0 + rng.f64() * 3.0;
        }
        fc.health_aware = rng.range_usize(0, 2) == 0;
        cfg.serving.fault = Some(fc);
        let r = ClusterSim::new(cfg).run();
        assert_eq!(r.finished, r.arrived, "no request may be lost under faults");
        assert!(r.tokens_conserved, "recovery must keep token accounting exact");
    });
}
