//! Integration tests asserting the *paper-shape* claims end-to-end on the
//! A100-scale simulator: who wins, by roughly what factor, and where the
//! crossovers fall (DESIGN.md §4). Absolute numbers are testbed-specific;
//! these tests pin the qualitative structure of every headline figure.
//!
//! Anchors are calibrated for the *bucketed* cost model (the default
//! since the cost-plane refactor: decode steps pay the padded rows of
//! the 2-D executable grid). Padding perturbs absolute step times by at
//! most the non-attention kernels' near-flat batch scaling plus one
//! dummy KV slot per padded attention row, so the paper-shape ratios are
//! only nudged; bands below were widened where the old bound sat close
//! to the measured exact-cost value (see EXPERIMENTS.md §Perf for the
//! recalibration protocol, and `ADRENALINE_EXACT_COSTS=1` to reproduce
//! the pre-refactor numbers bit-for-bit).

use adrenaline::config::ModelSpec;
use adrenaline::sim::{run_e2e_with, ClusterSim, E2eConfig, ExecMode, SimConfig};
use adrenaline::workload::WorkloadKind;

fn quick(model: ModelSpec, workload: WorkloadKind, on: bool, rate: f64, dur: f64) -> adrenaline::sim::SimReport {
    let mut cfg = if on {
        SimConfig::paper_default(model, workload, rate)
    } else {
        SimConfig::baseline(model, workload, rate)
    };
    cfg.duration_s = dur;
    ClusterSim::new(cfg).run()
}

/// Fig 11a shape: once the decode pool saturates, vLLM's TTFT explodes
/// (queueing) while Adrenaline defers the explosion.
#[test]
fn fig11a_ttft_blowup_at_saturation() {
    // The crossover band: vLLM is past its sustainable rate (~15 req/s on
    // this testbed), Adrenaline is not (its decode capacity is ~1.4x).
    let rate = 20.0;
    let base = quick(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, false, rate, 120.0);
    let adre = quick(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, true, rate, 120.0);
    let b = base.ttft.unwrap().mean;
    let a = adre.ttft.unwrap().mean;
    assert!(b / a > 3.0, "vLLM TTFT {b:.2}s should dwarf Adrenaline's {a:.2}s");
}

/// Fig 11d shape: baseline throughput plateaus, Adrenaline scales past it.
#[test]
fn fig11d_throughput_win_after_plateau() {
    let m = ModelSpec::llama2_7b();
    let base_lo = quick(m, WorkloadKind::ShareGpt, false, 16.0, 120.0);
    let base_hi = quick(m, WorkloadKind::ShareGpt, false, 32.0, 120.0);
    // Plateau: doubling the rate adds <15% throughput for the baseline.
    assert!(
        base_hi.throughput < base_lo.throughput * 1.15,
        "baseline should plateau: {} -> {}",
        base_lo.throughput,
        base_hi.throughput
    );
    let adre_hi = quick(m, WorkloadKind::ShareGpt, true, 32.0, 120.0);
    let speedup = adre_hi.throughput / base_hi.throughput;
    // Band floor recalibrated 1.2 -> 1.15 for bucketed costs: Adrenaline's
    // larger combined (local + offloaded) batches pad slightly more than
    // the baseline's local-only batches.
    assert!(
        (1.15..2.2).contains(&speedup),
        "Adrenaline speedup at saturation = {speedup:.2} (paper: ~1.47x for 7B ShareGPT)"
    );
}

/// Figs 13/14 shape: OpenThoughts' long outputs cause heavy preemption in
/// the baseline; Adrenaline mitigates it and cuts mean TPOT.
#[test]
fn fig13_openthoughts_preemption_mitigation() {
    let m = ModelSpec::llama2_7b();
    let base = quick(m, WorkloadKind::OpenThoughts, false, 2.0, 120.0);
    let adre = quick(m, WorkloadKind::OpenThoughts, true, 2.0, 120.0);
    assert!(base.preemptions > 50, "baseline preempts heavily: {}", base.preemptions);
    assert!(
        adre.preemptions < base.preemptions / 4,
        "Adrenaline cuts preemptions: {} vs {}",
        adre.preemptions,
        base.preemptions
    );
    let tb = base.tpot.unwrap().mean;
    let ta = adre.tpot.unwrap().mean;
    assert!(
        ta < tb * 0.85,
        "mean TPOT improves (paper: 26.9-29.5%): {ta:.4} vs {tb:.4}"
    );
    // P99 TPOT also improves (paper: 48.5-58.8% for 7B).
    let pb = base.tpot.unwrap().p99;
    let pa = adre.tpot.unwrap().p99;
    assert!(pa < pb, "P99 TPOT: {pa:.4} vs {pb:.4}");
}

/// Fig 16 shape: prefill-instance HBM capacity utilization roughly doubles
/// (paper: 2.28x) once the executor pool fills.
#[test]
fn fig16_prefill_hbm_capacity_gain() {
    let m = ModelSpec::llama2_7b();
    let base = quick(m, WorkloadKind::ShareGpt, false, 24.0, 120.0);
    let adre = quick(m, WorkloadKind::ShareGpt, true, 24.0, 120.0);
    let gain = adre.prefill_hbm_capacity_util / base.prefill_hbm_capacity_util;
    assert!(
        (1.5..3.5).contains(&gain),
        "capacity utilization gain = {gain:.2} (paper: 2.28x)"
    );
}

/// Fig 17a shape: prefill-instance bandwidth utilization rises with
/// offloading (paper: 1.49-2.07x).
#[test]
fn fig17a_prefill_bandwidth_gain() {
    let m = ModelSpec::llama2_7b();
    let base = quick(m, WorkloadKind::ShareGpt, false, 24.0, 120.0);
    let adre = quick(m, WorkloadKind::ShareGpt, true, 24.0, 120.0);
    assert!(
        adre.prefill_hbm_bw_util > base.prefill_hbm_bw_util * 1.2,
        "bw util: {} vs {}",
        adre.prefill_hbm_bw_util,
        base.prefill_hbm_bw_util
    );
}

/// Fig 17b shape: decode compute utilization rises with the bigger batch
/// (paper: 1.67x).
#[test]
fn fig17b_decode_compute_gain() {
    let m = ModelSpec::llama2_7b();
    let base = quick(m, WorkloadKind::ShareGpt, false, 24.0, 120.0);
    let adre = quick(m, WorkloadKind::ShareGpt, true, 24.0, 120.0);
    let gain = adre.decode_compute_util / base.decode_compute_util;
    assert!((1.1..2.5).contains(&gain), "decode compute gain = {gain:.2} (paper: 1.67x)");
}

/// 13B shows the same structure (Figs 12/14/17).
#[test]
fn llama13b_same_shapes() {
    let m = ModelSpec::llama2_13b();
    let base = quick(m, WorkloadKind::ShareGpt, false, 16.0, 120.0);
    let adre = quick(m, WorkloadKind::ShareGpt, true, 16.0, 120.0);
    assert!(adre.throughput > base.throughput, "{} vs {}", adre.throughput, base.throughput);
    assert!(adre.prefill_hbm_capacity_util > base.prefill_hbm_capacity_util);
}

/// The e2e driver produces both systems at every rate (the figure path).
#[test]
fn e2e_driver_integrity() {
    let cfg = E2eConfig {
        rates: vec![8.0, 24.0],
        duration_s: 60.0,
        ..E2eConfig::fig11()
    };
    let pts = run_e2e_with(&cfg, ExecMode::Parallel);
    assert_eq!(pts.len(), 4);
    for p in &pts {
        assert!(p.finished > 0);
        assert!(p.throughput_tok_s > 0.0);
        if p.system == "vllm" {
            assert_eq!(p.offloaded_fraction, 0.0);
        }
    }
}

/// SLO attainment / goodput (DistServe-style): at saturation, Adrenaline
/// keeps more requests inside the TTFT+TPOT SLOs than the baseline.
#[test]
fn slo_attainment_and_goodput() {
    let m = ModelSpec::llama2_7b();
    let base = quick(m, WorkloadKind::ShareGpt, false, 20.0, 120.0);
    let adre = quick(m, WorkloadKind::ShareGpt, true, 20.0, 120.0);
    assert!(base.ttft_slo_attainment <= 1.0 && base.ttft_slo_attainment >= 0.0);
    assert!(
        adre.ttft_slo_attainment > base.ttft_slo_attainment,
        "TTFT attainment: {} vs {}",
        adre.ttft_slo_attainment,
        base.ttft_slo_attainment
    );
    assert!(
        adre.goodput > base.goodput,
        "goodput: {} vs {}",
        adre.goodput,
        base.goodput
    );
    assert!(adre.goodput <= adre.throughput + 1e-9);
}

/// §3.3.2 adaptive partition: a tighter TTFT SLO reserves more SMs for
/// prefill (smaller executor share); the run still completes.
#[test]
fn adaptive_partition_tracks_ttft_slo() {
    let m = ModelSpec::llama2_7b();
    let mut loose = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 8.0);
    loose.serving.slo.ttft_s = 2.0;
    let loose = loose.with_adaptive_partition(1024);

    let mut tight = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 8.0);
    tight.serving.slo.ttft_s = 0.08;
    let tight = tight.with_adaptive_partition(1024);

    assert!(
        tight.cluster.attn_executor_sm_frac <= loose.cluster.attn_executor_sm_frac,
        "tight SLO must not grant the executor more SMs: {} vs {}",
        tight.cluster.attn_executor_sm_frac,
        loose.cluster.attn_executor_sm_frac
    );

    let mut cfg = loose.clone();
    cfg.duration_s = 40.0;
    let r = ClusterSim::new(cfg).run();
    assert!(r.finished > 0);
}

/// §3.4.2 flexibility: adding a prefill instance raises OB_mem (Eq 1 is
/// linear in n) and with it the offloading capacity and throughput.
#[test]
fn prefill_pool_scaling_raises_capacity() {
    let m = ModelSpec::llama2_7b();
    let mut one = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0);
    one.duration_s = 120.0;
    let one = ClusterSim::new(one).run();

    let mut two = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0);
    two.duration_s = 120.0;
    two.cluster.n_prefill = 2;
    let two = ClusterSim::new(two).run();

    assert!(
        two.throughput > one.throughput * 1.1,
        "2 prefill instances should lift throughput: {} vs {}",
        two.throughput,
        one.throughput
    );
}

/// Conservation laws under random load: no request is lost, every finished
/// request produced exactly its output_len tokens, and the clock is sane.
#[test]
fn property_sim_conservation() {
    adrenaline::util::prop::check("sim_conservation", 12, |rng| {
        let rate = 0.5 + rng.f64() * 20.0;
        let seed = rng.next_u64();
        let workload = if rng.f64() < 0.5 {
            WorkloadKind::ShareGpt
        } else {
            WorkloadKind::OpenThoughts
        };
        let model = if rng.f64() < 0.5 {
            ModelSpec::llama2_7b()
        } else {
            ModelSpec::llama2_13b()
        };
        let mut cfg = SimConfig::paper_default(model, workload, rate);
        cfg.duration_s = 20.0;
        cfg.seed = seed;
        let r = ClusterSim::new(cfg).run();
        assert!(r.finished <= r.arrived, "finished {} > arrived {}", r.finished, r.arrived);
        assert_eq!(r.finished, r.arrived, "20s trace must drain (rate {rate:.1})");
        assert!(r.sim_end_s.is_finite() && r.sim_end_s >= 0.0);
        assert!(r.offloaded_fraction >= 0.0 && r.offloaded_fraction <= 1.0);
        assert!(r.goodput <= r.throughput + 1e-9);
        // Occupancy never exceeded 1 (preemption enforced the budget).
        if let Some(max) = r.decode_occupancy.max_value() {
            assert!(max <= 1.0 + 1e-9, "decode occupancy {max}");
        }
    });
}
