//! Heterogeneous device-profile contracts (ISSUE 9).
//!
//! The per-device cost plane must be *structurally inert* by default and
//! *physically sensible* when enabled:
//!
//! * **Homogeneous bit-identity** — `profiles: None`, an all-`None`
//!   [`DeviceProfiles`], and explicit whole-A100 profiles for every role
//!   are three spellings of the same cluster; their reports must agree
//!   bit for bit across the scenario matrix (offload on/off, both
//!   engine paths — CI re-runs this suite under `ADRENALINE_NO_LEAP=1`,
//!   `ADRENALINE_NO_PAR=1` and `ADRENALINE_EXACT_COSTS=1`).
//! * **Executor monotonicity** — a standalone memory-rich executor
//!   (arXiv 2405.01814's H20-style device) must raise Eq 1's OB_mem and
//!   never price a purely-offloaded attention step worse than the
//!   colocated SM share it replaces.
//! * **Intra-GPU split** — a Nexus-style prefill/decode SM split prices
//!   prefill on exactly `partition.rs`'s Fig 10 slowdown curve and
//!   bandwidth on the Fig 9 superlinear curve.
//! * **Determinism** — every heterogeneous scenario replays
//!   bit-identically run over run.

use adrenaline::config::{
    DeviceProfile, DeviceProfiles, DeviceRole, GpuSpec, ModelSpec, OffloadPolicy,
};
use adrenaline::coordinator::OffloadBounds;
use adrenaline::gpu_model::{prefill_slowdown, CostMode, CostModel, Roofline};
use adrenaline::metrics::{LatencyStats, Timeline};
use adrenaline::sim::{ClusterSim, SimConfig, SimReport};
use adrenaline::workload::WorkloadKind;

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn assert_timeline_eq(name: &str, a: &Timeline, b: &Timeline) {
    assert_eq!(a.len(), b.len(), "{name}: timeline lengths differ");
    for (i, (pa, pb)) in a.points().iter().zip(b.points()).enumerate() {
        assert!(
            feq(pa.0, pb.0) && feq(pa.1, pb.1),
            "{name}[{i}]: {pa:?} vs {pb:?}"
        );
    }
}

fn assert_stats_eq(name: &str, a: &Option<LatencyStats>, b: &Option<LatencyStats>) {
    match (a, b) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count, "{name} count");
            assert!(feq(x.mean, y.mean), "{name} mean: {} vs {}", x.mean, y.mean);
            assert!(feq(x.p50, y.p50), "{name} p50");
            assert!(feq(x.p99, y.p99), "{name} p99");
            assert!(feq(x.max, y.max), "{name} max");
        }
        (None, None) => {}
        _ => panic!("{name} presence differs"),
    }
}

/// Full-report bitwise equality (`fleet.rs` house style): both sides of
/// every pairing take the same engine path, so even `events_processed`
/// must match.
fn assert_report_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.req_preemptions_total, b.req_preemptions_total);
    assert_eq!(a.tokens_conserved, b.tokens_conserved);
    assert_eq!(a.steps_simulated, b.steps_simulated, "step counts must agree");
    assert_eq!(a.events_processed, b.events_processed, "event counts must agree");
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.prefill_hbm_capacity_util, b.prefill_hbm_capacity_util));
    assert!(feq(a.prefill_hbm_bw_util, b.prefill_hbm_bw_util));
    assert!(feq(a.executor_bw_util, b.executor_bw_util));
    assert!(feq(a.executor_duty, b.executor_duty));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    assert!(feq(a.ttft_slo_attainment, b.ttft_slo_attainment));
    assert!(feq(a.tpot_slo_attainment, b.tpot_slo_attainment));
    assert!(feq(a.sim_end_s, b.sim_end_s), "{} vs {}", a.sim_end_s, b.sim_end_s);
    assert_stats_eq("ttft", &a.ttft, &b.ttft);
    assert_stats_eq("tpot", &a.tpot, &b.tpot);
    assert_timeline_eq("decode_occupancy", &a.decode_occupancy, &b.decode_occupancy);
    assert_timeline_eq("prefill_occupancy", &a.prefill_occupancy, &b.prefill_occupancy);
    assert_timeline_eq("batch_size", &a.batch_size, &b.batch_size);
    assert_eq!(a.graph_selections, b.graph_selections);
    assert_eq!(a.graph_used_slots, b.graph_used_slots);
    assert_eq!(a.graph_padded_slots, b.graph_padded_slots);
    assert_eq!(a.migrations_total, b.migrations_total);
    assert_eq!(a.migration_tokens_moved, b.migration_tokens_moved);
    assert_eq!(a.bounds_refreshes, b.bounds_refreshes);
    assert_eq!(a.b_tpot_observations, b.b_tpot_observations);
    assert_eq!(a.decision_counts, b.decision_counts);
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.requests_recovered, b.requests_recovered);
    assert!(feq(a.degraded_time_s, b.degraded_time_s));
    assert_timeline_eq("health", &a.health_timeline, &b.health_timeline);
    assert_timeline_eq("prefill_pool", &a.prefill_pool_timeline, &b.prefill_pool_timeline);
    assert_eq!(a.scale_ups, b.scale_ups);
    assert_eq!(a.scale_downs, b.scale_downs);
}

fn base_cfg(rate: f64, duration_s: f64) -> SimConfig {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, rate);
    cfg.duration_s = duration_s;
    cfg
}

/// Explicit whole-A100 profiles for every role: a third spelling of the
/// paper-default homogeneous cluster.
fn explicit_homogeneous() -> DeviceProfiles {
    let a100 = GpuSpec::a100_80g();
    DeviceProfiles {
        prefill: Some(DeviceProfile::whole(a100, DeviceRole::Prefill)),
        decode: Some(DeviceProfile::whole(a100, DeviceRole::Decode)),
        // No executor override: `Some(whole(..))` would mean a *standalone*
        // executor device; the paper default colocates it on prefill SMs.
        executor: None,
    }
}

#[test]
fn homogeneous_profiles_are_bit_identical_to_default() {
    // `profiles: None`, all-None profiles, and explicit whole-A100
    // prefill/decode profiles are the same cluster; the refactor must be
    // invisible in every report field, bit for bit, with offloading both
    // on (paper default) and off (vLLM-style baseline).
    for offload in [None, Some(OffloadPolicy::Disabled)] {
        let mut cfg = base_cfg(8.0, 30.0);
        if let Some(p) = offload {
            cfg.serving.offload = p;
        }
        assert!(cfg.cluster.profiles.is_none(), "paper default must not set profiles");
        let baseline = ClusterSim::new(cfg.clone()).run();
        assert!(baseline.finished > 0);

        let mut all_none = cfg.clone();
        all_none.cluster.profiles = Some(DeviceProfiles::default());
        assert_report_identical(&ClusterSim::new(all_none).run(), &baseline);

        let mut explicit = cfg;
        explicit.cluster.profiles = Some(explicit_homogeneous());
        assert_report_identical(&ClusterSim::new(explicit).run(), &baseline);
    }
}

#[test]
fn explicit_homogeneous_profiles_keep_the_offload_bounds() {
    // The admission plane reads the same Eq 1–3 numbers through the
    // profile indirection.
    let cfg = base_cfg(8.0, 30.0);
    let baseline =
        OffloadBounds::compute(&cfg.cluster, &cfg.model, &cfg.serving.slo, 512);
    let mut explicit = cfg.cluster;
    explicit.profiles = Some(explicit_homogeneous());
    let bounds = OffloadBounds::compute(&explicit, &cfg.model, &cfg.serving.slo, 512);
    assert_eq!(bounds, baseline);
    assert!(baseline.ob_mem > 0.0);
}

#[test]
fn memory_rich_standalone_executor_raises_ob_mem() {
    // arXiv 2405.01814's deployment: attention offloaded to a standalone
    // H20-class device. More lendable HBM (no weights resident) and more
    // achievable bandwidth than the colocated A100 SM share ⇒ Eq 1's
    // OB_mem must strictly rise.
    let cfg = base_cfg(8.0, 30.0);
    let colocated =
        OffloadBounds::compute(&cfg.cluster, &cfg.model, &cfg.serving.slo, 512).ob_mem;
    let mut hetero = cfg.cluster;
    hetero.profiles = Some(DeviceProfiles {
        executor: Some(DeviceProfile::whole(GpuSpec::h20_96g(), DeviceRole::Executor)),
        ..DeviceProfiles::default()
    });
    let standalone = OffloadBounds::ob_mem(&hetero, &cfg.model);
    assert!(
        standalone > colocated,
        "standalone H20 executor must raise OB_mem: {standalone} vs {colocated}"
    );
}

#[test]
fn memory_rich_executor_prices_offloaded_attention_no_worse() {
    // Same comparison at the priced-step level: a purely-offloaded decode
    // step's remote attention on the H20 executor is never slower than on
    // the colocated A100 half-partition (attention is bandwidth-bound at
    // real context lengths, and the H20's achievable bandwidth is higher).
    let a100 = GpuSpec::a100_80g();
    let h20 = GpuSpec::h20_96g();
    let m = ModelSpec::llama2_7b();
    let mk = |rl_exec: &Roofline| {
        CostModel::new(
            &Roofline::whole(a100),
            &Roofline::whole(a100),
            rl_exec,
            &m,
            CostModel::build_grid(&[1, 2, 4, 8], &[1, 2, 4, 8], 256),
            CostMode::Exact,
            None,
            15e-6,
            0.0,
        )
    };
    let mut colocated = mk(&Roofline::partition(a100, 0.5));
    let mut standalone = mk(&Roofline::whole(h20));
    let mut times = Vec::new();
    for ctx_sum in [8 * 256u64, 8 * 1024, 8 * 4096] {
        let slow = colocated.decode_step(0, 0, &[8], &[ctx_sum], &mut times);
        let fast = standalone.decode_step(0, 0, &[8], &[ctx_sum], &mut times);
        assert!(
            fast.remote_attention_s <= slow.remote_attention_s,
            "ctx_sum {ctx_sum}: {} vs {}",
            fast.remote_attention_s,
            slow.remote_attention_s
        );
        assert!(fast.step_s <= slow.step_s, "offloaded step time must be no worse");
    }
}

#[test]
fn intra_gpu_split_prices_on_the_partition_curves() {
    // A Nexus-style single-GPU prefill/decode split: prefill confined to
    // 45% of the SMs pays exactly `prefill_slowdown(0.45)` over the
    // whole-GPU prefill time (Fig 10), and each side's bandwidth follows
    // the Fig 9 superlinear curve through `Roofline::partition`.
    let a100 = GpuSpec::a100_80g();
    let m = ModelSpec::llama2_7b();
    let mk = |rl_prefill: &Roofline| {
        CostModel::new(
            rl_prefill,
            &Roofline::whole(a100),
            &Roofline::partition(a100, 0.25),
            &m,
            CostModel::build_grid(&[1, 2, 4, 8], &[1, 2, 4, 8], 256),
            CostMode::Bucketed,
            None,
            15e-6,
            0.0,
        )
    };
    let mut whole = mk(&Roofline::whole(a100));
    let mut split = mk(&Roofline::partition(a100, 0.45));
    let base = whole.prefill_time(2048, 0.0);
    let expected = base * prefill_slowdown(0.45);
    assert_eq!(split.prefill_time(2048, 0.0).to_bits(), expected.to_bits());

    // And end-to-end: the split cluster simulates cleanly with prefill
    // visibly slower than the whole-GPU reference.
    let mut cfg = base_cfg(4.0, 30.0);
    cfg.serving.offload = OffloadPolicy::Disabled;
    let baseline = ClusterSim::new(cfg.clone()).run();
    cfg.cluster.profiles = Some(DeviceProfiles {
        prefill: Some(DeviceProfile::partitioned(a100, DeviceRole::Prefill, 0.45)),
        decode: Some(DeviceProfile::partitioned(a100, DeviceRole::Decode, 0.55)),
        executor: None,
    });
    let split_run = ClusterSim::new(cfg).run();
    assert!(split_run.finished > 0);
    assert!(split_run.tokens_conserved);
    let (Some(b), Some(s)) = (&baseline.ttft, &split_run.ttft) else {
        panic!("both runs must finish requests");
    };
    assert!(
        s.mean > b.mean,
        "confined prefill must slow TTFT: {} vs {}",
        s.mean,
        b.mean
    );
}

#[test]
fn heterogeneous_scenarios_replay_deterministically() {
    // Bit-identical replays for both new scenario shapes: the standalone
    // H20 executor and the intra-GPU SM split.
    let a100 = GpuSpec::a100_80g();
    let offload_profiles = DeviceProfiles {
        executor: Some(DeviceProfile::whole(GpuSpec::h20_96g(), DeviceRole::Executor)),
        ..DeviceProfiles::default()
    };
    let split_profiles = DeviceProfiles {
        prefill: Some(DeviceProfile::partitioned(a100, DeviceRole::Prefill, 0.45)),
        decode: Some(DeviceProfile::partitioned(a100, DeviceRole::Decode, 0.55)),
        executor: None,
    };
    for (profiles, disable_offload) in [(offload_profiles, false), (split_profiles, true)] {
        let mut cfg = base_cfg(12.0, 25.0);
        cfg.cluster.profiles = Some(profiles);
        if disable_offload {
            cfg.serving.offload = OffloadPolicy::Disabled;
        }
        let a = ClusterSim::new(cfg.clone()).run();
        let b = ClusterSim::new(cfg).run();
        assert!(a.finished > 0);
        assert_report_identical(&a, &b);
    }
}
