//! Steady-state decode leaping — the bit-identity contract (ISSUE 5).
//!
//! Leaping is default-on, so its contract is the strongest the house
//! style has: on every scenario family, a leap run's `SimReport` must be
//! **bit-identical** to the `ServingConfig::no_leap` per-step reference
//! — same f64 op order for step times, duty decay, utilization
//! accumulators and timelines; same integer accounting in bulk — with
//! exactly one allowed difference, `events_processed` (collapsing decode
//! step events into leaps is the point). Figure anchors therefore need
//! no recalibration.
//!
//! The horizon-safety property ("a leap never skips a finish, a KV-pool
//! or executor-pool overflow, or a queued event") is pinned through the
//! same lens: the scenario matrix deliberately includes runs where each
//! of those boundaries fires constantly — finishes everywhere,
//! preemption churn under tiny pools (both overflow kinds), rebalance
//! migrations and bounds-feedback refresh ticks (dense queued events),
//! and two-decode-instance runs (the same-pass sole-starter guard plus
//! the cross-instance executor-pool overflow scan) — and any skipped
//! boundary diverges the reports. `ADRENALINE_NO_LEAP=1`
//! forces the reference path process-wide; CI re-runs this suite under
//! it so both modes stay green (the comparisons then pin the reference
//! against itself, and the default-on structural checks are env-aware).

use adrenaline::config::{BoundsFeedbackConfig, ModelSpec, RebalanceConfig};
use adrenaline::metrics::{LatencyStats, Timeline};
use adrenaline::sim::{parallel_map, ClusterSim, SimConfig, SimReport};
use adrenaline::workload::{ArrivalPattern, WorkloadKind};

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn assert_timeline_eq(name: &str, a: &Timeline, b: &Timeline) {
    assert_eq!(a.len(), b.len(), "{name}: timeline lengths differ");
    for (i, (pa, pb)) in a.points().iter().zip(b.points()).enumerate() {
        assert!(
            feq(pa.0, pb.0) && feq(pa.1, pb.1),
            "{name}[{i}]: {pa:?} vs {pb:?}"
        );
    }
}

/// Run `cfg` with leaping on and off; returns (leap, reference).
fn leap_pair(cfg: &SimConfig) -> (SimReport, SimReport) {
    let mut on = cfg.clone();
    on.serving.no_leap = false;
    let mut off = cfg.clone();
    off.serving.no_leap = true;
    let mut runs: Vec<SimReport> = parallel_map(2, |i| {
        ClusterSim::new(if i == 0 { on.clone() } else { off.clone() }).run()
    });
    let off = runs.pop().expect("two runs");
    let on = runs.pop().expect("two runs");
    (on, off)
}

fn assert_stats_eq(name: &str, a: &Option<LatencyStats>, b: &Option<LatencyStats>) {
    match (a, b) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count, "{name} count");
            assert!(feq(x.mean, y.mean), "{name} mean: {} vs {}", x.mean, y.mean);
            assert!(feq(x.p50, y.p50), "{name} p50");
            assert!(feq(x.p99, y.p99), "{name} p99");
            assert!(feq(x.max, y.max), "{name} max");
        }
        (None, None) => {}
        _ => panic!("{name} presence differs"),
    }
}

/// Everything in the report except `events_processed` must match bit for
/// bit between the leap run `a` and the per-step reference `b`.
fn assert_bit_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.req_preemptions_total, b.req_preemptions_total);
    assert_eq!(a.tokens_conserved, b.tokens_conserved);
    assert_eq!(a.steps_simulated, b.steps_simulated, "step counts must agree");
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.prefill_hbm_capacity_util, b.prefill_hbm_capacity_util));
    assert!(feq(a.prefill_hbm_bw_util, b.prefill_hbm_bw_util));
    assert!(feq(a.executor_bw_util, b.executor_bw_util));
    assert!(feq(a.executor_duty, b.executor_duty));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    assert!(feq(a.ttft_slo_attainment, b.ttft_slo_attainment));
    assert!(feq(a.tpot_slo_attainment, b.tpot_slo_attainment));
    assert!(feq(a.sim_end_s, b.sim_end_s), "{} vs {}", a.sim_end_s, b.sim_end_s);
    assert_stats_eq("ttft", &a.ttft, &b.ttft);
    assert_stats_eq("tpot", &a.tpot, &b.tpot);
    match (&a.window, &b.window) {
        (Some(x), Some(y)) => {
            assert!(feq(x.start, y.start) && feq(x.end, y.end), "window bounds");
            assert_eq!(x.saturated, y.saturated);
        }
        (None, None) => {}
        _ => panic!("stable-window presence differs"),
    }
    assert_timeline_eq("decode_occupancy", &a.decode_occupancy, &b.decode_occupancy);
    assert_timeline_eq("prefill_occupancy", &a.prefill_occupancy, &b.prefill_occupancy);
    assert_timeline_eq("batch_size", &a.batch_size, &b.batch_size);
    assert_eq!(a.exact_costs, b.exact_costs);
    assert_eq!(a.graph_selections, b.graph_selections);
    assert_eq!(a.graph_used_slots, b.graph_used_slots);
    assert_eq!(a.graph_padded_slots, b.graph_padded_slots);
    assert!(feq(a.graph_padding_overhead, b.graph_padding_overhead));
    assert_eq!(a.graph_bucket_hits, b.graph_bucket_hits);
    assert_eq!(a.migrations_total, b.migrations_total);
    assert_eq!(a.migrations_to_offload, b.migrations_to_offload);
    assert_eq!(a.migrations_to_local, b.migrations_to_local);
    assert_eq!(a.migration_tokens_moved, b.migration_tokens_moved);
    assert_timeline_eq("offloaded_frac", &a.offloaded_frac_timeline, &b.offloaded_frac_timeline);
    assert_timeline_eq(
        "prefill_pressure",
        &a.prefill_pressure_timeline,
        &b.prefill_pressure_timeline,
    );
    assert_eq!(a.metadata_residual, b.metadata_residual);
    assert_timeline_eq("b_tpot", &a.b_tpot_timeline, &b.b_tpot_timeline);
    assert_timeline_eq("ob", &a.ob_timeline, &b.ob_timeline);
    assert_eq!(a.bounds_refreshes, b.bounds_refreshes);
    assert_eq!(a.b_tpot_observations, b.b_tpot_observations);
    assert_eq!(a.decision_counts, b.decision_counts);
    assert_eq!(a.decision_counts_rerouted, b.decision_counts_rerouted);
    // Fault-plane availability metrics (ISSUE 6): crash schedules, retry
    // chains and health sampling must replay identically through leaps.
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.requests_recovered, b.requests_recovered);
    assert_eq!(a.recompute_tokens_replayed, b.recompute_tokens_replayed);
    assert_eq!(a.transfer_retries, b.transfer_retries);
    assert!(feq(a.degraded_time_s, b.degraded_time_s));
    assert_timeline_eq("health", &a.health_timeline, &b.health_timeline);
    // The one allowed difference; equality is fine too (under
    // ADRENALINE_NO_LEAP=1 both runs take the reference path).
    assert!(
        a.events_processed <= b.events_processed,
        "leaping must never add events: {} vs {}",
        a.events_processed,
        b.events_processed
    );
}

#[test]
fn baseline_poisson_bit_identity() {
    for policy_on in [true, false] {
        let model = ModelSpec::llama2_7b();
        let mut cfg = if policy_on {
            SimConfig::paper_default(model, WorkloadKind::ShareGpt, 2.0)
        } else {
            SimConfig::baseline(model, WorkloadKind::ShareGpt, 2.0)
        };
        cfg.duration_s = 40.0;
        let (on, off) = leap_pair(&cfg);
        assert!(on.finished > 0);
        assert_bit_identical(&on, &off);
    }
}

#[test]
fn saturated_bit_identity() {
    // The bench's saturation regime: dense batches, dispatch gating,
    // finishes on most steps — the leap boundaries that matter for the
    // perf claim all fire here.
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 24.0);
    cfg.duration_s = 40.0;
    let (on, off) = leap_pair(&cfg);
    assert!(on.finished > 0);
    assert_bit_identical(&on, &off);
}

#[test]
fn bursty_rebalance_bit_identity() {
    // Rebalance ticks + migrations: dense queued events cut leaps and
    // `Phase::Migrating` rows leave batches mid-window.
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 24.0);
    cfg.duration_s = 45.0;
    cfg.arrivals = ArrivalPattern::Bursty { period_s: 30.0, duty: 0.25, mult: 3.0 };
    cfg.serving.rebalance = Some(RebalanceConfig::default());
    let (on, off) = leap_pair(&cfg);
    assert!(on.finished > 0);
    assert!(on.migrations_total > 0, "the controller must act on this trace");
    assert_bit_identical(&on, &off);
}

#[test]
fn diurnal_bounds_feedback_bit_identity() {
    // Online B_TPOT loop: per-step estimator observations must replay in
    // order inside leaps, and refresh ticks must land between them.
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 24.0);
    cfg.duration_s = 45.0;
    cfg.arrivals = ArrivalPattern::Diurnal { period_s: 40.0, depth: 0.8 };
    cfg.cluster.n_prefill = 2;
    cfg.serving.bounds_feedback = Some(BoundsFeedbackConfig::default());
    let (on, off) = leap_pair(&cfg);
    assert!(on.finished > 0);
    assert!(on.b_tpot_observations > 0, "the estimator must observe steps");
    assert_bit_identical(&on, &off);
}

#[test]
fn preemption_churn_bit_identity() {
    // Tiny pools: the leap horizon's overflow bounds (decode KV blocks
    // and executor-pool budgets) fire constantly; an overshot horizon
    // would grant tokens the reference preempts first.
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::OpenThoughts, 1.0);
    cfg.duration_s = 20.0;
    cfg.serving.decode_kv_capacity_tokens = Some(16 * 1024);
    cfg.serving.executor_kv_capacity_tokens = Some(16 * 1024);
    let (on, off) = leap_pair(&cfg);
    assert!(on.preemptions > 0, "tiny pools must preempt");
    assert!(on.tokens_conserved);
    assert_bit_identical(&on, &off);
}

#[test]
fn two_decode_instances_bit_identity() {
    // Cross-instance interleaving: two decode instances share one
    // prefill instance's executor pool, so the cross-instance overflow
    // preemption scan and the run loop's same-pass sole-starter guard
    // both fire (a leap by one instance while another starts in the same
    // pass would emit future-stamped state ahead of the co-starter's
    // pass-time writes — the guard forces both onto the per-step path
    // for that one step).
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::OpenThoughts, 2.0);
    cfg.duration_s = 20.0;
    cfg.cluster.n_decode = 2;
    cfg.serving.executor_kv_capacity_tokens = Some(8 * 1024);
    let (on, off) = leap_pair(&cfg);
    assert!(on.finished > 0);
    assert_bit_identical(&on, &off);
}

#[test]
fn two_decode_instances_with_rebalance_bit_identity() {
    // Tick-driven migrations can free KV blocks on several decode
    // instances inside one pass — a multi-starter scenario, which the
    // epoch engine now owns (prices all lanes, merges deterministically).
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 24.0);
    cfg.duration_s = 40.0;
    cfg.arrivals = ArrivalPattern::Bursty { period_s: 30.0, duty: 0.25, mult: 3.0 };
    cfg.cluster.n_decode = 2;
    cfg.serving.rebalance = Some(RebalanceConfig::default());
    let (on, off) = leap_pair(&cfg);
    assert!(on.finished > 0);
    assert_bit_identical(&on, &off);
}

#[test]
fn exact_costs_bit_identity() {
    // Leaping composes with the exact (pre-bucketing) cost plane: no
    // grid selections, still bit-identical step series.
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 2.0);
    cfg.duration_s = 40.0;
    cfg.serving.exact_costs = true;
    let (on, off) = leap_pair(&cfg);
    assert!(on.exact_costs && on.finished > 0);
    assert_eq!(on.graph_selections, 0);
    assert_bit_identical(&on, &off);
}

#[test]
fn property_bit_identity_random_configs() {
    // Random rates, seeds, pool budgets and durations: the horizon must
    // never skip a finish, an overflow, or a queued event anywhere in
    // the configuration space — any skip diverges the paired reports.
    adrenaline::util::prop::check("step_leap_bit_identity", 5, |rng| {
        let model = ModelSpec::llama2_7b();
        let workload = if rng.range_usize(0, 2) == 0 {
            WorkloadKind::ShareGpt
        } else {
            WorkloadKind::OpenThoughts
        };
        let mut cfg = SimConfig::paper_default(model, workload, 0.5 + rng.f64() * 4.0);
        cfg.duration_s = 10.0 + rng.f64() * 10.0;
        cfg.seed = rng.next_u64();
        cfg.cluster.n_decode = 1 + rng.range_usize(0, 2) as u32;
        if rng.range_usize(0, 2) == 0 {
            let dec = 12 * 1024 + rng.range_usize(0, 32 * 1024);
            let exe = 8 * 1024 + rng.range_usize(0, 16 * 1024);
            cfg.serving.decode_kv_capacity_tokens = Some(dec);
            cfg.serving.executor_kv_capacity_tokens = Some(exe);
        }
        let (on, off) = leap_pair(&cfg);
        assert_bit_identical(&on, &off);
    });
}

#[test]
fn leap_collapses_events_on_quiet_traces() {
    // Low rate => long event-free stretches => large leaps. Skipped (in
    // spirit) under ADRENALINE_NO_LEAP=1, where both runs are the
    // reference and the counts legitimately tie.
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 1.0);
    cfg.duration_s = 30.0;
    let (on, off) = leap_pair(&cfg);
    assert_eq!(on.steps_simulated, off.steps_simulated);
    let env_forced = adrenaline::sim::engine_env().no_leap;
    if env_forced {
        assert_eq!(on.events_processed, off.events_processed);
    } else {
        assert!(
            (on.events_processed as f64) < off.events_processed as f64 * 0.7,
            "quiet traces must leap hard: {} vs {} events",
            on.events_processed,
            off.events_processed
        );
    }
}
