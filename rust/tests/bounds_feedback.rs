//! Online B_TPOT bounds feedback — behavioral contract (ISSUE 4).
//!
//! The static contract ("`bounds_feedback: None` behaves exactly as
//! before the feedback plane existed") is pinned from two sides:
//!
//! * structurally: without the knob no estimator is built, no observation
//!   hook fires, and no refresh tick is scheduled
//!   (`no_feedback_means_no_observation_hooks` in `sim::cluster`);
//! * behaviorally: [`frozen_feedback_is_inert`] shows that even with the
//!   estimator observing every step and refresh ticks firing, a frozen
//!   warm-up gate (`min_observations: u64::MAX`) leaves every simulated
//!   metric bit-identical to the static run — the feedback plane only
//!   perturbs the sim through `Proxy::observe_b_tpot`.
//!
//! The dynamic contract on the bursty trace: refreshes happen, the bound
//! tracks the observed workload, accounting survives, runs stay
//! deterministic, and TPOT-SLO attainment does not lose to the static
//! offline seed.
//!
//! The bursty scenario runs with `n_prefill = 2`: Eq 1's `OB_mem` scales
//! linearly with the prefill pool, so with two instances the compute
//! bound (Eq 2) is the binding term and online B_TPOT movement translates
//! directly into OB movement (at one instance `OB_mem` typically binds
//! and the loop is observational).

use adrenaline::config::{BoundsFeedbackConfig, ModelSpec, RebalanceConfig};
use adrenaline::sim::{parallel_map, ClusterSim, SimConfig, SimReport};
use adrenaline::workload::{ArrivalPattern, WorkloadKind};

/// The §Scenarios burst trace (same shape as the rebalancer suite).
const BURSTY: ArrivalPattern = ArrivalPattern::Bursty { period_s: 30.0, duty: 0.25, mult: 3.0 };

fn bursty_cfg(feedback: Option<BoundsFeedbackConfig>) -> SimConfig {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 24.0);
    cfg.duration_s = 120.0;
    cfg.arrivals = BURSTY;
    cfg.cluster.n_prefill = 2;
    cfg.serving.bounds_feedback = feedback;
    cfg
}

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

/// A ticking feedback plane whose warm-up gate never opens must leave
/// every simulated quantity bit-identical to the static run: the
/// estimator observes every step and every finish, the refresh ticks
/// sample the timelines, but nothing flows back into the proxy.
#[test]
fn frozen_feedback_is_inert() {
    let mut stat = bursty_cfg(None);
    stat.duration_s = 60.0;
    let frozen = BoundsFeedbackConfig { min_observations: u64::MAX, ..Default::default() };
    let mut ticking = bursty_cfg(Some(frozen));
    ticking.duration_s = 60.0;

    let runs: Vec<SimReport> = parallel_map(2, |i| {
        ClusterSim::new(if i == 0 { stat.clone() } else { ticking.clone() }).run()
    });
    let (a, b) = (&runs[0], &runs[1]);
    assert_eq!(b.bounds_refreshes, 0, "the warm-up gate must never open");
    assert!(b.b_tpot_observations > 0, "the estimator did observe");
    assert!(!b.b_tpot_timeline.is_empty(), "the ticks did sample");
    assert_eq!(b.b_tpot_timeline.len(), b.ob_timeline.len());
    // Every sample is the frozen offline seed.
    assert_eq!(b.b_tpot_timeline.min_value(), b.b_tpot_timeline.max_value());
    assert_eq!(b.ob_timeline.min_value(), b.ob_timeline.max_value());

    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    assert!(feq(a.ttft_slo_attainment, b.ttft_slo_attainment));
    assert!(feq(a.tpot_slo_attainment, b.tpot_slo_attainment));
    // (sim_end_s and the end-normalized utilization means are NOT
    // compared: the final tick legitimately advances the clock up to one
    // interval past the last finish.)
    match (&a.ttft, &b.ttft) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count);
            assert!(feq(x.mean, y.mean) && feq(x.p50, y.p50) && feq(x.p99, y.p99));
        }
        (None, None) => {}
        _ => panic!("ttft presence differs"),
    }
    match (&a.tpot, &b.tpot) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count);
            assert!(feq(x.mean, y.mean) && feq(x.p50, y.p50) && feq(x.p99, y.p99));
        }
        (None, None) => {}
        _ => panic!("tpot presence differs"),
    }
    assert_eq!(a.decode_occupancy.points(), b.decode_occupancy.points());
    assert_eq!(a.batch_size.points(), b.batch_size.points());
    assert_eq!(a.graph_selections, b.graph_selections);
    assert_eq!(a.graph_bucket_hits, b.graph_bucket_hits);
    assert_eq!(a.decision_counts, b.decision_counts);
    assert_eq!(a.decision_counts_rerouted, b.decision_counts_rerouted);
    // The only allowed difference: the refresh-tick events themselves.
    assert!(b.events_processed > a.events_processed);
}

/// The live loop: refreshes apply, the published bound tracks the
/// observed workload (the warm-up alone guarantees movement away from
/// the offline seed), and accounting survives.
#[test]
fn online_feedback_refreshes_and_tracks() {
    let r = ClusterSim::new(bursty_cfg(Some(BoundsFeedbackConfig::default()))).run();
    assert!(r.finished > 0);
    assert!(r.b_tpot_observations > 0, "steps must be observed");
    assert!(r.bounds_refreshes > 0, "the warm-up gate must open on this trace");
    assert!(!r.b_tpot_timeline.is_empty());
    assert_eq!(r.b_tpot_timeline.len(), r.ob_timeline.len(), "tick samples stay aligned");
    let bmin = r.b_tpot_timeline.min_value().unwrap();
    let bmax = r.b_tpot_timeline.max_value().unwrap();
    assert!(bmin >= 1.0, "B_TPOT must stay >= 1, got {bmin}");
    assert!(bmax > bmin, "the online bound must move with the workload");
    let omin = r.ob_timeline.min_value().unwrap();
    assert!(omin >= 0.0, "OB must stay >= 0, got {omin}");
    assert!(r.tokens_conserved, "feedback must not corrupt token accounting");
    assert_eq!(r.preemptions, r.req_preemptions_total);
    if r.finished == r.arrived {
        assert_eq!(r.metadata_residual, 0, "proxy metadata must drain");
    }
}

/// The acceptance bar (ISSUE 4): tracking the observed B_TPOT instead of
/// freezing the offline roofline seed must not lose TPOT-SLO attainment
/// on the bursty trace. The same measurement-noise band the rebalancer
/// suite uses (two different-event-stream runs) applies.
#[test]
fn online_feedback_tpot_attainment_not_worse_than_static() {
    let cfgs = [bursty_cfg(None), bursty_cfg(Some(BoundsFeedbackConfig::default()))];
    let runs: Vec<SimReport> = parallel_map(2, |i| ClusterSim::new(cfgs[i].clone()).run());
    let (stat, online) = (&runs[0], &runs[1]);
    assert_eq!(stat.bounds_refreshes, 0);
    assert!(online.bounds_refreshes > 0);
    assert!(
        online.tpot_slo_attainment >= stat.tpot_slo_attainment * 0.99,
        "online bounds lost TPOT attainment: {} vs static {}",
        online.tpot_slo_attainment,
        stat.tpot_slo_attainment
    );
    // And the run must not trade the SLO for collapsed throughput.
    assert!(
        online.throughput >= stat.throughput * 0.9,
        "online {} vs static {} throughput",
        online.throughput,
        stat.throughput
    );
}

/// Feedback + rebalancer: refreshes ride the rebalance ticks (no
/// standalone tick stream), so the three per-tick timelines stay aligned
/// and both control loops act on the live bound.
#[test]
fn feedback_rides_rebalance_ticks() {
    let mut cfg = bursty_cfg(Some(BoundsFeedbackConfig::default()));
    cfg.duration_s = 60.0;
    cfg.serving.rebalance = Some(RebalanceConfig::default());
    let r = ClusterSim::new(cfg).run();
    assert!(r.bounds_refreshes > 0);
    assert!(!r.prefill_pressure_timeline.is_empty());
    assert_eq!(
        r.b_tpot_timeline.len(),
        r.prefill_pressure_timeline.len(),
        "bounds samples must ride the rebalance ticks one-for-one"
    );
    assert!(r.tokens_conserved);
    assert_eq!(r.preemptions, r.req_preemptions_total);
}

/// Preemption churn under the live loop (tiny pools, long outputs): the
/// recompute re-route, the OB accounting it feeds (the ISSUE 4 undercount
/// fix — the debug-build proxy-token invariant in `sim::cluster` fails on
/// the pre-fix router), and the refresh machinery must compose.
#[test]
fn feedback_composes_with_preemption_churn() {
    let mut cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::OpenThoughts, 1.0);
    cfg.duration_s = 20.0;
    cfg.arrivals = ArrivalPattern::Bursty { period_s: 8.0, duty: 0.25, mult: 3.0 };
    cfg.serving.decode_kv_capacity_tokens = Some(16 * 1024);
    cfg.serving.executor_kv_capacity_tokens = Some(16 * 1024);
    cfg.serving.bounds_feedback = Some(BoundsFeedbackConfig::default());
    let r = ClusterSim::new(cfg).run();
    assert!(r.preemptions > 0, "tiny pools must preempt");
    assert!(r.tokens_conserved, "accounting must survive preempt+refresh churn");
    assert_eq!(r.preemptions, r.req_preemptions_total);
    assert!(r.finished > 0);
    // One re-route decision per preemption; one fresh decision per arrival.
    let fresh = r.decision_counts.0 + r.decision_counts.1 + r.decision_counts.2;
    let re = r.decision_counts_rerouted;
    assert_eq!(fresh as usize, r.arrived);
    assert_eq!(re.0 + re.1 + re.2, r.preemptions);
}

/// Feedback runs stay seed-deterministic, refreshes included.
#[test]
fn feedback_is_deterministic_given_seed() {
    let mut cfg = bursty_cfg(Some(BoundsFeedbackConfig::default()));
    cfg.duration_s = 45.0;
    let a = ClusterSim::new(cfg.clone()).run();
    let b = ClusterSim::new(cfg).run();
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.bounds_refreshes, b.bounds_refreshes);
    assert_eq!(a.b_tpot_observations, b.b_tpot_observations);
    assert_eq!(a.finished, b.finished);
    assert!(feq(a.throughput, b.throughput));
    assert_eq!(a.b_tpot_timeline.points(), b.b_tpot_timeline.points());
    assert_eq!(a.ob_timeline.points(), b.ob_timeline.points());
    assert_eq!(a.decision_counts, b.decision_counts);
    assert_eq!(a.decision_counts_rerouted, b.decision_counts_rerouted);
}
