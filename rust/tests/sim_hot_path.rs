//! Hot-path overhaul regression tests (EXPERIMENTS.md §Perf):
//!
//! * token-conservation property under forced KV-pool exhaustion (tiny
//!   HBM budgets + OpenThoughts-style long outputs — the preemption-heavy
//!   regime of Figs 13/14), with monotone preemption counters;
//! * bit-identical SimReports from the parallel sweep driver and the
//!   serial reference path, on both the bucketed (default) and exact
//!   cost paths;
//! * the cost plane's bucketed-vs-exact contract: bucketed step time
//!   dominates exact, with equality on bucket-aligned batches.

use adrenaline::config::{GpuSpec, ModelSpec};
use adrenaline::gpu_model::{CostMode, CostModel, InterferenceModel, Roofline};
use adrenaline::sim::{
    parallel_map, run_e2e_with, run_ratio_sweep_with, ClusterSim, ExecMode, SimConfig, SimReport,
};
use adrenaline::util::prop;
use adrenaline::workload::WorkloadKind;

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

#[test]
fn kv_exhaustion_conserves_tokens() {
    // Tiny pools force continual exhaustion: requests are preempted,
    // recomputed, and re-admitted many times. Conservation must hold
    // throughout, and the global preemption counter must equal the sum of
    // the per-request counters (monotonicity: nothing ever un-counts).
    let m = ModelSpec::llama2_7b();
    let mut cfg = SimConfig::paper_default(m, WorkloadKind::OpenThoughts, 1.0);
    cfg.duration_s = 30.0;
    cfg.serving.decode_kv_capacity_tokens = Some(16 * 1024);
    cfg.serving.executor_kv_capacity_tokens = Some(16 * 1024);
    let r = ClusterSim::new(cfg).run();
    assert!(r.preemptions > 0, "tiny pools must force preemption");
    assert!(r.tokens_conserved, "token accounting must survive preemption churn");
    assert_eq!(r.preemptions, r.req_preemptions_total, "counters must agree");
    assert!(r.finished > 0, "the run must still make progress");
}

#[test]
fn property_exhaustion_conservation_random_budgets() {
    prop::check("sim_exhaustion_conservation", 6, |rng| {
        let budget = 8 * 1024 + rng.range_usize(0, 24 * 1024);
        let rate = 0.5 + rng.f64() * 1.5;
        let m = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::OpenThoughts, rate);
        cfg.duration_s = 15.0;
        cfg.seed = rng.next_u64();
        cfg.serving.decode_kv_capacity_tokens = Some(budget);
        cfg.serving.executor_kv_capacity_tokens = Some(budget / 2);
        let r = ClusterSim::new(cfg).run();
        assert!(r.tokens_conserved, "budget={budget} rate={rate:.2}");
        assert_eq!(r.preemptions, r.req_preemptions_total);
        // Occupancy never exceeds 1: preemption enforced the budget.
        if let Some(max) = r.decode_occupancy.max_value() {
            assert!(max <= 1.0 + 1e-9, "decode occupancy {max}");
        }
    });
}

fn assert_reports_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.steps_simulated, b.steps_simulated);
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.prefill_hbm_capacity_util, b.prefill_hbm_capacity_util));
    assert!(feq(a.prefill_hbm_bw_util, b.prefill_hbm_bw_util));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    assert!(feq(a.executor_duty, b.executor_duty));
    assert!(feq(a.sim_end_s, b.sim_end_s));
    match (&a.ttft, &b.ttft) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count);
            assert!(feq(x.mean, y.mean) && feq(x.p50, y.p50) && feq(x.p99, y.p99));
        }
        (None, None) => {}
        _ => panic!("ttft presence differs"),
    }
    match (&a.tpot, &b.tpot) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count);
            assert!(feq(x.mean, y.mean) && feq(x.p50, y.p50) && feq(x.p99, y.p99));
        }
        (None, None) => {}
        _ => panic!("tpot presence differs"),
    }
    assert_eq!(a.decode_occupancy.points(), b.decode_occupancy.points());
    assert_eq!(a.batch_size.points(), b.batch_size.points());
    // Cost-plane observability must be deterministic too.
    assert_eq!(a.exact_costs, b.exact_costs);
    assert_eq!(a.graph_selections, b.graph_selections);
    assert_eq!(a.graph_used_slots, b.graph_used_slots);
    assert_eq!(a.graph_padded_slots, b.graph_padded_slots);
    assert!(feq(a.graph_padding_overhead, b.graph_padding_overhead));
    assert_eq!(a.graph_bucket_hits, b.graph_bucket_hits);
    // Rebalancer observability: counters, tick samples, residency.
    assert_eq!(a.migrations_total, b.migrations_total);
    assert_eq!(a.migrations_to_offload, b.migrations_to_offload);
    assert_eq!(a.migrations_to_local, b.migrations_to_local);
    assert_eq!(a.migration_tokens_moved, b.migration_tokens_moved);
    assert_eq!(a.offloaded_frac_timeline.points(), b.offloaded_frac_timeline.points());
    assert_eq!(a.prefill_pressure_timeline.points(), b.prefill_pressure_timeline.points());
    assert_eq!(a.metadata_residual, b.metadata_residual);
}

#[test]
fn ratio_sweep_parallel_matches_serial_bitwise() {
    let m = ModelSpec::llama2_7b();
    let ratios = [0.0, 0.4, 0.8];
    let par =
        run_ratio_sweep_with(m, WorkloadKind::ShareGpt, 8.0, &ratios, 30.0, ExecMode::Parallel);
    let ser =
        run_ratio_sweep_with(m, WorkloadKind::ShareGpt, 8.0, &ratios, 30.0, ExecMode::Serial);
    assert_eq!(par.len(), ser.len());
    for ((rp, p), (rs, s)) in par.iter().zip(&ser) {
        assert_eq!(rp, rs, "ratio order must match the serial driver");
        assert_reports_identical(p, s);
    }
}

/// The serial/parallel bitwise-equivalence contract holds on the bucketed
/// cost path (the new default) and on the exact ablation path alike.
#[test]
fn bucketed_and_exact_cost_paths_parallel_match_serial() {
    let m = ModelSpec::llama2_7b();
    let mk = |exact: bool, rate: f64| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
        cfg.duration_s = 25.0;
        cfg.serving.exact_costs = exact;
        cfg
    };
    let cfgs = [mk(false, 4.0), mk(true, 4.0), mk(false, 12.0), mk(true, 12.0)];
    let par: Vec<SimReport> =
        parallel_map(cfgs.len(), |i| ClusterSim::new(cfgs[i].clone()).run());
    let ser: Vec<SimReport> =
        cfgs.iter().map(|c| ClusterSim::new(c.clone()).run()).collect();
    for (p, s) in par.iter().zip(&ser) {
        assert_reports_identical(p, s);
    }
    // The bucketed runs actually exercised the grid; exact runs bypass it.
    assert!(!par[0].exact_costs && par[0].graph_selections > 0);
    assert!(par[0].graph_padded_slots > 0, "real batches rarely land on buckets");
    assert!(par[1].exact_costs);
    assert_eq!(par[1].graph_selections, 0);
}

/// Sim-level fidelity sanity: switching from exact to bucketed charging
/// perturbs throughput by the padding share, not by integer factors —
/// both runs are deterministic, so this is a fixed-number regression
/// band, not a flake risk.
#[test]
fn bucketed_run_stays_near_exact_run() {
    let m = ModelSpec::llama2_7b();
    let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 8.0);
    cfg.duration_s = 60.0;
    let bucketed = ClusterSim::new(cfg.clone()).run();
    cfg.serving.exact_costs = true;
    let exact = ClusterSim::new(cfg).run();
    assert!(bucketed.finished > 0 && exact.finished > 0);
    let ratio = bucketed.throughput / exact.throughput;
    assert!(
        (0.5..1.5).contains(&ratio),
        "bucketed/exact throughput ratio {ratio:.3} (bucketed {} exact {})",
        bucketed.throughput,
        exact.throughput
    );
}

/// The exact-vs-bucketed monotonicity contract at the cost-plane level:
/// a bucketed step is never cheaper than the exact step, and costs the
/// same exactly when the (local, offload) sub-batches land on captured
/// buckets.
#[test]
fn property_bucketed_step_time_dominates_exact() {
    let gpu = GpuSpec::a100_80g();
    let m = ModelSpec::llama2_7b();
    let rl = Roofline::whole(gpu);
    let rl_exec = Roofline::partition(gpu, 0.25);
    let mk = |mode: CostMode| {
        CostModel::new(
            &rl,
            &rl,
            &rl_exec,
            &m,
            CostModel::build_grid(&[1, 2, 4, 8], &[1, 2, 4, 8], 256),
            mode,
            Some(InterferenceModel::new(0.25)),
            15e-6,
            0.0,
        )
    };
    prop::check("sim_bucketed_dominates_exact", 200, |rng| {
        let mut exact = mk(CostMode::Exact);
        let mut bucketed = mk(CostMode::Bucketed);
        let local_rows = rng.range_u64(0, 256);
        let n_exec = rng.range_usize(1, 4);
        let remote_rows: Vec<u64> =
            (0..n_exec).map(|_| rng.range_u64(0, 32)).collect();
        let local_ctx = local_rows * rng.range_u64(1, 1500);
        let remote_ctx: Vec<u64> =
            remote_rows.iter().map(|&r| r * rng.range_u64(1, 1500)).collect();
        let mut out = Vec::new();
        let e = exact.decode_step(local_rows, local_ctx, &remote_rows, &remote_ctx, &mut out);
        let b =
            bucketed.decode_step(local_rows, local_ctx, &remote_rows, &remote_ctx, &mut out);
        assert!(
            b.step_s >= e.step_s,
            "bucketed {} < exact {} (local={local_rows} remote={remote_rows:?})",
            b.step_s,
            e.step_s
        );
        assert_eq!(b.flops.to_bits(), e.flops.to_bits(), "padding must not inflate FLOPs");
    });

    // Equality on a bucket-aligned batch (single executor, both
    // sub-batches exactly at captured capacities).
    let mut exact = mk(CostMode::Exact);
    let mut bucketed = mk(CostMode::Bucketed);
    let mut out = Vec::new();
    let e = exact.decode_step(32, 32 * 800, &[4], &[4 * 800], &mut out);
    let b = bucketed.decode_step(32, 32 * 800, &[4], &[4 * 800], &mut out);
    assert_eq!(b.step_s.to_bits(), e.step_s.to_bits(), "aligned batches pay no padding");
    assert_eq!(bucketed.graph_stats().padded_slots, 0);
}

#[test]
fn e2e_sweep_parallel_matches_serial() {
    let cfg = adrenaline::sim::E2eConfig {
        rates: vec![2.0, 6.0],
        duration_s: 30.0,
        ..adrenaline::sim::E2eConfig::fig13()
    };
    let par = run_e2e_with(&cfg, ExecMode::Parallel);
    let ser = run_e2e_with(&cfg, ExecMode::Serial);
    assert_eq!(par.len(), ser.len());
    for (p, s) in par.iter().zip(&ser) {
        assert_eq!((p.rate, p.system), (s.rate, s.system));
        assert!(feq(p.ttft_mean_s, s.ttft_mean_s));
        assert!(feq(p.tpot_mean_s, s.tpot_mean_s));
        assert!(feq(p.tpot_p99_s, s.tpot_p99_s));
        assert!(feq(p.throughput_tok_s, s.throughput_tok_s));
        assert_eq!(p.finished, s.finished);
        assert_eq!(p.preemptions, s.preemptions);
    }
}
