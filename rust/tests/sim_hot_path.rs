//! Hot-path overhaul regression tests (EXPERIMENTS.md §Perf):
//!
//! * token-conservation property under forced KV-pool exhaustion (tiny
//!   HBM budgets + OpenThoughts-style long outputs — the preemption-heavy
//!   regime of Figs 13/14), with monotone preemption counters;
//! * bit-identical SimReports from the parallel sweep driver and the
//!   serial reference path.

use adrenaline::config::ModelSpec;
use adrenaline::sim::{
    run_e2e, run_e2e_serial, run_ratio_sweep, run_ratio_sweep_serial, ClusterSim, SimConfig,
    SimReport,
};
use adrenaline::util::prop;
use adrenaline::workload::WorkloadKind;

/// NaN-tolerant exact (bitwise) float equality.
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

#[test]
fn kv_exhaustion_conserves_tokens() {
    // Tiny pools force continual exhaustion: requests are preempted,
    // recomputed, and re-admitted many times. Conservation must hold
    // throughout, and the global preemption counter must equal the sum of
    // the per-request counters (monotonicity: nothing ever un-counts).
    let m = ModelSpec::llama2_7b();
    let mut cfg = SimConfig::paper_default(m, WorkloadKind::OpenThoughts, 1.0);
    cfg.duration_s = 30.0;
    cfg.serving.decode_kv_capacity_tokens = Some(16 * 1024);
    cfg.serving.executor_kv_capacity_tokens = Some(16 * 1024);
    let r = ClusterSim::new(cfg).run();
    assert!(r.preemptions > 0, "tiny pools must force preemption");
    assert!(r.tokens_conserved, "token accounting must survive preemption churn");
    assert_eq!(r.preemptions, r.req_preemptions_total, "counters must agree");
    assert!(r.finished > 0, "the run must still make progress");
}

#[test]
fn property_exhaustion_conservation_random_budgets() {
    prop::check("sim_exhaustion_conservation", 6, |rng| {
        let budget = 8 * 1024 + rng.range_usize(0, 24 * 1024);
        let rate = 0.5 + rng.f64() * 1.5;
        let m = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::OpenThoughts, rate);
        cfg.duration_s = 15.0;
        cfg.seed = rng.next_u64();
        cfg.serving.decode_kv_capacity_tokens = Some(budget);
        cfg.serving.executor_kv_capacity_tokens = Some(budget / 2);
        let r = ClusterSim::new(cfg).run();
        assert!(r.tokens_conserved, "budget={budget} rate={rate:.2}");
        assert_eq!(r.preemptions, r.req_preemptions_total);
        // Occupancy never exceeds 1: preemption enforced the budget.
        if let Some(max) = r.decode_occupancy.max_value() {
            assert!(max <= 1.0 + 1e-9, "decode occupancy {max}");
        }
    });
}

fn assert_reports_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.events_processed, b.events_processed);
    assert!(feq(a.throughput, b.throughput), "{} vs {}", a.throughput, b.throughput);
    assert!(feq(a.goodput, b.goodput));
    assert!(feq(a.offloaded_fraction, b.offloaded_fraction));
    assert!(feq(a.prefill_hbm_capacity_util, b.prefill_hbm_capacity_util));
    assert!(feq(a.prefill_hbm_bw_util, b.prefill_hbm_bw_util));
    assert!(feq(a.decode_compute_util, b.decode_compute_util));
    assert!(feq(a.executor_duty, b.executor_duty));
    assert!(feq(a.sim_end_s, b.sim_end_s));
    match (&a.ttft, &b.ttft) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count);
            assert!(feq(x.mean, y.mean) && feq(x.p50, y.p50) && feq(x.p99, y.p99));
        }
        (None, None) => {}
        _ => panic!("ttft presence differs"),
    }
    match (&a.tpot, &b.tpot) {
        (Some(x), Some(y)) => {
            assert_eq!(x.count, y.count);
            assert!(feq(x.mean, y.mean) && feq(x.p50, y.p50) && feq(x.p99, y.p99));
        }
        (None, None) => {}
        _ => panic!("tpot presence differs"),
    }
    assert_eq!(a.decode_occupancy.points(), b.decode_occupancy.points());
    assert_eq!(a.batch_size.points(), b.batch_size.points());
}

#[test]
fn ratio_sweep_parallel_matches_serial_bitwise() {
    let m = ModelSpec::llama2_7b();
    let ratios = [0.0, 0.4, 0.8];
    let par = run_ratio_sweep(m, WorkloadKind::ShareGpt, 8.0, &ratios, 30.0);
    let ser = run_ratio_sweep_serial(m, WorkloadKind::ShareGpt, 8.0, &ratios, 30.0);
    assert_eq!(par.len(), ser.len());
    for ((rp, p), (rs, s)) in par.iter().zip(&ser) {
        assert_eq!(rp, rs, "ratio order must match the serial driver");
        assert_reports_identical(p, s);
    }
}

#[test]
fn e2e_sweep_parallel_matches_serial() {
    let cfg = adrenaline::sim::E2eConfig {
        rates: vec![2.0, 6.0],
        duration_s: 30.0,
        ..adrenaline::sim::E2eConfig::fig13()
    };
    let par = run_e2e(&cfg);
    let ser = run_e2e_serial(&cfg);
    assert_eq!(par.len(), ser.len());
    for (p, s) in par.iter().zip(&ser) {
        assert_eq!((p.rate, p.system), (s.rate, s.system));
        assert!(feq(p.ttft_mean_s, s.ttft_mean_s));
        assert!(feq(p.tpot_mean_s, s.tpot_mean_s));
        assert!(feq(p.tpot_p99_s, s.tpot_p99_s));
        assert!(feq(p.throughput_tok_s, s.throughput_tok_s));
        assert_eq!(p.finished, s.finished);
        assert_eq!(p.preemptions, s.preemptions);
    }
}
