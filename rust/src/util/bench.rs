//! Minimal criterion-style benchmark harness (the vendored crate set has
//! no criterion). Used by every target in `benches/`.
//!
//! Protocol per benchmark: warm up for `warmup_iters`, then time
//! `sample_iters` batches and report mean / p50 / p99 per iteration. For
//! figure-regeneration benches the harness also prints labelled data rows
//! (`row!`-style) so `cargo bench | tee bench_output.txt` doubles as the
//! figure data dump.

use std::time::Instant;

/// One benchmark's timing summary (seconds per iteration).
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, sample_iters: 20 }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, sample_iters: usize) -> Self {
        Bench { warmup_iters, sample_iters }
    }

    /// Time `f`, printing a criterion-like line. Returns the stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters.max(1) {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let stats = BenchStats {
            iters: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            p50_s: samples[n / 2],
            p99_s: samples[((n as f64 * 0.99).ceil() as usize).clamp(1, n) - 1],
            min_s: samples[0],
        };
        println!(
            "bench {name:<44} mean {:>12} p50 {:>12} p99 {:>12}",
            fmt_duration(stats.mean_s),
            fmt_duration(stats.p50_s),
            fmt_duration(stats.p99_s),
        );
        stats
    }
}

/// Human-scale duration.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// One figure data row, formatted but not printed — the `figures` binary
/// buffers rows per figure group so groups can run in parallel and still
/// print in a stable order.
pub fn figure_row_str(figure: &str, series: &str, x: f64, y: f64) -> String {
    format!("figure={figure} series={series} x={x} y={y:.6}")
}

/// Print a figure data row: a stable, grep-able format shared by benches
/// and the `figures` binary.
pub fn figure_row(figure: &str, series: &str, x: f64, y: f64) {
    println!("{}", figure_row_str(figure, series, x, y));
}

/// Black-box hint to stop the optimizer eliding benched work (stable-Rust
/// equivalent of `std::hint::black_box` pre-1.66; kept for clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_times_work() {
        let b = Bench::new(1, 5);
        let mut count = 0u64;
        let stats = b.run("spin", || {
            for i in 0..10_000u64 {
                count = black_box(count.wrapping_add(i));
            }
        });
        assert_eq!(stats.iters, 5);
        assert!(stats.mean_s > 0.0);
        assert!(stats.min_s <= stats.p50_s);
        assert!(stats.p50_s <= stats.p99_s);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(2.5).ends_with(" s"));
        assert!(fmt_duration(2.5e-3).ends_with(" ms"));
        assert!(fmt_duration(2.5e-6).contains("µs"));
        assert!(fmt_duration(2.5e-9).ends_with(" ns"));
    }
}
