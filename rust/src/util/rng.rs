//! Seeded PRNG and the samplers workload generation needs.
//!
//! xoshiro256++ seeded via SplitMix64 (Blackman & Vigna's reference
//! construction) — deterministic across platforms, which the replayable
//! traces rely on. Samplers: uniform, exponential (Poisson inter-arrival
//! gaps), standard normal (Box–Muller), log-normal (length distributions).

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) yields a good state.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()], spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        // Rejection-free Lemire-style multiply-shift is overkill here; a
        // simple scaled draw is fine for workload generation.
        lo + (self.f64() * (hi - lo) as f64) as u64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Exponential with rate `lambda` (mean 1/λ).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // Inverse CDF; guard the log(0) corner.
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = loop {
            let u1 = self.f64();
            if u1 > 0.0 {
                break (u1, self.f64());
            }
        };
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Log-normal: exp(mu + sigma·Z).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Rng::seed_from_u64(1);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::seed_from_u64(1);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let mut r2 = Rng::seed_from_u64(2);
        assert_ne!(a[0], r2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::seed_from_u64(5);
        let lambda = 4.0;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.03, "var = {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seed_from_u64(7);
        let mu = 220f64.ln();
        let n = 50_000;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(mu, 0.9)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        assert!((median - 220.0).abs() / 220.0 < 0.05, "median = {median}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(8);
        for _ in 0..10_000 {
            let x = r.range_u64(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "50 elements should move");
    }
}
