//! Dependency-free substrates.
//!
//! This build is fully offline: the only third-party crates available are
//! the minimal `anyhow` and `xla` shims vendored under rust/vendor/.
//! Everything a serving framework would normally pull from the ecosystem
//! is implemented here from scratch:
//!
//! * [`json`] — a small, strict JSON parser/serializer (manifest + config
//!   files);
//! * [`rng`] — SplitMix64 + xoshiro256++ PRNG with exponential, normal and
//!   log-normal samplers (workload generation);
//! * [`mod@bench`] — a minimal criterion-style benchmark harness (warmup,
//!   timed iterations, mean/p50/p99 reporting) used by `benches/*`;
//! * [`prop`] — a tiny property-testing loop (seeded case generation +
//!   shrink-free failure reporting) used where `proptest` would be.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
