//! Tiny property-testing loop (no proptest in the offline vendor set).
//!
//! `check(name, cases, |rng| ...)` runs `cases` seeded trials; the closure
//! builds a random input from the [`Rng`] and asserts the property. On
//! panic the harness re-raises with the failing case's seed so the trial
//! reproduces exactly (`PROP_SEED=<seed> cargo test ...`).

use super::rng::Rng;

/// Run `cases` random trials of `property`. Each trial gets a fresh RNG
/// derived from a base seed (env `PROP_SEED` overrides for replay).
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u64, property: F) {
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    if let Some(seed) = base {
        let mut rng = Rng::seed_from_u64(seed);
        property(&mut rng);
        return;
    }
    for case in 0..cases {
        // Derive per-case seeds deterministically from the property name so
        // different properties explore different inputs.
        let seed = fnv1a(name) ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from_u64(seed);
            property(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed on case {case} (replay with PROP_SEED={seed}): {msg}"
            );
        }
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_commutes", 50, |rng| {
            let a = rng.range_u64(0, 1000);
            let b = rng.range_u64(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always_fails", 3, |_rng| {
                panic!("nope");
            });
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("PROP_SEED="), "got: {msg}");
        assert!(msg.contains("always_fails"));
    }

    #[test]
    fn cases_get_distinct_inputs() {
        use std::sync::Mutex;
        let seen = Mutex::new(Vec::new());
        check("distinct", 10, |rng| {
            seen.lock().unwrap().push(rng.next_u64());
        });
        let v = seen.lock().unwrap();
        let mut uniq = v.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), v.len());
    }
}
