//! Minimal, strict JSON parser and serializer.
//!
//! No serde in the offline vendor set, so the manifest/config plumbing is
//! built on this. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (sufficient for machine-generated manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that errors with the path (manifest loading wants hard errors).
    pub fn require(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError::Schema(format!("missing field `{key}`")))
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse/schema errors.
#[derive(Debug, PartialEq)]
pub enum JsonError {
    Parse(usize, String),
    Schema(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(pos, msg) => write!(f, "json parse error at byte {pos}: {msg}"),
            JsonError::Schema(msg) => write!(f, "json schema error: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse(self.pos, msg.to_string())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected `{}`", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                // Multi-byte UTF-8 passes through.
                b => {
                    // Re-decode from the byte position (safe: input is &str).
                    let start = self.pos - 1;
                    let s = unsafe { std::str::from_utf8_unchecked(&self.bytes[start..]) };
                    let c = s.chars().next().unwrap();
                    self.pos = start + c.len_utf8();
                    let _ = b;
                    out.push(c);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::Parse(start, format!("bad number `{text}`")))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "model": {"d_model": 64, "rope_theta": 10000.0},
            "batch_buckets": [1, 2, 4, 8],
            "artifacts": ["attn_b1", "attn_b2"]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("model").unwrap().get("d_model").unwrap().as_u64(), Some(64));
        let buckets: Vec<u64> =
            v.get("batch_buckets").unwrap().as_arr().unwrap().iter().map(|b| b.as_u64().unwrap()).collect();
        assert_eq!(buckets, vec![1, 2, 4, 8]);
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = Json::parse(text).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\n\"quote\"\ttab\\".into());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap().as_str().unwrap(), "é");
    }

    #[test]
    fn require_reports_field() {
        let v = Json::parse("{}").unwrap();
        let err = v.require("model").unwrap_err();
        assert!(matches!(err, JsonError::Schema(m) if m.contains("model")));
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-2.0).as_u64(), None);
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
    }
}
