//! Per-request latency bookkeeping and aggregate statistics.

use crate::workload::RequestId;

/// Aggregated latency distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from_samples(samples: &[f64]) -> Option<Self> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("no NaN latencies"));
        let sum: f64 = sorted.iter().sum();
        Some(LatencyStats {
            count: sorted.len(),
            mean: sum / sorted.len() as f64,
            p50: percentile(&sorted, 0.50),
            p99: percentile(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        })
    }

    /// Count-weighted merge of per-partition stats (the fleet's
    /// cluster-wide TTFT/TPOT aggregate). `count`, `mean`, and `max` are
    /// exact; `p50`/`p99` are count-weighted means of the per-partition
    /// percentiles — an approximation, since exact fleet percentiles
    /// would need the raw samples, which reports deliberately do not
    /// retain. `None` when no partition has samples.
    pub fn merged<'a, I>(parts: I) -> Option<Self>
    where
        I: IntoIterator<Item = &'a LatencyStats>,
    {
        let mut count = 0usize;
        let (mut mean, mut p50, mut p99) = (0.0f64, 0.0f64, 0.0f64);
        let mut max = f64::NEG_INFINITY;
        for s in parts {
            if s.count == 0 {
                continue;
            }
            let w = s.count as f64;
            count += s.count;
            mean += w * s.mean;
            p50 += w * s.p50;
            p99 += w * s.p99;
            max = max.max(s.max);
        }
        if count == 0 {
            return None;
        }
        let n = count as f64;
        Some(LatencyStats { count, mean: mean / n, p50: p50 / n, p99: p99 / n, max })
    }
}

/// Nearest-rank percentile on a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// One request's lifecycle timestamps.
#[derive(Debug, Clone, Default)]
pub struct RequestMetrics {
    pub arrival_s: f64,
    pub first_token_s: Option<f64>,
    pub token_times_s: Vec<f64>,
    pub finished_s: Option<f64>,
}

impl RequestMetrics {
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|t| t - self.arrival_s)
    }

    /// TPOT samples: gaps between consecutive decode tokens (the paper's
    /// per-output-token latency; first token belongs to TTFT).
    pub fn tpot_samples(&self) -> Vec<f64> {
        let mut all = Vec::with_capacity(self.token_times_s.len());
        if let Some(first) = self.first_token_s {
            let mut prev = first;
            for &t in &self.token_times_s {
                all.push(t - prev);
                prev = t;
            }
        }
        all
    }

    pub fn output_tokens(&self) -> usize {
        // first token + subsequent decode tokens
        usize::from(self.first_token_s.is_some()) + self.token_times_s.len()
    }
}

/// Collects lifecycle events for all requests in a run.
///
/// Token-completion events stream into a sorted cumulative prefix-sum
/// series instead of a raw event list: producers (the sim's event clock
/// and the real path's wall clock) emit times in nondecreasing order, so
/// the series stays sorted by construction, same-instant events coalesce
/// into one entry (a whole decode batch lands on one step-end timestamp),
/// and [`MetricsRecorder::throughput_in_window`] answers from two binary
/// searches — O(log n) instead of the full-series rescan it used to do —
/// with values identical to the linear scan (the counts are the same
/// integers).
#[derive(Debug, Default)]
pub struct MetricsRecorder {
    /// Dense per-request slab indexed by `RequestId`. Workload generators
    /// hand out sequential ids on both the sim and real paths, so a flat
    /// vector replaces the old hash map on the per-token hot path: no
    /// hashing, no probe chains, and deterministic id-order iteration for
    /// the aggregate queries (all of which are order-insensitive anyway —
    /// the latency stats sort their samples before summing).
    requests: Vec<Option<RequestMetrics>>,
    /// Live entry count (`requests` holds `None` gaps for unseen ids).
    n_requests: usize,
    /// `(time, tokens completed at or before time)`, strictly increasing
    /// in both components.
    token_cum: Vec<(f64, u64)>,
}

impl MetricsRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Slab lookup-or-insert for `id` (grows the slab through `id`).
    fn entry(&mut self, id: RequestId) -> &mut RequestMetrics {
        let idx = id as usize;
        if idx >= self.requests.len() {
            self.requests.resize_with(idx + 1, || None);
        }
        let slot = &mut self.requests[idx];
        if slot.is_none() {
            *slot = Some(RequestMetrics::default());
            self.n_requests += 1;
        }
        slot.as_mut().expect("slot filled above")
    }

    /// Iterate live request entries (in id order).
    fn values(&self) -> impl Iterator<Item = &RequestMetrics> {
        self.requests.iter().flatten()
    }

    pub fn on_arrival(&mut self, id: RequestId, t: f64) {
        self.entry(id).arrival_s = t;
    }

    pub fn on_first_token(&mut self, id: RequestId, t: f64) {
        let r = self.entry(id);
        debug_assert!(r.first_token_s.is_none(), "duplicate first token for {id}");
        r.first_token_s = Some(t);
        self.push_token_event(t, 1);
    }

    pub fn on_token(&mut self, id: RequestId, t: f64) {
        self.entry(id).token_times_s.push(t);
        self.push_token_event(t, 1);
    }

    /// Bulk append of one request's token-completion times — the decode
    /// leap engine's per-row flush (`times` is the leaped steps' end-time
    /// sequence, nondecreasing). Appends to the request's own series
    /// only: the shared cumulative series is advanced once per leaped
    /// step via [`MetricsRecorder::on_step_tokens`] (pushing these times
    /// per row would arrive out of time order from the second row on).
    pub fn on_tokens(&mut self, id: RequestId, times: &[f64]) {
        if times.is_empty() {
            return;
        }
        self.entry(id).token_times_s.extend_from_slice(times);
    }

    /// Advance the cumulative token series by `n` tokens completing at
    /// `t` — exactly the prefix-sum contribution of `n` same-instant
    /// [`MetricsRecorder::on_token`] calls (a whole decode batch landing
    /// on one step-end timestamp).
    pub fn on_step_tokens(&mut self, t: f64, n: u64) {
        if n > 0 {
            self.push_token_event(t, n);
        }
    }

    fn push_token_event(&mut self, t: f64, n: u64) {
        if let Some(last) = self.token_cum.last_mut() {
            debug_assert!(t >= last.0, "token events must arrive in time order");
            if t <= last.0 {
                // Same instant (or, defensively in release builds, a clock
                // that failed to advance): coalesce — every window query
                // sums the same tokens either way.
                last.1 += n;
                return;
            }
            let cum = last.1 + n;
            self.token_cum.push((t, cum));
        } else {
            self.token_cum.push((t, n));
        }
    }

    /// Tokens completed at times `<= t` (cumulative prefix lookup).
    fn tokens_at_or_before(&self, t: f64) -> u64 {
        let idx = self.token_cum.partition_point(|&(et, _)| et <= t);
        if idx == 0 {
            0
        } else {
            self.token_cum[idx - 1].1
        }
    }

    /// Tokens completed at times strictly `< t`.
    fn tokens_before(&self, t: f64) -> u64 {
        let idx = self.token_cum.partition_point(|&(et, _)| et < t);
        if idx == 0 {
            0
        } else {
            self.token_cum[idx - 1].1
        }
    }

    /// Distinct token-event timestamps retained (observability: the
    /// coalesced series is what window queries binary-search).
    pub fn token_event_entries(&self) -> usize {
        self.token_cum.len()
    }

    pub fn on_finished(&mut self, id: RequestId, t: f64) {
        self.entry(id).finished_s = Some(t);
    }

    pub fn request(&self, id: RequestId) -> Option<&RequestMetrics> {
        self.requests.get(id as usize).and_then(|r| r.as_ref())
    }

    pub fn n_requests(&self) -> usize {
        self.n_requests
    }

    pub fn n_finished(&self) -> usize {
        self.values().filter(|r| r.finished_s.is_some()).count()
    }

    pub fn total_output_tokens(&self) -> usize {
        self.values().map(|r| r.output_tokens()).sum()
    }

    pub fn ttft_stats(&self) -> Option<LatencyStats> {
        let samples: Vec<f64> = self.values().filter_map(|r| r.ttft()).collect();
        LatencyStats::from_samples(&samples)
    }

    pub fn tpot_stats(&self) -> Option<LatencyStats> {
        let samples: Vec<f64> = self.values().flat_map(|r| r.tpot_samples()).collect();
        LatencyStats::from_samples(&samples)
    }

    /// Output-token throughput (tokens/s) within `[start, end]`, both ends
    /// inclusive. Two prefix-sum lookups — O(log n) in the number of
    /// distinct event timestamps, never a rescan.
    pub fn throughput_in_window(&self, start: f64, end: f64) -> f64 {
        if end <= start {
            return 0.0;
        }
        let tokens = self.tokens_at_or_before(end) - self.tokens_before(start);
        tokens as f64 / (end - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[5.0], 0.99), 5.0);
    }

    #[test]
    fn ttft_and_tpot() {
        let mut m = MetricsRecorder::new();
        m.on_arrival(1, 10.0);
        m.on_first_token(1, 10.5);
        m.on_token(1, 10.6);
        m.on_token(1, 10.8);
        m.on_finished(1, 10.8);
        let r = m.request(1).unwrap();
        assert!((r.ttft().unwrap() - 0.5).abs() < 1e-12);
        let tpot = r.tpot_samples();
        assert_eq!(tpot.len(), 2);
        assert!((tpot[0] - 0.1).abs() < 1e-12);
        assert!((tpot[1] - 0.2).abs() < 1e-12);
        assert_eq!(r.output_tokens(), 3);
    }

    #[test]
    fn aggregate_stats() {
        let mut m = MetricsRecorder::new();
        for (id, arrive, first) in [(1u64, 0.0, 1.0), (2, 0.0, 2.0), (3, 0.0, 3.0)] {
            m.on_arrival(id, arrive);
            m.on_first_token(id, first);
        }
        let s = m.ttft_stats().unwrap();
        assert_eq!(s.count, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn throughput_window() {
        let mut m = MetricsRecorder::new();
        m.on_arrival(1, 0.0);
        m.on_first_token(1, 1.0);
        for i in 0..10 {
            m.on_token(1, 1.0 + 0.1 * (i + 1) as f64);
        }
        // Window [1, 2]: 11 tokens over 1s.
        let tput = m.throughput_in_window(1.0, 2.0);
        assert!((tput - 11.0).abs() < 1e-9, "tput = {tput}");
        assert_eq!(m.throughput_in_window(5.0, 6.0), 0.0);
        assert_eq!(m.throughput_in_window(2.0, 1.0), 0.0);
    }

    #[test]
    fn slab_handles_sparse_ids_and_counts_live_entries() {
        let mut m = MetricsRecorder::new();
        m.on_arrival(5, 1.0);
        m.on_arrival(2, 0.5);
        assert_eq!(m.n_requests(), 2, "gap slots must not count as requests");
        assert!(m.request(0).is_none());
        assert!(m.request(3).is_none());
        assert!(m.request(9).is_none(), "past-the-slab lookups are None, not a panic");
        assert_eq!(m.request(5).unwrap().arrival_s, 1.0);
        m.on_arrival(5, 2.0);
        assert_eq!(m.n_requests(), 2, "re-touching an id must not double-count");
    }

    #[test]
    fn empty_stats_are_none() {
        let m = MetricsRecorder::new();
        assert!(m.ttft_stats().is_none());
        assert!(m.tpot_stats().is_none());
        assert_eq!(m.throughput_in_window(0.0, 1.0), 0.0);
    }

    #[test]
    fn bulk_tokens_match_per_token_calls() {
        // Two rows leaping 3 steps: the bulk entry points must leave the
        // recorder in exactly the per-token state (per-request series and
        // the cumulative step series alike).
        let times = [1.0, 1.5, 2.0];
        let mut per = MetricsRecorder::new();
        let mut bulk = MetricsRecorder::new();
        for m in [&mut per, &mut bulk] {
            m.on_arrival(1, 0.0);
            m.on_arrival(2, 0.0);
            m.on_first_token(1, 0.5);
            m.on_first_token(2, 0.5);
        }
        for &t in &times {
            per.on_token(1, t);
            per.on_token(2, t);
        }
        for &t in &times {
            bulk.on_step_tokens(t, 2);
        }
        bulk.on_tokens(1, &times);
        bulk.on_tokens(2, &times);
        bulk.on_tokens(2, &[]);
        for id in [1, 2] {
            assert_eq!(
                per.request(id).unwrap().token_times_s,
                bulk.request(id).unwrap().token_times_s
            );
            assert_eq!(per.request(id).unwrap().output_tokens(), 4);
        }
        assert_eq!(per.token_event_entries(), bulk.token_event_entries());
        for (a, b) in [(0.0, 3.0), (1.0, 1.5), (1.5, 2.0), (0.6, 0.9)] {
            assert_eq!(
                per.throughput_in_window(a, b).to_bits(),
                bulk.throughput_in_window(a, b).to_bits(),
                "window [{a}, {b}]"
            );
        }
        assert_eq!(per.total_output_tokens(), bulk.total_output_tokens());
    }

    #[test]
    fn same_instant_tokens_coalesce() {
        // A decode batch of 50 finishing one step produces 50 on_token
        // calls at the same timestamp: one prefix-sum entry, same counts.
        let mut m = MetricsRecorder::new();
        m.on_arrival(1, 0.0);
        m.on_first_token(1, 1.0);
        for _ in 0..49 {
            m.on_token(1, 1.0);
        }
        m.on_token(1, 2.0);
        assert_eq!(m.token_event_entries(), 2);
        assert!((m.throughput_in_window(0.5, 1.5) - 50.0).abs() < 1e-9);
        assert!((m.throughput_in_window(0.0, 2.0) - 25.5).abs() < 1e-9);
    }

    #[test]
    fn window_boundaries_are_inclusive() {
        let mut m = MetricsRecorder::new();
        m.on_arrival(1, 0.0);
        m.on_first_token(1, 1.0);
        m.on_token(1, 2.0);
        m.on_token(1, 3.0);
        // [1, 2] includes both endpoint events.
        assert!((m.throughput_in_window(1.0, 2.0) - 2.0).abs() < 1e-12);
        // [2, 3] likewise.
        assert!((m.throughput_in_window(2.0, 3.0) - 2.0).abs() < 1e-12);
        // (strictly between events) empty.
        assert_eq!(m.throughput_in_window(1.1, 1.9), 0.0);
    }

    #[test]
    fn property_prefix_sums_match_linear_rescan() {
        // The streaming aggregates must answer every window query with a
        // value bit-identical to the old full-list linear scan.
        crate::util::prop::check("metrics_prefix_vs_linear", 40, |rng| {
            let mut m = MetricsRecorder::new();
            let mut events: Vec<f64> = Vec::new();
            let mut t = 0.0f64;
            m.on_arrival(1, 0.0);
            t += rng.f64();
            m.on_first_token(1, t);
            events.push(t);
            for _ in 0..rng.range_usize(0, 300) {
                // ~1/3 of tokens share the previous timestamp (batched
                // step-ends), exercising the coalescing path.
                if rng.f64() > 0.33 {
                    t += rng.f64() * 0.2;
                }
                m.on_token(1, t);
                events.push(t);
            }
            let horizon = t + 1.0;
            for _ in 0..20 {
                let a = rng.f64() * horizon;
                let b = rng.f64() * horizon;
                let (start, end) = if a <= b { (a, b) } else { (b, a) };
                let linear: usize =
                    events.iter().filter(|&&e| (start..=end).contains(&e)).count();
                let reference =
                    if end <= start { 0.0 } else { linear as f64 / (end - start) };
                let got = m.throughput_in_window(start, end);
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "window [{start}, {end}]: got {got}, linear {reference}"
                );
            }
        });
    }
}
