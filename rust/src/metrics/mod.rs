//! Serving metrics: latency recorders, stable-window throughput, and
//! utilization timelines — the measurement conventions of §4.1.
//!
//! * TTFT — request arrival → first output token (includes queueing and,
//!   in PD disaggregation, the prefill→decode KV transfer).
//! * TPOT — per-token gap during decode (mean and P99).
//! * Output token throughput — decode tokens per second measured over the
//!   *stable equilibrium window*: between the first and last instants the
//!   decode instance's HBM is saturated, or (if never saturated) while the
//!   decode batch is ≥ 80 % of its peak (the paper's §4.1 definition).

mod recorder;
mod timeline;

pub use recorder::{LatencyStats, MetricsRecorder, RequestMetrics};
pub use timeline::{StableWindow, Timeline};
