//! Time-series utilization tracking and the §4.1 stable-window detector.

/// Append-only (time, value) series, e.g. HBM occupancy or batch size over
/// a run (Figs 2/16's x-axis is exactly this).
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    points: Vec<(f64, f64)>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: f64, v: f64) {
        debug_assert!(
            self.points.last().map_or(true, |&(pt, _)| t >= pt),
            "timeline must be pushed in time order"
        );
        self.points.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    pub fn max_value(&self) -> Option<f64> {
        self.points.iter().map(|&(_, v)| v).fold(None, |acc, v| {
            Some(acc.map_or(v, |a: f64| a.max(v)))
        })
    }

    pub fn min_value(&self) -> Option<f64> {
        self.points.iter().map(|&(_, v)| v).fold(None, |acc, v| {
            Some(acc.map_or(v, |a: f64| a.min(v)))
        })
    }

    /// Time-weighted mean value over [start, end] (step interpolation).
    pub fn time_weighted_mean(&self, start: f64, end: f64) -> Option<f64> {
        if end <= start || self.points.is_empty() {
            return None;
        }
        let mut acc = 0.0;
        let mut cur_val: Option<f64> = None;
        let mut cur_t = start;
        for &(t, v) in &self.points {
            if t < start {
                cur_val = Some(v);
                continue;
            }
            if t > end {
                break;
            }
            if let Some(cv) = cur_val {
                acc += cv * (t - cur_t);
            }
            cur_t = t;
            cur_val = Some(v);
        }
        let cv = cur_val?;
        acc += cv * (end - cur_t);
        Some(acc / (end - start))
    }

    /// First and last time the series is at/above `threshold` — the §4.1
    /// saturation window.
    pub fn window_at_or_above(&self, threshold: f64) -> Option<(f64, f64)> {
        let first = self.points.iter().find(|&&(_, v)| v >= threshold)?.0;
        let last = self.points.iter().rev().find(|&&(_, v)| v >= threshold)?.0;
        (last > first).then_some((first, last))
    }
}

/// The paper's stable-equilibrium measurement window (§4.1): the span where
/// decode HBM is saturated; if saturation never happens, the span where the
/// decode batch is ≥ 80 % of its peak.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StableWindow {
    pub start: f64,
    pub end: f64,
    /// Which rule fired.
    pub saturated: bool,
}

impl StableWindow {
    /// `occupancy`: KV-pool occupancy timeline in `[0, 1]`; `batch`: decode
    /// batch-size timeline.
    ///
    /// A saturation window shorter than `MIN_SATURATED_S` is a transient
    /// spike, not an equilibrium — measuring throughput inside it inflates
    /// the number arbitrarily, so such windows fall through to the
    /// batch-size rule.
    pub fn detect(occupancy: &Timeline, batch: &Timeline) -> Option<StableWindow> {
        const MIN_SATURATED_S: f64 = 5.0;
        // "Saturated" = occupancy reaches ~1 (block granularity: >= 0.98).
        if let Some((s, e)) = occupancy.window_at_or_above(0.98) {
            if e - s >= MIN_SATURATED_S {
                return Some(StableWindow { start: s, end: e, saturated: true });
            }
        }
        let peak = batch.max_value()?;
        if peak <= 0.0 {
            return None;
        }
        let (s, e) = batch.window_at_or_above(0.8 * peak)?;
        Some(StableWindow { start: s, end: e, saturated: false })
    }

    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_weighted_mean_step() {
        let mut tl = Timeline::new();
        tl.push(0.0, 1.0);
        tl.push(1.0, 3.0);
        // [0,2]: 1.0 for 1s, 3.0 for 1s -> mean 2.0
        assert!((tl.time_weighted_mean(0.0, 2.0).unwrap() - 2.0).abs() < 1e-12);
        // [0.5, 1.5]: 1.0 for 0.5s, 3.0 for 0.5s -> 2.0
        assert!((tl.time_weighted_mean(0.5, 1.5).unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn min_max_values() {
        let mut tl = Timeline::new();
        assert_eq!(tl.min_value(), None);
        assert_eq!(tl.max_value(), None);
        for (t, v) in [(0.0, 0.4), (1.0, -2.0), (2.0, 3.5)] {
            tl.push(t, v);
        }
        assert_eq!(tl.min_value(), Some(-2.0));
        assert_eq!(tl.max_value(), Some(3.5));
    }

    #[test]
    fn window_detection() {
        let mut tl = Timeline::new();
        for (t, v) in [(0.0, 0.2), (1.0, 0.99), (2.0, 1.0), (3.0, 0.5), (4.0, 0.99), (5.0, 0.3)] {
            tl.push(t, v);
        }
        assert_eq!(tl.window_at_or_above(0.98), Some((1.0, 4.0)));
        assert_eq!(tl.window_at_or_above(2.0), None);
    }

    #[test]
    fn stable_window_prefers_saturation() {
        let mut occ = Timeline::new();
        let mut batch = Timeline::new();
        for t in 0..10 {
            occ.push(t as f64, if (2..=8).contains(&t) { 1.0 } else { 0.5 });
            batch.push(t as f64, 10.0);
        }
        let w = StableWindow::detect(&occ, &batch).unwrap();
        assert!(w.saturated);
        assert_eq!((w.start, w.end), (2.0, 8.0));
    }

    #[test]
    fn transient_saturation_spike_ignored() {
        // A sub-5s saturation blip must not become the measurement window.
        let mut occ = Timeline::new();
        let mut batch = Timeline::new();
        for t in 0..20 {
            // 0.5 s saturation blip around t = 10 only.
            occ.push(t as f64, if t == 10 { 1.0 } else { 0.5 });
            if t == 10 {
                occ.push(10.5, 1.0);
            }
            batch.push(t as f64, if (4..=16).contains(&t) { 10.0 } else { 2.0 });
        }
        let w = StableWindow::detect(&occ, &batch).unwrap();
        assert!(!w.saturated, "spike must fall through to the batch rule");
        assert!(w.duration() > 5.0);
    }

    #[test]
    fn stable_window_falls_back_to_batch_rule() {
        let mut occ = Timeline::new();
        let mut batch = Timeline::new();
        for t in 0..10 {
            occ.push(t as f64, 0.4);
            let b = match t {
                0..=1 => 2.0,
                2..=7 => 10.0,
                _ => 9.0, // still >= 80% of peak
            };
            batch.push(t as f64, b);
        }
        let w = StableWindow::detect(&occ, &batch).unwrap();
        assert!(!w.saturated);
        assert_eq!((w.start, w.end), (2.0, 9.0));
        assert!((w.duration() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn empty_timelines_no_window() {
        assert!(StableWindow::detect(&Timeline::new(), &Timeline::new()).is_none());
    }
}
