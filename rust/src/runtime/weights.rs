//! ADRW weights loader (inverse of python/compile/aot.py::save_weights).
//!
//! Format: `b"ADRW"`, version u32 LE, count u32 LE, then per tensor:
//! name_len u16 LE + name bytes, ndim u8, dims u32 LE each, f32 LE data.

use std::collections::HashMap;
use std::path::Path;

use crate::Result;

/// One weight tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// All model weights, by name.
#[derive(Debug, Clone, Default)]
pub struct Weights {
    tensors: HashMap<String, Tensor>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Weights> {
        let data = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&data)
    }

    pub fn parse(data: &[u8]) -> Result<Weights> {
        anyhow::ensure!(data.len() >= 12 && &data[..4] == b"ADRW", "bad weights magic");
        let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
        anyhow::ensure!(version == 1, "unsupported weights version {version}");
        let count = u32::from_le_bytes(data[8..12].try_into().unwrap()) as usize;
        let mut off = 12usize;
        let mut tensors = HashMap::with_capacity(count);

        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            anyhow::ensure!(*off + n <= data.len(), "truncated weights file");
            let s = &data[*off..*off + n];
            *off += n;
            Ok(s)
        };

        for _ in 0..count {
            let nlen = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
            let name = std::str::from_utf8(take(&mut off, nlen)?)?.to_string();
            let ndim = take(&mut off, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize);
            }
            let numel: usize = shape.iter().product();
            let raw = take(&mut off, numel * 4)?;
            let mut values = Vec::with_capacity(numel);
            for chunk in raw.chunks_exact(4) {
                values.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            tensors.insert(name, Tensor { shape, data: values });
        }
        anyhow::ensure!(off == data.len(), "trailing bytes in weights file");
        Ok(Weights { tensors })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight tensor `{name}` not found"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(String::as_str)
    }

    /// Per-layer weight tensor, e.g. `layer_weight(0, "wq")`.
    pub fn layer(&self, layer: usize, name: &str) -> Result<&Tensor> {
        self.get(&format!("layers.{layer}.{name}"))
    }

    /// Stack a per-layer weight along a new leading L axis (the layout the
    /// fused prefill/decode artifacts take).
    pub fn stacked_layer(&self, n_layers: usize, name: &str) -> Result<Tensor> {
        let first = self.layer(0, name)?;
        let mut shape = vec![n_layers];
        shape.extend_from_slice(&first.shape);
        let mut data = Vec::with_capacity(n_layers * first.numel());
        for l in 0..n_layers {
            let t = self.layer(l, name)?;
            anyhow::ensure!(t.shape == first.shape, "inconsistent shapes for {name}");
            data.extend_from_slice(&t.data);
        }
        Ok(Tensor { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an ADRW blob in-memory (mirrors aot.save_weights).
    fn adrw(tensors: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"ADRW");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, shape, data) in tensors {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(shape.len() as u8);
            for &d in *shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in *data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn parse_roundtrip() {
        let blob = adrw(&[
            ("a", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            ("layers.0.wq", &[2], &[7.0, 8.0]),
            ("layers.1.wq", &[2], &[9.0, 10.0]),
        ]);
        let w = Weights::parse(&blob).unwrap();
        assert_eq!(w.len(), 3);
        let a = w.get("a").unwrap();
        assert_eq!(a.shape, vec![2, 3]);
        assert_eq!(a.data[4], 5.0);
        assert_eq!(w.layer(1, "wq").unwrap().data, vec![9.0, 10.0]);
    }

    #[test]
    fn stacked_layer_concatenates() {
        let blob = adrw(&[
            ("layers.0.wq", &[2], &[1.0, 2.0]),
            ("layers.1.wq", &[2], &[3.0, 4.0]),
        ]);
        let w = Weights::parse(&blob).unwrap();
        let s = w.stacked_layer(2, "wq").unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(Weights::parse(b"NOPE").is_err());
        assert!(Weights::parse(b"").is_err());
    }

    #[test]
    fn truncated_rejected() {
        let mut blob = adrw(&[("a", &[4], &[1.0, 2.0, 3.0, 4.0])]);
        blob.truncate(blob.len() - 3);
        assert!(Weights::parse(&blob).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut blob = adrw(&[("a", &[1], &[1.0])]);
        blob.push(0);
        assert!(Weights::parse(&blob).is_err());
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let w = Weights::parse(&adrw(&[])).unwrap();
        let err = w.get("embedding").unwrap_err();
        assert!(err.to_string().contains("embedding"));
    }

    #[test]
    fn scalar_tensor_ok() {
        let blob = adrw(&[("s", &[], &[42.0])]);
        let w = Weights::parse(&blob).unwrap();
        assert_eq!(w.get("s").unwrap().data, vec![42.0]);
        assert_eq!(w.get("s").unwrap().numel(), 1);
    }
}
