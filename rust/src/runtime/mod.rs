//! PJRT runtime: load AOT artifacts (HLO text), compile them on the CPU
//! PJRT client, and expose typed execution entry points to the engines.
//!
//! This is the only place the `xla` crate is touched. The flow mirrors
//! /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! HLO *text* is the interchange format (see python/compile/aot.py for
//! why serialized protos are rejected by xla_extension 0.5.1).
//!
//! Executables are compiled per (artifact-kind, bucket) and cached — the
//! runtime analogue of the paper's 2-D CUDA-graph capture grid: selecting
//! a `(C_d, C_o)` graph pair becomes selecting the `attn_b{C_d}` and
//! `attn_b{C_o}` executables.

mod engine;
mod manifest;
mod weights;

pub use engine::{ArtifactKind, ModelRuntime, PrefillOutput};
pub use manifest::Manifest;
pub use weights::Weights;
