//! Typed execution entry points over the compiled artifact set.
//!
//! One [`ModelRuntime`] owns one PJRT client — the process-level analogue
//! of one GPU. The prefill instance (with its colocated attention
//! executor) and the decode instance each own a separate `ModelRuntime`,
//! mirroring the paper's separate GPU pools.
//!
//! Executables compile lazily per `(kind, bucket)` and are cached for the
//! life of the runtime; `warmup()` pre-compiles the full grid (the
//! CUDA-graph capture pass). Inputs must already be padded to the bucket
//! size — the engines own the scratch buffers so the hot path stays
//! allocation-free.

use std::cell::RefCell;
use std::collections::HashMap;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;
use super::weights::{Tensor, Weights};
use crate::Result;

/// Per-layer weight names in artifact parameter order (must match
/// python/compile/model.py::LAYER_WEIGHT_NAMES).
const LAYER_WEIGHT_NAMES: [&str; 9] =
    ["ln_attn", "wq", "wk", "wv", "wo", "ln_ffn", "w_gate", "w_up", "w_down"];

/// Artifact families (the columns of the executable-bucket grid).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Embed,
    LayerPre,
    Attn,
    LayerPost,
    Head,
    DecodeFused,
    Prefill,
}

impl ArtifactKind {
    fn file_name(&self, bucket: usize) -> String {
        match self {
            ArtifactKind::Embed => format!("embed_b{bucket}"),
            ArtifactKind::LayerPre => format!("layer_pre_b{bucket}"),
            ArtifactKind::Attn => format!("attn_b{bucket}"),
            ArtifactKind::LayerPost => format!("layer_post_b{bucket}"),
            ArtifactKind::Head => format!("head_b{bucket}"),
            ArtifactKind::DecodeFused => format!("decode_fused_b{bucket}"),
            ArtifactKind::Prefill => format!("prefill_p{bucket}"),
        }
    }
}

/// Output of a prefill execution.
#[derive(Debug, Clone)]
pub struct PrefillOutput {
    pub first_token: i32,
    /// `[L, P_bucket, H, D]` flattened (batch dim of 1 squeezed).
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    /// The prompt bucket the prefill ran under.
    pub bucket: usize,
}

/// PJRT-backed model runtime for the tiny CPU-path model.
pub struct ModelRuntime {
    client: PjRtClient,
    pub manifest: Manifest,
    pub weights: Weights,
    executables: HashMap<(ArtifactKind, usize), PjRtLoadedExecutable>,
    // Cached weight literals (built once; reused every call).
    lit_embedding: Literal,
    lit_ln_final: Literal,
    /// Per layer, the 9 weight literals in parameter order.
    lit_layers: Vec<Vec<Literal>>,
    /// The 9 stacked `[L, ...]` literals (fused prefill/decode paths).
    lit_stacked: Vec<Literal>,
    /// Executions performed, by kind (observability/tests).
    exec_counts: RefCell<HashMap<ArtifactKind, u64>>,
}

// Single-copy literal construction (§Perf iteration 2):
// `Literal::vec1(..).reshape(..)` copies the host data twice (once into the
// rank-1 literal, once in `literal_reshape`); building straight from the
// shaped bytes halves the upload cost of the per-step kv/q tensors.

fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let numel: usize = dims.iter().product();
    anyhow::ensure!(numel == data.len(), "shape {dims:?} != data len {}", data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)?)
}

fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let numel: usize = dims.iter().product();
    anyhow::ensure!(numel == data.len(), "shape {dims:?} != data len {}", data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)?)
}

fn lit_of_tensor(t: &Tensor) -> Result<Literal> {
    lit_f32(&t.data, &t.shape)
}

impl ModelRuntime {
    /// Load manifest + weights from `dir` and stand up a CPU PJRT client.
    pub fn load(dir: &std::path::Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let weights = Weights::load(&manifest.weights_path())?;
        let client = PjRtClient::cpu()?;

        let n_layers = manifest.model.n_layers as usize;
        let lit_embedding = lit_of_tensor(weights.get("embedding")?)?;
        let lit_ln_final = lit_of_tensor(weights.get("ln_final")?)?;
        let mut lit_layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let mut lits = Vec::with_capacity(9);
            for name in LAYER_WEIGHT_NAMES {
                lits.push(lit_of_tensor(weights.layer(l, name)?)?);
            }
            lit_layers.push(lits);
        }
        let mut lit_stacked = Vec::with_capacity(9);
        for name in LAYER_WEIGHT_NAMES {
            lit_stacked.push(lit_of_tensor(&weights.stacked_layer(n_layers, name)?)?);
        }

        Ok(ModelRuntime {
            client,
            manifest,
            weights,
            executables: HashMap::new(),
            lit_embedding,
            lit_ln_final,
            lit_layers,
            lit_stacked,
            exec_counts: RefCell::new(HashMap::new()),
        })
    }

    /// Load from the repo-default artifacts/ directory.
    pub fn load_default() -> Result<ModelRuntime> {
        Self::load(&Manifest::default_dir())
    }

    // ----- bucket selection -------------------------------------------------

    /// Smallest batch bucket that fits `n` requests.
    pub fn batch_bucket_for(&self, n: usize) -> Result<usize> {
        self.manifest
            .batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow::anyhow!("batch {n} exceeds largest bucket"))
    }

    /// Smallest prompt bucket that fits `p` tokens.
    pub fn prompt_bucket_for(&self, p: usize) -> Result<usize> {
        self.manifest
            .prompt_buckets
            .iter()
            .copied()
            .find(|&b| b >= p)
            .ok_or_else(|| anyhow::anyhow!("prompt of {p} tokens exceeds largest bucket"))
    }

    // ----- compilation ------------------------------------------------------

    /// Compile (and cache) the executable for `(kind, bucket)`.
    fn ensure_compiled(&mut self, kind: ArtifactKind, bucket: usize) -> Result<()> {
        if !self.executables.contains_key(&(kind, bucket)) {
            let name = kind.file_name(bucket);
            let path = self.manifest.hlo_path(&name);
            let proto = HloModuleProto::from_text_file(&path)?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.executables.insert((kind, bucket), exe);
        }
        Ok(())
    }

    /// Pre-compile the whole executable grid (the paper's graph-capture
    /// warmup). Returns the number of executables compiled.
    pub fn warmup(&mut self) -> Result<usize> {
        let batch: Vec<usize> = self.manifest.batch_buckets.clone();
        let prompt: Vec<usize> = self.manifest.prompt_buckets.clone();
        let mut n = 0;
        for &b in &batch {
            for kind in [
                ArtifactKind::Embed,
                ArtifactKind::LayerPre,
                ArtifactKind::Attn,
                ArtifactKind::LayerPost,
                ArtifactKind::Head,
                ArtifactKind::DecodeFused,
            ] {
                self.ensure_compiled(kind, b)?;
                n += 1;
            }
        }
        for &p in &prompt {
            self.ensure_compiled(ArtifactKind::Prefill, p)?;
            n += 1;
        }
        Ok(n)
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }

    /// Execute a pre-compiled artifact with borrowed argument literals —
    /// zero host-side copies (the xla crate's `Literal::clone` is a deep
    /// `literal_clone`; avoiding it was the first §Perf win, see
    /// EXPERIMENTS.md).
    fn exec(&self, kind: ArtifactKind, bucket: usize, args: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self.executables.get(&(kind, bucket)).expect("ensure_compiled first");
        *self.exec_counts.borrow_mut().entry(kind).or_insert(0) += 1;
        let out = exe.execute::<&Literal>(args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// Executions performed for `kind` (observability/tests).
    pub fn exec_count(&self, kind: ArtifactKind) -> u64 {
        self.exec_counts.borrow().get(&kind).copied().unwrap_or(0)
    }

    // ----- model dims -------------------------------------------------------

    pub fn d_model(&self) -> usize {
        self.manifest.model.d_model as usize
    }

    pub fn n_layers(&self) -> usize {
        self.manifest.model.n_layers as usize
    }

    pub fn n_heads(&self) -> usize {
        self.manifest.model.n_heads as usize
    }

    pub fn head_dim(&self) -> usize {
        self.manifest.model.head_dim as usize
    }

    pub fn max_seq_len(&self) -> usize {
        self.manifest.model.max_seq_len as usize
    }

    /// Elements of one `[S, H, D]` per-request KV plane.
    pub fn kv_plane(&self) -> usize {
        self.max_seq_len() * self.n_heads() * self.head_dim()
    }

    // ----- typed execution --------------------------------------------------
    // All batch-shaped inputs must be padded to `bucket` length by the
    // caller; outputs come back bucket-sized too.

    /// tokens `[bucket]` → hidden `[bucket, D]`.
    pub fn embed(&mut self, tokens: &[i32], bucket: usize) -> Result<Vec<f32>> {
        self.ensure_compiled(ArtifactKind::Embed, bucket)?;
        let toks = lit_i32(tokens, &[bucket])?;
        let out = self.exec(ArtifactKind::Embed, bucket, &[&toks, &self.lit_embedding])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// hidden `[bucket, D]`, positions `[bucket]` → (q, k, v) each
    /// `[bucket, H, D]`.
    pub fn layer_pre(
        &mut self,
        hidden: &[f32],
        positions: &[i32],
        layer: usize,
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.ensure_compiled(ArtifactKind::LayerPre, bucket)?;
        let h = lit_f32(hidden, &[bucket, self.d_model()])?;
        let pos = lit_i32(positions, &[bucket])?;
        let args: Vec<&Literal> =
            [&h, &pos].into_iter().chain(self.lit_layers[layer][..4].iter()).collect();
        let out = self.exec(ArtifactKind::LayerPre, bucket, &args)?;
        Ok((out[0].to_vec()?, out[1].to_vec()?, out[2].to_vec()?))
    }

    /// THE offloadable unit. q `[bucket, H, D]`, caches `[bucket, S, H, D]`,
    /// seq_lens `[bucket]` → attn_out `[bucket, D]`.
    pub fn attention(
        &mut self,
        q: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
        seq_lens: &[i32],
        bucket: usize,
    ) -> Result<Vec<f32>> {
        self.ensure_compiled(ArtifactKind::Attn, bucket)?;
        let (h, d, s) = (self.n_heads(), self.head_dim(), self.max_seq_len());
        let ql = lit_f32(q, &[bucket, h, d])?;
        let kl = lit_f32(k_cache, &[bucket, s, h, d])?;
        let vl = lit_f32(v_cache, &[bucket, s, h, d])?;
        let sl = lit_i32(seq_lens, &[bucket])?;
        let out = self.exec(ArtifactKind::Attn, bucket, &[&ql, &kl, &vl, &sl])?;
        Ok(out[0].to_vec()?)
    }

    /// hidden + attn_out `[bucket, D]` → next hidden `[bucket, D]`.
    pub fn layer_post(
        &mut self,
        hidden: &[f32],
        attn_out: &[f32],
        layer: usize,
        bucket: usize,
    ) -> Result<Vec<f32>> {
        self.ensure_compiled(ArtifactKind::LayerPost, bucket)?;
        let h = lit_f32(hidden, &[bucket, self.d_model()])?;
        let a = lit_f32(attn_out, &[bucket, self.d_model()])?;
        let args: Vec<&Literal> =
            [&h, &a].into_iter().chain(self.lit_layers[layer][4..].iter()).collect();
        let out = self.exec(ArtifactKind::LayerPost, bucket, &args)?;
        Ok(out[0].to_vec()?)
    }

    /// hidden `[bucket, D]` → greedy next tokens `[bucket]`.
    pub fn head(&mut self, hidden: &[f32], bucket: usize) -> Result<Vec<i32>> {
        self.ensure_compiled(ArtifactKind::Head, bucket)?;
        let h = lit_f32(hidden, &[bucket, self.d_model()])?;
        let out = self.exec(
            ArtifactKind::Head,
            bucket,
            &[&h, &self.lit_ln_final, &self.lit_embedding],
        )?;
        Ok(out[0].to_vec::<i32>()?)
    }

    /// Run prefill for one prompt. Returns the first token and the
    /// populated KV cache (`[L, bucket, H, D]` per position, batch
    /// squeezed).
    pub fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOutput> {
        let p = prompt.len();
        let bucket = self.prompt_bucket_for(p)?;
        let mut padded = vec![0i32; bucket];
        padded[..p].copy_from_slice(prompt);
        self.ensure_compiled(ArtifactKind::Prefill, bucket)?;
        let toks = lit_i32(&padded, &[1, bucket])?;
        let lens = lit_i32(&[p as i32], &[1])?;
        let args: Vec<&Literal> = [&toks, &lens, &self.lit_embedding, &self.lit_ln_final]
            .into_iter()
            .chain(self.lit_stacked.iter())
            .collect();
        let out = self.exec(ArtifactKind::Prefill, bucket, &args)?;
        Ok(PrefillOutput {
            first_token: out[0].to_vec::<i32>()?[0],
            k_cache: out[1].to_vec()?,
            v_cache: out[2].to_vec()?,
            bucket,
        })
    }

    /// Fused decode step (the no-offload fast path). Caches are
    /// `[L, bucket, S, H, D]`; returns (next_tokens `[bucket]`,
    /// k_new `[L, bucket, H, D]`, v_new `[L, bucket, H, D]`).
    pub fn decode_fused(
        &mut self,
        tokens: &[i32],
        positions: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        bucket: usize,
    ) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>)> {
        let (l, s, h, d) =
            (self.n_layers(), self.max_seq_len(), self.n_heads(), self.head_dim());
        self.ensure_compiled(ArtifactKind::DecodeFused, bucket)?;
        let toks = lit_i32(tokens, &[bucket])?;
        let pos = lit_i32(positions, &[bucket])?;
        let kl = lit_f32(k_cache, &[l, bucket, s, h, d])?;
        let vl = lit_f32(v_cache, &[l, bucket, s, h, d])?;
        let args: Vec<&Literal> =
            [&toks, &pos, &kl, &vl, &self.lit_embedding, &self.lit_ln_final]
                .into_iter()
                .chain(self.lit_stacked.iter())
                .collect();
        let out = self.exec(ArtifactKind::DecodeFused, bucket, &args)?;
        Ok((out[0].to_vec()?, out[1].to_vec()?, out[2].to_vec()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_file_names() {
        assert_eq!(ArtifactKind::Attn.file_name(4), "attn_b4");
        assert_eq!(ArtifactKind::Prefill.file_name(64), "prefill_p64");
        assert_eq!(ArtifactKind::DecodeFused.file_name(1), "decode_fused_b1");
    }

    #[test]
    fn literal_shape_checks() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(lit_i32(&[1, 2], &[2]).is_ok());
    }
}
