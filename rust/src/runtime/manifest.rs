//! artifacts/manifest.json loader + consistency checks against the crate's
//! compiled-in model table.

use std::path::{Path, PathBuf};

use crate::config::ModelSpec;
use crate::util::json::Json;
use crate::Result;

/// Parsed artifact manifest (written by python/compile/aot.py).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub batch_buckets: Vec<usize>,
    pub prompt_buckets: Vec<usize>,
    pub artifacts: Vec<String>,
    pub layer_weight_names: Vec<String>,
    /// Model dims parsed from the manifest (must equal `ModelSpec::tiny()`).
    pub model: ModelSpec,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text)?;

        let m = v.require("model")?;
        let dim = |k: &str| -> Result<u64> {
            m.require(k)?
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("manifest model.{k} not a u64"))
        };
        let tiny = ModelSpec::tiny();
        let model = ModelSpec {
            name: "tiny",
            vocab_size: dim("vocab_size")?,
            d_model: dim("d_model")?,
            n_layers: dim("n_layers")?,
            n_heads: dim("n_heads")?,
            head_dim: dim("head_dim")?,
            ffn_hidden: dim("ffn_hidden")?,
            max_seq_len: dim("max_seq_len")?,
            dtype_bytes: tiny.dtype_bytes,
        };
        anyhow::ensure!(
            model == tiny,
            "artifact manifest dims {model:?} do not match compiled-in ModelSpec::tiny() \
             {tiny:?}; re-run `make artifacts` after syncing python/compile/model.py"
        );

        let u64_arr = |key: &str| -> Result<Vec<usize>> {
            v.require(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("manifest {key} not an array"))?
                .iter()
                .map(|b| {
                    b.as_u64()
                        .map(|n| n as usize)
                        .ok_or_else(|| anyhow::anyhow!("manifest {key} entry not a u64"))
                })
                .collect()
        };
        let str_arr = |key: &str| -> Result<Vec<String>> {
            v.require(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("manifest {key} not an array"))?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow::anyhow!("manifest {key} entry not a string"))
                })
                .collect()
        };

        let manifest = Manifest {
            dir: dir.to_path_buf(),
            seed: v.require("seed")?.as_u64().unwrap_or(0),
            batch_buckets: u64_arr("batch_buckets")?,
            prompt_buckets: u64_arr("prompt_buckets")?,
            artifacts: str_arr("artifacts")?,
            layer_weight_names: str_arr("layer_weight_names")?,
            model,
        };
        anyhow::ensure!(!manifest.batch_buckets.is_empty(), "no batch buckets");
        anyhow::ensure!(!manifest.prompt_buckets.is_empty(), "no prompt buckets");
        for name in &manifest.artifacts {
            let p = manifest.hlo_path(name);
            anyhow::ensure!(p.exists(), "artifact listed but missing: {}", p.display());
        }
        Ok(manifest)
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join("weights.bin")
    }

    /// Repo-default artifact location (next to Cargo.toml).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration-level manifest tests live in rust/tests/ (they need
    // `make artifacts`); here we test the failure paths with synthetic
    // manifests.

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn missing_dir_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent/zzz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn dim_mismatch_rejected() {
        let dir = std::env::temp_dir().join("adrenaline_manifest_bad_dims");
        write_manifest(
            &dir,
            r#"{"model": {"vocab_size": 999, "d_model": 64, "n_layers": 2,
                "n_heads": 4, "head_dim": 16, "ffn_hidden": 128,
                "max_seq_len": 128},
               "seed": 0, "batch_buckets": [1], "prompt_buckets": [16],
               "artifacts": [], "layer_weight_names": [],
               "global_weight_names": []}"#,
        );
        let err = Manifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("do not match"), "{err}");
    }

    #[test]
    fn missing_listed_artifact_rejected() {
        let dir = std::env::temp_dir().join("adrenaline_manifest_missing_art");
        write_manifest(
            &dir,
            r#"{"model": {"vocab_size": 256, "d_model": 64, "n_layers": 2,
                "n_heads": 4, "head_dim": 16, "ffn_hidden": 128,
                "max_seq_len": 128},
               "seed": 0, "batch_buckets": [1], "prompt_buckets": [16],
               "artifacts": ["ghost_b1"], "layer_weight_names": []}"#,
        );
        let err = Manifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("ghost_b1"), "{err}");
    }
}
