//! # Adrenaline — attention disaggregation for PD-disaggregated LLM serving
//!
//! A Rust + JAX + Pallas reproduction of *"Injecting Adrenaline into LLM
//! Serving: Boosting Resource Utilization and Throughput via Attention
//! Disaggregation"* (CS.DC 2025).
//!
//! The system is a three-layer stack:
//!
//! * **L3 (this crate)** — the serving coordinator: proxy/router, the
//!   load-aware offloading scheduler (the paper's Algorithm 1), continuous
//!   batching, paged KV-cache management, the prefill/decode engines and the
//!   attention executor, plus the PJRT runtime that executes AOT-compiled
//!   artifacts. Python never runs on the request path.
//! * **L2 (python/compile/model.py)** — the transformer forward pass, split
//!   at exactly the boundaries the paper disaggregates (pre-attention /
//!   attention / post-attention), lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — the Pallas decode-attention kernel:
//!   the memory-bound, offloadable unit of work.
//!
//! Because the paper's testbed (8×A100, Llama-2 7B/13B) is unavailable, the
//! A100-scale evaluation runs on [`gpu_model`] (an analytical roofline +
//! MPS-partition model calibrated to the paper's own measurements) driven by
//! the [`sim`] discrete-event cluster simulator, while the *real* serving
//! path ([`engine`], [`runtime`]) executes a tiny Llama-architecture model
//! end-to-end on the CPU PJRT client. See DESIGN.md for the substitution
//! table and the per-figure experiment index.

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod gpu_model;
pub mod kv;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
