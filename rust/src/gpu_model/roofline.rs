//! Roofline kernel timing: t = max(flops / F_eff, bytes / B_eff).

use crate::config::{DeviceProfile, GpuSpec};

/// FLOP and HBM-byte cost of one kernel invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelCost {
    pub flops: f64,
    pub bytes: f64,
}

impl KernelCost {
    pub fn new(flops: f64, bytes: f64) -> Self {
        KernelCost { flops, bytes }
    }

    /// Arithmetic intensity, FLOP/byte.
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes.max(1.0)
    }

    pub fn add(&self, other: &KernelCost) -> KernelCost {
        KernelCost { flops: self.flops + other.flops, bytes: self.bytes + other.bytes }
    }
}

/// Roofline evaluator for one GPU (optionally a fractional MPS partition).
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    pub gpu: GpuSpec,
    /// SM fraction available to this partition (1.0 = whole GPU).
    pub sm_frac: f64,
}

impl Roofline {
    pub fn whole(gpu: GpuSpec) -> Self {
        Roofline { gpu, sm_frac: 1.0 }
    }

    pub fn partition(gpu: GpuSpec, sm_frac: f64) -> Self {
        assert!(sm_frac > 0.0 && sm_frac <= 1.0, "sm_frac in (0,1], got {sm_frac}");
        Roofline { gpu, sm_frac }
    }

    /// Roofline for a resolved device profile. A `sm_frac: None` profile is
    /// the whole device; `whole(gpu)` and `partition(gpu, 1.0)` are the same
    /// value (`sm_frac: 1.0`), so the dispatch is bit-transparent either way.
    pub fn for_profile(profile: &DeviceProfile) -> Self {
        match profile.sm_frac {
            None => Roofline::whole(profile.gpu),
            Some(f) => Roofline::partition(profile.gpu, f),
        }
    }

    /// Effective compute throughput for this partition, FLOP/s. Compute
    /// scales ~linearly with SMs (each SM carries its own tensor cores).
    pub fn effective_flops(&self) -> f64 {
        self.gpu.peak_flops * self.gpu.compute_eff * self.sm_frac
    }

    /// Effective memory bandwidth for this partition, B/s. Bandwidth scales
    /// *superlinearly* with SM fraction (Fig 9): a small number of SMs can
    /// keep most of HBM busy because each SM sustains many outstanding
    /// loads.
    pub fn effective_bw(&self) -> f64 {
        self.gpu.hbm_bw * self.gpu.bw_eff * super::partition::bw_frac_of_sm_frac(self.sm_frac)
    }

    /// Kernel execution time, seconds.
    pub fn time(&self, cost: KernelCost) -> f64 {
        let tc = cost.flops / self.effective_flops();
        let tm = cost.bytes / self.effective_bw();
        tc.max(tm)
    }

    /// True if the kernel is memory-bound on this partition.
    pub fn memory_bound(&self, cost: KernelCost) -> bool {
        cost.bytes / self.effective_bw() >= cost.flops / self.effective_flops()
    }

    /// Compute utilization achieved by this kernel: fraction of the *whole
    /// GPU's* peak FLOPs actually used (the metric Figs 1b/5a/6a/17b plot).
    pub fn compute_utilization(&self, cost: KernelCost) -> f64 {
        let t = self.time(cost);
        if t <= 0.0 {
            return 0.0;
        }
        (cost.flops / t) / self.gpu.peak_flops
    }

    /// HBM bandwidth utilization achieved by this kernel: fraction of the
    /// whole GPU's peak bandwidth (Figs 1a/5b/6b/17a).
    pub fn bw_utilization(&self, cost: KernelCost) -> f64 {
        let t = self.time(cost);
        if t <= 0.0 {
            return 0.0;
        }
        (cost.bytes / t) / self.gpu.hbm_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    fn rl() -> Roofline {
        Roofline::whole(GpuSpec::a100_80g())
    }

    #[test]
    fn compute_bound_kernel_times_by_flops() {
        let r = rl();
        // Huge intensity => compute-bound.
        let c = KernelCost::new(1e15, 1e6);
        assert!(!r.memory_bound(c));
        let expected = 1e15 / r.effective_flops();
        assert!((r.time(c) - expected).abs() / expected < 1e-12);
    }

    #[test]
    fn memory_bound_kernel_times_by_bytes() {
        let r = rl();
        let c = KernelCost::new(1e6, 1e12);
        assert!(r.memory_bound(c));
        let expected = 1e12 / r.effective_bw();
        assert!((r.time(c) - expected).abs() / expected < 1e-12);
    }

    #[test]
    fn utilization_bounded_by_efficiency() {
        let r = rl();
        for (f, b) in [(1e15, 1e9), (1e12, 1e12), (1e9, 1e12)] {
            let c = KernelCost::new(f, b);
            assert!(r.compute_utilization(c) <= r.gpu.compute_eff + 1e-9);
            assert!(r.bw_utilization(c) <= r.gpu.bw_eff + 1e-9);
        }
    }

    #[test]
    fn partition_scales_compute_linearly() {
        let g = GpuSpec::a100_80g();
        let half = Roofline::partition(g, 0.5);
        assert!((half.effective_flops() / Roofline::whole(g).effective_flops() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn partition_bw_superlinear() {
        let g = GpuSpec::a100_80g();
        // 20% of SMs must give ~60% of bandwidth (Fig 9 anchor).
        let frac = Roofline::partition(g, 0.2).effective_bw() / Roofline::whole(g).effective_bw();
        assert!((0.55..0.65).contains(&frac), "got {frac}");
    }

    #[test]
    #[should_panic]
    fn zero_partition_rejected() {
        let _ = Roofline::partition(GpuSpec::a100_80g(), 0.0);
    }

    #[test]
    fn intensity() {
        assert!((KernelCost::new(100.0, 50.0).intensity() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn for_profile_dispatches_whole_vs_partition() {
        use crate::config::{DeviceProfile, DeviceRole};
        let g = GpuSpec::a100_80g();
        let whole = Roofline::for_profile(&DeviceProfile::whole(g, DeviceRole::Decode));
        assert_eq!(whole.sm_frac.to_bits(), 1.0f64.to_bits());
        let part =
            Roofline::for_profile(&DeviceProfile::partitioned(g, DeviceRole::Prefill, 0.45));
        assert_eq!(part.sm_frac.to_bits(), 0.45f64.to_bits());
        // whole(g) ≡ partition(g, 1.0): identical effective rates, bitwise.
        let unit = Roofline::partition(g, 1.0);
        assert_eq!(whole.effective_flops().to_bits(), unit.effective_flops().to_bits());
        assert_eq!(whole.effective_bw().to_bits(), unit.effective_bw().to_bits());
    }
}
