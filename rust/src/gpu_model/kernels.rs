//! Per-kernel cost & timing for the two phases — the four kernels the
//! paper profiles (Figs 3, 5, 6, 18b): QKV projection, attention, output
//! projection, FFN.

use super::roofline::{KernelCost, Roofline};
use crate::config::ModelSpec;

/// The four profiled kernel families.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    QkvProj,
    Attention,
    OutProj,
    Ffn,
}

impl KernelKind {
    pub const ALL: [KernelKind; 4] =
        [KernelKind::QkvProj, KernelKind::Attention, KernelKind::OutProj, KernelKind::Ffn];

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::QkvProj => "qkv_proj",
            KernelKind::Attention => "attention",
            KernelKind::OutProj => "out_proj",
            KernelKind::Ffn => "ffn",
        }
    }
}

/// Cost builder for one phase of one model.
#[derive(Debug, Clone, Copy)]
pub struct PhaseKernels {
    pub model: ModelSpec,
}

impl PhaseKernels {
    pub fn new(model: ModelSpec) -> Self {
        PhaseKernels { model }
    }

    /// Decode-step cost of one kernel for batch `b` with total context
    /// `ctx_total` tokens (sum of sequence lengths across the batch).
    pub fn decode_cost(&self, kind: KernelKind, b: u64, ctx_total: u64) -> KernelCost {
        let m = &self.model;
        match kind {
            KernelKind::QkvProj => KernelCost::new(m.decode_qkv_flops(b), m.decode_qkv_bytes(b)),
            KernelKind::Attention => {
                KernelCost::new(m.decode_attn_flops(ctx_total), m.decode_attn_bytes(ctx_total))
            }
            KernelKind::OutProj => {
                KernelCost::new(m.decode_oproj_flops(b), m.decode_oproj_bytes(b))
            }
            KernelKind::Ffn => KernelCost::new(m.decode_ffn_flops(b), m.decode_ffn_bytes(b)),
        }
    }

    /// Prefill cost of one kernel for a prompt batch totalling `p` tokens.
    pub fn prefill_cost(&self, kind: KernelKind, p: u64) -> KernelCost {
        let m = &self.model;
        match kind {
            KernelKind::QkvProj => KernelCost::new(m.prefill_qkv_flops(p), m.decode_qkv_bytes(p)),
            KernelKind::Attention => {
                KernelCost::new(m.prefill_attn_flops(p), m.prefill_attn_bytes(p))
            }
            KernelKind::OutProj => {
                KernelCost::new(m.prefill_oproj_flops(p), m.decode_oproj_bytes(p))
            }
            KernelKind::Ffn => KernelCost::new(m.prefill_ffn_flops(p), m.decode_ffn_bytes(p)),
        }
    }
}

/// Timed breakdown of one decode step.
#[derive(Debug, Clone, Copy)]
pub struct DecodeKernelTimes {
    pub qkv: f64,
    pub attention: f64,
    pub out_proj: f64,
    pub ffn: f64,
    pub head: f64,
}

impl DecodeKernelTimes {
    /// Time a full decode step on `rl` (batch `b`, total context
    /// `ctx_total`).
    pub fn compute(rl: &Roofline, model: &ModelSpec, b: u64, ctx_total: u64) -> Self {
        let pk = PhaseKernels::new(*model);
        let head =
            KernelCost::new(model.decode_head_flops(b), model.decode_head_bytes(b));
        DecodeKernelTimes {
            qkv: rl.time(pk.decode_cost(KernelKind::QkvProj, b, ctx_total)),
            attention: rl.time(pk.decode_cost(KernelKind::Attention, b, ctx_total)),
            out_proj: rl.time(pk.decode_cost(KernelKind::OutProj, b, ctx_total)),
            ffn: rl.time(pk.decode_cost(KernelKind::Ffn, b, ctx_total)),
            head: rl.time(head),
        }
    }

    pub fn total(&self) -> f64 {
        self.qkv + self.attention + self.out_proj + self.ffn + self.head
    }

    /// Time of the step's non-attention portion (what stays on the decode
    /// instance at 100 % offload).
    pub fn non_attention(&self) -> f64 {
        self.total() - self.attention
    }

    /// Fraction of per-layer time spent in attention — Fig 3's metric
    /// (head excluded: the paper plots per-transformer-layer shares).
    pub fn attention_share(&self) -> f64 {
        let layer = self.qkv + self.attention + self.out_proj + self.ffn;
        if layer <= 0.0 {
            0.0
        } else {
            self.attention / layer
        }
    }
}

/// Memoized decode-step costs for one roofline (§Perf, EXPERIMENTS.md).
///
/// The simulator's hot loop asks for the same three quantities millions of
/// times per run: the non-attention step time (a function of the batch
/// size alone), the decode-attention time (a function of the total context
/// alone), and the step FLOPs. Recomputing the full
/// [`DecodeKernelTimes`] roofline breakdown per step is ~5 roofline
/// evaluations per event; this table does the math once per distinct input
/// instead:
///
/// * **Batch dimension** — a dense lazy table indexed by exact batch size
///   (bounded by the scheduler's `max_batch`, so at most a few hundred
///   entries). The table is warmed at the executable-bucket grid's local
///   capacities ([`crate::coordinator::GraphCache`]) — the same bucket set
///   the paper's 2-D CUDA-graph capture pre-compiles — and backfills
///   lazily at step-granularity (bucket width 1), which keeps the memo
///   *exact* rather than rounding batches up to a captured bucket.
/// * **Context dimension** — decode attention's FLOPs and bytes are both
///   linear in `ctx_total` through the origin, so two cached per-token
///   rates reproduce `Roofline::time` bit-for-bit at any context length;
///   no table is needed at all.
#[derive(Debug, Clone)]
pub struct DecodeCostTable {
    model: ModelSpec,
    rl: Roofline,
    /// Non-attention step time by exact batch size (NaN = unfilled).
    non_attn: Vec<f64>,
    /// Attention FLOPs / HBM bytes per context token.
    attn_flops_per_ctx: f64,
    attn_bytes_per_ctx: f64,
    /// Cached effective roofline rates (deterministic per `rl`).
    eff_flops: f64,
    eff_bw: f64,
    /// Whole-step FLOPs per batch row (all non-attention kernels + head).
    flops_per_row: f64,
}

impl DecodeCostTable {
    pub fn new(rl: &Roofline, model: &ModelSpec) -> Self {
        DecodeCostTable {
            model: *model,
            rl: *rl,
            non_attn: Vec::new(),
            attn_flops_per_ctx: model.decode_attn_flops(1),
            attn_bytes_per_ctx: model.decode_attn_bytes(1),
            eff_flops: rl.effective_flops(),
            eff_bw: rl.effective_bw(),
            flops_per_row: model.decode_qkv_flops(1)
                + model.decode_oproj_flops(1)
                + model.decode_ffn_flops(1)
                + model.decode_head_flops(1),
        }
    }

    /// Pre-fill the batch table at the given bucket capacities (the
    /// graph-capture warm-up analogue; pass `GraphCache::local_buckets`).
    pub fn warm(&mut self, buckets: &[usize]) {
        for &b in buckets {
            if b > 0 {
                let _ = self.non_attention(b as u64);
            }
        }
    }

    /// Non-attention step time (qkv + oproj + ffn + head) for batch `b`,
    /// memoized per exact batch size.
    pub fn non_attention(&mut self, b: u64) -> f64 {
        let i = b as usize;
        if i >= self.non_attn.len() {
            self.non_attn.resize(i + 1, f64::NAN);
        }
        if self.non_attn[i].is_nan() {
            self.non_attn[i] =
                DecodeKernelTimes::compute(&self.rl, &self.model, b, 1).non_attention();
        }
        self.non_attn[i]
    }

    /// Decode-attention time over `ctx_total` context tokens. Exact: the
    /// cost is linear in context, so this equals timing the full
    /// [`KernelCost`] on the roofline.
    pub fn attention(&self, ctx_total: u64) -> f64 {
        if ctx_total == 0 {
            return 0.0;
        }
        let c = ctx_total as f64;
        ((c * self.attn_flops_per_ctx) / self.eff_flops)
            .max((c * self.attn_bytes_per_ctx) / self.eff_bw)
    }

    /// Whole-step FLOPs for compute-utilization accounting (equals
    /// [`ModelSpec::decode_step_flops`]).
    pub fn step_flops(&self, b: u64, ctx_total: u64) -> f64 {
        b as f64 * self.flops_per_row + ctx_total as f64 * self.attn_flops_per_ctx
    }

    /// Entries currently materialized in the batch table (observability).
    pub fn filled_entries(&self) -> usize {
        self.non_attn.iter().filter(|v| !v.is_nan()).count()
    }
}

/// Memoized prefill step costs for one roofline — the prefill-side mirror
/// of [`DecodeCostTable`] (§Perf, EXPERIMENTS.md).
///
/// `ClusterSim::prefill_time` used to recompute the full
/// [`PrefillKernelTimes`] roofline breakdown (four kernel timings) for
/// every prefill batch. Batched prompt-token totals repeat heavily across
/// a run (trace lengths recur, preempted requests re-prefill at the same
/// totals), so a dense lazy table indexed by the exact token count makes
/// each distinct total cost one computation. Unlike decode attention,
/// prefill attention is *quadratic* in the token count, so there is no
/// per-token linear shortcut: the table stores the full step time,
/// bit-identical to the direct computation (computed once, then reread).
#[derive(Debug, Clone)]
pub struct PrefillCostTable {
    model: ModelSpec,
    rl: Roofline,
    /// Step time by exact prompt-token total (NaN = unfilled).
    times: Vec<f64>,
}

impl PrefillCostTable {
    pub fn new(rl: &Roofline, model: &ModelSpec) -> Self {
        PrefillCostTable { model: *model, rl: *rl, times: Vec::new() }
    }

    /// Total prefill step time over `tokens` prompt tokens, memoized per
    /// exact token count.
    pub fn total(&mut self, tokens: u64) -> f64 {
        let i = tokens as usize;
        if i >= self.times.len() {
            self.times.resize(i + 1, f64::NAN);
        }
        if self.times[i].is_nan() {
            self.times[i] = PrefillKernelTimes::compute(&self.rl, &self.model, tokens).total();
        }
        self.times[i]
    }

    /// Entries currently materialized (observability).
    pub fn filled_entries(&self) -> usize {
        self.times.iter().filter(|v| !v.is_nan()).count()
    }
}

/// Timed breakdown of one prefill step.
#[derive(Debug, Clone, Copy)]
pub struct PrefillKernelTimes {
    pub qkv: f64,
    pub attention: f64,
    pub out_proj: f64,
    pub ffn: f64,
}

impl PrefillKernelTimes {
    pub fn compute(rl: &Roofline, model: &ModelSpec, p: u64) -> Self {
        let pk = PhaseKernels::new(*model);
        PrefillKernelTimes {
            qkv: rl.time(pk.prefill_cost(KernelKind::QkvProj, p)),
            attention: rl.time(pk.prefill_cost(KernelKind::Attention, p)),
            out_proj: rl.time(pk.prefill_cost(KernelKind::OutProj, p)),
            ffn: rl.time(pk.prefill_cost(KernelKind::Ffn, p)),
        }
    }

    pub fn total(&self) -> f64 {
        self.qkv + self.attention + self.out_proj + self.ffn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    fn setup() -> (Roofline, ModelSpec) {
        (Roofline::whole(GpuSpec::a100_80g()), ModelSpec::llama2_7b())
    }

    #[test]
    fn fig3_attention_share_grows_with_batch() {
        // Fig 3: attention share of the decode layer grows with batch size
        // and reaches ~69.5% at batch 80, seq 1K.
        let (rl, m) = setup();
        let mut prev = 0.0;
        for b in [8u64, 16, 32, 64, 80] {
            let t = DecodeKernelTimes::compute(&rl, &m, b, b * 1024);
            let share = t.attention_share();
            assert!(share > prev, "share must grow: b={b} share={share}");
            prev = share;
        }
        let t80 = DecodeKernelTimes::compute(&rl, &m, 80, 80 * 1024);
        let share = t80.attention_share();
        assert!((0.60..0.80).contains(&share), "Fig 3 anchor: share(80) = {share:.3}");
    }

    #[test]
    fn fig1b_decode_compute_utilization_low() {
        // Fig 1b: decode compute utilization < 26% across batch sizes.
        let (rl, m) = setup();
        let pk = PhaseKernels::new(m);
        for b in [1u64, 8, 32, 80, 128] {
            let ctx = b * 1024;
            let mut cost = KernelCost::new(0.0, 0.0);
            for k in KernelKind::ALL {
                cost = cost.add(&pk.decode_cost(k, b, ctx));
            }
            let util = rl.compute_utilization(cost);
            assert!(util < 0.26, "decode compute util at b={b} is {util:.3}");
        }
    }

    #[test]
    fn fig1a_prefill_bw_utilization_low() {
        // Fig 1a: prefill HBM bandwidth utilization < 30%.
        let (rl, m) = setup();
        let pk = PhaseKernels::new(m);
        for p in [512u64, 1024, 2048, 4096] {
            let mut cost = KernelCost::new(0.0, 0.0);
            for k in KernelKind::ALL {
                cost = cost.add(&pk.prefill_cost(k, p));
            }
            let util = rl.bw_utilization(cost);
            assert!(util < 0.30, "prefill bw util at p={p} is {util:.3}");
        }
    }

    #[test]
    fn fig5_prefill_kernels_compute_bound() {
        let (rl, m) = setup();
        let pk = PhaseKernels::new(m);
        for k in KernelKind::ALL {
            assert!(
                !rl.memory_bound(pk.prefill_cost(k, 2048)),
                "{} should be compute-bound in prefill",
                k.name()
            );
        }
    }

    #[test]
    fn fig6_decode_kernels_memory_bound_small_batch() {
        let (rl, m) = setup();
        let pk = PhaseKernels::new(m);
        for k in KernelKind::ALL {
            assert!(
                rl.memory_bound(pk.decode_cost(k, 8, 8 * 1024)),
                "{} should be memory-bound in decode at b=8",
                k.name()
            );
        }
    }

    #[test]
    fn decode_attention_time_scales_with_context() {
        let (rl, m) = setup();
        let t1 = DecodeKernelTimes::compute(&rl, &m, 32, 32 * 512).attention;
        let t2 = DecodeKernelTimes::compute(&rl, &m, 32, 32 * 1024).attention;
        assert!((t2 / t1 - 2.0).abs() < 0.05, "attention ~linear in context");
    }

    #[test]
    fn non_attention_time_stable_while_memory_bound() {
        // Eq 2's premise: while non-attention kernels stay memory-bound,
        // their time barely moves with batch size (weights dominate bytes).
        let (rl, m) = setup();
        let t8 = DecodeKernelTimes::compute(&rl, &m, 8, 8 * 1024).non_attention();
        let t64 = DecodeKernelTimes::compute(&rl, &m, 64, 64 * 1024).non_attention();
        assert!(t64 / t8 < 1.25, "non-attn time should be ~flat: {}", t64 / t8);
    }

    #[test]
    fn cost_table_non_attention_matches_direct_compute() {
        let (rl, m) = setup();
        let mut tab = DecodeCostTable::new(&rl, &m);
        for b in [1u64, 3, 8, 17, 64, 200, 256] {
            let direct = DecodeKernelTimes::compute(&rl, &m, b, 1).non_attention();
            // Same computation, cached: bit-identical, twice.
            assert_eq!(tab.non_attention(b), direct, "b={b}");
            assert_eq!(tab.non_attention(b), direct, "b={b} (cached)");
        }
        assert!(tab.filled_entries() >= 7);
    }

    #[test]
    fn cost_table_attention_linear_and_exact() {
        let (rl, m) = setup();
        let tab = DecodeCostTable::new(&rl, &m);
        assert_eq!(tab.attention(0), 0.0);
        for ctx in [1u64, 37, 1024, 81920, 1_000_000] {
            let direct = rl.time(KernelCost::new(m.decode_attn_flops(ctx), m.decode_attn_bytes(ctx)));
            let memo = tab.attention(ctx);
            assert!(
                (memo - direct).abs() <= direct.abs() * 1e-12,
                "ctx={ctx}: memo={memo:e} direct={direct:e}"
            );
        }
    }

    #[test]
    fn cost_table_step_flops_matches_model() {
        let (rl, m) = setup();
        let tab = DecodeCostTable::new(&rl, &m);
        for (b, ctx) in [(1u64, 128u64), (7, 4096), (80, 80 * 1024), (256, 1_000_000)] {
            let direct = m.decode_step_flops(b, ctx);
            let memo = tab.step_flops(b, ctx);
            assert!(
                (memo - direct).abs() <= direct.abs() * 1e-12,
                "b={b} ctx={ctx}: memo={memo:e} direct={direct:e}"
            );
        }
    }

    #[test]
    fn cost_table_warms_at_graph_cache_buckets() {
        let (rl, m) = setup();
        let grid = crate::coordinator::GraphCache::new(&[1, 2, 4, 8], &[1, 2, 4, 8], None);
        let mut tab = DecodeCostTable::new(&rl, &m);
        tab.warm(grid.local_buckets());
        // The 0 bucket is skipped; the four real capacities are filled.
        assert_eq!(tab.filled_entries(), 4);
    }

    #[test]
    fn cost_table_partition_roofline() {
        // The executor partition's table must use the partition's rates.
        let m = ModelSpec::llama2_7b();
        let whole = Roofline::whole(GpuSpec::a100_80g());
        let part = Roofline::partition(GpuSpec::a100_80g(), 0.5);
        let tw = DecodeCostTable::new(&whole, &m);
        let tp = DecodeCostTable::new(&part, &m);
        assert!(tp.attention(4096) > tw.attention(4096));
    }

    #[test]
    fn prefill_cost_table_matches_direct_compute() {
        let (rl, m) = setup();
        let mut tab = PrefillCostTable::new(&rl, &m);
        for p in [1u64, 128, 511, 512, 2048, 8192] {
            let direct = PrefillKernelTimes::compute(&rl, &m, p).total();
            // Same computation, cached: bit-identical, twice.
            assert_eq!(tab.total(p), direct, "p={p}");
            assert_eq!(tab.total(p), direct, "p={p} (cached)");
        }
        assert_eq!(tab.filled_entries(), 6);
    }

    #[test]
    fn prefill_time_grows_with_prompt() {
        let (rl, m) = setup();
        let t1 = PrefillKernelTimes::compute(&rl, &m, 512).total();
        let t2 = PrefillKernelTimes::compute(&rl, &m, 2048).total();
        assert!(t2 > 3.5 * t1);
    }
}
