//! Offline profiling + adaptive SM partition — §3.3.2.
//!
//! The paper's two-stage scheme:
//!
//! 1. **Offline profiling**: measure prefill latency across (SM fraction,
//!    prompt length) with the kernel profiler. Here the "profiler" is the
//!    roofline + the Fig 10 slowdown curve; the table is serializable so a
//!    deployment can ship real measurements instead.
//! 2. **Online serving**: given the TTFT SLO and the workload's prompt
//!    statistics, pick the *minimal* SM fraction that keeps prefill within
//!    SLO, and hand the complement to the attention executor.

use crate::config::{GpuSpec, ModelSpec};
use crate::util::json::Json;

use super::kernels::PrefillKernelTimes;
use super::partition::prefill_slowdown;
use super::roofline::Roofline;

/// One measured point: prefill latency at (sm_frac, prompt tokens).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfileEntry {
    pub sm_frac: f64,
    pub prompt_tokens: u64,
    pub latency_s: f64,
}

/// The offline-profiling table.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PrefillProfile {
    entries: Vec<ProfileEntry>,
}

impl PrefillProfile {
    /// Build the table from the GPU model (stands in for the paper's
    /// kernel profiler; a deployment would load real measurements via
    /// [`PrefillProfile::from_json`]).
    pub fn measure(gpu: &GpuSpec, model: &ModelSpec, sm_fracs: &[f64], prompts: &[u64]) -> Self {
        let rl = Roofline::whole(*gpu);
        let mut entries = Vec::with_capacity(sm_fracs.len() * prompts.len());
        for &p in prompts {
            let base = PrefillKernelTimes::compute(&rl, model, p).total();
            for &s in sm_fracs {
                assert!(s > 0.0 && s <= 1.0, "sm_frac in (0,1]");
                entries.push(ProfileEntry {
                    sm_frac: s,
                    prompt_tokens: p,
                    latency_s: base * prefill_slowdown(s),
                });
            }
        }
        PrefillProfile { entries }
    }

    /// Default grid: 10 SM steps × the paper's prompt-length range.
    pub fn default_grid(gpu: &GpuSpec, model: &ModelSpec) -> Self {
        let fracs: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        Self::measure(gpu, model, &fracs, &[256, 512, 1024, 2048, 4096])
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Interpolated prefill latency at (sm_frac, tokens): nearest profiled
    /// SM fraction at or below `sm_frac`, linear interpolation in tokens
    /// (prefill time is ~linear+quadratic in p; piecewise-linear between
    /// grid points is within a few percent).
    pub fn latency(&self, sm_frac: f64, tokens: u64) -> Option<f64> {
        let frac = self
            .entries
            .iter()
            .map(|e| e.sm_frac)
            .filter(|&s| s <= sm_frac + 1e-12)
            .fold(None::<f64>, |acc, s| Some(acc.map_or(s, |a| a.max(s))))?;
        let mut at_frac: Vec<&ProfileEntry> =
            self.entries.iter().filter(|e| (e.sm_frac - frac).abs() < 1e-12).collect();
        at_frac.sort_by_key(|e| e.prompt_tokens);
        match at_frac.binary_search_by_key(&tokens, |e| e.prompt_tokens) {
            Ok(i) => Some(at_frac[i].latency_s),
            Err(0) => {
                // Below the grid: scale the smallest point linearly.
                let e = at_frac.first()?;
                Some(e.latency_s * tokens as f64 / e.prompt_tokens as f64)
            }
            Err(i) if i >= at_frac.len() => {
                // Above the grid: scale the largest point quadratically
                // (attention-dominated regime).
                let e = at_frac.last()?;
                let r = tokens as f64 / e.prompt_tokens as f64;
                Some(e.latency_s * r * r)
            }
            Err(i) => {
                let (lo, hi) = (at_frac[i - 1], at_frac[i]);
                let w = (tokens - lo.prompt_tokens) as f64
                    / (hi.prompt_tokens - lo.prompt_tokens) as f64;
                Some(lo.latency_s * (1.0 - w) + hi.latency_s * w)
            }
        }
    }

    /// §3.3.2 online stage: the minimal profiled SM fraction whose prefill
    /// latency for `tokens`-token prompts stays within `ttft_slo_s`
    /// (queueing headroom is the caller's concern). `None` if even the
    /// whole GPU misses the SLO.
    pub fn min_prefill_sm_frac(&self, tokens: u64, ttft_slo_s: f64) -> Option<f64> {
        let mut fracs: Vec<f64> = self.entries.iter().map(|e| e.sm_frac).collect();
        fracs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        fracs.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        fracs
            .into_iter()
            .find(|&s| self.latency(s, tokens).is_some_and(|l| l <= ttft_slo_s))
    }

    /// The SM fraction left for the attention executor after reserving the
    /// minimal prefill share (clamped to leave the executor something only
    /// when the SLO allows it).
    pub fn executor_sm_frac(&self, tokens: u64, ttft_slo_s: f64) -> f64 {
        match self.min_prefill_sm_frac(tokens, ttft_slo_s) {
            Some(s) => (1.0 - s).max(0.0),
            None => 0.0, // SLO needs the whole GPU: no executor share
        }
    }

    // ----- serialization (ship real profiler output) ------------------------

    pub fn to_json(&self) -> String {
        Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    let mut o = std::collections::BTreeMap::new();
                    o.insert("sm".into(), Json::Num(e.sm_frac));
                    o.insert("tokens".into(), Json::Num(e.prompt_tokens as f64));
                    o.insert("latency_s".into(), Json::Num(e.latency_s));
                    Json::Obj(o)
                })
                .collect(),
        )
        .to_string()
    }

    pub fn from_json(text: &str) -> crate::Result<Self> {
        let v = Json::parse(text)?;
        let entries = v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("profile must be an array"))?
            .iter()
            .map(|e| {
                Ok(ProfileEntry {
                    sm_frac: e
                        .get("sm")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow::anyhow!("missing sm"))?,
                    prompt_tokens: e
                        .get("tokens")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| anyhow::anyhow!("missing tokens"))?,
                    latency_s: e
                        .get("latency_s")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow::anyhow!("missing latency_s"))?,
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(PrefillProfile { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};

    fn profile() -> PrefillProfile {
        PrefillProfile::default_grid(&GpuSpec::a100_80g(), &ModelSpec::llama2_7b())
    }

    #[test]
    fn latency_monotone_in_both_axes() {
        let p = profile();
        // More SMs -> faster.
        let slow = p.latency(0.3, 1024).unwrap();
        let fast = p.latency(0.9, 1024).unwrap();
        assert!(fast < slow);
        // Longer prompts -> slower.
        assert!(p.latency(0.5, 2048).unwrap() > p.latency(0.5, 512).unwrap());
    }

    #[test]
    fn interpolation_between_grid_points() {
        let p = profile();
        let lo = p.latency(0.5, 1024).unwrap();
        let hi = p.latency(0.5, 2048).unwrap();
        let mid = p.latency(0.5, 1536).unwrap();
        assert!(lo < mid && mid < hi);
    }

    #[test]
    fn min_sm_frac_meets_slo() {
        let p = profile();
        // 7B prefill of 1024 tokens on a full A100 takes ~20 ms — a 200 ms
        // TTFT SLO leaves a lot of SM headroom.
        let s = p.min_prefill_sm_frac(1024, 0.2).unwrap();
        assert!(s < 0.5, "loose SLO needs few SMs: {s}");
        assert!(p.latency(s, 1024).unwrap() <= 0.2);
        // A brutal SLO needs everything (or is unreachable).
        let tight = p.min_prefill_sm_frac(4096, 1e-4);
        assert!(tight.is_none());
    }

    #[test]
    fn executor_gets_the_complement() {
        let p = profile();
        let s = p.min_prefill_sm_frac(1024, 0.2).unwrap();
        assert!((p.executor_sm_frac(1024, 0.2) - (1.0 - s)).abs() < 1e-12);
        assert_eq!(p.executor_sm_frac(4096, 1e-4), 0.0);
    }

    #[test]
    fn tighter_slo_reserves_more_sms() {
        let p = profile();
        let loose = p.min_prefill_sm_frac(2048, 1.0).unwrap();
        let tight = p.min_prefill_sm_frac(2048, 0.25).unwrap();
        assert!(tight >= loose, "tight {tight} vs loose {loose}");
    }

    #[test]
    fn json_roundtrip() {
        let p = profile();
        let back = PrefillProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn out_of_grid_extrapolation_finite() {
        let p = profile();
        assert!(p.latency(0.5, 64).unwrap() > 0.0);
        assert!(p.latency(0.5, 16384).unwrap() > p.latency(0.5, 4096).unwrap());
        assert!(p.latency(0.05, 1024).is_none(), "below smallest profiled frac");
    }
}
