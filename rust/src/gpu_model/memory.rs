//! HBM capacity accounting for one instance (Figs 2 and 16).

use crate::config::{ClusterSpec, ModelSpec};

/// Snapshot of HBM usage on one GPU instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HbmUsage {
    /// Model weights, bytes.
    pub weights: f64,
    /// Peak activation workspace, bytes.
    pub activations: f64,
    /// KV-cache bytes currently allocated.
    pub kv_cache: f64,
    /// Total HBM capacity, bytes.
    pub capacity: f64,
}

impl HbmUsage {
    /// Usage for an instance serving `model` with `kv_tokens` of KV resident.
    pub fn for_instance(cluster: &ClusterSpec, model: &ModelSpec, kv_tokens: u64) -> Self {
        Self::on_capacity(cluster.gpu.hbm_capacity, model, kv_tokens)
    }

    /// Usage on a specific device's HBM capacity — the per-profile variant
    /// of [`for_instance`] (heterogeneous instance classes each account
    /// against their own device).
    ///
    /// [`for_instance`]: HbmUsage::for_instance
    pub fn on_capacity(capacity: f64, model: &ModelSpec, kv_tokens: u64) -> Self {
        HbmUsage {
            weights: model.weight_bytes(),
            activations: Self::activation_workspace(model),
            kv_cache: kv_tokens as f64 * model.kv_bytes_per_token(),
            capacity,
        }
    }

    /// Peak activation workspace: a few full hidden-state buffers for the
    /// largest batch plus the FFN intermediate. Small next to weights/KV;
    /// modeled as 6 buffers of max_batch_tokens × max(d, ffn) elements.
    pub fn activation_workspace(model: &ModelSpec) -> f64 {
        let max_tokens = 8192.0; // scheduler's max_prefill_tokens default
        let widest = model.d_model.max(model.ffn_hidden) as f64;
        6.0 * max_tokens * widest * model.dtype_bytes
    }

    pub fn total_used(&self) -> f64 {
        self.weights + self.activations + self.kv_cache
    }

    /// HBM capacity utilization in [0, 1] — the Fig 2/16 metric.
    pub fn utilization(&self) -> f64 {
        (self.total_used() / self.capacity).min(1.0)
    }

    /// KV cache's share of capacity (the paper reports 57.3 % for the
    /// decode instance).
    pub fn kv_share(&self) -> f64 {
        self.kv_cache / self.capacity
    }

    /// KV tokens that fit in the remaining budget given vLLM-style
    /// `memory_utilization` head-room.
    pub fn kv_token_budget(
        cluster: &ClusterSpec,
        model: &ModelSpec,
    ) -> u64 {
        Self::kv_token_budget_in(cluster.usable_hbm(), model)
    }

    /// KV-token budget inside an explicit usable-HBM allowance — the
    /// per-profile variant of [`kv_token_budget`] (pair with
    /// [`ClusterSpec::usable_hbm_of`] for a role's own device).
    ///
    /// [`kv_token_budget`]: HbmUsage::kv_token_budget
    pub fn kv_token_budget_in(usable_hbm: f64, model: &ModelSpec) -> u64 {
        let budget = usable_hbm
            - model.weight_bytes()
            - Self::activation_workspace(model);
        (budget.max(0.0) / model.kv_bytes_per_token()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, ModelSpec};

    #[test]
    fn fig2_prefill_instance_utilization_low() {
        // Fig 2: prefill instance sits around 20% capacity (weights +
        // workspace only — KV leaves immediately after transfer).
        let c = ClusterSpec::paper_default();
        let m = ModelSpec::llama2_7b();
        let u = HbmUsage::for_instance(&c, &m, 0);
        assert!((0.15..0.25).contains(&u.utilization()), "util = {}", u.utilization());
    }

    #[test]
    fn fig2_decode_instance_utilization_high() {
        // Fig 2: decode instance ~75.5% after warmup with KV at 57.3%.
        let c = ClusterSpec::paper_default();
        let m = ModelSpec::llama2_7b();
        let budget = HbmUsage::kv_token_budget(&c, &m);
        let u = HbmUsage::for_instance(&c, &m, budget);
        assert!((0.70..0.82).contains(&u.utilization()), "util = {}", u.utilization());
        assert!((0.50..0.62).contains(&u.kv_share()), "kv share = {}", u.kv_share());
    }

    #[test]
    fn kv_budget_positive_and_sane() {
        let c = ClusterSpec::paper_default();
        for m in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b()] {
            let budget = HbmUsage::kv_token_budget(&c, &m);
            assert!(budget > 10_000, "{}: budget = {budget}", m.name);
            assert!(budget < 1_000_000);
        }
    }

    #[test]
    fn utilization_clamped() {
        let c = ClusterSpec::paper_default();
        let m = ModelSpec::llama2_7b();
        let u = HbmUsage::for_instance(&c, &m, u64::MAX / 1024);
        assert!(u.utilization() <= 1.0);
    }

    #[test]
    fn per_device_budget_matches_cluster_path_and_scales_with_hbm() {
        use crate::config::GpuSpec;
        let c = ClusterSpec::paper_default();
        let m = ModelSpec::llama2_7b();
        assert_eq!(
            HbmUsage::kv_token_budget_in(c.usable_hbm(), &m),
            HbmUsage::kv_token_budget(&c, &m),
            "delegation is the same expression"
        );
        let richer = HbmUsage::kv_token_budget_in(c.usable_hbm_of(&GpuSpec::h20_96g()), &m);
        assert!(richer > HbmUsage::kv_token_budget(&c, &m), "more HBM, more KV tokens");
    }

    #[test]
    fn more_kv_more_utilization() {
        let c = ClusterSpec::paper_default();
        let m = ModelSpec::llama2_13b();
        let u1 = HbmUsage::for_instance(&c, &m, 10_000);
        let u2 = HbmUsage::for_instance(&c, &m, 50_000);
        assert!(u2.utilization() > u1.utilization());
    }
}
