//! The unified cost plane: every step-time quantity the simulator charges
//! flows through [`CostModel`], so the bucket-granularity fidelity policy
//! lives in exactly one place instead of being smeared across
//! `sim/cluster.rs`, `gpu_model/kernels.rs`, and
//! `coordinator/graph_cache.rs`.
//!
//! # Cost modes
//!
//! * [`CostMode::Bucketed`] (default) — decode steps pay the padded rows
//!   of the 2-D executable grid (§3.2.2): each step selects the smallest
//!   captured `(C_d, C_o)` pair covering its (local, offloaded) sub-batch
//!   via [`GraphCache::select`], the non-attention executables run at
//!   `C_d + C_o` rows, and every padded attention row reads its single
//!   dummy KV slot. This is what the real 2-D CUDA-graph / AOT-executable
//!   path executes, so the simulator's step times now carry the same
//!   bucket-granularity trade-off DistServe-style systems tune.
//! * [`CostMode::Exact`] — the pre-bucketing model (costs at exact batch
//!   sizes), kept for ablations and bit-identical regression against the
//!   PR 1 baselines. Enabled via `ServingConfig::exact_costs` or the
//!   `ADRENALINE_EXACT_COSTS=1` environment switch.
//!
//! In both modes the underlying roofline math is memoized:
//! [`DecodeCostTable`] for decode steps, [`PrefillCostTable`] for prefill
//! batches (previously recomputed per batch), warmed at the grid's local
//! capacities the way real graph capture pre-compiles them.
//!
//! Step FLOPs stay *useful* FLOPs (exact rows/contexts) in both modes:
//! padding burns wall-clock, not useful work, so decode compute
//! utilization dips by exactly the padding share — the effect Fig 17b's
//! ablation wants visible.

use crate::config::ModelSpec;
use crate::coordinator::{BucketPair, GraphCache, GraphCacheStats};

use super::kernels::{DecodeCostTable, PrefillCostTable};
use super::partition::InterferenceModel;
use super::roofline::Roofline;

/// Prefill's own HBM-bandwidth draw when unconstrained (Fig 1a) — the
/// demand fraction the interference model weighs against the executor's.
pub const PREFILL_BW_FRAC: f64 = 0.25;

/// How decode-step costs are charged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostMode {
    /// Exact per-batch costs (pre-bucketing model; ablation/regression).
    Exact,
    /// Costs padded to the selected executable-bucket pair (default).
    Bucketed,
}

/// One decode step's cost breakdown.
#[derive(Debug, Clone, Copy)]
pub struct DecodeStepCost {
    /// Total step wall time (non-attention + max(local, remote+sync) +
    /// eager launch overhead).
    pub step_s: f64,
    pub non_attention_s: f64,
    pub local_attention_s: f64,
    /// Max over executor partitions, including the per-layer sync
    /// overhead when any row is offloaded.
    pub remote_attention_s: f64,
    /// Useful FLOPs (exact, never padded) for utilization accounting.
    pub flops: f64,
    /// The selected executable pair (None in exact mode, or if the step
    /// exceeded the grid and fell back to exact charging).
    pub bucket: Option<BucketPair>,
}

/// The simulator's cost plane. Owns the memoized roofline tables, the
/// executable-bucket grid, and the prefill interference model.
#[derive(Debug, Clone)]
pub struct CostModel {
    mode: CostMode,
    /// Decode-step costs on the decode instance's device roofline.
    decode: DecodeCostTable,
    /// Attention costs on the executor's device (an SM partition of the
    /// prefill GPU when colocated, a whole standalone device otherwise).
    executor: DecodeCostTable,
    /// Memoized prefill step times on the prefill device's *whole-GPU*
    /// roofline; static SM confinement is priced by
    /// `prefill_sm_slowdown` below (partition.rs's Fig 10 curve, not a
    /// naive roofline rescale).
    prefill: PrefillCostTable,
    /// Static intra-GPU split multiplier on prefill steps:
    /// `prefill_slowdown(sm_frac)` of the prefill device's partition,
    /// exactly 1.0 for a whole-GPU prefill device (and then never
    /// multiplied in, keeping the default bit-identical).
    prefill_sm_slowdown: f64,
    /// The 2-D executable grid; selection statistics accumulate here.
    grid: GraphCache,
    /// Colocation interference (None when offloading is disabled or the
    /// executor runs on its own device — prefill then has the GPU alone).
    interference: Option<InterferenceModel>,
    /// The prefill GPU's achievable-bandwidth efficiency (for the
    /// executor's bandwidth cap inside the interference model).
    gpu_bw_eff: f64,
    /// KV-cache bytes per token (all layers) — the unit of KV movement.
    kv_bytes_per_token: f64,
    /// Inter-GPU interconnect bandwidth, B/s (NVLink).
    interconnect_bw: f64,
    /// Per-layer decode<->executor sync overhead, whole-step total.
    sync_total_s: f64,
    /// Extra CPU launch overhead per step (eager ablation; 0 with graphs).
    eager_launch_overhead_s: f64,
    /// Per-executor-partition straggler multipliers (the fault plane's
    /// slowdown windows). Empty until a window ever opens — the
    /// structurally-inert default: [`CostModel::decode_step`] then never
    /// touches a multiplier, so fault-free runs keep the exact pre-fault
    /// f64 op order bit for bit.
    executor_slowdown: Vec<f64>,
    /// Reusable scratch for [`CostModel::decode_step_series`]: the
    /// advancing per-partition ctx sums and the per-step executor-time
    /// staging buffer (no allocation after warm-up).
    series_ctx: Vec<u64>,
    series_exec: Vec<f64>,
}

impl CostModel {
    /// Build the cost plane from per-role device rooflines: prefill steps
    /// price on `rl_prefill`, decode steps on `rl_decode`, offloaded
    /// attention on `rl_executor`. The homogeneous default (every role on
    /// the same whole GPU, colocated executor partition) reproduces the
    /// single-`GpuSpec` model bit for bit; heterogeneous profiles
    /// (arXiv 2405.01814's memory-rich executor, Nexus-style intra-GPU
    /// prefill/decode splits) just pass different rooflines.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rl_prefill: &Roofline,
        rl_decode: &Roofline,
        rl_executor: &Roofline,
        model: &ModelSpec,
        grid: GraphCache,
        mode: CostMode,
        interference: Option<InterferenceModel>,
        sync_overhead_s: f64,
        eager_launch_overhead_s: f64,
    ) -> Self {
        let mut decode = DecodeCostTable::new(rl_decode, model);
        // Warm at the captured capacities (the graph-capture analogue);
        // everything else backfills lazily and exactly.
        decode.warm(grid.local_buckets());
        // A partitioned prefill device pays the Fig 10 slowdown curve,
        // not a naive roofline rescale (prefill has a non-GPU fraction
        // and sublinear compute sensitivity — partition.rs). Computed
        // only off the 1.0 whole-GPU case: `prefill_slowdown(1.0)` is
        // *mathematically* 1 but not guaranteed bit-exactly 1.0 in f64,
        // and the default path must stay untouched.
        let prefill_sm_slowdown = if rl_prefill.sm_frac != 1.0 {
            super::partition::prefill_slowdown(rl_prefill.sm_frac)
        } else {
            1.0
        };
        CostModel {
            mode,
            decode,
            executor: DecodeCostTable::new(rl_executor, model),
            prefill: PrefillCostTable::new(&Roofline::whole(rl_prefill.gpu), model),
            prefill_sm_slowdown,
            grid,
            interference,
            gpu_bw_eff: rl_prefill.gpu.bw_eff,
            kv_bytes_per_token: model.kv_bytes_per_token(),
            // KV moves prefill->decode: the path's bottleneck link.
            interconnect_bw: rl_prefill.gpu.interconnect_bw.min(rl_decode.gpu.interconnect_bw),
            sync_total_s: sync_overhead_s * model.n_layers as f64,
            eager_launch_overhead_s,
            executor_slowdown: Vec::new(),
            series_ctx: Vec::new(),
            series_exec: Vec::new(),
        }
    }

    /// Open a straggler window on executor partition `pi`: its offloaded
    /// attention times are multiplied by `factor` until cleared.
    pub fn set_executor_slowdown(&mut self, pi: usize, factor: f64) {
        if self.executor_slowdown.len() <= pi {
            self.executor_slowdown.resize(pi + 1, 1.0);
        }
        self.executor_slowdown[pi] = factor;
    }

    /// Close the straggler window on `pi` (multiplier back to 1).
    pub fn clear_executor_slowdown(&mut self, pi: usize) {
        if let Some(s) = self.executor_slowdown.get_mut(pi) {
            *s = 1.0;
        }
    }

    /// Wall time to move `tokens` of KV cache across the interconnect —
    /// the prefill→decode transfer and both directions of a runtime
    /// offload migration. Bit-identical to the legacy inline
    /// `bytes / interconnect_bw` formula (pinned by test).
    pub fn kv_transfer_time(&self, tokens: u64) -> f64 {
        let bytes = tokens as f64 * self.kv_bytes_per_token;
        bytes / self.interconnect_bw
    }

    /// Build the step-cost bucket grid from the configured capture lists,
    /// extended by doubling the largest capacity until both dimensions
    /// cover `max_batch` — the scheduler caps batches there, so every
    /// reachable step selects a captured pair (real capture does the same:
    /// the grid must span the servable batch range or the step splits).
    pub fn build_grid(
        decode_buckets: &[usize],
        offload_buckets: &[usize],
        max_batch: usize,
    ) -> GraphCache {
        let extend = |buckets: &[usize]| -> Vec<usize> {
            let mut v = buckets.to_vec();
            if let Some(&last) = v.last() {
                let mut cap = last;
                while cap < max_batch && cap > 0 {
                    cap *= 2;
                    v.push(cap);
                }
            }
            v
        };
        GraphCache::new(&extend(decode_buckets), &extend(offload_buckets), None)
    }

    pub fn mode(&self) -> CostMode {
        self.mode
    }

    pub fn grid(&self) -> &GraphCache {
        &self.grid
    }

    pub fn graph_stats(&self) -> GraphCacheStats {
        self.grid.stats()
    }

    pub fn bucket_hits(&self) -> Vec<(BucketPair, u64)> {
        self.grid.bucket_hits()
    }

    pub fn padding_overhead(&self) -> f64 {
        self.grid.padding_overhead()
    }

    /// Prefill step time over `tokens` prompt tokens. `executor_duty` is
    /// the colocated executor's recent duty cycle in [0, 1]: the MPS
    /// reservation always applies, bandwidth contention in proportion to
    /// the duty cycle.
    pub fn prefill_time(&mut self, tokens: u64, executor_duty: f64) -> f64 {
        let mut base = self.prefill.total(tokens);
        // Static SM confinement (intra-GPU prefill/decode split). Gated
        // on != 1.0 so whole-GPU prefill keeps the exact legacy op order.
        if self.prefill_sm_slowdown != 1.0 {
            base *= self.prefill_sm_slowdown;
        }
        let Some(interference) = self.interference else {
            return base;
        };
        let attn_bw = interference.attn_bw_cap(self.gpu_bw_eff);
        let idle = interference.prefill_slowdown_idle();
        let active = interference.prefill_slowdown_active(PREFILL_BW_FRAC, attn_bw);
        base * (idle * (1.0 - executor_duty) + active * executor_duty)
    }

    /// One decode step's cost from the per-instance aggregates.
    ///
    /// * `local_rows` / `local_ctx_sum` — non-offloaded rows in the batch
    ///   and the sum of their resident KV tokens (the token being
    ///   generated is added here, one per row).
    /// * `remote_rows` / `remote_ctx_sums` — the same per executor
    ///   partition (indexed by prefill instance).
    /// * `executor_times_out` — cleared and filled with each executor's
    ///   attention seconds (0.0 where no rows), so the caller can
    ///   attribute busy time; its capacity is reused across calls.
    pub fn decode_step(
        &mut self,
        local_rows: u64,
        local_ctx_sum: u64,
        remote_rows: &[u64],
        remote_ctx_sums: &[u64],
        executor_times_out: &mut Vec<f64>,
    ) -> DecodeStepCost {
        debug_assert_eq!(remote_rows.len(), remote_ctx_sums.len());
        executor_times_out.clear();
        executor_times_out.resize(remote_rows.len(), 0.0);

        let remote_rows_total: u64 = remote_rows.iter().sum();
        let b_total = local_rows + remote_rows_total;

        // Bucket selection: the step runs padded to the smallest captured
        // pair covering (local, offload). A step beyond the grid (only
        // possible with a hand-shrunk grid) falls back to exact charging.
        let bucket = match self.mode {
            CostMode::Exact => None,
            CostMode::Bucketed => {
                self.grid.select(local_rows as usize, remote_rows_total as usize)
            }
        };
        let (rows_charged, local_pad) = match bucket {
            Some(p) => ((p.local + p.offload) as u64, p.local as u64 - local_rows),
            None => (b_total, 0),
        };

        // Non-attention executables run at the captured batch shape.
        let non_attention_s = self.decode.non_attention(rows_charged);

        // Each local row attends over its context plus the token being
        // generated; each padded row reads its single dummy slot.
        let local_attention_s = if local_rows > 0 {
            self.decode.attention(local_ctx_sum + local_rows + local_pad)
        } else {
            0.0
        };

        // Remote attention on each involved executor partition, in
        // parallel. Each executor runs the smallest offload-bucket
        // executable covering *its own* rows (the decode-side pair above
        // covers the step total; padding every executor to that total's
        // bucket would overcharge multi-executor steps), so its padded
        // rows each read one dummy KV slot.
        let mut remote_attention_s: f64 = 0.0;
        let mut remote_ctx_total: u64 = 0;
        let mut any_remote = false;
        for (pi, (&rows, &ctx_sum)) in remote_rows.iter().zip(remote_ctx_sums).enumerate() {
            if rows == 0 {
                continue;
            }
            any_remote = true;
            let ctx = ctx_sum + rows;
            remote_ctx_total += ctx;
            let pad = if bucket.is_some() {
                self.grid.cover_offload(rows as usize).map_or(0, |b| b as u64 - rows)
            } else {
                0
            };
            let mut t = self.executor.attention(ctx + pad);
            // Straggler windows (fault plane): a lagging executor's
            // attention stretches by its slowdown factor. Gated on != 1.0
            // so fault-free runs keep the exact pre-fault f64 op order.
            if let Some(&s) = self.executor_slowdown.get(pi) {
                if s != 1.0 {
                    t *= s;
                }
            }
            executor_times_out[pi] = t;
            remote_attention_s = remote_attention_s.max(t);
        }
        if any_remote {
            remote_attention_s += self.sync_total_s;
        }

        let step_s = non_attention_s
            + local_attention_s.max(remote_attention_s)
            + self.eager_launch_overhead_s;

        let local_for_flops = if local_rows > 0 { local_ctx_sum + local_rows } else { 0 };
        let flops = self.decode.step_flops(b_total, local_for_flops + remote_ctx_total);

        DecodeStepCost {
            step_s,
            non_attention_s,
            local_attention_s,
            remote_attention_s,
            flops,
            bucket,
        }
    }

    /// Price a run of consecutive decode steps with a *frozen* batch
    /// composition — the steady-state leap engine's inner loop (§Perf).
    /// Between scheduler events each step adds exactly one token per row,
    /// so the context sums advance by the row counts from one step to the
    /// next while the row counts stay fixed. Starting from `t0`, steps
    /// are priced one at a time — identical f64 op order (and identical
    /// grid-selection statistics) to calling [`CostModel::decode_step`]
    /// per step with hand-advanced aggregates, so a leaped run's
    /// step-time sequence is bit-identical to the per-step reference —
    /// appending each step's cost to `costs_out` and its per-partition
    /// executor seconds to `executor_times_out` (flattened,
    /// `remote_rows.len()` entries per step), until the first step that
    /// must become a scheduled event:
    ///
    /// * it is the `max_steps`-th step priced (the caller's clean-step
    ///   horizon: first finish / pool overflow — or 1 when leaping is
    ///   disabled), or
    /// * it ends at or after `stop_before` (a queued event would
    ///   interleave; queue ties must keep resolving in push order), or
    /// * it ends after `hard_stop` (the run loop stops on the event that
    ///   pops past its cutoff, so that step's tokens are never granted).
    ///
    /// Always prices at least one step and returns the count; the caller
    /// commits all but the last inline and schedules the last.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_step_series(
        &mut self,
        t0: f64,
        stop_before: Option<f64>,
        hard_stop: f64,
        max_steps: usize,
        local_rows: u64,
        local_ctx_sum: u64,
        remote_rows: &[u64],
        remote_ctx_sums: &[u64],
        costs_out: &mut Vec<DecodeStepCost>,
        executor_times_out: &mut Vec<f64>,
    ) -> usize {
        debug_assert!(max_steps >= 1, "a step series prices at least one step");
        costs_out.clear();
        executor_times_out.clear();
        let mut ctx = std::mem::take(&mut self.series_ctx);
        let mut exec = std::mem::take(&mut self.series_exec);
        ctx.clear();
        ctx.extend_from_slice(remote_ctx_sums);
        let mut local_ctx = local_ctx_sum;
        let mut t = t0;
        loop {
            let cost = self.decode_step(local_rows, local_ctx, remote_rows, &ctx, &mut exec);
            costs_out.push(cost);
            executor_times_out.extend_from_slice(&exec);
            let t_end = t + cost.step_s;
            let interior = costs_out.len() < max_steps
                && stop_before.map_or(true, |te| t_end < te)
                && t_end <= hard_stop;
            if !interior {
                break;
            }
            local_ctx += local_rows;
            for (c, &r) in ctx.iter_mut().zip(remote_rows) {
                *c += r;
            }
            t = t_end;
        }
        let n = costs_out.len();
        self.series_ctx = ctx;
        self.series_exec = exec;
        n
    }

    /// Copy the straggler multipliers from `src`. Epoch pricers —
    /// per-instance clones of the authoritative cost model that live on
    /// worker threads — re-sync before each epoch, so fault-plane
    /// slowdown windows opened or closed since the clone was taken price
    /// bit-identically to the authoritative model.
    pub fn sync_executor_slowdowns(&mut self, src: &CostModel) {
        self.executor_slowdown.clear();
        self.executor_slowdown.extend_from_slice(&src.executor_slowdown);
    }

    /// Record the executable-grid statistics [`CostModel::decode_step`]
    /// would have recorded for one step with these aggregates. The epoch
    /// merge calls this on the authoritative model for exactly the steps
    /// that started: pricing ran on a clone (stats discarded), and steps
    /// priced speculatively past the epoch horizon must not count — the
    /// serial reference would only price them later, if at all.
    pub fn record_decode_selection(&mut self, local_rows: u64, remote_rows_total: u64) {
        if self.mode == CostMode::Bucketed {
            self.grid.record_selection(local_rows as usize, remote_rows_total as usize);
        }
    }
}

/// Online B_TPOT estimator (§3.4.2) — the feedback half of the bounds
/// plane. The simulator feeds it every decode step's (batch, wall time)
/// and every finished request's mean TPOT; it maintains an EMA of step
/// time at each captured `GraphCache` local bucket plus a request-level
/// TPOT EMA, and answers "what is the largest batch currently meeting the
/// TPOT SLO" so the proxy can refresh `OB_comp` as load and context
/// lengths shift (`Proxy::observe_b_tpot`).
///
/// The request-level EMA corrects for what raw step times cannot see:
/// tokens wait on scheduling gaps, migrations, and recompute, so observed
/// per-token latency is at least the step time. The ratio of the two EMAs
/// becomes a ≥ 1 inflation factor applied to the per-bucket step curve
/// before it is compared against the SLO.
#[derive(Debug, Clone)]
pub struct BTpotEstimator {
    /// Captured local-batch capacities, ascending (zero filtered out).
    buckets: Vec<usize>,
    /// Per-bucket step-time EMA; NaN = bucket not yet observed.
    step_ema: Vec<f64>,
    /// EMA weight for each new observation.
    alpha: f64,
    /// Bucket-agnostic step-time EMA (denominator of the inflation).
    global_step_ema: f64,
    /// Finished requests' mean-TPOT EMA (numerator of the inflation).
    req_tpot_ema: f64,
    observations: u64,
}

impl BTpotEstimator {
    pub fn new(buckets: &[usize], alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "EMA alpha must be in (0, 1], got {alpha}");
        let buckets: Vec<usize> = buckets.iter().copied().filter(|&b| b > 0).collect();
        assert!(!buckets.is_empty(), "estimator needs at least one non-zero bucket");
        debug_assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets must ascend");
        BTpotEstimator {
            step_ema: vec![f64::NAN; buckets.len()],
            buckets,
            alpha,
            global_step_ema: f64::NAN,
            req_tpot_ema: f64::NAN,
            observations: 0,
        }
    }

    /// Index of the smallest bucket covering `batch` (the bucket the
    /// executable grid would run this batch at); saturates at the largest.
    fn cover(&self, batch: usize) -> usize {
        match self.buckets.binary_search(&batch) {
            Ok(i) => i,
            Err(i) => i.min(self.buckets.len() - 1),
        }
    }

    fn ema_update(slot: &mut f64, alpha: f64, x: f64) {
        *slot = if slot.is_nan() { x } else { alpha * x + (1.0 - alpha) * *slot };
    }

    /// One decode step of `batch` rows took `step_s` seconds.
    pub fn observe_step(&mut self, batch: usize, step_s: f64) {
        if batch == 0 || !step_s.is_finite() || step_s < 0.0 {
            return;
        }
        let i = self.cover(batch);
        Self::ema_update(&mut self.step_ema[i], self.alpha, step_s);
        Self::ema_update(&mut self.global_step_ema, self.alpha, step_s);
        self.observations += 1;
    }

    /// A finished request's mean per-output-token latency.
    pub fn observe_request_tpot(&mut self, tpot_s: f64) {
        if !tpot_s.is_finite() || tpot_s < 0.0 {
            return;
        }
        Self::ema_update(&mut self.req_tpot_ema, self.alpha, tpot_s);
    }

    /// Decode-step observations ingested so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Step-time → TPOT inflation factor (≥ 1; 1 until both EMAs exist).
    fn inflation(&self) -> f64 {
        if self.req_tpot_ema.is_nan()
            || self.global_step_ema.is_nan()
            || self.global_step_ema <= 0.0
        {
            return 1.0;
        }
        (self.req_tpot_ema / self.global_step_ema).max(1.0)
    }

    /// Largest batch currently meeting `tpot_slo_s`: scan the observed
    /// buckets ascending, keep the largest whose inflated step EMA fits
    /// the SLO, and stop at the first observed violator (step time grows
    /// with batch, so buckets past it are not trusted even if a stale EMA
    /// there still looks good). If the smallest observed bucket already
    /// violates, the frontier sits below it — report the bucket beneath
    /// (or 1). `None` until any step has been observed.
    pub fn b_tpot(&self, tpot_slo_s: f64) -> Option<usize> {
        let infl = self.inflation();
        let mut best: Option<usize> = None;
        for (i, &b) in self.buckets.iter().enumerate() {
            let ema = self.step_ema[i];
            if ema.is_nan() {
                continue;
            }
            if ema * infl <= tpot_slo_s {
                best = Some(b);
            } else {
                return best.or(Some(if i == 0 { 1 } else { self.buckets[i - 1] }));
            }
        }
        best
    }
}

/// Exponentially-decayed duty-cycle estimator for the colocated attention
/// executor — the "recent duty" the prefill interference model weighs
/// bandwidth contention by. Busy seconds decay with time constant
/// `tau_s`, so a busy warm-up phase stops haunting the steady state (the
/// old lifetime-cumulative ratio never forgot it).
#[derive(Debug, Clone)]
pub struct DutyCycleEstimator {
    tau_s: f64,
    last_t: f64,
    w_executor: f64,
    w_prefill: f64,
}

impl DutyCycleEstimator {
    pub fn new(tau_s: f64) -> Self {
        assert!(tau_s > 0.0, "duty time constant must be positive, got {tau_s}");
        DutyCycleEstimator { tau_s, last_t: 0.0, w_executor: 0.0, w_prefill: 0.0 }
    }

    fn decay_to(&mut self, t: f64) {
        if t > self.last_t {
            let f = (-(t - self.last_t) / self.tau_s).exp();
            self.w_executor *= f;
            self.w_prefill *= f;
            self.last_t = t;
        }
    }

    /// The prefill pipeline ran for `busy_s` seconds, observed at time `t`.
    pub fn record_prefill(&mut self, t: f64, busy_s: f64) {
        self.decay_to(t);
        self.w_prefill += busy_s.max(0.0);
    }

    /// The attention executor ran for `busy_s` seconds, observed at `t`.
    pub fn record_executor(&mut self, t: f64, busy_s: f64) {
        self.decay_to(t);
        self.w_executor += busy_s.max(0.0);
    }

    /// Executor share of recent busy time, in [0, 1] (0 before any work).
    pub fn duty(&self) -> f64 {
        let total = self.w_executor + self.w_prefill;
        if total > 0.0 {
            (self.w_executor / total).min(1.0)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::gpu_model::kernels::DecodeCostTable;

    fn setup(mode: CostMode) -> CostModel {
        let gpu = GpuSpec::a100_80g();
        let m = ModelSpec::llama2_7b();
        let rl = Roofline::whole(gpu);
        let rl_exec = Roofline::partition(gpu, 0.25);
        let grid = CostModel::build_grid(&[1, 2, 4, 8], &[1, 2, 4, 8], 256);
        CostModel::new(
            &rl,
            &rl,
            &rl_exec,
            &m,
            grid,
            mode,
            Some(InterferenceModel::new(0.25)),
            15e-6,
            0.0,
        )
    }

    #[test]
    fn build_grid_covers_max_batch() {
        let g = CostModel::build_grid(&[1, 2, 4, 8], &[1, 2, 4, 8], 256);
        assert!(g.max_local() >= 256);
        assert!(g.max_offload() >= 256);
        // The configured capacities survive the extension.
        for b in [1usize, 2, 4, 8] {
            assert!(g.local_buckets().contains(&b));
        }
    }

    #[test]
    fn exact_mode_matches_legacy_inline_formula() {
        // The exact path must reproduce the pre-refactor step math
        // bit-for-bit (the ADRENALINE_EXACT_COSTS regression contract).
        let gpu = GpuSpec::a100_80g();
        let m = ModelSpec::llama2_7b();
        let rl = Roofline::whole(gpu);
        let rl_exec = Roofline::partition(gpu, 0.25);
        let mut cm = setup(CostMode::Exact);
        let mut legacy = DecodeCostTable::new(&rl, &m);
        let mut legacy_exec = DecodeCostTable::new(&rl_exec, &m);
        let sync_total = 15e-6 * m.n_layers as f64;

        let mut out = Vec::new();
        for (lr, lc, rr, rc) in [
            (0u64, 0u64, vec![3u64, 0], vec![900u64, 0]),
            (7, 4321, vec![0, 0], vec![0, 0]),
            (100, 120_000, vec![5, 9], vec![4000, 11_000]),
            (1, 1, vec![1, 1], vec![1, 1]),
        ] {
            let cost = cm.decode_step(lr, lc, &rr, &rc, &mut out);
            assert!(cost.bucket.is_none());

            // Legacy inline computation (pre-refactor decode_step_time).
            let b_total = lr + rr.iter().sum::<u64>();
            let non_attn = legacy.non_attention(b_total);
            let local_attn = legacy.attention(if lr > 0 { lc + lr } else { 0 });
            let mut remote_attn: f64 = 0.0;
            let mut remote_ctx_total = 0u64;
            let mut any = false;
            for (&rows, &ctx_sum) in rr.iter().zip(&rc) {
                if rows == 0 {
                    continue;
                }
                any = true;
                let ctx = ctx_sum + rows;
                remote_ctx_total += ctx;
                remote_attn = remote_attn.max(legacy_exec.attention(ctx));
            }
            if any {
                remote_attn += sync_total;
            }
            let step = non_attn + local_attn.max(remote_attn);
            let lf = if lr > 0 { lc + lr } else { 0 };
            let flops = legacy.step_flops(b_total, lf + remote_ctx_total);
            assert_eq!(cost.step_s.to_bits(), step.to_bits(), "step ({lr},{lc})");
            assert_eq!(cost.flops.to_bits(), flops.to_bits(), "flops ({lr},{lc})");
        }
    }

    #[test]
    fn straggler_slowdown_scales_remote_attention_and_clears() {
        let mut cm = setup(CostMode::Exact);
        let mut out = Vec::new();
        let base = cm.decode_step(4, 4 * 500, &[6, 3], &[6 * 800, 3 * 800], &mut out);
        let base_exec = out.clone();

        cm.set_executor_slowdown(1, 2.0);
        let slow = cm.decode_step(4, 4 * 500, &[6, 3], &[6 * 800, 3 * 800], &mut out);
        assert_eq!(out[0].to_bits(), base_exec[0].to_bits(), "healthy partition unchanged");
        assert_eq!(out[1].to_bits(), (base_exec[1] * 2.0).to_bits(), "straggler doubled");
        assert!(slow.step_s >= base.step_s);
        // FLOPs count useful work — a straggler burns time, not work.
        assert_eq!(slow.flops.to_bits(), base.flops.to_bits());

        cm.clear_executor_slowdown(1);
        let back = cm.decode_step(4, 4 * 500, &[6, 3], &[6 * 800, 3 * 800], &mut out);
        assert_eq!(back.step_s.to_bits(), base.step_s.to_bits(), "cleared window restores base");
    }

    #[test]
    fn property_bucketed_dominates_exact() {
        // Bucketed step time >= exact step time for any reachable batch,
        // with equality when the sub-batches land exactly on captured
        // buckets (no padded rows anywhere).
        crate::util::prop::check("cost_bucketed_dominates_exact", 300, |rng| {
            let mut exact = setup(CostMode::Exact);
            let mut bucketed = setup(CostMode::Bucketed);
            let local_rows = rng.range_u64(0, 201);
            let remote = rng.range_u64(0, 51);
            let local_ctx = local_rows * rng.range_u64(1, 2048);
            let remote_ctx = remote * rng.range_u64(1, 2048);
            let mut out = Vec::new();
            let e = exact.decode_step(local_rows, local_ctx, &[remote], &[remote_ctx], &mut out);
            let b = bucketed.decode_step(local_rows, local_ctx, &[remote], &[remote_ctx], &mut out);
            assert!(
                b.step_s >= e.step_s,
                "bucketed {} < exact {} at rows=({local_rows},{remote})",
                b.step_s,
                e.step_s
            );
            // Useful FLOPs are identical: padding burns time, not work.
            assert_eq!(b.flops.to_bits(), e.flops.to_bits());
            // On-bucket batches pay zero padding.
            let pair = b.bucket.expect("grid covers max_batch");
            if pair.local as u64 == local_rows && pair.offload as u64 == remote {
                assert_eq!(b.step_s.to_bits(), e.step_s.to_bits(), "aligned batch must be free");
            }
        });
    }

    #[test]
    fn bucket_aligned_batch_costs_exactly_like_exact() {
        let mut exact = setup(CostMode::Exact);
        let mut bucketed = setup(CostMode::Bucketed);
        let mut out = Vec::new();
        // 16 local + 8 offloaded rows: both captured capacities.
        let e = exact.decode_step(16, 16 * 700, &[8], &[8 * 700], &mut out);
        let b = bucketed.decode_step(16, 16 * 700, &[8], &[8 * 700], &mut out);
        assert_eq!(b.bucket, Some(BucketPair { local: 16, offload: 8 }));
        assert_eq!(b.step_s.to_bits(), e.step_s.to_bits());
        assert_eq!(bucketed.graph_stats().padded_slots, 0);
        // Off-bucket: strictly more expensive.
        let e2 = exact.decode_step(17, 17 * 700, &[8], &[8 * 700], &mut out);
        let b2 = bucketed.decode_step(17, 17 * 700, &[8], &[8 * 700], &mut out);
        assert!(b2.step_s > e2.step_s, "{} vs {}", b2.step_s, e2.step_s);
        assert!(bucketed.graph_stats().padded_slots > 0);
    }

    #[test]
    fn multi_executor_pads_each_to_its_own_bucket() {
        // Two executors with 16 rows each: the decode-side pair covers the
        // 32-row total, but each executor runs its own 16-row bucket — no
        // dummy-slot padding anywhere, so the step must cost exactly like
        // the exact model (padding each executor to the total's 32-bucket
        // would overcharge 16 dummy rows per executor).
        let mut exact = setup(CostMode::Exact);
        let mut bucketed = setup(CostMode::Bucketed);
        let mut out = Vec::new();
        let rows = [16u64, 16];
        let ctx = [16 * 600u64, 16 * 600];
        let e = exact.decode_step(8, 8 * 600, &rows, &ctx, &mut out);
        let b = bucketed.decode_step(8, 8 * 600, &rows, &ctx, &mut out);
        assert_eq!(b.bucket, Some(BucketPair { local: 8, offload: 32 }));
        assert_eq!(bucketed.graph_stats().padded_slots, 0);
        assert_eq!(b.step_s.to_bits(), e.step_s.to_bits());
    }

    #[test]
    fn executor_times_reported_per_partition() {
        let mut cm = setup(CostMode::Bucketed);
        let mut out = Vec::new();
        let cost = cm.decode_step(4, 4 * 512, &[3, 0, 6], &[3 * 512, 0, 6 * 512], &mut out);
        assert_eq!(out.len(), 3);
        assert!(out[0] > 0.0 && out[2] > 0.0);
        assert_eq!(out[1], 0.0);
        // Max executor time is what the step overlaps against (plus sync).
        assert!(cost.remote_attention_s > out[0].max(out[2]));
    }

    #[test]
    fn step_series_matches_manual_stepping_bitwise() {
        // The leap engine's contract: pricing k frozen-composition steps
        // through the series helper is bit-identical (costs, executor
        // times, grid statistics) to k hand-advanced `decode_step` calls.
        let mut manual = setup(CostMode::Bucketed);
        let mut series = setup(CostMode::Bucketed);
        let local_rows = 13u64;
        let mut local_ctx = 13 * 700u64;
        let remote_rows = [3u64, 0];
        let mut remote_ctx = [3 * 500u64, 0];
        let steps = 17usize;
        let mut exec = Vec::new();
        let mut want = Vec::new();
        let mut want_exec = Vec::new();
        for _ in 0..steps {
            let cost =
                manual.decode_step(local_rows, local_ctx, &remote_rows, &remote_ctx, &mut exec);
            want.push(cost);
            want_exec.extend_from_slice(&exec);
            local_ctx += local_rows;
            for (ctx, &r) in remote_ctx.iter_mut().zip(&remote_rows) {
                *ctx += r;
            }
        }
        let mut got = Vec::new();
        let mut got_exec = Vec::new();
        let n = series.decode_step_series(
            5.0,
            None,
            f64::INFINITY,
            steps,
            13,
            13 * 700,
            &remote_rows,
            &[3 * 500, 0],
            &mut got,
            &mut got_exec,
        );
        assert_eq!(n, steps);
        assert_eq!(got.len(), steps);
        assert_eq!(got_exec.len(), want_exec.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.step_s.to_bits(), g.step_s.to_bits());
            assert_eq!(w.flops.to_bits(), g.flops.to_bits());
            assert_eq!(w.bucket, g.bucket);
        }
        for (w, g) in want_exec.iter().zip(&got_exec) {
            assert_eq!(w.to_bits(), g.to_bits());
        }
        let (ms, ss) = (manual.graph_stats(), series.graph_stats());
        assert_eq!(ms.selections, ss.selections);
        assert_eq!(ms.used_slots, ss.used_slots);
        assert_eq!(ms.padded_slots, ss.padded_slots);
        assert_eq!(manual.bucket_hits(), series.bucket_hits());
    }

    #[test]
    fn step_series_respects_the_event_and_step_bounds() {
        let mut cm = setup(CostMode::Bucketed);
        let mut costs = Vec::new();
        let mut exec = Vec::new();
        // max_steps = 1: exactly one priced step (the per-step reference
        // path runs the same code with the horizon forced to zero).
        let n = cm.decode_step_series(
            0.0,
            None,
            f64::INFINITY,
            1,
            8,
            8 * 600,
            &[0, 0],
            &[0, 0],
            &mut costs,
            &mut exec,
        );
        assert_eq!(n, 1);
        assert_eq!(costs.len(), 1);
        assert_eq!(exec.len(), 2);
        let step1 = costs[0].step_s;
        // A same-instant queued event: the very first step breaches it.
        let n = cm.decode_step_series(
            3.0,
            Some(3.0),
            f64::INFINITY,
            100,
            8,
            8 * 600,
            &[0, 0],
            &[0, 0],
            &mut costs,
            &mut exec,
        );
        assert_eq!(n, 1);
        // An event a few steps out: interior steps end strictly before
        // it, the boundary step ends at/after it.
        let te = 3.0 + 2.5 * step1;
        let n = cm.decode_step_series(
            3.0,
            Some(te),
            f64::INFINITY,
            100,
            8,
            8 * 600,
            &[0, 0],
            &[0, 0],
            &mut costs,
            &mut exec,
        );
        assert!(n >= 2, "n = {n}");
        let mut t = 3.0;
        for (i, c) in costs.iter().enumerate() {
            t += c.step_s;
            if i + 1 < n {
                assert!(t < te, "interior step {i} must end before the event");
            } else {
                assert!(t >= te, "the boundary step must reach the event");
            }
        }
        // The hard stop is an inclusive bound on committed step ends.
        let n = cm.decode_step_series(
            0.0,
            None,
            0.0,
            100,
            8,
            8 * 600,
            &[0, 0],
            &[0, 0],
            &mut costs,
            &mut exec,
        );
        assert_eq!(n, 1, "a step ending past the hard stop is the boundary");
    }

    #[test]
    fn kv_transfer_time_matches_legacy_inline_formula() {
        // The sim used to compute the prefill->decode transfer inline as
        // `kv_tokens as f64 * model.kv_bytes_per_token() / interconnect_bw`;
        // the cost-plane version must be bit-identical (the rebalancer's
        // migration charging reuses the same path).
        let gpu = GpuSpec::a100_80g();
        let m = ModelSpec::llama2_7b();
        let cm = setup(CostMode::Bucketed);
        for tokens in [0u64, 1, 137, 4096, 1_000_000] {
            let legacy = tokens as f64 * m.kv_bytes_per_token() / gpu.interconnect_bw;
            assert_eq!(
                cm.kv_transfer_time(tokens).to_bits(),
                legacy.to_bits(),
                "tokens={tokens}"
            );
        }
        // Sanity: ~0.5 MiB/token over 600 GB/s NVLink.
        let per_tok = cm.kv_transfer_time(1);
        assert!((per_tok - 524288.0 / 600e9).abs() < 1e-12);
    }

    #[test]
    fn prefill_time_memoizes_and_applies_interference() {
        let gpu = GpuSpec::a100_80g();
        let m = ModelSpec::llama2_7b();
        let rl = Roofline::whole(gpu);
        let rl_exec = Roofline::partition(gpu, 0.25);
        let grid = CostModel::build_grid(&[1, 2, 4, 8], &[1, 2, 4, 8], 256);
        let interference = InterferenceModel::new(0.25);
        let mut with = CostModel::new(
            &rl,
            &rl,
            &rl_exec,
            &m,
            grid.clone(),
            CostMode::Bucketed,
            Some(interference),
            15e-6,
            0.0,
        );
        let mut without =
            CostModel::new(&rl, &rl, &rl_exec, &m, grid, CostMode::Bucketed, None, 15e-6, 0.0);
        let base = crate::gpu_model::PrefillKernelTimes::compute(&rl, &m, 2048).total();
        // No interference model: the raw roofline time, bit-identical.
        assert_eq!(without.prefill_time(2048, 0.7).to_bits(), base.to_bits());
        // With the executor colocated, the MPS reservation alone slows
        // prefill even at duty 0, and activity slows it further.
        let idle = with.prefill_time(2048, 0.0);
        let busy = with.prefill_time(2048, 1.0);
        assert!(idle > base);
        assert!(busy >= idle);
        // Memoized: same value again.
        assert_eq!(with.prefill_time(2048, 0.0).to_bits(), idle.to_bits());
    }

    #[test]
    fn partitioned_prefill_pays_the_fig10_slowdown_curve() {
        // An intra-GPU split (Nexus-style): prefill confined to 45% of
        // the SMs pays exactly prefill_slowdown(0.45) over the whole-GPU
        // time — partition.rs's curve, wired into priced steps.
        let gpu = GpuSpec::a100_80g();
        let m = ModelSpec::llama2_7b();
        let rl_whole = Roofline::whole(gpu);
        let rl_part = Roofline::partition(gpu, 0.45);
        let rl_exec = Roofline::partition(gpu, 0.25);
        let mk = |rl_prefill: &Roofline| {
            CostModel::new(
                rl_prefill,
                &rl_whole,
                &rl_exec,
                &m,
                CostModel::build_grid(&[1, 2, 4, 8], &[1, 2, 4, 8], 256),
                CostMode::Bucketed,
                None,
                15e-6,
                0.0,
            )
        };
        let mut whole = mk(&rl_whole);
        let mut split = mk(&rl_part);
        for tokens in [128u64, 1024, 4096] {
            let base = whole.prefill_time(tokens, 0.0);
            let slowed = split.prefill_time(tokens, 0.0);
            let want = base * crate::gpu_model::partition::prefill_slowdown(0.45);
            assert_eq!(slowed.to_bits(), want.to_bits(), "tokens={tokens}");
            assert!(slowed > base);
        }
    }

    #[test]
    fn per_role_rooflines_price_each_side_on_its_own_device() {
        // Heterogeneous offload (arXiv 2405.01814): a memory-rich
        // standalone executor beats the colocated A100 half-partition on
        // attention, and a decode device with more bandwidth shrinks
        // decode steps. Also: the KV link is the min of both ends.
        let a100 = GpuSpec::a100_80g();
        let h20 = GpuSpec::h20_96g();
        let m = ModelSpec::llama2_7b();
        let mk = |rl_decode: &Roofline, rl_exec: &Roofline| {
            CostModel::new(
                &Roofline::whole(a100),
                rl_decode,
                rl_exec,
                &m,
                CostModel::build_grid(&[1, 2, 4, 8], &[1, 2, 4, 8], 256),
                CostMode::Exact,
                None,
                15e-6,
                0.0,
            )
        };
        let mut colocated = mk(&Roofline::whole(a100), &Roofline::partition(a100, 0.5));
        let mut hetero = mk(&Roofline::whole(a100), &Roofline::whole(h20));
        let mut out = Vec::new();
        // Pure-offload step: remote attention dominates.
        let c = colocated.decode_step(0, 0, &[32], &[32 * 1500], &mut out);
        let h = hetero.decode_step(0, 0, &[32], &[32 * 1500], &mut out);
        assert!(
            h.remote_attention_s < c.remote_attention_s,
            "H20 executor ({}) must beat the A100 half-partition ({})",
            h.remote_attention_s,
            c.remote_attention_s
        );
        // The interconnect is the bottleneck of the two ends' links.
        let h20_decode = mk(&Roofline::whole(h20), &Roofline::whole(h20));
        let want = 1_000_000u64 as f64 * m.kv_bytes_per_token()
            / a100.interconnect_bw.min(h20.interconnect_bw);
        assert_eq!(h20_decode.kv_transfer_time(1_000_000).to_bits(), want.to_bits());
    }

    // ----- BTpotEstimator ---------------------------------------------------

    #[test]
    fn b_tpot_estimator_tracks_slo_frontier() {
        let mut est = BTpotEstimator::new(&[0, 1, 2, 4, 8, 16, 32], 0.5);
        assert_eq!(est.b_tpot(0.1), None, "no observations yet");
        // Batches 3 and 7 (buckets 4 and 8) comfortably meet a 100 ms SLO.
        est.observe_step(3, 0.02);
        est.observe_step(7, 0.04);
        assert_eq!(est.b_tpot(0.1), Some(8));
        // Bucket 32 violates: the frontier stays at 8 even though the 16
        // bucket is unobserved.
        est.observe_step(20, 0.25);
        assert_eq!(est.b_tpot(0.1), Some(8));
        assert_eq!(est.observations(), 3);
    }

    #[test]
    fn b_tpot_estimator_reports_below_smallest_violator() {
        let mut est = BTpotEstimator::new(&[1, 2, 4, 8], 1.0);
        // Only bucket 4 observed, and it misses the SLO: the frontier sits
        // below it.
        est.observe_step(4, 0.5);
        assert_eq!(est.b_tpot(0.1), Some(2));
        // Smallest bucket violating => fall to 1.
        let mut est1 = BTpotEstimator::new(&[1, 2], 1.0);
        est1.observe_step(1, 0.5);
        assert_eq!(est1.b_tpot(0.1), Some(1));
    }

    #[test]
    fn b_tpot_estimator_request_tpot_inflates_the_curve() {
        let mut est = BTpotEstimator::new(&[1, 2, 4, 8], 1.0);
        est.observe_step(8, 0.08);
        assert_eq!(est.b_tpot(0.1), Some(8));
        // Requests report 3x the raw step time (queueing/recompute gaps):
        // the inflated curve (0.24 s) misses the SLO, frontier drops.
        est.observe_request_tpot(0.24);
        assert_eq!(est.b_tpot(0.1), Some(4));
        // Request TPOT below step time never deflates (factor clamps at 1).
        let mut est2 = BTpotEstimator::new(&[1, 2, 4, 8], 1.0);
        est2.observe_step(8, 0.08);
        est2.observe_request_tpot(0.01);
        assert_eq!(est2.b_tpot(0.1), Some(8));
    }

    /// Property (ISSUE 4): observing nondecreasing batches that all meet
    /// the SLO keeps the derived B_TPOT nondecreasing, and it always
    /// covers the largest batch observed so far.
    #[test]
    fn property_b_tpot_monotone_in_observed_batch() {
        crate::util::prop::check("b_tpot_monotone", 200, |rng| {
            let buckets = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
            let slo = 0.1;
            let mut est = BTpotEstimator::new(&buckets, 0.3);
            let mut batch = 1usize;
            let mut prev = 0usize;
            for _ in 0..30 {
                batch = (batch + rng.range_usize(0, 32)).min(256);
                // Step time strictly under the SLO (inflation stays 1: no
                // request samples are fed here).
                est.observe_step(batch, slo * rng.f64() * 0.99);
                let got = est.b_tpot(slo).expect("observed => derivable");
                assert!(got >= prev, "b_tpot regressed {prev} -> {got} at batch {batch}");
                assert!(got >= batch, "b_tpot {got} below an SLO-meeting batch {batch}");
                prev = got;
            }
        });
    }

    // ----- DutyCycleEstimator -----------------------------------------------

    #[test]
    fn duty_estimator_forgets_busy_warmup() {
        // Lifetime-cumulative duty after a 10 s all-executor warm-up then
        // 100 s of pure prefill would still read 10/110 ≈ 0.09; the
        // decayed estimate must fall well below it.
        let mut d = DutyCycleEstimator::new(10.0);
        assert_eq!(d.duty(), 0.0);
        d.record_executor(10.0, 10.0);
        assert_eq!(d.duty(), 1.0);
        let mut t = 10.0;
        while t < 110.0 {
            t += 1.0;
            d.record_prefill(t, 1.0);
        }
        assert!(d.duty() < 0.01, "warm-up must decay away, duty = {}", d.duty());
    }

    #[test]
    fn duty_estimator_tracks_recent_mix() {
        let mut d = DutyCycleEstimator::new(5.0);
        // Steady 50/50 mix: duty converges near 0.5 regardless of decay.
        let mut t = 0.0;
        for _ in 0..100 {
            t += 0.5;
            d.record_prefill(t, 0.25);
            d.record_executor(t, 0.25);
        }
        assert!((d.duty() - 0.5).abs() < 1e-9, "duty = {}", d.duty());
        // Out-of-order-free: time standing still keeps the ratio.
        let before = d.duty();
        d.record_prefill(t, 0.0);
        assert!((d.duty() - before).abs() < 1e-12);
    }
}
