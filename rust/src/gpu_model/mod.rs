//! Analytical A100 performance model.
//!
//! The paper's claims rest on resource-utilization arithmetic measured on
//! real A100s. With no GPU available (DESIGN.md §1), this module encodes
//! that arithmetic directly:
//!
//! * [`roofline`] — kernel execution time = max(compute time, memory time)
//!   with calibrated efficiency factors;
//! * [`kernels`] — the four profiled kernels (QKV proj / attention /
//!   O proj / FFN) for both phases, built on the FLOP/byte tables in
//!   [`crate::config::ModelSpec`];
//! * [`partition`] — the MPS SM-partitioning curves: superlinear bandwidth
//!   vs SM fraction (Fig 9) and sublinear prefill slowdown (Fig 10), plus
//!   the colocation interference model;
//! * [`memory`] — HBM capacity accounting (weights, activations, KV);
//! * [`cost`] — the unified cost plane: memoized decode/prefill step-time
//!   tables routed through the 2-D executable-bucket grid (the simulator
//!   pays the same padded rows the real capture grid executes).
//!
//! Calibration anchors (unit-tested against the paper's numbers):
//!   Fig 1a: prefill HBM-bw utilization < 30 %;
//!   Fig 1b: decode compute utilization < 26 %;
//!   Fig 3: attention = 69.5 % of decode layer time at batch 80, seq 1K;
//!   Fig 9: 20 % SMs ⇒ ~60 % of peak bandwidth;
//!   Fig 18a: attention executor sustains ~83 % of the bandwidth cap.

pub mod cost;
pub mod kernels;
pub mod memory;
pub mod partition;
pub mod profile;
pub mod roofline;

pub use cost::{
    BTpotEstimator, CostMode, CostModel, DecodeStepCost, DutyCycleEstimator, PREFILL_BW_FRAC,
};
pub use kernels::{
    DecodeCostTable, DecodeKernelTimes, KernelKind, PhaseKernels, PrefillCostTable,
    PrefillKernelTimes,
};
pub use memory::HbmUsage;
pub use partition::{bw_frac_of_sm_frac, prefill_slowdown, InterferenceModel};
pub use profile::{PrefillProfile, ProfileEntry};
pub use roofline::{KernelCost, Roofline};
