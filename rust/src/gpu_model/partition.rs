//! MPS SM-partitioning curves and the colocation interference model.
//!
//! §3.3.1's two observations, fitted as closed-form curves and anchored to
//! the paper's measurements:
//!
//! 1. *Bandwidth vs SMs is superlinear* (Fig 9): "20% SMs obtain 60% of
//!    A100's HBM bandwidth". We model `bw_frac = sm_frac^ALPHA_BW` with
//!    `ALPHA_BW` chosen so bw_frac(0.2) ≈ 0.60.
//! 2. *Prefill latency vs SMs is sublinear* (Fig 10): compute shrinks with
//!    SMs but a fraction of the prefill step (routing, scheduling, KV
//!    transfer, launch overhead) does not use SMs at all.

/// Exponent of the bandwidth-vs-SM-fraction power law. 0.2^0.317 ≈ 0.60.
pub const ALPHA_BW: f64 = 0.317;

/// Fraction of the prefill step that does not consume SMs (CPU-side
/// scheduling, KV-transfer issue, launch gaps). Calibrated so that 50 % of
/// SMs keeps ≈ 63 % of prefill throughput, matching Fig 10's sublinear
/// shape.
pub const PREFILL_NON_GPU_FRAC: f64 = 0.12;

/// Mild superlinearity of GEMM efficiency in SM count: fewer SMs lose some
/// tiling efficiency. Exponent slightly below 1 keeps the slowdown
/// sublinear overall (Fig 10).
pub const ALPHA_PREFILL_COMPUTE: f64 = 0.93;

/// Fraction of peak HBM bandwidth reachable with `sm_frac` of the SMs
/// (Fig 9's curve). Clamped to [0, 1].
pub fn bw_frac_of_sm_frac(sm_frac: f64) -> f64 {
    if sm_frac <= 0.0 {
        return 0.0;
    }
    sm_frac.min(1.0).powf(ALPHA_BW)
}

/// Prefill latency multiplier when the prefill engine is restricted to
/// `sm_frac` of the SMs (Fig 10's curve, inverted: > 1 means slower).
pub fn prefill_slowdown(sm_frac: f64) -> f64 {
    assert!(sm_frac > 0.0 && sm_frac <= 1.0);
    let gpu_part = (1.0 - PREFILL_NON_GPU_FRAC) / sm_frac.powf(ALPHA_PREFILL_COMPUTE);
    gpu_part + PREFILL_NON_GPU_FRAC
}

/// Colocation interference between the prefill engine and the attention
/// executor sharing one GPU under an MPS split (§3.3.2).
#[derive(Debug, Clone, Copy)]
pub struct InterferenceModel {
    /// SM fraction reserved for the attention executor.
    pub attn_sm_frac: f64,
}

impl InterferenceModel {
    pub fn new(attn_sm_frac: f64) -> Self {
        assert!(
            (0.0..1.0).contains(&attn_sm_frac),
            "attention executor needs [0,1) of the SMs, got {attn_sm_frac}"
        );
        InterferenceModel { attn_sm_frac }
    }

    /// SM fraction left for the prefill engine.
    pub fn prefill_sm_frac(&self) -> f64 {
        1.0 - self.attn_sm_frac
    }

    /// Prefill latency multiplier while the attention executor is *idle*
    /// (MPS reservation alone).
    pub fn prefill_slowdown_idle(&self) -> f64 {
        prefill_slowdown(self.prefill_sm_frac())
    }

    /// Prefill latency multiplier while the attention executor is actively
    /// streaming KV. On top of the SM reservation, the executor consumes
    /// HBM bandwidth; prefill is compute-bound (Fig 5) so it only stalls to
    /// the extent its own (small) bandwidth demand exceeds what is left.
    ///
    /// `prefill_bw_frac`: the bandwidth fraction the prefill kernels would
    /// use unconstrained (< 0.30 per Fig 1a); `attn_bw_frac`: what the
    /// executor is drawing (up to ~0.83 per Fig 18a).
    pub fn prefill_slowdown_active(&self, prefill_bw_frac: f64, attn_bw_frac: f64) -> f64 {
        let base = self.prefill_slowdown_idle();
        let available = (1.0 - attn_bw_frac).max(1e-3);
        if prefill_bw_frac <= available {
            base
        } else {
            // Bandwidth-starved: the memory-traffic part of prefill dilates.
            base * (prefill_bw_frac / available)
        }
    }

    /// Bandwidth fraction (of the whole GPU's peak) the attention executor
    /// can sustain with its SM share.
    pub fn attn_bw_cap(&self, bw_eff: f64) -> f64 {
        bw_eff * bw_frac_of_sm_frac(self.attn_sm_frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_anchor_20pct_sms_60pct_bw() {
        let f = bw_frac_of_sm_frac(0.2);
        assert!((f - 0.60).abs() < 0.02, "bw_frac(0.2) = {f}");
    }

    #[test]
    fn bw_frac_monotone_and_bounded() {
        let mut prev = 0.0;
        for i in 1..=20 {
            let f = bw_frac_of_sm_frac(i as f64 / 20.0);
            assert!(f >= prev);
            assert!(f <= 1.0);
            prev = f;
        }
        assert!((bw_frac_of_sm_frac(1.0) - 1.0).abs() < 1e-12);
        assert_eq!(bw_frac_of_sm_frac(0.0), 0.0);
    }

    #[test]
    fn bw_frac_superlinear() {
        // Superlinear in the Fig 9 sense: frac of bandwidth > frac of SMs.
        for s in [0.1, 0.2, 0.4, 0.6, 0.8] {
            assert!(bw_frac_of_sm_frac(s) > s);
        }
    }

    #[test]
    fn fig10_sublinear_slowdown() {
        // Halving SMs must cost less than 2x latency (sublinear).
        let s = prefill_slowdown(0.5);
        assert!(s < 2.0, "slowdown(0.5) = {s}");
        assert!(s > 1.3);
        // Full SMs ⇒ no slowdown.
        assert!((prefill_slowdown(1.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn slowdown_monotone_decreasing_in_sms() {
        let mut prev = f64::INFINITY;
        for i in 1..=10 {
            let s = prefill_slowdown(i as f64 / 10.0);
            assert!(s <= prev);
            prev = s;
        }
    }

    #[test]
    fn interference_idle_vs_active() {
        let m = InterferenceModel::new(0.2);
        assert!((m.prefill_sm_frac() - 0.8).abs() < 1e-12);
        let idle = m.prefill_slowdown_idle();
        // Prefill draws 25% bw, executor draws 50%: still fits -> no extra.
        assert_eq!(m.prefill_slowdown_active(0.25, 0.50), idle);
        // Executor draws 83%: prefill's 25% no longer fits -> dilation.
        assert!(m.prefill_slowdown_active(0.25, 0.83) > idle);
    }

    #[test]
    fn attn_bw_cap_at_20pct_sms() {
        let m = InterferenceModel::new(0.2);
        // 83% ceiling × 60% partition curve ≈ 50% of peak.
        let cap = m.attn_bw_cap(0.83);
        assert!((0.45..0.55).contains(&cap), "cap = {cap}");
    }

    #[test]
    #[should_panic]
    fn full_gpu_for_executor_rejected() {
        let _ = InterferenceModel::new(1.0);
    }
}
