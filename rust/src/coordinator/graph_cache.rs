//! The 2-D executable-bucket cache — the paper's two-dimensional CUDA
//! graph (§3.2.2), re-expressed for the AOT/PJRT runtime.
//!
//! The paper captures CUDA graphs over a grid `(C_d, C_o)` of (local decode
//! batch, offloaded attention batch) capacities, limits the grid with
//! configurable intervals to bound storage, and per step selects the
//! smallest captured graph covering both sub-batches. Here each "graph" is
//! the pair of AOT-compiled executables `attn_b{C_d}` / `attn_b{C_o}` plus
//! the bucket-sized non-attention executables — the selection problem and
//! the storage trade-off are identical.
//!
//! Since the cost-plane refactor the simulator also routes every decode
//! step through [`GraphCache::select`] (see [`crate::gpu_model::cost`]),
//! so the grid's padding statistics describe the *simulated* runs too,
//! not just the real decode path.

/// A selected bucket pair: the step runs local attention padded to
/// `local`, offloaded attention padded to `offload`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BucketPair {
    pub local: usize,
    pub offload: usize,
}

/// Statistics for observability/ablation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GraphCacheStats {
    pub selections: u64,
    /// Padded slots summed over selections (the cost of bucketing).
    pub padded_slots: u64,
    /// Requested slots summed over selections.
    pub used_slots: u64,
}

/// The capture grid + selector.
#[derive(Debug, Clone)]
pub struct GraphCache {
    /// Captured capacities for the local dimension (C_d), ascending.
    local_buckets: Vec<usize>,
    /// Captured capacities for the offload dimension (C_o), ascending.
    /// Always includes 0 (steps with nothing offloaded).
    offload_buckets: Vec<usize>,
    stats: GraphCacheStats,
    /// Per-pair selection counts, row-major over
    /// `(local_idx, offload_idx)` — the hit histogram ablations plot.
    hits: Vec<u64>,
}

/// A configured bucket list must be usable as-is by the capture planner:
/// non-empty, strictly ascending, and free of zero capacities (the 0
/// bucket is added internally for empty sub-batches).
fn validate_buckets(dim: &str, buckets: &[usize]) -> crate::Result<()> {
    anyhow::ensure!(!buckets.is_empty(), "{dim} bucket list is empty");
    for (i, &b) in buckets.iter().enumerate() {
        anyhow::ensure!(b > 0, "{dim} bucket list contains a zero capacity (index {i})");
        if i > 0 {
            anyhow::ensure!(
                b > buckets[i - 1],
                "{dim} bucket list must be strictly ascending: {} then {} at index {i}",
                buckets[i - 1],
                b
            );
        }
    }
    Ok(())
}

impl GraphCache {
    /// Build from the configured bucket lists. `interval_limit` caps the
    /// total number of captured pairs (the paper's configurable interval):
    /// when `|C_d| * |C_o|` exceeds it, coarser grids are used (every k-th
    /// bucket kept, largest always retained).
    ///
    /// Panics with a clear message on an invalid bucket configuration; use
    /// [`GraphCache::try_new`] to handle the error instead (the real-path
    /// server does, so a bad config file fails at startup, not mid-serve).
    pub fn new(
        local_buckets: &[usize],
        offload_buckets: &[usize],
        interval_limit: Option<usize>,
    ) -> Self {
        Self::try_new(local_buckets, offload_buckets, interval_limit)
            .unwrap_or_else(|e| panic!("invalid executable-bucket grid: {e}"))
    }

    /// Fallible constructor: rejects empty, unsorted/duplicated, or
    /// zero-capacity bucket lists instead of silently misbehaving.
    pub fn try_new(
        local_buckets: &[usize],
        offload_buckets: &[usize],
        interval_limit: Option<usize>,
    ) -> crate::Result<Self> {
        validate_buckets("local (C_d)", local_buckets)?;
        validate_buckets("offload (C_o)", offload_buckets)?;
        // Both dimensions include 0: a step may have nothing offloaded, or
        // (at high offload ratios) nothing local.
        let mut local: Vec<usize> = local_buckets.to_vec();
        local.push(0);
        local.sort_unstable();
        local.dedup();
        let mut offload: Vec<usize> = offload_buckets.to_vec();
        offload.push(0);
        offload.sort_unstable();
        offload.dedup();

        if let Some(limit) = interval_limit {
            anyhow::ensure!(limit >= 2, "interval limit must allow at least a 2x1 grid");
            while local.len() * offload.len() > limit {
                // Thin the larger dimension, keeping first and last.
                let v = if local.len() >= offload.len() { &mut local } else { &mut offload };
                if v.len() <= 2 {
                    break;
                }
                let keep_last = *v.last().unwrap();
                let thinned: Vec<usize> =
                    v.iter().copied().step_by(2).chain(std::iter::once(keep_last)).collect();
                *v = thinned;
                v.sort_unstable();
                v.dedup();
            }
        }
        let hits = vec![0; local.len() * offload.len()];
        Ok(GraphCache {
            local_buckets: local,
            offload_buckets: offload,
            stats: Default::default(),
            hits,
        })
    }

    pub fn grid_size(&self) -> usize {
        self.local_buckets.len() * self.offload_buckets.len()
    }

    pub fn local_buckets(&self) -> &[usize] {
        &self.local_buckets
    }

    pub fn offload_buckets(&self) -> &[usize] {
        &self.offload_buckets
    }

    pub fn stats(&self) -> GraphCacheStats {
        self.stats
    }

    /// Selection counts per captured pair, non-zero entries only.
    pub fn bucket_hits(&self) -> Vec<(BucketPair, u64)> {
        let mut out = Vec::new();
        for (li, &l) in self.local_buckets.iter().enumerate() {
            for (oi, &o) in self.offload_buckets.iter().enumerate() {
                let n = self.hits[li * self.offload_buckets.len() + oi];
                if n > 0 {
                    out.push((BucketPair { local: l, offload: o }, n));
                }
            }
        }
        out
    }

    pub fn max_local(&self) -> usize {
        *self.local_buckets.last().unwrap()
    }

    pub fn max_offload(&self) -> usize {
        *self.offload_buckets.last().unwrap()
    }

    /// Select the smallest captured pair covering `(local, offload)`
    /// (§3.2.2: "the smallest two-dimensional CUDA graph that accommodates
    /// both local and remote attention batches"). Returns `None` if either
    /// dimension exceeds the grid (the scheduler must split the step).
    pub fn select(&mut self, local: usize, offload: usize) -> Option<BucketPair> {
        let pair = self.peek_select(local, offload)?;
        self.record_selection(local, offload);
        Some(pair)
    }

    /// [`select`] without recording statistics: the pure selection
    /// function. The simulator's epoch engine prices steps speculatively
    /// on cloned cost models (whose stats are discarded) and afterwards
    /// records stats on the authoritative grid for exactly the steps that
    /// actually started, via [`record_selection`] with the same arguments.
    ///
    /// [`select`]: GraphCache::select
    /// [`record_selection`]: GraphCache::record_selection
    pub fn peek_select(&self, local: usize, offload: usize) -> Option<BucketPair> {
        let li = self.local_buckets.iter().position(|&b| b >= local)?;
        let oi = self.offload_buckets.iter().position(|&b| b >= offload)?;
        Some(BucketPair { local: self.local_buckets[li], offload: self.offload_buckets[oi] })
    }

    /// Record the statistics [`select`] would have recorded for
    /// `(local, offload)`. No-op when the pair exceeds the grid (matching
    /// [`select`], which mutates nothing on the oversize fallback).
    ///
    /// [`select`]: GraphCache::select
    pub fn record_selection(&mut self, local: usize, offload: usize) {
        let Some(li) = self.local_buckets.iter().position(|&b| b >= local) else {
            return;
        };
        let Some(oi) = self.offload_buckets.iter().position(|&b| b >= offload) else {
            return;
        };
        let l = self.local_buckets[li];
        let o = self.offload_buckets[oi];
        self.stats.selections += 1;
        self.stats.used_slots += (local + offload) as u64;
        self.stats.padded_slots += ((l - local) + (o - offload)) as u64;
        self.hits[li * self.offload_buckets.len() + oi] += 1;
    }

    /// Smallest captured offload capacity covering `n` rows, without
    /// recording a selection. The cost plane uses this to size each
    /// executor's own attention executable: the decode-side [`select`]
    /// covers the step's *total* offloaded batch, but every executor runs
    /// a bucket of its own row count (padding each executor to the total's
    /// bucket would overcharge multi-executor steps).
    ///
    /// [`select`]: GraphCache::select
    pub fn cover_offload(&self, n: usize) -> Option<usize> {
        self.offload_buckets.iter().copied().find(|&b| b >= n)
    }

    /// Fraction of compute wasted to padding so far (ablation metric for
    /// bucket-interval choices).
    pub fn padding_overhead(&self) -> f64 {
        let total = self.stats.used_slots + self.stats.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.stats.padded_slots as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_smallest_covering_pair() {
        let mut g = GraphCache::new(&[1, 2, 4, 8], &[1, 2, 4, 8], None);
        assert_eq!(g.select(3, 1), Some(BucketPair { local: 4, offload: 1 }));
        assert_eq!(g.select(1, 0), Some(BucketPair { local: 1, offload: 0 }));
        assert_eq!(g.select(8, 8), Some(BucketPair { local: 8, offload: 8 }));
        assert_eq!(g.select(5, 5), Some(BucketPair { local: 8, offload: 8 }));
    }

    #[test]
    fn oversize_returns_none() {
        let mut g = GraphCache::new(&[1, 2, 4], &[1, 2], None);
        assert_eq!(g.select(5, 0), None);
        assert_eq!(g.select(1, 3), None);
    }

    #[test]
    fn zero_offload_bucket_always_present() {
        let g = GraphCache::new(&[1], &[4], None);
        assert!(g.offload_buckets().contains(&0));
    }

    #[test]
    fn rejects_bad_bucket_lists() {
        assert!(GraphCache::try_new(&[], &[1], None).is_err(), "empty local");
        assert!(GraphCache::try_new(&[1], &[], None).is_err(), "empty offload");
        assert!(GraphCache::try_new(&[1, 0, 2], &[1], None).is_err(), "zero bucket");
        assert!(GraphCache::try_new(&[1, 4, 2], &[1], None).is_err(), "unsorted");
        assert!(GraphCache::try_new(&[1, 2, 2, 4], &[1], None).is_err(), "duplicate");
        assert!(GraphCache::try_new(&[1, 2], &[1, 2], Some(1)).is_err(), "limit < 2");
        assert!(GraphCache::try_new(&[1, 2, 4], &[1, 2, 4], None).is_ok());
    }

    #[test]
    #[should_panic(expected = "invalid executable-bucket grid")]
    fn new_panics_with_clear_message() {
        let _ = GraphCache::new(&[], &[1], None);
    }

    #[test]
    fn interval_limit_thins_grid_keeping_extremes() {
        let g = GraphCache::new(
            &[1, 2, 3, 4, 5, 6, 7, 8],
            &[1, 2, 3, 4, 5, 6, 7, 8],
            Some(20),
        );
        assert!(g.grid_size() <= 20, "grid = {}", g.grid_size());
        assert_eq!(g.max_local(), 8, "largest bucket must survive thinning");
        assert_eq!(g.max_offload(), 8);
        assert!(g.local_buckets().contains(&0), "smallest bucket survives");
    }

    #[test]
    fn property_interval_limit_retains_largest_buckets() {
        // The paper's interval coarsening trades padding for storage; it
        // must never lose the grid's extremes — dropping the largest
        // bucket would cap the servable batch, dropping 0 would break
        // empty sub-batches.
        crate::util::prop::check("graph_cache_limit_retention", 200, |rng| {
            let n_local = rng.range_usize(1, 12);
            let n_offload = rng.range_usize(1, 12);
            let mut local: Vec<usize> = Vec::new();
            let mut cap = 0usize;
            for _ in 0..n_local {
                cap += rng.range_usize(1, 9);
                local.push(cap);
            }
            let mut offload: Vec<usize> = Vec::new();
            cap = 0;
            for _ in 0..n_offload {
                cap += rng.range_usize(1, 9);
                offload.push(cap);
            }
            let limit = rng.range_usize(2, 40);
            let g = GraphCache::new(&local, &offload, Some(limit));
            assert_eq!(g.max_local(), *local.last().unwrap(), "largest C_d retained");
            assert_eq!(g.max_offload(), *offload.last().unwrap(), "largest C_o retained");
            assert!(g.local_buckets().contains(&0));
            assert!(g.offload_buckets().contains(&0));
            // The thinning loop stops once both dimensions are down to
            // {0, max}; the grid can never exceed max(limit, 4).
            assert!(
                g.grid_size() <= limit.max(4),
                "grid {} vs limit {limit}",
                g.grid_size()
            );
        });
    }

    #[test]
    fn peek_then_record_equals_select() {
        let mut direct = GraphCache::new(&[1, 2, 4], &[1, 2, 4], None);
        let mut split = direct.clone();
        // Includes an oversize pair: select mutates nothing there, so the
        // split path must not either.
        for &(l, o) in &[(3usize, 0usize), (1, 2), (4, 4), (5, 0), (1, 1)] {
            let sel = direct.select(l, o);
            assert_eq!(split.peek_select(l, o), sel);
            split.record_selection(l, o);
        }
        assert_eq!(direct.stats(), split.stats());
        assert_eq!(direct.bucket_hits(), split.bucket_hits());
    }

    #[test]
    fn padding_accounting() {
        let mut g = GraphCache::new(&[4], &[4], None);
        g.select(3, 2).unwrap(); // 5 used, 3 padded
        assert_eq!(g.stats().used_slots, 5);
        assert_eq!(g.stats().padded_slots, 3);
        assert!((g.padding_overhead() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn padding_zero_on_exact_hits() {
        let mut g = GraphCache::new(&[1, 2, 4], &[1, 2, 4], None);
        g.select(2, 4).unwrap();
        assert_eq!(g.padding_overhead(), 0.0);
    }

    #[test]
    fn hit_histogram_counts_selections() {
        let mut g = GraphCache::new(&[1, 2, 4], &[1, 2, 4], None);
        g.select(3, 0).unwrap(); // -> (4, 0)
        g.select(4, 0).unwrap(); // -> (4, 0)
        g.select(1, 2).unwrap(); // -> (1, 2)
        let hits = g.bucket_hits();
        assert_eq!(hits.len(), 2);
        assert!(hits.contains(&(BucketPair { local: 4, offload: 0 }, 2)));
        assert!(hits.contains(&(BucketPair { local: 1, offload: 2 }, 1)));
        assert_eq!(hits.iter().map(|&(_, n)| n).sum::<u64>(), g.stats().selections);
    }

    #[test]
    fn property_selection_covers_and_is_minimal() {
        crate::util::prop::check("graph_cache_minimal_cover", 200, |rng| {
            let mut g = GraphCache::new(&[1, 2, 4, 8, 16], &[1, 2, 4, 8, 16], None);
            let local = rng.range_usize(1, 17);
            let offload = rng.range_usize(0, 17);
            let pair = g.select(local, offload).unwrap();
            // Covers.
            assert!(pair.local >= local && pair.offload >= offload);
            // Minimal: no captured bucket strictly between need and choice.
            for &b in g.local_buckets() {
                assert!(!(b >= local && b < pair.local), "non-minimal local bucket {b}");
            }
            for &b in g.offload_buckets() {
                assert!(!(b >= offload && b < pair.offload), "non-minimal offload bucket {b}");
            }
        });
    }
}
