//! The 2-D executable-bucket cache — the paper's two-dimensional CUDA
//! graph (§3.2.2), re-expressed for the AOT/PJRT runtime.
//!
//! The paper captures CUDA graphs over a grid `(C_d, C_o)` of (local decode
//! batch, offloaded attention batch) capacities, limits the grid with
//! configurable intervals to bound storage, and per step selects the
//! smallest captured graph covering both sub-batches. Here each "graph" is
//! the pair of AOT-compiled executables `attn_b{C_d}` / `attn_b{C_o}` plus
//! the bucket-sized non-attention executables — the selection problem and
//! the storage trade-off are identical.

/// A selected bucket pair: the step runs local attention padded to
/// `local`, offloaded attention padded to `offload`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BucketPair {
    pub local: usize,
    pub offload: usize,
}

/// Statistics for observability/ablation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GraphCacheStats {
    pub selections: u64,
    /// Padded slots summed over selections (the cost of bucketing).
    pub padded_slots: u64,
    /// Requested slots summed over selections.
    pub used_slots: u64,
}

/// The capture grid + selector.
#[derive(Debug, Clone)]
pub struct GraphCache {
    /// Captured capacities for the local dimension (C_d), ascending.
    local_buckets: Vec<usize>,
    /// Captured capacities for the offload dimension (C_o), ascending.
    /// Always includes 0 (steps with nothing offloaded).
    offload_buckets: Vec<usize>,
    stats: GraphCacheStats,
}

impl GraphCache {
    /// Build from the configured bucket lists. `interval_limit` caps the
    /// total number of captured pairs (the paper's configurable interval):
    /// when `|C_d| * |C_o|` exceeds it, coarser grids are used (every k-th
    /// bucket kept, largest always retained).
    pub fn new(
        local_buckets: &[usize],
        offload_buckets: &[usize],
        interval_limit: Option<usize>,
    ) -> Self {
        assert!(!local_buckets.is_empty(), "need at least one local bucket");
        // Both dimensions include 0: a step may have nothing offloaded, or
        // (at high offload ratios) nothing local.
        let mut local: Vec<usize> = local_buckets.to_vec();
        local.push(0);
        local.sort_unstable();
        local.dedup();
        let mut offload: Vec<usize> = offload_buckets.to_vec();
        offload.push(0);
        offload.sort_unstable();
        offload.dedup();

        if let Some(limit) = interval_limit {
            assert!(limit >= 2, "interval limit must allow at least a 2x1 grid");
            while local.len() * offload.len() > limit {
                // Thin the larger dimension, keeping first and last.
                let v = if local.len() >= offload.len() { &mut local } else { &mut offload };
                if v.len() <= 2 {
                    break;
                }
                let keep_last = *v.last().unwrap();
                let thinned: Vec<usize> =
                    v.iter().copied().step_by(2).chain(std::iter::once(keep_last)).collect();
                *v = thinned;
                v.sort_unstable();
                v.dedup();
            }
        }
        GraphCache { local_buckets: local, offload_buckets: offload, stats: Default::default() }
    }

    pub fn grid_size(&self) -> usize {
        self.local_buckets.len() * self.offload_buckets.len()
    }

    pub fn local_buckets(&self) -> &[usize] {
        &self.local_buckets
    }

    pub fn offload_buckets(&self) -> &[usize] {
        &self.offload_buckets
    }

    pub fn stats(&self) -> GraphCacheStats {
        self.stats
    }

    pub fn max_local(&self) -> usize {
        *self.local_buckets.last().unwrap()
    }

    pub fn max_offload(&self) -> usize {
        *self.offload_buckets.last().unwrap()
    }

    /// Select the smallest captured pair covering `(local, offload)`
    /// (§3.2.2: "the smallest two-dimensional CUDA graph that accommodates
    /// both local and remote attention batches"). Returns `None` if either
    /// dimension exceeds the grid (the scheduler must split the step).
    pub fn select(&mut self, local: usize, offload: usize) -> Option<BucketPair> {
        let l = *self.local_buckets.iter().find(|&&b| b >= local)?;
        let o = *self.offload_buckets.iter().find(|&&b| b >= offload)?;
        self.stats.selections += 1;
        self.stats.used_slots += (local + offload) as u64;
        self.stats.padded_slots += ((l - local) + (o - offload)) as u64;
        Some(BucketPair { local: l, offload: o })
    }

    /// Fraction of compute wasted to padding so far (ablation metric for
    /// bucket-interval choices).
    pub fn padding_overhead(&self) -> f64 {
        let total = self.stats.used_slots + self.stats.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.stats.padded_slots as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_smallest_covering_pair() {
        let mut g = GraphCache::new(&[1, 2, 4, 8], &[1, 2, 4, 8], None);
        assert_eq!(g.select(3, 1), Some(BucketPair { local: 4, offload: 1 }));
        assert_eq!(g.select(1, 0), Some(BucketPair { local: 1, offload: 0 }));
        assert_eq!(g.select(8, 8), Some(BucketPair { local: 8, offload: 8 }));
        assert_eq!(g.select(5, 5), Some(BucketPair { local: 8, offload: 8 }));
    }

    #[test]
    fn oversize_returns_none() {
        let mut g = GraphCache::new(&[1, 2, 4], &[1, 2], None);
        assert_eq!(g.select(5, 0), None);
        assert_eq!(g.select(1, 3), None);
    }

    #[test]
    fn zero_offload_bucket_always_present() {
        let g = GraphCache::new(&[1], &[4], None);
        assert!(g.offload_buckets().contains(&0));
    }

    #[test]
    fn interval_limit_thins_grid_keeping_extremes() {
        let g = GraphCache::new(
            &[1, 2, 3, 4, 5, 6, 7, 8],
            &[1, 2, 3, 4, 5, 6, 7, 8],
            Some(20),
        );
        assert!(g.grid_size() <= 20, "grid = {}", g.grid_size());
        assert_eq!(g.max_local(), 8, "largest bucket must survive thinning");
        assert_eq!(g.max_offload(), 8);
        assert!(g.local_buckets().contains(&0), "smallest bucket survives");
    }

    #[test]
    fn padding_accounting() {
        let mut g = GraphCache::new(&[4], &[4], None);
        g.select(3, 2).unwrap(); // 5 used, 3 padded
        assert_eq!(g.stats().used_slots, 5);
        assert_eq!(g.stats().padded_slots, 3);
        assert!((g.padding_overhead() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn padding_zero_on_exact_hits() {
        let mut g = GraphCache::new(&[1, 2, 4], &[1, 2, 4], None);
        g.select(2, 4).unwrap();
        assert_eq!(g.padding_overhead(), 0.0);
    }

    #[test]
    fn property_selection_covers_and_is_minimal() {
        crate::util::prop::check("graph_cache_minimal_cover", 200, |rng| {
            let mut g = GraphCache::new(&[1, 2, 4, 8, 16], &[1, 2, 4, 8, 16], None);
            let local = rng.range_usize(1, 17);
            let offload = rng.range_usize(0, 17);
            let pair = g.select(local, offload).unwrap();
            // Covers.
            assert!(pair.local >= local && pair.offload >= offload);
            // Minimal: no captured bucket strictly between need and choice.
            for &b in g.local_buckets() {
                assert!(!(b >= local && b < pair.local), "non-minimal local bucket {b}");
            }
            for &b in g.offload_buckets() {
                assert!(!(b >= offload && b < pair.offload), "non-minimal offload bucket {b}");
            }
        });
    }
}
