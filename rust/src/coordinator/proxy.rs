//! The proxy: global request router + the home of the load-aware
//! offloading scheduler (§3.4.2).
//!
//! The proxy sees every request and response, so it can cheaply maintain
//! the runtime metadata (active requests, sequence lengths) that
//! Algorithm 1 consumes, track `B_TPOT` online, and rescale `OB_mem`
//! whenever prefill instances join or leave.

use crate::config::OffloadPolicy;
use crate::workload::{Request, RequestId};

use super::bounds::OffloadBounds;
use super::scheduler::{OffloadDecision, OffloadScheduler, ReqMeta, RuntimeMetadata};

/// Routing outcome for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteDecision {
    /// Which prefill instance runs the prompt.
    pub prefill_instance: usize,
    /// Which decode instance owns the request.
    pub decode_instance: usize,
    /// Whether (and why) its decode attention is offloaded.
    pub offload: OffloadDecision,
}

/// The global proxy/scheduler.
#[derive(Debug)]
pub struct Proxy {
    scheduler: OffloadScheduler,
    /// Per-decode-instance runtime metadata.
    meta: Vec<RuntimeMetadata>,
    n_prefill: usize,
    rr_prefill: usize,
    /// Heartbeat-observed prefill health (fault plane). Sizes stay fixed:
    /// a crashed instance is masked out of routing, never removed, so the
    /// per-instance executor-pool vectors elsewhere keep their indices.
    prefill_healthy: Vec<bool>,
    /// Heartbeat-observed decode health.
    decode_healthy: Vec<bool>,
    /// Graceful degradation toggle (`FaultConfig::health_aware`). When
    /// `false` the proxy records health but neither masks routing nor
    /// rescales bounds — the naive fail-and-recompute baseline.
    health_aware: bool,
    /// Fresh-arrival decision counters: (c1, c2, local). One increment per
    /// arriving request, so the sum always equals the arrival count.
    pub decision_counts: (u64, u64, u64),
    /// Re-route decision counters for preempted requests re-admitted via
    /// the recompute path — kept separate so the admission counters above
    /// are not inflated by preemption churn (one increment per preemption).
    pub decision_counts_rerouted: (u64, u64, u64),
}

impl Proxy {
    pub fn new(policy: OffloadPolicy, bounds: OffloadBounds, n_prefill: usize, n_decode: usize) -> Self {
        assert!(n_prefill >= 1 && n_decode >= 1);
        Proxy {
            scheduler: OffloadScheduler::new(policy, bounds),
            meta: vec![RuntimeMetadata::new(); n_decode],
            n_prefill,
            rr_prefill: 0,
            prefill_healthy: vec![true; n_prefill],
            decode_healthy: vec![true; n_decode],
            health_aware: true,
            decision_counts: (0, 0, 0),
            decision_counts_rerouted: (0, 0, 0),
        }
    }

    pub fn n_decode(&self) -> usize {
        self.meta.len()
    }

    pub fn n_prefill(&self) -> usize {
        self.n_prefill
    }

    pub fn bounds(&self) -> &OffloadBounds {
        &self.scheduler.bounds
    }

    pub fn metadata(&self, decode_instance: usize) -> &RuntimeMetadata {
        &self.meta[decode_instance]
    }

    /// Route a new request: prefill round-robin, decode to the
    /// least-loaded instance (by resident tokens), offload per Algorithm 1
    /// against that instance's metadata. The request is admitted into the
    /// metadata immediately (the §3.2.1 "hint": the attention executor
    /// learns about offloaded requests before their first decode step).
    pub fn route(&mut self, req: &Request) -> RouteDecision {
        self.route_at(req, req.prompt_len, false)
    }

    /// Re-route a preempted request resuming via the recompute path. The
    /// recompute prefill re-materializes `resumed_len = prompt + generated`
    /// tokens of KV, so that — not the original prompt length — is the
    /// `used_token` the offload budget must account (routing with the bare
    /// prompt length undercounted every preempted request's OB share by
    /// its generated tokens). Counted under `decision_counts_rerouted`.
    pub fn route_resumed(&mut self, req: &Request, resumed_len: usize) -> RouteDecision {
        debug_assert!(
            resumed_len >= req.prompt_len,
            "resumption length {resumed_len} below prompt {}",
            req.prompt_len
        );
        self.route_at(req, resumed_len, true)
    }

    fn route_at(&mut self, req: &Request, used_token: usize, rerouted: bool) -> RouteDecision {
        // Degraded routing: with health-aware mode on and at least one
        // live instance, crashed instances are skipped. With every
        // instance down (or in naive mode) the pre-fault paths run
        // unchanged — all-healthy runs stay bit-identical to a proxy
        // without the health plane.
        let mask_prefill =
            self.health_aware && self.prefill_healthy.iter().any(|&h| !h)
                && self.prefill_healthy.iter().any(|&h| h);
        let prefill_instance = if mask_prefill {
            let mut pick = self.rr_prefill;
            while !self.prefill_healthy[pick] {
                pick = (pick + 1) % self.n_prefill;
            }
            self.rr_prefill = (pick + 1) % self.n_prefill;
            pick
        } else {
            let pick = self.rr_prefill;
            self.rr_prefill = (self.rr_prefill + 1) % self.n_prefill;
            pick
        };

        let mask_decode = self.health_aware && self.decode_healthy.iter().any(|&h| h);
        let decode_instance = self
            .meta
            .iter()
            .enumerate()
            .filter(|(i, _)| !mask_decode || self.decode_healthy[*i])
            .min_by_key(|(_, m)| m.decode_used_tokens() + m.attn_used_tokens())
            .map(|(i, _)| i)
            .expect("at least one decode instance");

        let rm = ReqMeta { used_token, max_token: req.max_token().max(used_token) };
        let offload = self.scheduler.need_offload(rm, &self.meta[decode_instance]);
        let counts = if rerouted {
            &mut self.decision_counts_rerouted
        } else {
            &mut self.decision_counts
        };
        match offload {
            OffloadDecision::C1 => counts.0 += 1,
            OffloadDecision::C2 => counts.1 += 1,
            OffloadDecision::Local => counts.2 += 1,
        }
        self.meta[decode_instance].admit(req.id, rm, offload.offloaded());
        RouteDecision { prefill_instance, decode_instance, offload }
    }

    /// A decode step emitted one token for `id` on `instance`.
    pub fn on_token(&mut self, instance: usize, id: RequestId) {
        self.meta[instance].on_token(id);
    }

    /// A leaped run of `n` decode steps emitted `n` tokens for `id` on
    /// `instance` (bulk form of [`Proxy::on_token`]; integer accounting,
    /// so `n` single-token calls land on the same state).
    pub fn on_token_bulk(&mut self, instance: usize, id: RequestId, n: usize) {
        self.meta[instance].on_tokens(id, n);
    }

    /// Request finished (or was cancelled): drop its metadata.
    pub fn on_finished(&mut self, instance: usize, id: RequestId) {
        self.meta[instance].remove(id);
    }

    /// A request was preempted on the decode instance: it leaves the
    /// running set until re-admitted (recompute path re-routes it).
    pub fn on_preempted(&mut self, instance: usize, id: RequestId) {
        self.meta[instance].remove(id);
    }

    /// A running request's attention migrated between local and offloaded
    /// (the runtime rebalancer, §3.4.2 extended). Keeps the metadata the
    /// offload scheduler consults consistent with actual residency.
    /// Returns `true` iff the request was tracked.
    pub fn on_migrated(&mut self, instance: usize, id: RequestId, offloaded: bool) -> bool {
        self.meta[instance].set_offloaded(id, offloaded)
    }

    /// A decode instance crashed while `id`'s attention was offloaded: its
    /// KV lives in a *prefill* instance's executor HBM, so the request
    /// survives the crash — move its metadata off the dead instance onto
    /// the least-loaded survivor and return the new home. Known-unhealthy
    /// survivors are masked too (health-aware mode); with no other
    /// instance at all the request re-admits on `from` and stalls until
    /// recovery.
    pub fn reroute_decode(
        &mut self,
        from: usize,
        req: &Request,
        used_token: usize,
        offloaded: bool,
    ) -> usize {
        self.meta[from].remove(req.id);
        let mask = self.health_aware
            && self.decode_healthy.iter().enumerate().any(|(i, &h)| h && i != from);
        let to = self
            .meta
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != from && (!mask || self.decode_healthy[*i]))
            .min_by_key(|(_, m)| m.decode_used_tokens() + m.attn_used_tokens())
            .map(|(i, _)| i)
            .unwrap_or(from);
        let rm = ReqMeta { used_token, max_token: req.max_token().max(used_token) };
        self.meta[to].admit(req.id, rm, offloaded);
        to
    }

    /// Would migrating tracked *local* request `id` to offloaded keep
    /// decode instance `instance` within Algorithm 1's OB bound? Unlike
    /// admission (where the candidate is in neither set), a migration
    /// moves the request's tokens from the local sum to the offloaded sum,
    /// so the post-move state is checked:
    /// `attn_used + used <= (decode_used - used) · OB`.
    pub fn migration_within_bound(&self, instance: usize, id: RequestId) -> bool {
        let ob = self.scheduler.bounds.ob();
        if ob <= 0.0 {
            return false;
        }
        let m = &self.meta[instance];
        if m.is_offloaded(id) {
            return false;
        }
        let Some(used) = m.used_token_of(id) else { return false };
        let decode_after = m.decode_used_tokens().saturating_sub(used) as f64;
        (m.attn_used_tokens() + used) as f64 <= decode_after * ob
    }

    /// Online B_TPOT refresh (§3.4.2): the proxy watches observed decode
    /// batch sizes that met the TPOT SLO and feeds the max back in.
    pub fn observe_b_tpot(&mut self, b_tpot: usize) {
        self.scheduler.bounds.set_b_tpot(b_tpot);
    }

    /// Prefill pool grew/shrank: rescale OB_mem (Eq 1 is linear in n).
    pub fn set_prefill_instances(&mut self, n: usize) {
        assert!(n >= 1);
        let old = self.n_prefill as f64;
        self.n_prefill = n;
        self.rr_prefill %= n;
        self.prefill_healthy.resize(n, true);
        self.scheduler.bounds.rescale_ob_mem(old, n as f64);
    }

    /// Switch between graceful (health-aware) and naive routing.
    pub fn set_health_aware(&mut self, aware: bool) {
        self.health_aware = aware;
    }

    /// Heartbeat-observed health transition for a prefill instance (and
    /// the attention executor colocated on it). In health-aware mode a
    /// crash masks the instance out of round-robin routing — so no new
    /// offloads land on its executor — and rescales `OB_mem` for the
    /// lost capacity (Eq 1 is linear in the live instance count);
    /// recovery reverses both. Transitions through a fully-dead pool are
    /// skipped symmetrically so the bound survives the round trip.
    pub fn set_prefill_health(&mut self, instance: usize, healthy: bool) {
        if self.prefill_healthy[instance] == healthy {
            return;
        }
        let old = self.healthy_prefill_count();
        self.prefill_healthy[instance] = healthy;
        let new = self.healthy_prefill_count();
        if self.health_aware && old > 0 && new > 0 {
            self.scheduler.bounds.rescale_ob_mem(old as f64, new as f64);
        }
    }

    /// Heartbeat-observed health transition for a decode instance.
    pub fn set_decode_health(&mut self, instance: usize, healthy: bool) {
        self.decode_healthy[instance] = healthy;
    }

    pub fn is_prefill_healthy(&self, instance: usize) -> bool {
        self.prefill_healthy[instance]
    }

    pub fn is_decode_healthy(&self, instance: usize) -> bool {
        self.decode_healthy[instance]
    }

    pub fn healthy_prefill_count(&self) -> usize {
        self.prefill_healthy.iter().filter(|&&h| h).count()
    }

    pub fn healthy_decode_count(&self) -> usize {
        self.decode_healthy.iter().filter(|&&h| h).count()
    }

    /// Offloaded fraction among currently-running requests (Fig 15's knob,
    /// observed).
    pub fn offloaded_fraction(&self) -> f64 {
        let (mut offl, mut total) = (0usize, 0usize);
        for m in &self.meta {
            offl += m.offloaded_count();
            total += m.total_count();
        }
        if total == 0 {
            0.0
        } else {
            offl as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn bounds() -> OffloadBounds {
        OffloadBounds::new(0.7, 160, 80)
    }

    fn req(id: u64, prompt: usize, output: usize) -> Request {
        Request::new(id, 0.0, prompt, output)
    }

    #[test]
    fn round_robin_prefill_assignment() {
        let mut p = Proxy::new(OffloadPolicy::Disabled, bounds(), 3, 1);
        let picks: Vec<usize> =
            (0..6).map(|i| p.route(&req(i, 10, 10)).prefill_instance).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn decode_goes_to_least_loaded() {
        let mut p = Proxy::new(OffloadPolicy::Disabled, bounds(), 1, 2);
        let d0 = p.route(&req(0, 1000, 10)).decode_instance;
        let d1 = p.route(&req(1, 10, 10)).decode_instance;
        assert_ne!(d0, d1, "second request must avoid the loaded instance");
        // Third: instance with the 10-token request is lighter.
        let d2 = p.route(&req(2, 10, 10)).decode_instance;
        assert_eq!(d2, d1);
    }

    #[test]
    fn offload_decisions_tracked_in_metadata() {
        let mut p = Proxy::new(OffloadPolicy::LoadAware, bounds(), 1, 1);
        // Seed local load so the budget is meaningful.
        let r0 = p.route(&req(0, 500, 100));
        assert_eq!(r0.offload, OffloadDecision::Local, "empty decode => no budget");
        let r1 = p.route(&req(1, 50, 50));
        assert!(r1.offload.offloaded(), "small request under 0.7*500 budget");
        assert!(p.metadata(0).is_offloaded(1));
        assert_eq!(p.offloaded_fraction(), 0.5);
    }

    #[test]
    fn finish_and_preempt_clear_metadata() {
        let mut p = Proxy::new(OffloadPolicy::Disabled, bounds(), 1, 1);
        p.route(&req(0, 10, 10));
        p.route(&req(1, 10, 10));
        p.on_token(0, 0);
        assert_eq!(p.metadata(0).decode_used_tokens(), 21);
        p.on_finished(0, 0);
        p.on_preempted(0, 1);
        assert_eq!(p.metadata(0).total_count(), 0);
    }

    /// Regression (ISSUE 4 satellite): a preempted request resuming at
    /// `prompt + generated` must re-enter the metadata at its resumption
    /// length — `route` used to admit it at the bare prompt length,
    /// undercounting the OB budget by every generated token.
    #[test]
    fn resumed_route_accounts_generated_tokens() {
        let mut p = Proxy::new(OffloadPolicy::Disabled, bounds(), 1, 1);
        let r = req(0, 100, 50);
        p.route(&r);
        for _ in 0..20 {
            p.on_token(0, 0);
        }
        assert_eq!(p.metadata(0).decode_used_tokens(), 120);
        p.on_preempted(0, 0);
        assert_eq!(p.metadata(0).decode_used_tokens(), 0);
        // Recompute resumes at prompt + generated = 120 tokens.
        p.route_resumed(&r, 120);
        assert_eq!(
            p.metadata(0).decode_used_tokens(),
            120,
            "re-admission must account the resumed sequence length"
        );
        assert_eq!(p.metadata(0).used_token_of(0), Some(120));
    }

    /// Satellite: re-routes land in their own counters; the fresh-arrival
    /// counters keep summing to the arrival count.
    #[test]
    fn reroute_decisions_counted_separately() {
        let mut p = Proxy::new(OffloadPolicy::LoadAware, bounds(), 1, 1);
        let r0 = req(0, 500, 100);
        let r1 = req(1, 50, 50);
        p.route(&r0);
        p.route(&r1);
        let fresh = p.decision_counts;
        assert_eq!(fresh.0 + fresh.1 + fresh.2, 2, "one decision per arrival");
        assert_eq!(p.decision_counts_rerouted, (0, 0, 0));
        // Preempt + re-admit both: only the rerouted counters move.
        p.on_preempted(0, 0);
        p.route_resumed(&r0, 510);
        p.on_preempted(0, 1);
        p.route_resumed(&r1, 60);
        assert_eq!(p.decision_counts, fresh, "arrival counters must not inflate");
        let re = p.decision_counts_rerouted;
        assert_eq!(re.0 + re.1 + re.2, 2, "one rerouted decision per preemption");
    }

    #[test]
    fn bulk_tokens_match_per_token_calls() {
        let mut per = Proxy::new(OffloadPolicy::LoadAware, bounds(), 1, 2);
        let mut bulk = Proxy::new(OffloadPolicy::LoadAware, bounds(), 1, 2);
        let mut homes = Vec::new();
        for id in 0..6u64 {
            let r = req(id, 50 + 10 * id as usize, 50);
            let d = per.route(&r).decode_instance;
            assert_eq!(d, bulk.route(&r).decode_instance, "same routing state");
            homes.push(d);
        }
        for (id, &d) in homes.iter().enumerate() {
            for _ in 0..7 {
                per.on_token(d, id as u64);
            }
            bulk.on_token_bulk(d, id as u64, 7);
        }
        for d in 0..2 {
            let (p, b) = (per.metadata(d), bulk.metadata(d));
            assert_eq!(p.decode_used_tokens(), b.decode_used_tokens());
            assert_eq!(p.attn_used_tokens(), b.attn_used_tokens());
            for id in 0..6u64 {
                assert_eq!(p.used_token_of(id), b.used_token_of(id));
            }
        }
        // Untracked ids are ignored, same as the per-token path.
        bulk.on_token_bulk(0, 99, 3);
        per.on_token(0, 99);
        assert_eq!(per.metadata(0).decode_used_tokens(), bulk.metadata(0).decode_used_tokens());
    }

    #[test]
    fn prefill_scaling_rescales_ob_mem() {
        let mut p = Proxy::new(OffloadPolicy::LoadAware, bounds(), 2, 1);
        let before = p.bounds().ob_mem;
        p.set_prefill_instances(4);
        assert!((p.bounds().ob_mem / before - 2.0).abs() < 1e-9);
        assert_eq!(p.n_prefill(), 4);
    }

    #[test]
    fn migration_updates_metadata_and_respects_bound() {
        // Disabled policy: every admission stays local, so the rebalancer
        // (which checks the bound independently of the admission policy)
        // is the only thing moving requests.
        let mut p = Proxy::new(OffloadPolicy::Disabled, bounds(), 1, 1);
        let r0 = p.route(&req(0, 1000, 100));
        let r1 = p.route(&req(1, 100, 50));
        assert_eq!(r0.offload, OffloadDecision::Local);
        assert_eq!(r1.offload, OffloadDecision::Local);
        // ob = min(0.7, (160-80)/80) = 0.7. Moving 100 tokens:
        // attn(0)+100 <= (1100-100)*0.7 -> 100 <= 700: within bound.
        assert!(p.migration_within_bound(0, 1));
        // Moving the 1000-token request: 1000 <= (1100-1000)*0.7 fails.
        assert!(!p.migration_within_bound(0, 0));
        // Untracked ids are refused.
        assert!(!p.migration_within_bound(0, 99));
        assert!(p.on_migrated(0, 1, true));
        assert!(p.metadata(0).is_offloaded(1));
        assert!(!p.migration_within_bound(0, 1), "already offloaded");
        assert_eq!(p.offloaded_fraction(), 0.5);
        // Migrating back restores the local set.
        assert!(p.on_migrated(0, 1, false));
        assert!(!p.metadata(0).is_offloaded(1));
        assert!(!p.on_migrated(0, 99, true));
    }

    #[test]
    fn unhealthy_prefill_skipped_then_readmitted() {
        let mut p = Proxy::new(OffloadPolicy::Disabled, bounds(), 3, 1);
        p.set_prefill_health(1, false);
        let picks: Vec<usize> =
            (0..4).map(|i| p.route(&req(i, 10, 10)).prefill_instance).collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "crashed instance must be routed around");
        p.set_prefill_health(1, true);
        let picks: Vec<usize> =
            (4..7).map(|i| p.route(&req(i, 10, 10)).prefill_instance).collect();
        assert!(picks.contains(&1), "recovery must re-admit the instance: {picks:?}");
    }

    #[test]
    fn prefill_health_rescales_ob_mem_round_trip() {
        let mut p = Proxy::new(OffloadPolicy::LoadAware, bounds(), 2, 1);
        let before = p.bounds().ob_mem;
        p.set_prefill_health(0, false);
        assert!((p.bounds().ob_mem / before - 0.5).abs() < 1e-9, "half the executor capacity");
        assert_eq!(p.healthy_prefill_count(), 1);
        // Idempotent: repeating the same observation must not re-scale.
        p.set_prefill_health(0, false);
        assert!((p.bounds().ob_mem / before - 0.5).abs() < 1e-9);
        p.set_prefill_health(0, true);
        assert!((p.bounds().ob_mem / before - 1.0).abs() < 1e-9, "recovery restores the bound");
        // A trip through a fully-dead pool also round-trips.
        p.set_prefill_health(0, false);
        p.set_prefill_health(1, false);
        p.set_prefill_health(0, true);
        p.set_prefill_health(1, true);
        assert!((p.bounds().ob_mem / before - 1.0).abs() < 1e-9);
    }

    #[test]
    fn unhealthy_decode_avoided_until_recovery() {
        let mut p = Proxy::new(OffloadPolicy::Disabled, bounds(), 1, 2);
        p.set_decode_health(0, false);
        for id in 0..3u64 {
            assert_eq!(p.route(&req(id, 10, 10)).decode_instance, 1);
        }
        p.set_decode_health(0, true);
        // Instance 0 is empty, instance 1 holds three requests: the
        // least-loaded pick must return to the recovered instance.
        assert_eq!(p.route(&req(3, 10, 10)).decode_instance, 0);
    }

    #[test]
    fn naive_mode_ignores_health() {
        let mut p = Proxy::new(OffloadPolicy::LoadAware, bounds(), 2, 2);
        p.set_health_aware(false);
        let before = p.bounds().ob_mem;
        p.set_prefill_health(0, false);
        p.set_decode_health(0, false);
        assert_eq!(p.bounds().ob_mem, before, "naive mode never rescales");
        let picks: Vec<usize> =
            (0..4).map(|i| p.route(&req(i, 10, 10)).prefill_instance).collect();
        assert_eq!(picks, vec![0, 1, 0, 1], "naive mode keeps routing to the crash");
        let mut q = Proxy::new(OffloadPolicy::LoadAware, bounds(), 2, 2);
        q.set_health_aware(false);
        q.set_decode_health(0, false);
        assert_eq!(
            q.route(&req(0, 10, 10)).decode_instance,
            0,
            "naive least-loaded pick still lands on the crashed instance"
        );
    }

    #[test]
    fn reroute_decode_moves_metadata_to_survivor() {
        let mut p = Proxy::new(OffloadPolicy::Disabled, bounds(), 1, 3);
        let r = req(0, 100, 50);
        let home = p.route(&r).decode_instance;
        for _ in 0..30 {
            p.on_token(home, 0);
        }
        // Load a survivor so the least-loaded pick is disambiguated.
        let heavy = (home + 1) % 3;
        p.set_decode_health(home, false);
        let mut q = Proxy::new(OffloadPolicy::Disabled, bounds(), 1, 1);
        q.route(&req(7, 2000, 10));
        // (separate proxy just exercises the single-instance fallback below)
        p.meta[heavy].admit(99, ReqMeta { used_token: 5000, max_token: 5000 }, false);
        let to = p.reroute_decode(home, &r, 130, true);
        assert_ne!(to, home, "victim must leave the crashed instance");
        assert_ne!(to, heavy, "least-loaded survivor wins");
        assert_eq!(p.metadata(home).total_count(), 0);
        assert_eq!(p.metadata(to).used_token_of(0), Some(130), "resumed length re-admitted");
        assert!(p.metadata(to).is_offloaded(0), "offloaded residency survives the move");
        // Single decode instance: nowhere to go — re-admit in place.
        q.on_preempted(0, 7);
        assert_eq!(q.reroute_decode(0, &req(7, 2000, 10), 2010, false), 0);
    }

    #[test]
    fn property_requests_conserved() {
        prop::check("proxy_conserves_requests", 50, |rng| {
            let n_decode = rng.range_usize(1, 4);
            let mut p = Proxy::new(OffloadPolicy::LoadAware, bounds(), 1, n_decode);
            let n = rng.range_usize(1, 40);
            let mut homes = Vec::new();
            for id in 0..n as u64 {
                let r = req(id, rng.range_usize(1, 500), rng.range_usize(1, 500));
                homes.push(p.route(&r).decode_instance);
            }
            let total: usize = (0..n_decode).map(|i| p.metadata(i).total_count()).sum();
            assert_eq!(total, n, "every routed request is tracked exactly once");
            // Finish them all; metadata must drain to zero.
            for (id, &home) in homes.iter().enumerate() {
                p.on_finished(home, id as u64);
            }
            let total: usize = (0..n_decode).map(|i| p.metadata(i).total_count()).sum();
            assert_eq!(total, 0);
        });
    }

    #[test]
    fn property_offload_never_without_budget() {
        prop::check("offload_respects_bound", 100, |rng| {
            let ob_mem = rng.f64();
            let b = OffloadBounds::new(
                ob_mem,
                100 + rng.range_usize(0, 100),
                1 + rng.range_usize(0, 99),
            );
            let mut p = Proxy::new(OffloadPolicy::LoadAware, b, 1, 1);
            for id in 0..30u64 {
                let r = req(id, rng.range_usize(1, 300), rng.range_usize(1, 300));
                let d = p.route(&r);
                if d.offload.offloaded() {
                    // Invariant: after admission the offloaded token share
                    // is within OB (C1) or the batch-count ratio is (C2).
                    let m = p.metadata(0);
                    let ob = p.bounds().ob();
                    let within_tokens = (m.attn_used_tokens() as f64)
                        <= (m.decode_used_tokens() as f64) * ob + 1e-9;
                    let within_counts = (m.offloaded_count() as f64)
                        <= (m.local_count() as f64) * ob + 1.0;
                    assert!(
                        within_tokens || within_counts,
                        "offload admitted beyond both bounds (ob={ob})"
                    );
                }
            }
        });
    }
}
