//! Load-aware offloading scheduling — the paper's Algorithm 1 plus the
//! runtime metadata it consumes (§3.4.2–§3.4.3).
//!
//! The proxy tracks, per decode instance, the set of locally-running
//! requests (`LR`) and the set whose attention is offloaded (`OR`), with
//! each request's `used_token` (current sequence length) and `max_token`
//! (prompt + max output). A new request's attention is offloaded iff
//! condition C1 or C2 holds, keeping the offloaded share within
//! `OB(n, B_max)`.
//!
//! Fidelity note: Algorithm 1's listing computes `attn_max_tokens`
//! (line 2) but tests `attn_used_tokens + req.max_token` in C1 (line 5).
//! We implement the listing as printed; `attn_max_tokens` is still
//! tracked and exposed for the stricter variant (ablation
//! `ablation_admission` compares both).

use std::collections::HashMap;

use crate::config::OffloadPolicy;
use crate::workload::RequestId;

use super::bounds::OffloadBounds;

/// Per-request runtime metadata the proxy keeps (§3.4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReqMeta {
    /// Current sequence length (prompt + generated so far).
    pub used_token: usize,
    /// Prompt + maximum output length.
    pub max_token: usize,
}

/// Runtime metadata for one decode instance and its attention executor.
#[derive(Debug, Default, Clone)]
pub struct RuntimeMetadata {
    /// Locally-running requests (attention on the decode instance).
    local: HashMap<RequestId, ReqMeta>,
    /// Requests whose attention is offloaded.
    offloaded: HashMap<RequestId, ReqMeta>,
}

impl RuntimeMetadata {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn local_count(&self) -> usize {
        self.local.len()
    }

    pub fn offloaded_count(&self) -> usize {
        self.offloaded.len()
    }

    pub fn total_count(&self) -> usize {
        self.local.len() + self.offloaded.len()
    }

    /// Σ used_token over locally-running requests.
    pub fn decode_used_tokens(&self) -> usize {
        self.local.values().map(|m| m.used_token).sum()
    }

    /// Σ used_token over offloaded requests.
    pub fn attn_used_tokens(&self) -> usize {
        self.offloaded.values().map(|m| m.used_token).sum()
    }

    /// Σ max_token over offloaded requests (Algorithm 1 line 2).
    pub fn attn_max_tokens(&self) -> usize {
        self.offloaded.values().map(|m| m.max_token).sum()
    }

    pub fn is_offloaded(&self, id: RequestId) -> bool {
        self.offloaded.contains_key(&id)
    }

    /// Current sequence length of a tracked request (either set).
    pub fn used_token_of(&self, id: RequestId) -> Option<usize> {
        self.local
            .get(&id)
            .or_else(|| self.offloaded.get(&id))
            .map(|m| m.used_token)
    }

    /// Move a tracked request between the local and offloaded sets (a
    /// runtime migration, §3.4.2 extended). Returns `true` iff the request
    /// is tracked; already being on the requested side is a no-op.
    pub fn set_offloaded(&mut self, id: RequestId, offloaded: bool) -> bool {
        if offloaded {
            if let Some(m) = self.local.remove(&id) {
                self.offloaded.insert(id, m);
                return true;
            }
            self.offloaded.contains_key(&id)
        } else {
            if let Some(m) = self.offloaded.remove(&id) {
                self.local.insert(id, m);
                return true;
            }
            self.local.contains_key(&id)
        }
    }

    pub fn admit(&mut self, id: RequestId, meta: ReqMeta, offloaded: bool) {
        debug_assert!(!self.local.contains_key(&id) && !self.offloaded.contains_key(&id));
        if offloaded {
            self.offloaded.insert(id, meta);
        } else {
            self.local.insert(id, meta);
        }
    }

    /// A decode step produced one token for `id`.
    pub fn on_token(&mut self, id: RequestId) {
        self.on_tokens(id, 1);
    }

    /// `n` consecutive decode steps produced `n` tokens for `id` — the
    /// decode leap engine's bulk form of [`RuntimeMetadata::on_token`]
    /// (one map lookup per leap instead of one per step).
    pub fn on_tokens(&mut self, id: RequestId, n: usize) {
        if let Some(m) = self.local.get_mut(&id).or_else(|| self.offloaded.get_mut(&id)) {
            m.used_token += n;
        }
    }

    pub fn remove(&mut self, id: RequestId) -> bool {
        self.local.remove(&id).is_some() || self.offloaded.remove(&id).is_some()
    }

    pub fn local_ids(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.local.keys().copied()
    }

    pub fn offloaded_ids(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.offloaded.keys().copied()
    }
}

/// What the rebalance controller wants for one prefill instance this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebalanceMode {
    /// Prefill is lightly loaded: grow the offloaded share toward the OB
    /// bound (migrating decode attention onto this instance's executor).
    Offload,
    /// A prefill burst is in flight: hold new offload migrations to this
    /// instance and reclaim attention if its executor pool is choking
    /// prompt dispatch.
    Reclaim,
}

/// Feedback controller for runtime offload rebalancing (the dynamic
/// extension of Algorithm 1; EXPERIMENTS.md §Scenarios).
///
/// Per tick the simulator reports each prefill instance's *pressure* —
/// queued prompt tokens over `max_prefill_tokens`, i.e. how many full
/// prefill batches are waiting — and the controller answers with a mode.
/// The mode is a Schmitt trigger around the setpoint (0.5 batches): it
/// flips to [`RebalanceMode::Reclaim`] at `0.5 + hysteresis`, back to
/// [`RebalanceMode::Offload`] at `0.5 - hysteresis`, and holds its
/// previous answer inside the band — so a pressure signal hovering at the
/// threshold cannot make the controller thrash migrations.
#[derive(Debug, Clone)]
pub struct RebalanceController {
    cfg: crate::config::RebalanceConfig,
    /// Sticky per-prefill-instance mode (hysteresis state).
    modes: Vec<RebalanceMode>,
}

/// Pressure setpoint: half a prefill batch queued.
const REBALANCE_PRESSURE_SETPOINT: f64 = 0.5;

impl RebalanceController {
    pub fn new(cfg: crate::config::RebalanceConfig, n_prefill: usize) -> Self {
        assert!(cfg.interval_s > 0.0 && n_prefill >= 1);
        RebalanceController { cfg, modes: vec![RebalanceMode::Offload; n_prefill] }
    }

    pub fn interval_s(&self) -> f64 {
        self.cfg.interval_s
    }

    pub fn max_migrations_per_interval(&self) -> usize {
        self.cfg.max_migrations_per_interval
    }

    pub fn mode(&self, prefill_instance: usize) -> RebalanceMode {
        self.modes[prefill_instance]
    }

    /// Feed one tick's pressure observation for `prefill_instance` and get
    /// the (possibly unchanged) mode back.
    pub fn assess(&mut self, prefill_instance: usize, pressure: f64) -> RebalanceMode {
        let low = (REBALANCE_PRESSURE_SETPOINT - self.cfg.hysteresis).max(0.0);
        let high = REBALANCE_PRESSURE_SETPOINT + self.cfg.hysteresis;
        let mode = if pressure >= high {
            RebalanceMode::Reclaim
        } else if pressure <= low {
            RebalanceMode::Offload
        } else {
            self.modes[prefill_instance]
        };
        self.modes[prefill_instance] = mode;
        mode
    }
}

/// Why the scheduler admitted (or refused) an offload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadDecision {
    /// Offload admitted by condition C1 (worst-case length fits the bound).
    C1,
    /// Offload admitted by condition C2 (current lengths + batch ratio fit).
    C2,
    /// Keep attention local.
    Local,
}

impl OffloadDecision {
    pub fn offloaded(&self) -> bool {
        !matches!(self, OffloadDecision::Local)
    }
}

/// The load-aware offloading scheduler (Algorithm 1).
#[derive(Debug)]
pub struct OffloadScheduler {
    pub policy: OffloadPolicy,
    pub bounds: OffloadBounds,
    /// Round-robin counter for the FixedRatio fallback policy.
    fixed_acc: f64,
}

impl OffloadScheduler {
    pub fn new(policy: OffloadPolicy, bounds: OffloadBounds) -> Self {
        OffloadScheduler { policy, bounds, fixed_acc: 0.0 }
    }

    /// Decide whether `req`'s decode attention should be offloaded, given
    /// the decode instance's current runtime metadata.
    pub fn need_offload(&mut self, req: ReqMeta, meta: &RuntimeMetadata) -> OffloadDecision {
        match self.policy {
            OffloadPolicy::Disabled => OffloadDecision::Local,
            OffloadPolicy::FixedRatio(r) => {
                // Deterministic round-robin at ratio r (the naive baseline
                // Fig 15 sweeps): offload whenever the accumulated quota
                // crosses 1.
                self.fixed_acc += r.clamp(0.0, 1.0);
                if self.fixed_acc >= 1.0 {
                    self.fixed_acc -= 1.0;
                    OffloadDecision::C1
                } else {
                    OffloadDecision::Local
                }
            }
            OffloadPolicy::LoadAware => self.algorithm1(req, meta),
            OffloadPolicy::LoadAwareStrict => self.algorithm1_strict(req, meta),
        }
    }

    /// Algorithm 1, as printed in the paper.
    fn algorithm1(&self, req: ReqMeta, meta: &RuntimeMetadata) -> OffloadDecision {
        let ob = self.bounds.ob();
        if ob <= 0.0 {
            return OffloadDecision::Local;
        }
        let attn_used = meta.attn_used_tokens() as f64;
        let decode_used = meta.decode_used_tokens() as f64;
        let budget = decode_used * ob;

        // C1: even the request's maximal length fits within the bound.
        if attn_used + (req.max_token as f64) < budget {
            return OffloadDecision::C1;
        }
        // C2: current lengths fit AND the attention batch ratio stays
        // within the bound.
        let or_count = meta.offloaded_count() as f64;
        let lr_count = meta.local_count() as f64;
        if attn_used + (req.used_token as f64) < budget && or_count + 1.0 < lr_count * ob {
            return OffloadDecision::C2;
        }
        OffloadDecision::Local
    }

    /// The stricter C1 variant using Σ max_token (see module docs).
    pub fn algorithm1_strict(&self, req: ReqMeta, meta: &RuntimeMetadata) -> OffloadDecision {
        let ob = self.bounds.ob();
        if ob <= 0.0 {
            return OffloadDecision::Local;
        }
        let budget = meta.decode_used_tokens() as f64 * ob;
        if ((meta.attn_max_tokens() + req.max_token) as f64) < budget {
            return OffloadDecision::C1;
        }
        let or_count = meta.offloaded_count() as f64;
        let lr_count = meta.local_count() as f64;
        if ((meta.attn_used_tokens() + req.used_token) as f64) < budget
            && or_count + 1.0 < lr_count * ob
        {
            return OffloadDecision::C2;
        }
        OffloadDecision::Local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bounds(ob_mem: f64, b_max: usize, b_tpot: usize) -> OffloadBounds {
        OffloadBounds::new(ob_mem, b_max, b_tpot)
    }

    fn meta_with(local: &[(u64, usize, usize)], offl: &[(u64, usize, usize)]) -> RuntimeMetadata {
        let mut m = RuntimeMetadata::new();
        for &(id, used, max) in local {
            m.admit(id, ReqMeta { used_token: used, max_token: max }, false);
        }
        for &(id, used, max) in offl {
            m.admit(id, ReqMeta { used_token: used, max_token: max }, true);
        }
        m
    }

    #[test]
    fn c1_admits_small_request_under_empty_executor() {
        // OB = min(0.7, (160-80)/80 = 1.0) = 0.7; budget = 1000*0.7 = 700.
        let mut s = OffloadScheduler::new(OffloadPolicy::LoadAware, bounds(0.7, 160, 80));
        let meta = meta_with(&[(1, 500, 600), (2, 500, 600)], &[]);
        let req = ReqMeta { used_token: 100, max_token: 300 };
        assert_eq!(s.need_offload(req, &meta), OffloadDecision::C1);
    }

    #[test]
    fn refuses_when_bound_exhausted() {
        let mut s = OffloadScheduler::new(OffloadPolicy::LoadAware, bounds(0.5, 160, 80));
        // decode_used = 400 => budget 200; attn already holds 190.
        let meta = meta_with(&[(1, 400, 500)], &[(2, 190, 200)]);
        let req = ReqMeta { used_token: 50, max_token: 120 };
        assert_eq!(s.need_offload(req, &meta), OffloadDecision::Local);
    }

    #[test]
    fn c2_admits_when_current_fits_but_max_does_not() {
        // budget = 1000*0.7 = 700. attn_used=300. req.max_token=500 =>
        // C1 fails (300+500=800 >= 700); C2: 300+100=400 < 700 and
        // |OR|+1 = 2 < |LR|*0.7 = 3*... need |LR| >= 5 => use 5 locals.
        let mut s = OffloadScheduler::new(OffloadPolicy::LoadAware, bounds(0.7, 160, 80));
        let meta = meta_with(
            &[(1, 200, 300), (2, 200, 300), (3, 200, 300), (4, 200, 300), (5, 200, 300)],
            &[(10, 300, 400)],
        );
        let req = ReqMeta { used_token: 100, max_token: 500 };
        assert_eq!(s.need_offload(req, &meta), OffloadDecision::C2);
    }

    #[test]
    fn zero_ob_never_offloads() {
        let mut s = OffloadScheduler::new(OffloadPolicy::LoadAware, bounds(0.0, 100, 100));
        let meta = meta_with(&[(1, 1000, 2000)], &[]);
        let req = ReqMeta { used_token: 1, max_token: 2 };
        assert_eq!(s.need_offload(req, &meta), OffloadDecision::Local);
    }

    #[test]
    fn disabled_policy_never_offloads() {
        let mut s = OffloadScheduler::new(OffloadPolicy::Disabled, bounds(1.0, 1000, 10));
        let meta = meta_with(&[(1, 10, 20)], &[]);
        assert_eq!(
            s.need_offload(ReqMeta { used_token: 1, max_token: 2 }, &meta),
            OffloadDecision::Local
        );
    }

    #[test]
    fn fixed_ratio_hits_exact_fraction() {
        let mut s = OffloadScheduler::new(OffloadPolicy::FixedRatio(0.7), bounds(1.0, 100, 10));
        let meta = RuntimeMetadata::new();
        let req = ReqMeta { used_token: 1, max_token: 2 };
        let n = 1000;
        let offl = (0..n)
            .filter(|_| s.need_offload(req, &meta).offloaded())
            .count();
        // f64 quota accumulation: allow one round-off on either side.
        assert!((699..=701).contains(&offl), "offloaded {offl}/1000 at ratio 0.7");
    }

    #[test]
    fn metadata_tracks_tokens_and_removal() {
        let mut m = meta_with(&[(1, 10, 20)], &[(2, 30, 40)]);
        assert_eq!(m.decode_used_tokens(), 10);
        assert_eq!(m.attn_used_tokens(), 30);
        assert_eq!(m.attn_max_tokens(), 40);
        m.on_token(1);
        m.on_token(2);
        assert_eq!(m.decode_used_tokens(), 11);
        assert_eq!(m.attn_used_tokens(), 31);
        assert!(m.is_offloaded(2));
        assert!(!m.is_offloaded(1));
        assert!(m.remove(2));
        assert!(!m.remove(2));
        assert_eq!(m.offloaded_count(), 0);
    }

    #[test]
    fn metadata_migration_moves_between_sets() {
        let mut m = meta_with(&[(1, 10, 20)], &[(2, 30, 40)]);
        assert!(m.set_offloaded(1, true), "local -> offloaded");
        assert!(m.is_offloaded(1));
        assert_eq!(m.attn_used_tokens(), 40);
        assert_eq!(m.decode_used_tokens(), 0);
        // Idempotent on the same side; unknown ids are refused.
        assert!(m.set_offloaded(1, true));
        assert!(m.set_offloaded(2, false));
        assert!(!m.is_offloaded(2));
        assert!(!m.set_offloaded(99, true));
        assert_eq!(m.used_token_of(2), Some(30));
        assert_eq!(m.used_token_of(99), None);
    }

    /// Satellite: RuntimeMetadata's local/offloaded token sums and counts
    /// stay consistent with a reference residency model across random
    /// admit / token / finish(remove) / preempt(remove) / migrate
    /// sequences — the invariant the sim's proxy bookkeeping relies on.
    #[test]
    fn property_metadata_sums_consistent_under_admit_finish_preempt_migrate() {
        crate::util::prop::check("metadata_residency_consistency", 100, |rng| {
            let mut m = RuntimeMetadata::new();
            // Reference model: id -> (used, offloaded).
            let mut reference: Vec<(u64, usize, bool)> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                match rng.range_usize(0, 5) {
                    // Admit (routing decision).
                    0 | 1 => {
                        let used = rng.range_usize(1, 400);
                        let off = rng.range_usize(0, 2) == 1;
                        m.admit(
                            next_id,
                            ReqMeta { used_token: used, max_token: used + rng.range_usize(1, 400) },
                            off,
                        );
                        reference.push((next_id, used, off));
                        next_id += 1;
                    }
                    // One decode token for a random tracked request.
                    2 => {
                        if !reference.is_empty() {
                            let i = rng.range_usize(0, reference.len());
                            reference[i].1 += 1;
                            m.on_token(reference[i].0);
                        }
                    }
                    // Finish or preempt: both remove from the metadata.
                    3 => {
                        if !reference.is_empty() {
                            let i = rng.range_usize(0, reference.len());
                            let (id, _, _) = reference.swap_remove(i);
                            assert!(m.remove(id));
                        }
                    }
                    // Migrate: flip the side.
                    _ => {
                        if !reference.is_empty() {
                            let i = rng.range_usize(0, reference.len());
                            reference[i].2 = !reference[i].2;
                            assert!(m.set_offloaded(reference[i].0, reference[i].2));
                        }
                    }
                }
                // Invariants after every op.
                let local_sum: usize =
                    reference.iter().filter(|r| !r.2).map(|r| r.1).sum();
                let off_sum: usize = reference.iter().filter(|r| r.2).map(|r| r.1).sum();
                let local_n = reference.iter().filter(|r| !r.2).count();
                let off_n = reference.iter().filter(|r| r.2).count();
                assert_eq!(m.decode_used_tokens(), local_sum);
                assert_eq!(m.attn_used_tokens(), off_sum);
                assert_eq!(m.local_count(), local_n);
                assert_eq!(m.offloaded_count(), off_n);
                assert_eq!(m.total_count(), reference.len());
                for &(id, used, off) in &reference {
                    assert_eq!(m.is_offloaded(id), off, "id {id} side");
                    assert_eq!(m.used_token_of(id), Some(used), "id {id} used");
                }
            }
        });
    }

    #[test]
    fn rebalance_controller_schmitt_trigger() {
        let cfg = crate::config::RebalanceConfig {
            interval_s: 0.25,
            hysteresis: 0.25,
            max_migrations_per_interval: 16,
        };
        let mut c = RebalanceController::new(cfg, 2);
        // Starts permissive (idle system should offload).
        assert_eq!(c.mode(0), RebalanceMode::Offload);
        // Inside the band: holds the previous mode.
        assert_eq!(c.assess(0, 0.5), RebalanceMode::Offload);
        assert_eq!(c.assess(0, 0.74), RebalanceMode::Offload);
        // Crossing the high threshold flips to Reclaim...
        assert_eq!(c.assess(0, 0.75), RebalanceMode::Reclaim);
        // ...and stays there anywhere inside the band (hysteresis).
        assert_eq!(c.assess(0, 0.5), RebalanceMode::Reclaim);
        assert_eq!(c.assess(0, 0.26), RebalanceMode::Reclaim);
        // Only dropping to the low threshold releases it.
        assert_eq!(c.assess(0, 0.25), RebalanceMode::Offload);
        // Instances are independent.
        assert_eq!(c.assess(1, 10.0), RebalanceMode::Reclaim);
        assert_eq!(c.mode(0), RebalanceMode::Offload);
        assert_eq!(c.interval_s(), 0.25);
        assert_eq!(c.max_migrations_per_interval(), 16);
    }

    #[test]
    fn strict_variant_is_no_weaker() {
        // Anywhere strict admits C1, the printed variant must too
        // (attn_used <= attn_max).
        let s = OffloadScheduler::new(OffloadPolicy::LoadAware, bounds(0.8, 160, 80));
        let meta = meta_with(&[(1, 900, 1000)], &[(2, 100, 150)]);
        let req = ReqMeta { used_token: 50, max_token: 200 };
        if s.algorithm1_strict(req, &meta) == OffloadDecision::C1 {
            assert_eq!(s.algorithm1(req, &meta), OffloadDecision::C1);
        }
    }
}
