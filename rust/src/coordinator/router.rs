//! Cluster-level router (ISSUE 8): picks which P/D *group* a request
//! lands on, one layer above the per-group [`crate::coordinator::Proxy`]
//! (which keeps routing within its group exactly as before). DistServe
//! (PAPERS.md) is the motivation: cluster goodput is decided by
//! placement above the group proxies, not inside them.

use crate::config::RouterPolicy;
use crate::workload::RequestId;

/// Requests whose ids share a block of this size count as one "session"
/// for [`RouterPolicy::SessionSticky`]. The trace plane has no real
/// session ids, so consecutive-id blocks stand in: a multi-turn user
/// whose requests arrive close together in the trace stays on one
/// group, which is the KV-affinity property the policy models.
pub const SESSION_BLOCK: u64 = 8;

/// Deterministic cluster router. Stateless apart from the round-robin
/// cursor and the decision tally, so fleet runs stay seed-deterministic.
#[derive(Debug, Clone)]
pub struct ClusterRouter {
    policy: RouterPolicy,
    groups: usize,
    rr: usize,
    /// Requests routed to each group (reported as
    /// `FleetReport::router_decisions`).
    pub decisions: Vec<u64>,
}

impl ClusterRouter {
    pub fn new(policy: RouterPolicy, groups: usize) -> Self {
        assert!(groups >= 1, "a fleet needs at least one group");
        ClusterRouter { policy, groups, rr: 0, decisions: vec![0; groups] }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Pick the group for `id`. `headroom[g]` is group g's current
    /// offload/KV headroom in tokens (only consulted by
    /// [`RouterPolicy::LeastLoaded`]; pass anything for the static
    /// policies — they never look).
    pub fn route(&mut self, id: RequestId, headroom: &[f64]) -> usize {
        let g = match self.policy {
            RouterPolicy::RoundRobin => {
                let g = self.rr;
                self.rr = (self.rr + 1) % self.groups;
                g
            }
            RouterPolicy::SessionSticky => {
                (splitmix(id / SESSION_BLOCK) % self.groups as u64) as usize
            }
            RouterPolicy::LeastLoaded => {
                debug_assert_eq!(headroom.len(), self.groups);
                // Argmax headroom; ties break toward the lowest index so
                // the decision is deterministic.
                let mut best = 0usize;
                for (i, &h) in headroom.iter().enumerate().skip(1) {
                    if h > headroom[best] {
                        best = i;
                    }
                }
                best
            }
        };
        self.decisions[g] += 1;
        g
    }
}

/// splitmix64 finalizer — a cheap, well-mixed session hash (seed-free,
/// so routing is reproducible across runs and processes).
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = ClusterRouter::new(RouterPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..7).map(|id| r.route(id, &[])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(r.decisions, vec![3, 2, 2]);
    }

    #[test]
    fn least_loaded_takes_argmax_with_low_index_ties() {
        let mut r = ClusterRouter::new(RouterPolicy::LeastLoaded, 3);
        assert_eq!(r.route(0, &[1.0, 5.0, 2.0]), 1);
        assert_eq!(r.route(1, &[4.0, 4.0, 4.0]), 0, "ties break to the lowest index");
        assert_eq!(r.route(2, &[-1.0, -2.0, 0.0]), 2);
        assert_eq!(r.decisions.iter().sum::<u64>(), 3);
    }

    #[test]
    fn session_sticky_pins_id_blocks() {
        let mut r = ClusterRouter::new(RouterPolicy::SessionSticky, 4);
        // All ids inside one SESSION_BLOCK land on the same group.
        let base = 3 * SESSION_BLOCK;
        let first = r.route(base, &[]);
        for id in base + 1..base + SESSION_BLOCK {
            assert_eq!(r.route(id, &[]), first);
        }
        // Across many sessions every group gets traffic (the hash mixes).
        let mut seen = vec![false; 4];
        for session in 0..64u64 {
            seen[r.route(session * SESSION_BLOCK, &[])] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 sessions must cover 4 groups");
    }

    #[test]
    fn deterministic_across_instances() {
        for policy in [RouterPolicy::RoundRobin, RouterPolicy::SessionSticky] {
            let mut a = ClusterRouter::new(policy, 3);
            let mut b = ClusterRouter::new(policy, 3);
            for id in 0..100 {
                assert_eq!(a.route(id, &[]), b.route(id, &[]));
            }
            assert_eq!(a.decisions, b.decisions);
        }
    }

    #[test]
    #[should_panic]
    fn zero_groups_panics() {
        ClusterRouter::new(RouterPolicy::RoundRobin, 0);
    }
}
