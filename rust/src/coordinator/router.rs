//! Cluster-level router (ISSUE 8): picks which P/D *group* a request
//! lands on, one layer above the per-group [`crate::coordinator::Proxy`]
//! (which keeps routing within its group exactly as before). DistServe
//! (PAPERS.md) is the motivation: cluster goodput is decided by
//! placement above the group proxies, not inside them.

use crate::config::RouterPolicy;
use crate::workload::RequestId;

/// Requests whose ids share a block of this size count as one "session"
/// for [`RouterPolicy::SessionSticky`]. The trace plane has no real
/// session ids, so consecutive-id blocks stand in: a multi-turn user
/// whose requests arrive close together in the trace stays on one
/// group, which is the KV-affinity property the policy models.
pub const SESSION_BLOCK: u64 = 8;

/// Deterministic cluster router. Stateless apart from the round-robin
/// cursor and the decision tally, so fleet runs stay seed-deterministic.
#[derive(Debug, Clone)]
pub struct ClusterRouter {
    policy: RouterPolicy,
    groups: usize,
    rr: usize,
    /// Requests routed to each group (reported as
    /// `FleetReport::router_decisions`).
    pub decisions: Vec<u64>,
    /// Health-driven diversions (ISSUE 10): arrivals whose nominal
    /// policy pick was masked as down and landed elsewhere.
    pub reroutes: u64,
}

impl ClusterRouter {
    pub fn new(policy: RouterPolicy, groups: usize) -> Self {
        assert!(groups >= 1, "a fleet needs at least one group");
        ClusterRouter { policy, groups, rr: 0, decisions: vec![0; groups], reroutes: 0 }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Pick the group for `id`. `headroom[g]` is group g's current
    /// offload/KV headroom in tokens (only consulted by
    /// [`RouterPolicy::LeastLoaded`]; pass anything for the static
    /// policies — they never look).
    pub fn route(&mut self, id: RequestId, headroom: &[f64]) -> usize {
        let g = match self.policy {
            RouterPolicy::RoundRobin => {
                let g = self.rr;
                self.rr = (self.rr + 1) % self.groups;
                g
            }
            RouterPolicy::SessionSticky => {
                (splitmix(id / SESSION_BLOCK) % self.groups as u64) as usize
            }
            RouterPolicy::LeastLoaded => {
                debug_assert_eq!(headroom.len(), self.groups);
                Self::argmax(headroom)
            }
        };
        self.decisions[g] += 1;
        g
    }

    /// Health-aware variant of [`ClusterRouter::route`] (ISSUE 10):
    /// `up[g]` marks groups that can currently accept work, and arrivals
    /// whose nominal pick is masked divert —
    ///
    /// * round-robin takes the next up group in cyclic order (the cursor
    ///   still lands one past the chosen group, so with every group up
    ///   this is exactly `route`);
    /// * session-sticky falls back to the up group with the most
    ///   headroom (the session's affinity is already lost either way);
    /// * least-loaded takes its argmax over up groups only.
    ///
    /// With *no* group up, falls back to the health-blind `route` pick:
    /// the caller decides whether that arrival queues against a future
    /// recovery or is shed by admission control.
    pub fn route_masked(&mut self, id: RequestId, headroom: &[f64], up: &[bool]) -> usize {
        debug_assert_eq!(up.len(), self.groups);
        if up.iter().all(|&u| !u) {
            return self.route(id, headroom);
        }
        let (g, diverted) = match self.policy {
            RouterPolicy::RoundRobin => {
                let nominal = self.rr;
                let mut g = nominal;
                while !up[g] {
                    g = (g + 1) % self.groups;
                }
                self.rr = (g + 1) % self.groups;
                (g, g != nominal)
            }
            RouterPolicy::SessionSticky => {
                let nominal = (splitmix(id / SESSION_BLOCK) % self.groups as u64) as usize;
                if up[nominal] {
                    (nominal, false)
                } else {
                    (Self::argmax_up(headroom, up), true)
                }
            }
            RouterPolicy::LeastLoaded => {
                debug_assert_eq!(headroom.len(), self.groups);
                let nominal = Self::argmax(headroom);
                if up[nominal] {
                    (nominal, false)
                } else {
                    (Self::argmax_up(headroom, up), true)
                }
            }
        };
        self.decisions[g] += 1;
        self.reroutes += u64::from(diverted);
        g
    }

    /// Argmax headroom; ties break toward the lowest index so the
    /// decision is deterministic.
    fn argmax(headroom: &[f64]) -> usize {
        let mut best = 0usize;
        for (i, &h) in headroom.iter().enumerate().skip(1) {
            if h > headroom[best] {
                best = i;
            }
        }
        best
    }

    /// Argmax headroom over up groups only (low-index ties). The caller
    /// guarantees at least one up group.
    fn argmax_up(headroom: &[f64], up: &[bool]) -> usize {
        let mut best: Option<usize> = None;
        for (i, &h) in headroom.iter().enumerate() {
            if !up[i] {
                continue;
            }
            match best {
                Some(b) if h <= headroom[b] => {}
                _ => best = Some(i),
            }
        }
        best.expect("route_masked checked for at least one up group")
    }
}

/// splitmix64 finalizer — a cheap, well-mixed session hash (seed-free,
/// so routing is reproducible across runs and processes).
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = ClusterRouter::new(RouterPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..7).map(|id| r.route(id, &[])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(r.decisions, vec![3, 2, 2]);
    }

    #[test]
    fn least_loaded_takes_argmax_with_low_index_ties() {
        let mut r = ClusterRouter::new(RouterPolicy::LeastLoaded, 3);
        assert_eq!(r.route(0, &[1.0, 5.0, 2.0]), 1);
        assert_eq!(r.route(1, &[4.0, 4.0, 4.0]), 0, "ties break to the lowest index");
        assert_eq!(r.route(2, &[-1.0, -2.0, 0.0]), 2);
        assert_eq!(r.decisions.iter().sum::<u64>(), 3);
    }

    #[test]
    fn session_sticky_pins_id_blocks() {
        let mut r = ClusterRouter::new(RouterPolicy::SessionSticky, 4);
        // All ids inside one SESSION_BLOCK land on the same group.
        let base = 3 * SESSION_BLOCK;
        let first = r.route(base, &[]);
        for id in base + 1..base + SESSION_BLOCK {
            assert_eq!(r.route(id, &[]), first);
        }
        // Across many sessions every group gets traffic (the hash mixes).
        let mut seen = vec![false; 4];
        for session in 0..64u64 {
            seen[r.route(session * SESSION_BLOCK, &[])] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 sessions must cover 4 groups");
    }

    #[test]
    fn deterministic_across_instances() {
        for policy in [RouterPolicy::RoundRobin, RouterPolicy::SessionSticky] {
            let mut a = ClusterRouter::new(policy, 3);
            let mut b = ClusterRouter::new(policy, 3);
            for id in 0..100 {
                assert_eq!(a.route(id, &[]), b.route(id, &[]));
            }
            assert_eq!(a.decisions, b.decisions);
        }
    }

    #[test]
    #[should_panic]
    fn zero_groups_panics() {
        ClusterRouter::new(RouterPolicy::RoundRobin, 0);
    }

    #[test]
    fn masked_route_with_all_up_equals_route() {
        for policy in
            [RouterPolicy::RoundRobin, RouterPolicy::SessionSticky, RouterPolicy::LeastLoaded]
        {
            let mut plain = ClusterRouter::new(policy, 3);
            let mut masked = ClusterRouter::new(policy, 3);
            let up = [true, true, true];
            for id in 0..50 {
                let h = [(id % 5) as f64, (id % 3) as f64, (id % 7) as f64];
                assert_eq!(plain.route(id, &h), masked.route_masked(id, &h, &up));
            }
            assert_eq!(plain.decisions, masked.decisions);
            assert_eq!(masked.reroutes, 0, "no divert when every group is up");
        }
    }

    #[test]
    fn masked_round_robin_skips_down_groups_and_keeps_cycling() {
        let mut r = ClusterRouter::new(RouterPolicy::RoundRobin, 3);
        let up = [true, false, true];
        let picks: Vec<usize> =
            (0..6).map(|id| r.route_masked(id, &[], &up)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2, 0, 2], "down group 1 is skipped in cycle order");
        assert_eq!(r.reroutes, 3, "every landing that displaced the cursor off 1 counts");
        // Group 1 recovers: the cycle includes it again.
        let picks: Vec<usize> =
            (0..3).map(|id| r.route_masked(id, &[], &[true; 3])).collect();
        assert_eq!(picks, vec![0, 1, 2]);
    }

    #[test]
    fn masked_session_sticky_diverts_to_best_headroom() {
        let mut r = ClusterRouter::new(RouterPolicy::SessionSticky, 4);
        // Find a session that nominally lands on some group n, then mask n.
        let id = 5 * SESSION_BLOCK;
        let nominal = {
            let mut probe = ClusterRouter::new(RouterPolicy::SessionSticky, 4);
            probe.route(id, &[])
        };
        let mut up = [true; 4];
        up[nominal] = false;
        let mut h = [1.0; 4];
        let expect = (nominal + 1) % 4;
        h[expect] = 9.0;
        assert_eq!(r.route_masked(id, &h, &up), expect, "divert to max-headroom up group");
        assert_eq!(r.reroutes, 1);
        // Sticky ids on an up group never divert.
        up[nominal] = true;
        assert_eq!(r.route_masked(id, &h, &up), nominal);
        assert_eq!(r.reroutes, 1);
    }

    #[test]
    fn masked_least_loaded_takes_argmax_over_up_groups() {
        let mut r = ClusterRouter::new(RouterPolicy::LeastLoaded, 3);
        // The global argmax is down: take the best up group instead.
        assert_eq!(r.route_masked(0, &[1.0, 9.0, 2.0], &[true, false, true]), 2);
        assert_eq!(r.reroutes, 1);
        // Ties among up groups break to the lowest index.
        assert_eq!(r.route_masked(1, &[4.0, 9.0, 4.0], &[true, false, true]), 0);
    }

    #[test]
    fn masked_route_with_no_up_group_falls_back_to_blind_pick() {
        let mut r = ClusterRouter::new(RouterPolicy::RoundRobin, 2);
        let down = [false, false];
        assert_eq!(r.route_masked(0, &[], &down), 0);
        assert_eq!(r.route_masked(1, &[], &down), 1, "blind fallback still cycles");
        assert_eq!(r.reroutes, 0, "the fallback is not a divert — nothing was up");
    }
}
