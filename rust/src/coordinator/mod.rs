//! The paper's system contribution: routing, load-aware offload
//! scheduling (Algorithm 1), batching, and the 2-D executable-bucket
//! cache. Populated incrementally; see DESIGN.md §3 (S12, S16).

pub mod bounds;
pub mod graph_cache;
pub mod proxy;
pub mod router;
pub mod scheduler;

pub use bounds::OffloadBounds;
pub use graph_cache::{BucketPair, GraphCache, GraphCacheStats};
pub use proxy::{Proxy, RouteDecision};
pub use router::ClusterRouter;
pub use scheduler::{OffloadScheduler, RebalanceController, RebalanceMode, RuntimeMetadata};
