//! Offloading-ratio upper bounds — the paper's Eqs (1)–(3).
//!
//! * `OB_mem(n)` (Eq 1): how much attention the prefill side can absorb,
//!   limited by the HBM capacity and bandwidth its attention executors can
//!   dedicate, relative to the decode instance's.
//! * `OB_comp(B_max)` (Eq 2): how much the decode batch can grow before the
//!   *non-attention* kernels leave the memory-bound regime and start
//!   charging extra time per extra request.
//! * `OB` (Eq 3): the min of the two.

use crate::config::{ClusterSpec, ModelSpec, SloConfig};
use crate::gpu_model::{DecodeKernelTimes, HbmUsage, InterferenceModel, Roofline};

/// The computed offload bounds for one decode instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OffloadBounds {
    /// Eq 1: memory-side bound on offloaded/local attention ratio.
    pub ob_mem: f64,
    /// Eq 2 numerator input: largest decode batch for which non-attention
    /// kernels stay (approximately) memory-bound.
    pub b_max: usize,
    /// Largest batch meeting the TPOT SLO without offloading (B_TPOT).
    /// Tracked from runtime metadata; seeded from the model here.
    pub b_tpot: usize,
    /// Drift-free rescaling reference: `ob_mem` as it was when the prefill
    /// pool had `n_ref` instances. Captured on the first resize so every
    /// later resize recomputes `ob_mem` from one multiply instead of
    /// compounding per-resize f64 rounding.
    ob_mem_ref: f64,
    /// Reference prefill-instance count for `ob_mem_ref` (0 = no resize
    /// has happened yet).
    n_ref: f64,
}

impl OffloadBounds {
    /// Bounds from already-derived quantities (tests, overrides). The
    /// rescaling reference anchors on the first `rescale_ob_mem` call.
    pub fn new(ob_mem: f64, b_max: usize, b_tpot: usize) -> Self {
        OffloadBounds { ob_mem, b_max, b_tpot, ob_mem_ref: ob_mem, n_ref: 0.0 }
    }

    /// Offline-profiling stage: derive all three quantities from the GPU
    /// model (the paper uses kernel profilers; we use the roofline).
    ///
    /// `avg_seq` is the expected per-request context length (workload
    /// statistic), used to translate batch sizes into attention traffic.
    pub fn compute(
        cluster: &ClusterSpec,
        model: &ModelSpec,
        slo: &SloConfig,
        avg_seq: u64,
    ) -> OffloadBounds {
        OffloadBounds::new(
            Self::ob_mem(cluster, model),
            Self::b_max(cluster, model, slo),
            Self::b_tpot(cluster, model, slo, avg_seq),
        )
    }

    /// Eq 1. `HBM_pi`: capacity each prefill-side attention executor can
    /// lend (colocated: the prefill GPU's usable HBM minus weights /
    /// workspace; standalone executor device: its whole usable HBM — a
    /// pure attention store holds no weights). `BW_pi`: the bandwidth the
    /// executor sustains (colocated: its SM share's cap on the prefill
    /// GPU; standalone: its own device's achievable bandwidth).
    /// Denominators are the *decode device's* KV capacity and attention
    /// bandwidth — each side now priced on its own profile.
    pub fn ob_mem(cluster: &ClusterSpec, model: &ModelSpec) -> f64 {
        let n = cluster.prefill_per_decode();
        let pre = cluster.prefill_profile();
        let dec = cluster.decode_profile();

        let dec_spare = cluster.usable_hbm_of(&dec.gpu)
            - model.weight_bytes()
            - HbmUsage::activation_workspace(model);
        let hbm_d = dec_spare.max(0.0);
        let bw_d = dec.gpu.hbm_bw * dec.gpu.bw_eff; // decode attention gets its whole device

        let (hbm_pi, bw_pi) = if cluster.executor_is_colocated() {
            let spare = cluster.usable_hbm_of(&pre.gpu)
                - model.weight_bytes()
                - HbmUsage::activation_workspace(model);
            let interf = InterferenceModel::new(cluster.attn_executor_sm_frac);
            (spare.max(0.0), pre.gpu.hbm_bw * interf.attn_bw_cap(pre.gpu.bw_eff))
        } else {
            let exec = cluster.executor_profile();
            (cluster.usable_hbm_of(&exec.gpu), Roofline::for_profile(&exec).effective_bw())
        };

        let mem_ratio = n * hbm_pi / hbm_d;
        let bw_ratio = n * bw_pi / bw_d;
        mem_ratio.min(bw_ratio)
    }

    /// Largest batch for which growing the decode batch does not push the
    /// *non-attention* kernels past their share of the TPOT budget (Eq 2's
    /// B_max).
    ///
    /// Calibration note: a literal "first detectable increase over the
    /// memory-bound floor" is stricter than the paper's own deployment —
    /// Fig 17b reports the non-attention kernels absorbing +8.8 % compute
    /// at 40 % offload and +44.7 % at 80 % while TPOT still improves, i.e.
    /// the system tolerates non-attention growth as long as the step stays
    /// within the TPOT budget. We therefore take B_max as the largest
    /// batch whose non-attention time fits `NON_ATTN_TPOT_SHARE` of the
    /// TPOT SLO (attention gets the rest; it is the larger term at real
    /// context lengths — Fig 3), floored by the memory-bound inflection.
    const NON_ATTN_TPOT_SHARE: f64 = 0.5;

    pub fn b_max(cluster: &ClusterSpec, model: &ModelSpec, slo: &SloConfig) -> usize {
        let rl = Roofline::for_profile(&cluster.decode_profile());
        let floor = DecodeKernelTimes::compute(&rl, model, 1, 1).non_attention();
        let budget = (slo.tpot_s * Self::NON_ATTN_TPOT_SHARE).max(floor * 1.25);
        let fits = |b: usize| {
            DecodeKernelTimes::compute(&rl, model, b as u64, b as u64).non_attention() <= budget
        };
        if !fits(1) {
            return 1;
        }
        let mut b = 1usize;
        while b < 4096 && fits((b * 2).min(4096)) {
            b = (b * 2).min(4096);
        }
        if b >= 4096 {
            return 4096;
        }
        let (mut lo, mut hi) = (b, b * 2);
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if fits(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Largest batch the decode instance can handle *without offloading*:
    /// the smaller of the SLO-derived batch (decode step time ≤ TPOT) and
    /// the HBM-derived batch (KV for the whole batch fits the decode
    /// pool). The HBM cap is what makes vLLM's throughput plateau in
    /// Fig 11d. Refreshed online by the proxy as load shifts.
    pub fn b_tpot(
        cluster: &ClusterSpec,
        model: &ModelSpec,
        slo: &SloConfig,
        avg_seq: u64,
    ) -> usize {
        let dec = cluster.decode_profile();
        let kv_budget = HbmUsage::kv_token_budget_in(cluster.usable_hbm_of(&dec.gpu), model);
        let hbm_cap = (kv_budget / avg_seq.max(1)).max(1) as usize;
        let rl = Roofline::for_profile(&dec);
        let mut best = 0usize;
        let mut b = 1usize;
        while b <= 4096 {
            let t = DecodeKernelTimes::compute(&rl, model, b as u64, b as u64 * avg_seq)
                .total();
            if t <= slo.tpot_s {
                best = b;
                b *= 2;
            } else {
                break;
            }
        }
        if best == 0 {
            return 1; // SLO unreachable even at b=1; decode still runs
        }
        if best >= 4096 {
            return hbm_cap.min(4096);
        }
        // Refine between best and 2*best.
        let (mut lo, mut hi) = (best, (best * 2).min(4096));
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            let t = DecodeKernelTimes::compute(&rl, model, mid as u64, mid as u64 * avg_seq)
                .total();
            if t <= slo.tpot_s {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo.min(hbm_cap)
    }

    /// Eq 2: OB_comp = (B_max − B_TPOT) / B_TPOT.
    pub fn ob_comp(&self) -> f64 {
        if self.b_tpot == 0 {
            return 0.0;
        }
        ((self.b_max.saturating_sub(self.b_tpot)) as f64 / self.b_tpot as f64).max(0.0)
    }

    /// Eq 3: OB = min(OB_mem, OB_comp).
    pub fn ob(&self) -> f64 {
        self.ob_mem.min(self.ob_comp())
    }

    /// Refresh B_TPOT from runtime observation (the proxy calls this as
    /// load shifts; OB_comp and OB move with it).
    pub fn set_b_tpot(&mut self, b_tpot: usize) {
        self.b_tpot = b_tpot.max(1);
    }

    /// Refresh OB_mem when prefill instances are added/removed (§3.4.2).
    ///
    /// Eq 1 is linear in n, so the new value is recomputed exactly from a
    /// reference pair `(n_ref, ob_mem_ref)` captured on the first resize —
    /// repeated resizes used to compound `ob_mem *= new/old` multiplies,
    /// drifting a few ULPs per round trip. Returning to the reference
    /// count now restores `ob_mem` bit-exactly (`x * 1.0 == x`).
    pub fn rescale_ob_mem(&mut self, old_n: f64, new_n: f64) {
        if old_n <= 0.0 || new_n <= 0.0 {
            return;
        }
        if self.n_ref <= 0.0 {
            self.n_ref = old_n;
            self.ob_mem_ref = self.ob_mem;
        }
        self.ob_mem = self.ob_mem_ref * (new_n / self.n_ref);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, ModelSpec, SloConfig};

    fn setup() -> (ClusterSpec, ModelSpec, SloConfig) {
        (ClusterSpec::paper_default(), ModelSpec::llama2_7b(), SloConfig::default())
    }

    #[test]
    fn ob_mem_positive_and_bw_limited() {
        let (c, m, _) = setup();
        let ob = OffloadBounds::ob_mem(&c, &m);
        assert!(ob > 0.0);
        // With equal capacity budgets, the binding term is bandwidth:
        // executor bw cap (bw_frac(0.5)·0.83 ≈ 0.67 peak) over decode's
        // 0.83 peak ≈ 0.8.
        assert!((0.6..1.0).contains(&ob), "ob_mem = {ob}");
    }

    #[test]
    fn ob_mem_scales_with_prefill_instances() {
        let (mut c, m, _) = setup();
        let ob1 = OffloadBounds::ob_mem(&c, &m);
        c.n_prefill = 2;
        let ob2 = OffloadBounds::ob_mem(&c, &m);
        assert!((ob2 / ob1 - 2.0).abs() < 1e-9, "Eq 1 is linear in n");
    }

    #[test]
    fn explicit_homogeneous_profiles_do_not_move_the_bounds() {
        use crate::config::{DeviceProfile, DeviceProfiles, DeviceRole, GpuSpec};
        let (c, m, slo) = setup();
        let base = OffloadBounds::compute(&c, &m, &slo, 1024);
        let mut with = c;
        with.profiles = Some(DeviceProfiles {
            prefill: Some(DeviceProfile::whole(GpuSpec::a100_80g(), DeviceRole::Prefill)),
            decode: Some(DeviceProfile::whole(GpuSpec::a100_80g(), DeviceRole::Decode)),
            executor: None,
        });
        assert_eq!(OffloadBounds::compute(&with, &m, &slo, 1024), base);
        with.profiles = Some(DeviceProfiles::default());
        assert_eq!(OffloadBounds::compute(&with, &m, &slo, 1024), base);
    }

    #[test]
    fn standalone_memory_rich_executor_raises_ob_mem() {
        use crate::config::{DeviceProfile, DeviceProfiles, DeviceRole, GpuSpec};
        let (c, m, _) = setup();
        let colocated = OffloadBounds::ob_mem(&c, &m);
        let mut hetero = c;
        hetero.profiles = Some(DeviceProfiles {
            prefill: None,
            decode: None,
            executor: Some(DeviceProfile::whole(GpuSpec::h20_96g(), DeviceRole::Executor)),
        });
        let standalone = OffloadBounds::ob_mem(&hetero, &m);
        // A whole memory-rich device holds more KV (no weights resident)
        // and sustains more bandwidth than the colocated SM share, so the
        // Eq 1 bound must strictly grow (arXiv 2405.01814's premise).
        assert!(
            standalone > colocated,
            "standalone = {standalone}, colocated = {colocated}"
        );
    }

    #[test]
    fn b_max_in_plausible_range() {
        let (c, m, _) = setup();
        let b_max = OffloadBounds::b_max(&c, &m, &SloConfig::default());
        // 7B on A100: non-attention kernels stay memory-bound into the
        // hundreds of requests.
        assert!(b_max >= 64, "b_max = {b_max}");
        assert!(b_max <= 4096);
    }

    #[test]
    fn b_tpot_decreases_with_context() {
        let (c, m, slo) = setup();
        let short = OffloadBounds::b_tpot(&c, &m, &slo, 256);
        let long = OffloadBounds::b_tpot(&c, &m, &slo, 2048);
        assert!(short >= long, "short={short} long={long}");
        assert!(long >= 1);
    }

    #[test]
    fn ob_is_min_of_both() {
        let (c, m, slo) = setup();
        let b = OffloadBounds::compute(&c, &m, &slo, 1024);
        assert!(b.ob() <= b.ob_mem + 1e-12);
        assert!(b.ob() <= b.ob_comp() + 1e-12);
        assert!(b.ob() >= 0.0);
    }

    #[test]
    fn ob_comp_zero_when_tpot_at_bmax() {
        let (c, m, slo) = setup();
        let mut b = OffloadBounds::compute(&c, &m, &slo, 1024);
        b.set_b_tpot(b.b_max);
        assert_eq!(b.ob_comp(), 0.0);
        // And OB collapses to 0: no headroom -> no offloading benefit.
        assert_eq!(b.ob(), 0.0);
    }

    #[test]
    fn rescale_tracks_instance_changes() {
        let (c, m, slo) = setup();
        let mut b = OffloadBounds::compute(&c, &m, &slo, 1024);
        let before = b.ob_mem;
        b.rescale_ob_mem(1.0, 3.0);
        assert!((b.ob_mem / before - 3.0).abs() < 1e-9);
    }

    /// Satellite (ISSUE 4): any chain of resizes that returns to the
    /// starting instance count restores `ob_mem` bit-exactly — the old
    /// `ob_mem *= new/old` compounding drifted a few ULPs per round trip.
    #[test]
    fn property_rescale_round_trip_is_bit_exact() {
        crate::util::prop::check("rescale_ob_mem_drift_free", 200, |rng| {
            let mut b = OffloadBounds::new(
                rng.f64(),
                100 + rng.range_usize(0, 1000),
                1 + rng.range_usize(0, 99),
            );
            let original = b.ob_mem.to_bits();
            let n0 = 1.0 + rng.range_usize(0, 7) as f64;
            let mut cur = n0;
            for _ in 0..rng.range_usize(1, 40) {
                let next = 1.0 + rng.range_usize(0, 7) as f64;
                b.rescale_ob_mem(cur, next);
                cur = next;
            }
            b.rescale_ob_mem(cur, n0);
            assert_eq!(
                b.ob_mem.to_bits(),
                original,
                "returning to n={n0} must restore ob_mem bit-exactly"
            );
        });
    }

    /// Feedback-plane invariant (ISSUE 4): whatever B_TPOT the online
    /// estimator feeds back, `0 <= ob() <= ob_mem`, and growing the
    /// observed batch (B_TPOT up) never grows the offload bound.
    #[test]
    fn property_online_b_tpot_keeps_ob_bounded_and_monotone() {
        crate::util::prop::check("ob_bounded_monotone", 200, |rng| {
            let mut b = OffloadBounds::new(rng.f64(), 1 + rng.range_usize(0, 4096), 1);
            let mut prev_ob = f64::INFINITY;
            let mut bt = 1usize;
            for _ in 0..20 {
                bt += rng.range_usize(0, 64);
                b.set_b_tpot(bt);
                let ob = b.ob();
                assert!(ob >= 0.0, "ob() went negative: {ob}");
                assert!(ob <= b.ob_mem + 1e-12, "ob {} above ob_mem {}", ob, b.ob_mem);
                assert!(
                    ob <= prev_ob + 1e-12,
                    "larger observed B_TPOT must not grow OB: {ob} after {prev_ob}"
                );
                prev_ob = ob;
            }
        });
    }
}
