//! End-to-end sweep harness: the request-rate sweeps behind Figs 11–14
//! and the offload-ratio sweep behind Figs 15/17.
//!
//! Sweep points are independent, seed-deterministic simulations, so one
//! driver serves both execution strategies: [`run_e2e_with`] /
//! [`run_ratio_sweep_with`] take an [`ExecMode`] and produce
//! **bit-identical** output whether points fan out across all cores
//! (`ExecMode::Parallel`, the default) or run inline
//! (`ExecMode::Serial`, the equivalence-test reference). Set
//! `ADRENALINE_SERIAL=1` to force every
//! [`parallel_map`] serial process-wide (resolved once, through
//! [`engine_env`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};

use crate::config::{ModelSpec, OffloadPolicy};
use crate::workload::WorkloadKind;

use super::cluster::{ClusterSim, SimConfig, SimReport};
use super::engine_mode::engine_env;

/// Process-wide parallelism settings, resolved exactly once. Hot sweep
/// loops call [`parallel_map`] per point; re-reading `ADRENALINE_SERIAL`
/// and re-issuing the `available_parallelism` syscall on every call is
/// waste, and the answers cannot change mid-process anyway.
#[derive(Debug)]
pub struct ParallelismConfig {
    /// `ADRENALINE_SERIAL=1`: force every [`parallel_map`] serial.
    pub serial: bool,
    /// Detected hardware thread count (≥ 1).
    pub hw_threads: usize,
}

/// The once-initialized [`ParallelismConfig`]. The serial switch comes
/// from the engine-mode env snapshot ([`engine_env`]) — the single
/// `ADRENALINE_*` read site.
pub fn par_config() -> &'static ParallelismConfig {
    static CONFIG: OnceLock<ParallelismConfig> = OnceLock::new();
    CONFIG.get_or_init(|| ParallelismConfig {
        serial: engine_env().serial,
        hw_threads: std::thread::available_parallelism().map_or(1, |p| p.get()),
    })
}

/// Process-wide thread-budget permits, seeded with the hardware thread
/// count. Every layer that spawns workers — across-run [`parallel_map`]
/// and the within-run [`WorkerPool`] — draws from this one pool, so
/// nested fan-out (figure groups → sweeps → per-run epoch workers)
/// degrades each inner level toward serial instead of oversubscribing
/// the host with groups × sweeps × instances threads.
fn thread_permits() -> &'static AtomicUsize {
    static PERMITS: OnceLock<AtomicUsize> = OnceLock::new();
    PERMITS.get_or_init(|| AtomicUsize::new(par_config().hw_threads))
}

/// Take up to `want` permits from the process-wide thread budget and
/// return how many were actually granted (possibly 0). Never blocks.
pub fn budget_acquire(want: usize) -> usize {
    if want == 0 {
        return 0;
    }
    let permits = thread_permits();
    let mut cur = permits.load(Ordering::Relaxed);
    loop {
        let take = want.min(cur);
        if take == 0 {
            return 0;
        }
        match permits.compare_exchange(cur, cur - take, Ordering::AcqRel, Ordering::Relaxed) {
            Ok(_) => return take,
            Err(now) => cur = now,
        }
    }
}

/// Return `n` permits taken by [`budget_acquire`].
pub fn budget_release(n: usize) {
    if n > 0 {
        thread_permits().fetch_add(n, Ordering::AcqRel);
    }
}

/// Deterministic parallel map: computes `f(0)..f(n-1)` on a pool of
/// worker threads and returns the results in index order. Each index is
/// claimed exactly once off an atomic counter, so results depend only on
/// `f`, never on scheduling. Falls back to serial for trivial inputs,
/// single-core machines, an exhausted thread budget, or
/// `ADRENALINE_SERIAL=1`.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_capped(n, usize::MAX, f)
}

/// [`parallel_map`] with an explicit worker cap. Worker threads are drawn
/// from the process-wide budget ([`budget_acquire`]), so callers whose
/// work items fan out *again* internally (e.g. the `figures` binary runs
/// figure groups that each drive parallel sweeps, whose sims may spawn
/// within-run epoch workers) compose without oversubscription: inner
/// levels see whatever permits the outer levels left and otherwise run
/// serial. The explicit cap remains for callers that want *less* than
/// their budget share.
pub fn parallel_map_capped<T, F>(n: usize, max_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let pc = par_config();
    let want = pc.hw_threads.min(n).min(max_threads.max(1));
    if pc.serial || want <= 1 {
        return (0..n).map(f).collect();
    }
    let threads = budget_acquire(want);
    if threads <= 1 {
        // A single extra worker plus an idle collector is no faster than
        // the caller doing the work itself; give the permit back.
        budget_release(threads);
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = f(i);
                if tx.send((i, result)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, result) in rx {
            out[i] = Some(result);
        }
    });
    budget_release(threads);
    out.into_iter()
        .map(|r| r.expect("every sweep point completes exactly once"))
        .collect()
}

type PoolJob = Box<dyn FnOnce() + Send>;

/// One unit of work for [`WorkerPool::run_batch`]: an owned closure whose
/// result is routed back to the submitting thread in input order.
pub type PoolTask<T> = Box<dyn FnOnce() -> T + Send>;

/// A persistent pool of worker threads for within-run parallelism
/// (`ClusterSim` epoch pricing). Threads are spawned once per pool — a
/// per-epoch dispatch costs two channel sends per task, not a thread
/// spawn — and are drawn from the same process-wide permits as
/// [`parallel_map`], so sweeps already running one sim per core hand
/// their sims zero-worker pools (pure inline execution) instead of
/// oversubscribing. A zero-worker pool is fully functional:
/// [`WorkerPool::run_batch`] just runs every task on the calling thread,
/// which is also the `ADRENALINE_NO_PAR=1` serial-reference path.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<PoolJob>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    permits: usize,
}

impl WorkerPool {
    /// Spawn up to `want` persistent workers, bounded by the process-wide
    /// thread budget. May legitimately return a pool with zero workers.
    pub fn new(want: usize) -> WorkerPool {
        let permits = budget_acquire(want);
        let (tx, rx) = mpsc::channel::<PoolJob>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(permits);
        for _ in 0..permits {
            let rx = Arc::clone(&rx);
            handles.push(std::thread::spawn(move || loop {
                let job = match rx.lock() {
                    Ok(guard) => guard.recv(),
                    Err(_) => break,
                };
                match job {
                    Ok(job) => job(),
                    Err(_) => break,
                }
            }));
        }
        WorkerPool { tx: Some(tx), handles, permits }
    }

    /// Number of live worker threads (0 ⇒ `run_batch` is inline).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run every task and return the results in task order. The calling
    /// thread participates instead of idling, so a batch of `n` tasks on
    /// a pool of `w` workers runs at concurrency `min(n, w + 1)`. Task
    /// results must not depend on scheduling — callers get them back in
    /// input order regardless of which thread ran what.
    pub fn run_batch<T: Send + 'static>(&self, tasks: Vec<PoolTask<T>>) -> Vec<T> {
        let n = tasks.len();
        if self.workers() == 0 || n <= 1 {
            return tasks.into_iter().map(|t| t()).collect();
        }
        let slots: Arc<Vec<Mutex<Option<PoolTask<T>>>>> =
            Arc::new(tasks.into_iter().map(|t| Mutex::new(Some(t))).collect());
        let next = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        // One drain job per worker; each claims task indices off the shared
        // counter until the batch is exhausted, then returns the worker to
        // the pool's job queue.
        for _ in 0..self.workers().min(n - 1) {
            let slots = Arc::clone(&slots);
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let job: PoolJob = Box::new(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= slots.len() {
                    break;
                }
                let task = slots[i].lock().ok().and_then(|mut slot| slot.take());
                if let Some(task) = task {
                    if tx.send((i, task())).is_err() {
                        break;
                    }
                }
            });
            self.tx
                .as_ref()
                .expect("pool sender lives until drop")
                .send(job)
                .expect("pool workers outlive the pool handle");
        }
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= slots.len() {
                break;
            }
            let task = slots[i].lock().ok().and_then(|mut slot| slot.take());
            if let Some(task) = task {
                let _ = tx.send((i, task()));
            }
        }
        drop(tx);
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        for (i, result) in rx {
            out[i] = Some(result);
        }
        out.into_iter()
            .map(|r| r.expect("every epoch task completes exactly once"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
        budget_release(self.permits);
    }
}

/// One figure panel's configuration.
#[derive(Debug, Clone)]
pub struct E2eConfig {
    pub model: ModelSpec,
    pub workload: WorkloadKind,
    pub rates: Vec<f64>,
    pub duration_s: f64,
    pub seed: u64,
}

impl E2eConfig {
    /// Fig 11: ShareGPT + Llama-2 7B.
    pub fn fig11() -> Self {
        E2eConfig {
            model: ModelSpec::llama2_7b(),
            workload: WorkloadKind::ShareGpt,
            rates: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            duration_s: 240.0,
            seed: 42,
        }
    }

    /// Fig 12: ShareGPT + Llama-2 13B.
    pub fn fig12() -> Self {
        E2eConfig { model: ModelSpec::llama2_13b(), ..Self::fig11() }
    }

    /// Fig 13: OpenThoughts + Llama-2 7B (longer outputs, lower rates).
    pub fn fig13() -> Self {
        E2eConfig {
            model: ModelSpec::llama2_7b(),
            workload: WorkloadKind::OpenThoughts,
            rates: vec![0.5, 1.0, 1.5, 2.0, 2.5],
            duration_s: 240.0,
            seed: 42,
        }
    }

    /// Fig 14: OpenThoughts + Llama-2 13B.
    pub fn fig14() -> Self {
        E2eConfig { model: ModelSpec::llama2_13b(), ..Self::fig13() }
    }
}

/// One point of an E2E sweep (one system at one rate).
#[derive(Debug, Clone)]
pub struct E2ePoint {
    pub rate: f64,
    pub system: &'static str,
    pub ttft_mean_s: f64,
    pub tpot_mean_s: f64,
    pub tpot_p99_s: f64,
    pub throughput_tok_s: f64,
    pub finished: usize,
    pub preemptions: u64,
    pub offloaded_fraction: f64,
    /// Fraction of charged batch slots wasted to executable-bucket
    /// padding at this point (0 under `ADRENALINE_EXACT_COSTS=1`).
    pub graph_padding_overhead: f64,
}

impl E2ePoint {
    pub fn from_report(rate: f64, system: &'static str, r: &SimReport) -> Self {
        E2ePoint {
            rate,
            system,
            ttft_mean_s: r.ttft.map(|s| s.mean).unwrap_or(f64::NAN),
            tpot_mean_s: r.tpot.map(|s| s.mean).unwrap_or(f64::NAN),
            tpot_p99_s: r.tpot.map(|s| s.p99).unwrap_or(f64::NAN),
            throughput_tok_s: r.throughput,
            finished: r.finished,
            preemptions: r.preemptions,
            offloaded_fraction: r.offloaded_fraction,
            graph_padding_overhead: r.graph_padding_overhead,
        }
    }
}

/// Build the SimConfig for one (rate, system) sweep point.
fn e2e_point_config(cfg: &E2eConfig, rate: f64, system: &str) -> SimConfig {
    let mut c = if system == "vllm" {
        SimConfig::baseline(cfg.model, cfg.workload, rate)
    } else {
        SimConfig::paper_default(cfg.model, cfg.workload, rate)
    };
    c.duration_s = cfg.duration_s;
    c.seed = cfg.seed;
    c
}

/// How a sweep executes. Points are seed-deterministic and independent,
/// so both modes produce bit-identical output — `Serial` exists as the
/// equivalence-test reference and for debugging, not as a different
/// semantics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// One simulation per core via [`parallel_map`] (the default).
    #[default]
    Parallel,
    /// Every point inline on the calling thread.
    Serial,
}

/// Run the vLLM-baseline and Adrenaline systems across the sweep under
/// the given [`ExecMode`]. Output order (and every value) is identical
/// across modes.
pub fn run_e2e_with(cfg: &E2eConfig, mode: ExecMode) -> Vec<E2ePoint> {
    let jobs: Vec<(f64, &'static str)> = cfg
        .rates
        .iter()
        .flat_map(|&rate| [(rate, "vllm"), (rate, "adrenaline")])
        .collect();
    let point = |i: usize| {
        let (rate, system) = jobs[i];
        let report = ClusterSim::new(e2e_point_config(cfg, rate, system)).run();
        E2ePoint::from_report(rate, system, &report)
    };
    match mode {
        ExecMode::Parallel => parallel_map(jobs.len(), point),
        ExecMode::Serial => (0..jobs.len()).map(point).collect(),
    }
}

/// Build the SimConfig for one ratio-sweep point.
fn ratio_point_config(
    model: ModelSpec,
    workload: WorkloadKind,
    rate: f64,
    ratio: f64,
    duration_s: f64,
) -> SimConfig {
    let mut cfg = SimConfig::paper_default(model, workload, rate);
    cfg.duration_s = duration_s;
    cfg.serving.offload = if ratio <= 0.0 {
        OffloadPolicy::Disabled
    } else {
        OffloadPolicy::FixedRatio(ratio)
    };
    cfg
}

/// Offload-ratio sweep (Fig 15/17): fixed-ratio policies at one rate,
/// under the given [`ExecMode`]. Output is identical across modes.
pub fn run_ratio_sweep_with(
    model: ModelSpec,
    workload: WorkloadKind,
    rate: f64,
    ratios: &[f64],
    duration_s: f64,
    mode: ExecMode,
) -> Vec<(f64, SimReport)> {
    let point = |i: usize| {
        let ratio = ratios[i];
        let cfg = ratio_point_config(model, workload, rate, ratio, duration_s);
        (ratio, ClusterSim::new(cfg).run())
    };
    match mode {
        ExecMode::Parallel => parallel_map(ratios.len(), point),
        ExecMode::Serial => (0..ratios.len()).map(point).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2e_sweep_produces_point_pairs() {
        let cfg = E2eConfig {
            rates: vec![1.0, 3.0],
            duration_s: 40.0,
            ..E2eConfig::fig11()
        };
        let pts = run_e2e_with(&cfg, ExecMode::Parallel);
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().any(|p| p.system == "vllm"));
        assert!(pts.iter().any(|p| p.system == "adrenaline"));
        for p in &pts {
            assert!(p.finished > 0, "rate {} {}", p.rate, p.system);
        }
    }

    #[test]
    fn ratio_sweep_monotone_offload_fraction() {
        let pts = run_ratio_sweep_with(
            ModelSpec::llama2_7b(),
            WorkloadKind::ShareGpt,
            2.0,
            &[0.0, 0.4, 0.8],
            40.0,
            ExecMode::default(),
        );
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].1.offloaded_fraction, 0.0);
        assert!(pts[1].1.offloaded_fraction < pts[2].1.offloaded_fraction);
    }

    #[test]
    fn parallel_map_preserves_index_order() {
        let out = parallel_map(100, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
        assert!(parallel_map(0, |i| i).is_empty());
        assert_eq!(parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn parallel_map_capped_matches_uncapped() {
        for cap in [1usize, 2, 64] {
            let out = parallel_map_capped(40, cap, |i| i * i);
            assert_eq!(out, (0..40).map(|i| i * i).collect::<Vec<_>>(), "cap {cap}");
        }
        // cap 0 is clamped to 1 worker, not a deadlock.
        assert_eq!(parallel_map_capped(3, 0, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn thread_budget_is_bounded_and_refundable() {
        let got = budget_acquire(usize::MAX);
        assert!(got <= par_config().hw_threads);
        budget_release(got);
        assert_eq!(budget_acquire(0), 0);
    }

    #[test]
    fn worker_pool_returns_batch_results_in_order() {
        let pool = WorkerPool::new(3);
        // Several rounds over the same persistent pool: workers must
        // return to the job queue between batches.
        for round in 0..3usize {
            let tasks: Vec<PoolTask<usize>> = (0..17usize)
                .map(|i| -> PoolTask<usize> { Box::new(move || i * i + round) })
                .collect();
            let out = pool.run_batch(tasks);
            assert_eq!(out, (0..17).map(|i| i * i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 0);
        let tasks: Vec<PoolTask<usize>> =
            (0..5usize).map(|i| -> PoolTask<usize> { Box::new(move || i + 1) }).collect();
        assert_eq!(pool.run_batch(tasks), vec![1, 2, 3, 4, 5]);
    }

    /// NaN-tolerant exact equality (sweep points at unfinished rates can
    /// legitimately carry NaN latency means).
    fn feq(a: f64, b: f64) -> bool {
        a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
    }

    #[test]
    fn parallel_e2e_matches_serial_bitwise() {
        let cfg = E2eConfig {
            rates: vec![1.0, 2.0, 3.0],
            duration_s: 30.0,
            ..E2eConfig::fig11()
        };
        let par = run_e2e_with(&cfg, ExecMode::Parallel);
        let ser = run_e2e_with(&cfg, ExecMode::Serial);
        assert_eq!(par.len(), ser.len());
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.rate, s.rate);
            assert_eq!(p.system, s.system);
            assert!(feq(p.ttft_mean_s, s.ttft_mean_s), "{} {}", p.ttft_mean_s, s.ttft_mean_s);
            assert!(feq(p.tpot_mean_s, s.tpot_mean_s));
            assert!(feq(p.tpot_p99_s, s.tpot_p99_s));
            assert!(feq(p.throughput_tok_s, s.throughput_tok_s));
            assert_eq!(p.finished, s.finished);
            assert_eq!(p.preemptions, s.preemptions);
            assert!(feq(p.offloaded_fraction, s.offloaded_fraction));
            assert!(feq(p.graph_padding_overhead, s.graph_padding_overhead));
        }
    }
}
