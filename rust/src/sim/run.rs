//! End-to-end sweep harness: the request-rate sweeps behind Figs 11–14
//! and the offload-ratio sweep behind Figs 15/17.

use crate::config::{ModelSpec, OffloadPolicy};
use crate::workload::WorkloadKind;

use super::cluster::{ClusterSim, SimConfig, SimReport};

/// One figure panel's configuration.
#[derive(Debug, Clone)]
pub struct E2eConfig {
    pub model: ModelSpec,
    pub workload: WorkloadKind,
    pub rates: Vec<f64>,
    pub duration_s: f64,
    pub seed: u64,
}

impl E2eConfig {
    /// Fig 11: ShareGPT + Llama-2 7B.
    pub fn fig11() -> Self {
        E2eConfig {
            model: ModelSpec::llama2_7b(),
            workload: WorkloadKind::ShareGpt,
            rates: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            duration_s: 240.0,
            seed: 42,
        }
    }

    /// Fig 12: ShareGPT + Llama-2 13B.
    pub fn fig12() -> Self {
        E2eConfig { model: ModelSpec::llama2_13b(), ..Self::fig11() }
    }

    /// Fig 13: OpenThoughts + Llama-2 7B (longer outputs, lower rates).
    pub fn fig13() -> Self {
        E2eConfig {
            model: ModelSpec::llama2_7b(),
            workload: WorkloadKind::OpenThoughts,
            rates: vec![0.5, 1.0, 1.5, 2.0, 2.5],
            duration_s: 240.0,
            seed: 42,
        }
    }

    /// Fig 14: OpenThoughts + Llama-2 13B.
    pub fn fig14() -> Self {
        E2eConfig { model: ModelSpec::llama2_13b(), ..Self::fig13() }
    }
}

/// One point of an E2E sweep (one system at one rate).
#[derive(Debug)]
pub struct E2ePoint {
    pub rate: f64,
    pub system: &'static str,
    pub ttft_mean_s: f64,
    pub tpot_mean_s: f64,
    pub tpot_p99_s: f64,
    pub throughput_tok_s: f64,
    pub finished: usize,
    pub preemptions: u64,
    pub offloaded_fraction: f64,
}

impl E2ePoint {
    pub fn from_report(rate: f64, system: &'static str, r: &SimReport) -> Self {
        E2ePoint {
            rate,
            system,
            ttft_mean_s: r.ttft.map(|s| s.mean).unwrap_or(f64::NAN),
            tpot_mean_s: r.tpot.map(|s| s.mean).unwrap_or(f64::NAN),
            tpot_p99_s: r.tpot.map(|s| s.p99).unwrap_or(f64::NAN),
            throughput_tok_s: r.throughput,
            finished: r.finished,
            preemptions: r.preemptions,
            offloaded_fraction: r.offloaded_fraction,
        }
    }
}

/// Run the vLLM-baseline and Adrenaline systems across the sweep.
pub fn run_e2e(cfg: &E2eConfig) -> Vec<E2ePoint> {
    let mut out = Vec::new();
    for &rate in &cfg.rates {
        let mut base = SimConfig::baseline(cfg.model, cfg.workload, rate);
        base.duration_s = cfg.duration_s;
        base.seed = cfg.seed;
        let br = ClusterSim::new(base).run();
        out.push(E2ePoint::from_report(rate, "vllm", &br));

        let mut adre = SimConfig::paper_default(cfg.model, cfg.workload, rate);
        adre.duration_s = cfg.duration_s;
        adre.seed = cfg.seed;
        let ar = ClusterSim::new(adre).run();
        out.push(E2ePoint::from_report(rate, "adrenaline", &ar));
    }
    out
}

/// Offload-ratio sweep (Fig 15/17): fixed-ratio policies at one rate.
pub fn run_ratio_sweep(
    model: ModelSpec,
    workload: WorkloadKind,
    rate: f64,
    ratios: &[f64],
    duration_s: f64,
) -> Vec<(f64, SimReport)> {
    ratios
        .iter()
        .map(|&ratio| {
            let mut cfg = SimConfig::paper_default(model, workload, rate);
            cfg.duration_s = duration_s;
            cfg.serving.offload = if ratio <= 0.0 {
                OffloadPolicy::Disabled
            } else {
                OffloadPolicy::FixedRatio(ratio)
            };
            (ratio, ClusterSim::new(cfg).run())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2e_sweep_produces_point_pairs() {
        let cfg = E2eConfig {
            rates: vec![1.0, 3.0],
            duration_s: 40.0,
            ..E2eConfig::fig11()
        };
        let pts = run_e2e(&cfg);
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().any(|p| p.system == "vllm"));
        assert!(pts.iter().any(|p| p.system == "adrenaline"));
        for p in &pts {
            assert!(p.finished > 0, "rate {} {}", p.rate, p.system);
        }
    }

    #[test]
    fn ratio_sweep_monotone_offload_fraction() {
        let pts = run_ratio_sweep(
            ModelSpec::llama2_7b(),
            WorkloadKind::ShareGpt,
            2.0,
            &[0.0, 0.4, 0.8],
            40.0,
        );
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].1.offloaded_fraction, 0.0);
        assert!(pts[1].1.offloaded_fraction < pts[2].1.offloaded_fraction);
    }
}
