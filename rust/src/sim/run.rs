//! End-to-end sweep harness: the request-rate sweeps behind Figs 11–14
//! and the offload-ratio sweep behind Figs 15/17.
//!
//! Sweep points are independent, seed-deterministic simulations, so the
//! default drivers fan them out across all cores with [`parallel_map`] and
//! produce output **bit-identical** to the serial paths
//! ([`run_e2e_serial`] / [`run_ratio_sweep_serial`], kept for the
//! equivalence tests and for debugging). Set `ADRENALINE_SERIAL=1` to
//! force serial execution.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::config::{ModelSpec, OffloadPolicy};
use crate::workload::WorkloadKind;

use super::cluster::{ClusterSim, SimConfig, SimReport};

/// Deterministic parallel map: computes `f(0)..f(n-1)` on a pool of
/// worker threads and returns the results in index order. Each index is
/// claimed exactly once off an atomic counter, so results depend only on
/// `f`, never on scheduling. Falls back to serial for trivial inputs,
/// single-core machines, or `ADRENALINE_SERIAL=1`.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_capped(n, usize::MAX, f)
}

/// [`parallel_map`] with an explicit worker cap. Callers whose work items
/// fan out *again* internally (e.g. the `figures` binary runs figure
/// groups that each drive parallel sweeps) cap the outer level so total
/// live work stays near the core count instead of groups × cores.
pub fn parallel_map_capped<T, F>(n: usize, max_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let force_serial = std::env::var("ADRENALINE_SERIAL").map_or(false, |v| v == "1");
    let threads = std::thread::available_parallelism()
        .map_or(1, |p| p.get())
        .min(n)
        .min(max_threads.max(1));
    if force_serial || threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = f(i);
                if tx.send((i, result)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, result) in rx {
            out[i] = Some(result);
        }
    });
    out.into_iter()
        .map(|r| r.expect("every sweep point completes exactly once"))
        .collect()
}

/// One figure panel's configuration.
#[derive(Debug, Clone)]
pub struct E2eConfig {
    pub model: ModelSpec,
    pub workload: WorkloadKind,
    pub rates: Vec<f64>,
    pub duration_s: f64,
    pub seed: u64,
}

impl E2eConfig {
    /// Fig 11: ShareGPT + Llama-2 7B.
    pub fn fig11() -> Self {
        E2eConfig {
            model: ModelSpec::llama2_7b(),
            workload: WorkloadKind::ShareGpt,
            rates: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            duration_s: 240.0,
            seed: 42,
        }
    }

    /// Fig 12: ShareGPT + Llama-2 13B.
    pub fn fig12() -> Self {
        E2eConfig { model: ModelSpec::llama2_13b(), ..Self::fig11() }
    }

    /// Fig 13: OpenThoughts + Llama-2 7B (longer outputs, lower rates).
    pub fn fig13() -> Self {
        E2eConfig {
            model: ModelSpec::llama2_7b(),
            workload: WorkloadKind::OpenThoughts,
            rates: vec![0.5, 1.0, 1.5, 2.0, 2.5],
            duration_s: 240.0,
            seed: 42,
        }
    }

    /// Fig 14: OpenThoughts + Llama-2 13B.
    pub fn fig14() -> Self {
        E2eConfig { model: ModelSpec::llama2_13b(), ..Self::fig13() }
    }
}

/// One point of an E2E sweep (one system at one rate).
#[derive(Debug, Clone)]
pub struct E2ePoint {
    pub rate: f64,
    pub system: &'static str,
    pub ttft_mean_s: f64,
    pub tpot_mean_s: f64,
    pub tpot_p99_s: f64,
    pub throughput_tok_s: f64,
    pub finished: usize,
    pub preemptions: u64,
    pub offloaded_fraction: f64,
    /// Fraction of charged batch slots wasted to executable-bucket
    /// padding at this point (0 under `ADRENALINE_EXACT_COSTS=1`).
    pub graph_padding_overhead: f64,
}

impl E2ePoint {
    pub fn from_report(rate: f64, system: &'static str, r: &SimReport) -> Self {
        E2ePoint {
            rate,
            system,
            ttft_mean_s: r.ttft.map(|s| s.mean).unwrap_or(f64::NAN),
            tpot_mean_s: r.tpot.map(|s| s.mean).unwrap_or(f64::NAN),
            tpot_p99_s: r.tpot.map(|s| s.p99).unwrap_or(f64::NAN),
            throughput_tok_s: r.throughput,
            finished: r.finished,
            preemptions: r.preemptions,
            offloaded_fraction: r.offloaded_fraction,
            graph_padding_overhead: r.graph_padding_overhead,
        }
    }
}

/// Build the SimConfig for one (rate, system) sweep point.
fn e2e_point_config(cfg: &E2eConfig, rate: f64, system: &str) -> SimConfig {
    let mut c = if system == "vllm" {
        SimConfig::baseline(cfg.model, cfg.workload, rate)
    } else {
        SimConfig::paper_default(cfg.model, cfg.workload, rate)
    };
    c.duration_s = cfg.duration_s;
    c.seed = cfg.seed;
    c
}

/// Run the vLLM-baseline and Adrenaline systems across the sweep, one
/// simulation per core. Output order (and every value) is identical to
/// [`run_e2e_serial`].
pub fn run_e2e(cfg: &E2eConfig) -> Vec<E2ePoint> {
    let jobs: Vec<(f64, &'static str)> = cfg
        .rates
        .iter()
        .flat_map(|&rate| [(rate, "vllm"), (rate, "adrenaline")])
        .collect();
    parallel_map(jobs.len(), |i| {
        let (rate, system) = jobs[i];
        let report = ClusterSim::new(e2e_point_config(cfg, rate, system)).run();
        E2ePoint::from_report(rate, system, &report)
    })
}

/// Serial reference driver for [`run_e2e`].
pub fn run_e2e_serial(cfg: &E2eConfig) -> Vec<E2ePoint> {
    let mut out = Vec::new();
    for &rate in &cfg.rates {
        for system in ["vllm", "adrenaline"] {
            let report = ClusterSim::new(e2e_point_config(cfg, rate, system)).run();
            out.push(E2ePoint::from_report(rate, system, &report));
        }
    }
    out
}

/// Build the SimConfig for one ratio-sweep point.
fn ratio_point_config(
    model: ModelSpec,
    workload: WorkloadKind,
    rate: f64,
    ratio: f64,
    duration_s: f64,
) -> SimConfig {
    let mut cfg = SimConfig::paper_default(model, workload, rate);
    cfg.duration_s = duration_s;
    cfg.serving.offload = if ratio <= 0.0 {
        OffloadPolicy::Disabled
    } else {
        OffloadPolicy::FixedRatio(ratio)
    };
    cfg
}

/// Offload-ratio sweep (Fig 15/17): fixed-ratio policies at one rate, one
/// simulation per core. Identical output to [`run_ratio_sweep_serial`].
pub fn run_ratio_sweep(
    model: ModelSpec,
    workload: WorkloadKind,
    rate: f64,
    ratios: &[f64],
    duration_s: f64,
) -> Vec<(f64, SimReport)> {
    parallel_map(ratios.len(), |i| {
        let ratio = ratios[i];
        let cfg = ratio_point_config(model, workload, rate, ratio, duration_s);
        (ratio, ClusterSim::new(cfg).run())
    })
}

/// Serial reference driver for [`run_ratio_sweep`].
pub fn run_ratio_sweep_serial(
    model: ModelSpec,
    workload: WorkloadKind,
    rate: f64,
    ratios: &[f64],
    duration_s: f64,
) -> Vec<(f64, SimReport)> {
    ratios
        .iter()
        .map(|&ratio| {
            let cfg = ratio_point_config(model, workload, rate, ratio, duration_s);
            (ratio, ClusterSim::new(cfg).run())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2e_sweep_produces_point_pairs() {
        let cfg = E2eConfig {
            rates: vec![1.0, 3.0],
            duration_s: 40.0,
            ..E2eConfig::fig11()
        };
        let pts = run_e2e(&cfg);
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().any(|p| p.system == "vllm"));
        assert!(pts.iter().any(|p| p.system == "adrenaline"));
        for p in &pts {
            assert!(p.finished > 0, "rate {} {}", p.rate, p.system);
        }
    }

    #[test]
    fn ratio_sweep_monotone_offload_fraction() {
        let pts = run_ratio_sweep(
            ModelSpec::llama2_7b(),
            WorkloadKind::ShareGpt,
            2.0,
            &[0.0, 0.4, 0.8],
            40.0,
        );
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].1.offloaded_fraction, 0.0);
        assert!(pts[1].1.offloaded_fraction < pts[2].1.offloaded_fraction);
    }

    #[test]
    fn parallel_map_preserves_index_order() {
        let out = parallel_map(100, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
        assert!(parallel_map(0, |i| i).is_empty());
        assert_eq!(parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn parallel_map_capped_matches_uncapped() {
        for cap in [1usize, 2, 64] {
            let out = parallel_map_capped(40, cap, |i| i * i);
            assert_eq!(out, (0..40).map(|i| i * i).collect::<Vec<_>>(), "cap {cap}");
        }
        // cap 0 is clamped to 1 worker, not a deadlock.
        assert_eq!(parallel_map_capped(3, 0, |i| i), vec![0, 1, 2]);
    }

    /// NaN-tolerant exact equality (sweep points at unfinished rates can
    /// legitimately carry NaN latency means).
    fn feq(a: f64, b: f64) -> bool {
        a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
    }

    #[test]
    fn parallel_e2e_matches_serial_bitwise() {
        let cfg = E2eConfig {
            rates: vec![1.0, 2.0, 3.0],
            duration_s: 30.0,
            ..E2eConfig::fig11()
        };
        let par = run_e2e(&cfg);
        let ser = run_e2e_serial(&cfg);
        assert_eq!(par.len(), ser.len());
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.rate, s.rate);
            assert_eq!(p.system, s.system);
            assert!(feq(p.ttft_mean_s, s.ttft_mean_s), "{} {}", p.ttft_mean_s, s.ttft_mean_s);
            assert!(feq(p.tpot_mean_s, s.tpot_mean_s));
            assert!(feq(p.tpot_p99_s, s.tpot_p99_s));
            assert!(feq(p.throughput_tok_s, s.throughput_tok_s));
            assert_eq!(p.finished, s.finished);
            assert_eq!(p.preemptions, s.preemptions);
            assert!(feq(p.offloaded_fraction, s.offloaded_fraction));
            assert!(feq(p.graph_padding_overhead, s.graph_padding_overhead));
        }
    }
}
