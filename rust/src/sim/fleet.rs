//! Fleet-scale serving (ISSUE 8): N independent P/D groups — each an
//! ordinary [`ClusterSim`] topology — sharing one arrival trace behind a
//! cluster-level [`ClusterRouter`], with optional per-group prefill-pool
//! autoscaling (`FleetConfig::autoscale`, handled inside each group's
//! sim). DistServe (PAPERS.md) motivates the layer: at fleet scale,
//! goodput is decided by *placement above* the per-group proxies, which
//! keep routing within their group exactly as before.
//!
//! Two execution strategies:
//!
//! * **Pre-partition** (round-robin, session-sticky, or a single group,
//!   with no health-aware fault plane and no admission control): the
//!   policy is a pure function of the request id, so the whole trace
//!   is routed upfront, each group's slice is renumbered onto a dense
//!   local id space, and the groups run as completely independent sims —
//!   one per core via [`parallel_map`], bit-identical to running them
//!   serially. A one-group fleet is exactly `ClusterSim::with_trace`
//!   over the generated trace, i.e. bit-identical to a bare sim (pinned
//!   by `rust/tests/fleet.rs`).
//! * **Lockstep co-simulation** (least-loaded with ≥ 2 groups; any
//!   policy once `FleetConfig::overload` or a health-aware fault plane
//!   with ≥ 2 groups is armed): the router needs every group's *live*
//!   state at each arrival instant, so the groups advance together.
//!   Before injecting an arrival at `t`, every group receives a
//!   [`ClusterSim::fence`] at `t` and is pumped strictly past its events
//!   before `t`; the fence holds a smaller queue `seq` than the injected
//!   arrival, so the decode leap engine's strict next-event horizon
//!   fences every leap off the injection with no new engine machinery.
//!   The schedule is fully deterministic: same seed, same trace, same
//!   routing, same reports.
//!
//! ## Fleet fault tolerance (ISSUE 10)
//!
//! Three planes compose on the lockstep path, each inert unless armed:
//!
//! * **Health-aware routing** — at every admission instant the fleet
//!   reads each group's ground-truth stall state
//!   ([`ClusterSim::group_stalled`]) and masks stalled groups out of the
//!   routing decision ([`ClusterRouter::route_masked`]); round-robin and
//!   session-sticky arrivals whose nominal group is down divert live
//!   instead of stranding in a pre-partitioned slice.
//! * **Cross-group failover** — a stalled group's still-queued requests
//!   are exported ([`ClusterSim::export_pending`]) and re-injected into
//!   the healthiest surviving group (best observed health fraction, ties
//!   by live headroom). The exported request carries the recompute-path
//!   token ledger (effective prompt, remaining output), so the
//!   destination's ordinary arrival path conserves tokens unchanged.
//! * **Admission control** (`FleetConfig::overload`) — an arrival is
//!   admitted only if some routable group predicts a TTFT within the
//!   budget ([`ClusterSim::predicted_ttft`]); otherwise it retries with
//!   exponential backoff up to `max_retries` times and is then *shed*.
//!   Prediction grows with prompt length, so the largest prompts shed
//!   first — graceful degradation ordering. A shed request is an SLO
//!   miss, not a non-event: it stays in the attainment denominator
//!   (`FleetReport::fleet_slo_attainment`).

use std::sync::Mutex;

use crate::config::{FleetConfig, OverloadConfig, RouterPolicy};
use crate::coordinator::ClusterRouter;
use crate::metrics::{LatencyStats, Timeline};
use crate::workload::{Request, TraceGenerator};

use super::cluster::{ClusterSim, SimConfig, SimReport};
use super::run::parallel_map;

/// Seed stride between groups: group 0 keeps the fleet seed (so a
/// one-group fleet is bit-identical to a bare sim); further groups get
/// decorrelated fault/jitter RNG streams. The trace itself is generated
/// once from the fleet seed and shared, so routing — not seeding —
/// decides what each group serves.
const GROUP_SEED_STRIDE: u64 = 0x9e37_79b9_7f4a_7c15;

/// Post-run fleet report: the per-group [`SimReport`]s plus fleet-wide
/// aggregates.
#[derive(Debug)]
pub struct FleetReport {
    /// Per-group reports, group-index order.
    pub groups: Vec<SimReport>,
    /// Requests the cluster router sent to each group (re-admitted
    /// retries and failed-over re-injections count at their new group).
    pub router_decisions: Vec<u64>,
    /// Sum of per-group stable-window throughputs, tok/s.
    pub fleet_throughput: f64,
    /// Sum of per-group goodputs (DistServe metric), tok/s.
    pub fleet_goodput: f64,
    /// Count-weighted merge of per-group TTFT stats
    /// ([`LatencyStats::merged`]; percentiles approximate).
    pub fleet_ttft: Option<LatencyStats>,
    /// Count-weighted merge of per-group TPOT stats.
    pub fleet_tpot: Option<LatencyStats>,
    /// Unique requests offered to the fleet: every trace arrival counts
    /// exactly once — shed arrivals included, failed-over requests not
    /// double-counted across their two slab entries.
    pub arrived: usize,
    pub finished: usize,
    pub steps_simulated: u64,
    pub events_processed: u64,
    /// Fleet-wide routable prefill-pool size over time: the step-function
    /// sum of every group's `prefill_pool_timeline` (empty without
    /// autoscaling).
    pub fleet_size_timeline: Timeline,
    /// Total scaling actions across the fleet (scale-ups + initiated
    /// scale-downs).
    pub scale_events: u64,
    // ----- fleet fault tolerance (ISSUE 10; all zero / empty without a
    // health-aware fault plane or `FleetConfig::overload`) --------------
    /// Arrivals rejected by admission control after exhausting their
    /// retry budget.
    pub requests_shed: u64,
    /// Requests exported out of a stalled group and re-injected into a
    /// surviving one (equals the sum of per-group `requests_exported`).
    pub requests_failed_over: u64,
    /// Re-admission attempts performed for deferred arrivals.
    pub retries: u64,
    /// Arrivals the router diverted off a masked (stalled) nominal group.
    pub router_reroutes: u64,
    /// Per-group availability (1.0 = accepting work, 0.0 = stalled),
    /// sampled at admission instants on change. Empty without the
    /// health-aware lockstep plane.
    pub availability: Vec<Timeline>,
    /// Pooled SLO attainment with shed arrivals in the denominator:
    /// `Σ requests_slo_met / (Σ finished + requests_shed)`. A shed
    /// request is an SLO miss, not a non-event (ISSUE 10 satellite; see
    /// EXPERIMENTS.md §Fleet-faults).
    pub fleet_slo_attainment: f64,
    /// Shed-aware goodput, tok/s: `Σ slo_met_tokens / duration_s` —
    /// output tokens of SLO-met requests over the *offered* timeline.
    /// Deliberately not stable-window-based: on faulted runs a
    /// post-recovery drain burst can capture or dilute the window
    /// arbitrarily, and a window metric would let shedding inflate the
    /// rate by serving less. Shed and stranded requests contribute
    /// exactly zero here.
    pub fleet_goodput_shed_aware: f64,
}

/// A deferred arrival waiting out its admission-control backoff.
struct PendingRetry {
    /// Re-admission instant.
    due: f64,
    /// Admission attempts already made (1 after the first rejection).
    attempts: u32,
    /// Scheduling tie-break (after due time and prompt length), in
    /// deferral order.
    seq: u64,
    req: Request,
}

/// Lockstep-path fault-tolerance tallies (ISSUE 10).
#[derive(Debug, Default)]
struct FaultStats {
    requests_shed: u64,
    requests_failed_over: u64,
    retries: u64,
    availability: Vec<Timeline>,
}

/// The fleet simulator. Owns one [`SimConfig`] describing every group's
/// base topology plus the shared trace parameters; groups can override
/// their device profiles via [`FleetConfig::group_profiles`]
/// (heterogeneous fleets — ISSUE 9).
pub struct FleetSim {
    cfg: SimConfig,
    fleet: FleetConfig,
}

impl FleetSim {
    /// `cfg.serving.fleet` decides the shape; `None` behaves as the
    /// default one-group round-robin fleet (bit-identical to a bare
    /// [`ClusterSim`] run — `rust/tests/fleet.rs` pins it).
    pub fn new(cfg: SimConfig) -> Self {
        let fleet = cfg.serving.fleet.clone().unwrap_or_default();
        assert!(fleet.groups >= 1, "a fleet needs at least one group");
        assert!(
            fleet.group_profiles.len() <= fleet.groups as usize,
            "group_profiles lists {} entries for {} groups",
            fleet.group_profiles.len(),
            fleet.groups
        );
        FleetSim { cfg, fleet }
    }

    pub fn run(self) -> FleetReport {
        let groups = self.fleet.groups.max(1) as usize;
        let mut gen = TraceGenerator::new(self.cfg.workload, self.cfg.rate, self.cfg.seed)
            .with_arrivals(self.cfg.arrivals);
        let trace = gen.trace(self.cfg.duration_s);
        let mut router = ClusterRouter::new(self.fleet.router, groups);

        // The lockstep co-simulation runs whenever a routing decision
        // needs live group state: least-loaded always; any policy once
        // admission control or a multi-group health-aware fault plane is
        // armed. A naive (health_aware: false) faulted fleet keeps the
        // pre-partition path — that health-blind, strand-on-crash
        // baseline is exactly what EXPERIMENTS.md §Fleet-faults compares
        // against.
        let health_fleet = groups >= 2
            && self.cfg.serving.fault.as_ref().map_or(false, |f| f.health_aware);
        let lockstep = (groups >= 2 && self.fleet.router == RouterPolicy::LeastLoaded)
            || self.fleet.overload.is_some()
            || health_fleet;
        let (reports, fx) = if lockstep {
            Self::run_lockstep(&self.cfg, trace, &mut router, groups, &self.fleet)
        } else {
            (
                Self::run_partitioned(&self.cfg, trace, &mut router, groups),
                FaultStats::default(),
            )
        };

        let fleet_size_timeline =
            stepwise_sum(&reports.iter().map(|r| &r.prefill_pool_timeline).collect::<Vec<_>>());
        let fleet_ttft = LatencyStats::merged(reports.iter().filter_map(|r| r.ttft.as_ref()));
        let fleet_tpot = LatencyStats::merged(reports.iter().filter_map(|r| r.tpot.as_ref()));
        debug_assert_eq!(
            fx.requests_failed_over,
            reports.iter().map(|r| r.requests_exported).sum::<u64>(),
            "every export must have been re-injected exactly once"
        );
        let finished: usize = reports.iter().map(|r| r.finished).sum();
        let fleet_throughput: f64 = reports.iter().map(|r| r.throughput).sum();
        // Shed-aware attainment (ISSUE 10 satellite): pooled across
        // groups, with shed arrivals in the denominator as misses.
        let slo_met: usize = reports.iter().map(|r| r.requests_slo_met).sum();
        let slo_den = finished as u64 + fx.requests_shed;
        let fleet_slo_attainment =
            if slo_den == 0 { 0.0 } else { slo_met as f64 / slo_den as f64 };
        let slo_met_tokens: u64 = reports.iter().map(|r| r.slo_met_tokens).sum();
        FleetReport {
            router_decisions: router.decisions.clone(),
            fleet_throughput,
            fleet_goodput: reports.iter().map(|r| r.goodput).sum(),
            fleet_ttft,
            fleet_tpot,
            // Per-group `arrived` counts every slab entry: subtract the
            // failed-over duplicates, add back the shed arrivals that
            // never entered a group.
            arrived: reports.iter().map(|r| r.arrived).sum::<usize>()
                + fx.requests_shed as usize
                - fx.requests_failed_over as usize,
            finished,
            steps_simulated: reports.iter().map(|r| r.steps_simulated).sum(),
            events_processed: reports.iter().map(|r| r.events_processed).sum(),
            fleet_size_timeline,
            scale_events: reports.iter().map(|r| r.scale_ups + r.scale_downs).sum(),
            requests_shed: fx.requests_shed,
            requests_failed_over: fx.requests_failed_over,
            retries: fx.retries,
            router_reroutes: router.reroutes,
            availability: fx.availability,
            fleet_slo_attainment,
            fleet_goodput_shed_aware: slo_met_tokens as f64 / self.cfg.duration_s,
            groups: reports,
        }
    }

    /// Per-group config: identical topology/serving knobs; group 0 keeps
    /// the fleet seed, others get decorrelated RNG streams. Group-scoped
    /// scripted faults (ISSUE 10) are resolved here: entries targeting
    /// another group are dropped, retained entries lose their scope
    /// marker (`ClusterSim` rejects scoped entries — scoping is a
    /// fleet-layer concept).
    fn group_config(cfg: &SimConfig, g: usize) -> SimConfig {
        let mut c = cfg.clone();
        if g > 0 {
            c.seed = cfg.seed.wrapping_add((g as u64).wrapping_mul(GROUP_SEED_STRIDE));
        }
        if let Some(Some(p)) = cfg.serving.fleet.as_ref().and_then(|f| f.group_profiles.get(g)) {
            c.cluster.profiles = Some(*p);
        }
        if let Some(fc) = c.serving.fault.as_mut() {
            fc.script.retain(|s| s.group.map_or(true, |sg| sg as usize == g));
            for s in &mut fc.script {
                s.group = None;
            }
        }
        c
    }

    /// Static policies: route the whole trace upfront, renumber each
    /// slice dense, run the groups independently (one per core).
    fn run_partitioned(
        cfg: &SimConfig,
        trace: Vec<Request>,
        router: &mut ClusterRouter,
        groups: usize,
    ) -> Vec<SimReport> {
        let mut parts: Vec<Vec<Request>> = (0..groups).map(|_| Vec::new()).collect();
        for req in trace {
            let g = router.route(req.id, &[]);
            parts[g].push(req);
        }
        for part in &mut parts {
            for (i, r) in part.iter_mut().enumerate() {
                r.id = i as u64;
            }
        }
        let cfgs: Vec<SimConfig> = (0..groups).map(|g| Self::group_config(cfg, g)).collect();
        // `parallel_map` wants `Fn`, not `FnOnce`; each group's slice is
        // handed over through a take-once slot.
        let slots: Vec<Mutex<Option<Vec<Request>>>> =
            parts.into_iter().map(|p| Mutex::new(Some(p))).collect();
        parallel_map(groups, |g| {
            let part = slots[g]
                .lock()
                .expect("no panics while holding a slot")
                .take()
                .expect("each group runs exactly once");
            ClusterSim::with_trace(cfgs[g].clone(), part).run()
        })
    }

    /// Live-state policies: co-simulate the groups in lockstep so every
    /// routing, failover, and admission decision reads each group's
    /// state *at the admission instant*.
    fn run_lockstep(
        cfg: &SimConfig,
        trace: Vec<Request>,
        router: &mut ClusterRouter,
        groups: usize,
        fleet: &FleetConfig,
    ) -> (Vec<SimReport>, FaultStats) {
        let overload = fleet.overload;
        let health_gated =
            groups >= 2 && cfg.serving.fault.as_ref().map_or(false, |f| f.health_aware);
        // Offload bounds derive from the mean sequence length; use the
        // full shared trace so every group prices against the same
        // bounds a whole-trace build would.
        let avg_seq = if trace.is_empty() {
            1024
        } else {
            (trace.iter().map(|r| r.total_tokens()).sum::<usize>() / trace.len()) as u64
        };
        let mut sims: Vec<ClusterSim> = (0..groups)
            .map(|g| ClusterSim::lockstep(Self::group_config(cfg, g), avg_seq))
            .collect();
        for sim in &mut sims {
            sim.prime();
        }
        let mut stats = FaultStats::default();
        if health_gated {
            stats.availability = (0..groups).map(|_| Timeline::new()).collect();
        }
        let mut avail_last = vec![f64::NAN; groups];
        let mut headroom = vec![0.0f64; groups];
        let mut up = vec![true; groups];
        let mut retry_q: Vec<PendingRetry> = Vec::new();
        let mut retry_seq = 0u64;
        let mut last_t = f64::NEG_INFINITY;

        let mut arrivals = trace.into_iter().peekable();
        loop {
            // Next admission instant: the earlier of the next trace
            // arrival and the earliest due retry (retries ordered by
            // (due, prompt, deferral seq) — the smallest prompts
            // re-admit first, matching the shed-largest-first
            // degradation order; arrivals win exact-time ties).
            let next_retry = retry_q
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    (a.due, a.req.prompt_len, a.seq)
                        .partial_cmp(&(b.due, b.req.prompt_len, b.seq))
                        .expect("retry due times are finite")
                })
                .map(|(i, r)| (i, r.due));
            let take_arrival = match (arrivals.peek(), next_retry) {
                (Some(req), Some((_, due))) => req.arrival_s <= due,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };
            let (req, t, attempts) = if take_arrival {
                let req = arrivals.next().expect("peeked");
                let t = req.arrival_s;
                (req, t, 0)
            } else {
                let (i, due) = next_retry.expect("checked");
                let pr = retry_q.swap_remove(i);
                stats.retries += 1;
                (pr.req, due, pr.attempts)
            };
            debug_assert!(t >= last_t, "lockstep needs time-ordered admission instants");
            last_t = t;
            // Fence first, then pump strictly past events before `t`:
            // after this, every group's clock is < `t` and no group has
            // committed state at or beyond the admission instant.
            for sim in &mut sims {
                sim.fence(t);
                sim.pump(t);
            }
            if health_gated {
                Self::failover(&mut sims, t, &mut stats);
            }
            for (g, sim) in sims.iter().enumerate() {
                up[g] = !sim.group_stalled();
                headroom[g] = sim.router_headroom();
            }
            if health_gated {
                Self::sample_availability(&mut stats, &mut avail_last, &up, t);
            }
            Self::admit_or_defer(
                &mut sims,
                router,
                overload,
                health_gated,
                &headroom,
                &up,
                req,
                t,
                attempts,
                &mut retry_q,
                &mut retry_seq,
                &mut stats,
            );
        }
        // Final failover pass: a group that stalled after the last
        // admission instant still hands its queued work to a survivor
        // before the drain (recovered-in-place groups drain themselves).
        if health_gated {
            let t_end = cfg.duration_s.max(last_t);
            for sim in &mut sims {
                sim.fence(t_end);
                sim.pump(t_end);
            }
            Self::failover(&mut sims, t_end, &mut stats);
            for (g, sim) in sims.iter().enumerate() {
                up[g] = !sim.group_stalled();
            }
            Self::sample_availability(&mut stats, &mut avail_last, &up, t_end);
        }
        let reports = sims
            .into_iter()
            .map(|mut sim| {
                sim.close_arrivals();
                sim.pump(f64::INFINITY);
                sim.report()
            })
            .collect();
        (reports, stats)
    }

    /// Admission control + routing for one request at instant `t`.
    /// Without `overload`, every request routes; with it, the request is
    /// admitted only when the best predicted TTFT across up groups fits
    /// the budget, and otherwise backs off (or is shed once its retry
    /// budget is spent).
    #[allow(clippy::too_many_arguments)]
    fn admit_or_defer(
        sims: &mut [ClusterSim],
        router: &mut ClusterRouter,
        overload: Option<OverloadConfig>,
        health_gated: bool,
        headroom: &[f64],
        up: &[bool],
        mut req: Request,
        t: f64,
        attempts: u32,
        retry_q: &mut Vec<PendingRetry>,
        retry_seq: &mut u64,
        stats: &mut FaultStats,
    ) {
        if let Some(ov) = overload {
            let best = sims
                .iter_mut()
                .enumerate()
                .filter(|(g, _)| up[*g])
                .map(|(_, s)| s.predicted_ttft(t, req.prompt_len))
                .fold(f64::INFINITY, f64::min);
            // NaN-proof negation: defer unless provably within budget.
            if !(best <= ov.ttft_budget_s) {
                if attempts < ov.max_retries {
                    let backoff = (ov.retry_backoff_s * (1u64 << attempts.min(32)) as f64)
                        .min(ov.retry_backoff_cap_s);
                    *retry_seq += 1;
                    retry_q.push(PendingRetry {
                        due: t + backoff,
                        attempts: attempts + 1,
                        seq: *retry_seq,
                        req,
                    });
                } else {
                    stats.requests_shed += 1;
                }
                return;
            }
        }
        // Retried arrivals re-enter at their admission instant (the
        // deferral is visible in `retries`, not in the group's TTFT).
        req.arrival_s = t;
        let g = if health_gated {
            router.route_masked(req.id, headroom, up)
        } else {
            router.route(req.id, headroom)
        };
        sims[g].inject(req);
    }

    /// Cross-group failover at instant `t`: every stalled group's queued
    /// requests move to the healthiest surviving group. A no-op when no
    /// group — or every group — is stalled (with nowhere to go, queued
    /// work waits for its own group's recovery instead).
    fn failover(sims: &mut [ClusterSim], t: f64, stats: &mut FaultStats) {
        let stalled: Vec<bool> = sims.iter().map(|s| s.group_stalled()).collect();
        if !stalled.iter().any(|&s| s) || stalled.iter().all(|&s| s) {
            return;
        }
        for g in 0..sims.len() {
            if !stalled[g] {
                continue;
            }
            let moved = sims[g].export_pending(t);
            if moved.is_empty() {
                continue;
            }
            // Healthiest surviving group: best observed health fraction,
            // ties by live headroom, then the lowest index.
            let mut dest: Option<(usize, (f64, f64))> = None;
            for (d, sim) in sims.iter().enumerate() {
                if stalled[d] {
                    continue;
                }
                let key = (sim.health_fraction(), sim.router_headroom());
                dest = match dest {
                    Some((_, best)) if key.0 < best.0 || (key.0 == best.0 && key.1 <= best.1) => {
                        dest
                    }
                    _ => Some((d, key)),
                };
            }
            let (d, _) = dest.expect("a surviving group exists");
            stats.requests_failed_over += moved.len() as u64;
            for r in moved {
                sims[d].inject(r);
            }
        }
    }

    /// Append per-group availability samples at `t`, on change only (the
    /// timelines stay step-functions, not per-arrival dumps).
    fn sample_availability(
        stats: &mut FaultStats,
        avail_last: &mut [f64],
        up: &[bool],
        t: f64,
    ) {
        for (g, &u) in up.iter().enumerate() {
            let v = if u { 1.0 } else { 0.0 };
            if avail_last[g] != v {
                avail_last[g] = v;
                stats.availability[g].push(t, v);
            }
        }
    }
}

/// Step-function sum of several timelines: at every sample time in any
/// input, emit the sum of each input's most recent value at or before
/// that time (inputs are carried forward between their own samples).
/// Pool timelines all start with a t=0 sample, so the carry-forward is
/// well-defined from the origin.
fn stepwise_sum(lines: &[&Timeline]) -> Timeline {
    let mut idx = vec![0usize; lines.len()];
    let mut cur = vec![0.0f64; lines.len()];
    let mut out = Timeline::new();
    loop {
        let mut next: Option<f64> = None;
        for (i, l) in lines.iter().enumerate() {
            if let Some(&(t, _)) = l.points().get(idx[i]) {
                next = Some(next.map_or(t, |n: f64| n.min(t)));
            }
        }
        let Some(t) = next else { break };
        for (i, l) in lines.iter().enumerate() {
            while let Some(&(pt, v)) = l.points().get(idx[i]) {
                if pt <= t {
                    cur[i] = v;
                    idx[i] += 1;
                } else {
                    break;
                }
            }
        }
        out.push(t, cur.iter().sum());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl(points: &[(f64, f64)]) -> Timeline {
        let mut t = Timeline::new();
        for &(x, v) in points {
            t.push(x, v);
        }
        t
    }

    #[test]
    fn stepwise_sum_carries_values_forward() {
        let a = tl(&[(0.0, 2.0), (1.0, 3.0), (4.0, 1.0)]);
        let b = tl(&[(0.0, 4.0), (2.0, 5.0)]);
        let s = stepwise_sum(&[&a, &b]);
        assert_eq!(
            s.points(),
            &[(0.0, 6.0), (1.0, 7.0), (2.0, 8.0), (4.0, 6.0)],
            "each sample time sums the latest value of every input"
        );
        assert!(stepwise_sum(&[]).is_empty());
        let empty = Timeline::new();
        assert_eq!(stepwise_sum(&[&a, &empty]).points(), a.points());
    }

    #[test]
    fn group_config_keeps_group_zero_seed() {
        use crate::config::ModelSpec;
        use crate::workload::WorkloadKind;
        let cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 1.0);
        assert_eq!(FleetSim::group_config(&cfg, 0).seed, cfg.seed);
        let s1 = FleetSim::group_config(&cfg, 1).seed;
        let s2 = FleetSim::group_config(&cfg, 2).seed;
        assert_ne!(s1, cfg.seed);
        assert_ne!(s1, s2, "groups get decorrelated RNG streams");
    }

    #[test]
    fn group_config_scopes_fault_scripts() {
        use crate::config::{
            FaultConfig, FaultKind, FleetConfig, ModelSpec, ScriptedFault,
        };
        use crate::workload::WorkloadKind;
        let mut cfg =
            SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 1.0);
        cfg.serving.fleet = Some(FleetConfig { groups: 3, ..Default::default() });
        cfg.serving.fault = Some(FaultConfig {
            script: vec![
                ScriptedFault {
                    kind: FaultKind::PrefillCrash,
                    instance: 0,
                    at_s: 5.0,
                    down_s: 2.0,
                    group: Some(1),
                },
                ScriptedFault {
                    kind: FaultKind::Straggler,
                    instance: 0,
                    at_s: 9.0,
                    down_s: 3.0,
                    group: None,
                },
            ],
            ..Default::default()
        });
        for g in 0..3usize {
            let script = FleetSim::group_config(&cfg, g).serving.fault.unwrap().script;
            let expect = if g == 1 { 2 } else { 1 };
            assert_eq!(script.len(), expect, "group {g} keeps its own + unscoped entries");
            assert!(
                script.iter().all(|s| s.group.is_none()),
                "scoping is resolved before the group sim sees the script"
            );
        }
    }
}
