//! Fleet-scale serving (ISSUE 8): N independent P/D groups — each an
//! ordinary [`ClusterSim`] topology — sharing one arrival trace behind a
//! cluster-level [`ClusterRouter`], with optional per-group prefill-pool
//! autoscaling (`FleetConfig::autoscale`, handled inside each group's
//! sim). DistServe (PAPERS.md) motivates the layer: at fleet scale,
//! goodput is decided by *placement above* the per-group proxies, which
//! keep routing within their group exactly as before.
//!
//! Two execution strategies, chosen by the router policy:
//!
//! * **Pre-partition** (round-robin, session-sticky, or a single group):
//!   the policy is a pure function of the request id, so the whole trace
//!   is routed upfront, each group's slice is renumbered onto a dense
//!   local id space, and the groups run as completely independent sims —
//!   one per core via [`parallel_map`], bit-identical to running them
//!   serially. A one-group fleet is exactly `ClusterSim::with_trace`
//!   over the generated trace, i.e. bit-identical to a bare sim (pinned
//!   by `rust/tests/fleet.rs`).
//! * **Lockstep co-simulation** (least-loaded with ≥ 2 groups): the
//!   router needs every group's *live* headroom at each arrival instant,
//!   so the groups advance together. Before injecting an arrival at
//!   `t`, every group receives a [`ClusterSim::fence`] at `t` and is
//!   pumped strictly past its events before `t`; the fence holds a
//!   smaller queue `seq` than the injected arrival, so the decode leap
//!   engine's strict next-event horizon fences every leap off the
//!   injection with no new engine machinery. The schedule is fully
//!   deterministic: same seed, same trace, same routing, same reports.

use std::sync::Mutex;

use crate::config::{FleetConfig, RouterPolicy};
use crate::coordinator::ClusterRouter;
use crate::metrics::{LatencyStats, Timeline};
use crate::workload::{Request, TraceGenerator};

use super::cluster::{ClusterSim, SimConfig, SimReport};
use super::run::parallel_map;

/// Seed stride between groups: group 0 keeps the fleet seed (so a
/// one-group fleet is bit-identical to a bare sim); further groups get
/// decorrelated fault/jitter RNG streams. The trace itself is generated
/// once from the fleet seed and shared, so routing — not seeding —
/// decides what each group serves.
const GROUP_SEED_STRIDE: u64 = 0x9e37_79b9_7f4a_7c15;

/// Post-run fleet report: the per-group [`SimReport`]s plus fleet-wide
/// aggregates.
#[derive(Debug)]
pub struct FleetReport {
    /// Per-group reports, group-index order.
    pub groups: Vec<SimReport>,
    /// Requests the cluster router sent to each group.
    pub router_decisions: Vec<u64>,
    /// Sum of per-group stable-window throughputs, tok/s.
    pub fleet_throughput: f64,
    /// Sum of per-group goodputs (DistServe metric), tok/s.
    pub fleet_goodput: f64,
    /// Count-weighted merge of per-group TTFT stats
    /// ([`LatencyStats::merged`]; percentiles approximate).
    pub fleet_ttft: Option<LatencyStats>,
    /// Count-weighted merge of per-group TPOT stats.
    pub fleet_tpot: Option<LatencyStats>,
    pub arrived: usize,
    pub finished: usize,
    pub steps_simulated: u64,
    pub events_processed: u64,
    /// Fleet-wide routable prefill-pool size over time: the step-function
    /// sum of every group's `prefill_pool_timeline` (empty without
    /// autoscaling).
    pub fleet_size_timeline: Timeline,
    /// Total scaling actions across the fleet (scale-ups + initiated
    /// scale-downs).
    pub scale_events: u64,
}

/// The fleet simulator. Owns one [`SimConfig`] describing every group's
/// base topology plus the shared trace parameters; groups can override
/// their device profiles via [`FleetConfig::group_profiles`]
/// (heterogeneous fleets — ISSUE 9).
pub struct FleetSim {
    cfg: SimConfig,
    fleet: FleetConfig,
}

impl FleetSim {
    /// `cfg.serving.fleet` decides the shape; `None` behaves as the
    /// default one-group round-robin fleet (bit-identical to a bare
    /// [`ClusterSim`] run — `rust/tests/fleet.rs` pins it).
    pub fn new(cfg: SimConfig) -> Self {
        let fleet = cfg.serving.fleet.clone().unwrap_or_default();
        assert!(fleet.groups >= 1, "a fleet needs at least one group");
        assert!(
            fleet.group_profiles.len() <= fleet.groups as usize,
            "group_profiles lists {} entries for {} groups",
            fleet.group_profiles.len(),
            fleet.groups
        );
        FleetSim { cfg, fleet }
    }

    pub fn run(self) -> FleetReport {
        let groups = self.fleet.groups.max(1) as usize;
        let mut gen = TraceGenerator::new(self.cfg.workload, self.cfg.rate, self.cfg.seed)
            .with_arrivals(self.cfg.arrivals);
        let trace = gen.trace(self.cfg.duration_s);
        let mut router = ClusterRouter::new(self.fleet.router, groups);

        let reports = if groups >= 2 && self.fleet.router == RouterPolicy::LeastLoaded {
            Self::run_lockstep(&self.cfg, trace, &mut router, groups)
        } else {
            Self::run_partitioned(&self.cfg, trace, &mut router, groups)
        };

        let fleet_size_timeline =
            stepwise_sum(&reports.iter().map(|r| &r.prefill_pool_timeline).collect::<Vec<_>>());
        let fleet_ttft = LatencyStats::merged(reports.iter().filter_map(|r| r.ttft.as_ref()));
        let fleet_tpot = LatencyStats::merged(reports.iter().filter_map(|r| r.tpot.as_ref()));
        FleetReport {
            router_decisions: router.decisions.clone(),
            fleet_throughput: reports.iter().map(|r| r.throughput).sum(),
            fleet_goodput: reports.iter().map(|r| r.goodput).sum(),
            fleet_ttft,
            fleet_tpot,
            arrived: reports.iter().map(|r| r.arrived).sum(),
            finished: reports.iter().map(|r| r.finished).sum(),
            steps_simulated: reports.iter().map(|r| r.steps_simulated).sum(),
            events_processed: reports.iter().map(|r| r.events_processed).sum(),
            fleet_size_timeline,
            scale_events: reports.iter().map(|r| r.scale_ups + r.scale_downs).sum(),
            groups: reports,
        }
    }

    /// Per-group config: identical topology/serving knobs; group 0 keeps
    /// the fleet seed, others get decorrelated RNG streams.
    fn group_config(cfg: &SimConfig, g: usize) -> SimConfig {
        let mut c = cfg.clone();
        if g > 0 {
            c.seed = cfg.seed.wrapping_add((g as u64).wrapping_mul(GROUP_SEED_STRIDE));
        }
        if let Some(Some(p)) = cfg.serving.fleet.as_ref().and_then(|f| f.group_profiles.get(g)) {
            c.cluster.profiles = Some(*p);
        }
        c
    }

    /// Static policies: route the whole trace upfront, renumber each
    /// slice dense, run the groups independently (one per core).
    fn run_partitioned(
        cfg: &SimConfig,
        trace: Vec<Request>,
        router: &mut ClusterRouter,
        groups: usize,
    ) -> Vec<SimReport> {
        let mut parts: Vec<Vec<Request>> = (0..groups).map(|_| Vec::new()).collect();
        for req in trace {
            let g = router.route(req.id, &[]);
            parts[g].push(req);
        }
        for part in &mut parts {
            for (i, r) in part.iter_mut().enumerate() {
                r.id = i as u64;
            }
        }
        let cfgs: Vec<SimConfig> = (0..groups).map(|g| Self::group_config(cfg, g)).collect();
        // `parallel_map` wants `Fn`, not `FnOnce`; each group's slice is
        // handed over through a take-once slot.
        let slots: Vec<Mutex<Option<Vec<Request>>>> =
            parts.into_iter().map(|p| Mutex::new(Some(p))).collect();
        parallel_map(groups, |g| {
            let part = slots[g]
                .lock()
                .expect("no panics while holding a slot")
                .take()
                .expect("each group runs exactly once");
            ClusterSim::with_trace(cfgs[g].clone(), part).run()
        })
    }

    /// Least-loaded: co-simulate the groups in lockstep so every routing
    /// decision reads each group's state *at the arrival instant*.
    fn run_lockstep(
        cfg: &SimConfig,
        trace: Vec<Request>,
        router: &mut ClusterRouter,
        groups: usize,
    ) -> Vec<SimReport> {
        // Offload bounds derive from the mean sequence length; use the
        // full shared trace so every group prices against the same
        // bounds a whole-trace build would.
        let avg_seq = if trace.is_empty() {
            1024
        } else {
            (trace.iter().map(|r| r.total_tokens()).sum::<usize>() / trace.len()) as u64
        };
        let mut sims: Vec<ClusterSim> = (0..groups)
            .map(|g| ClusterSim::lockstep(Self::group_config(cfg, g), avg_seq))
            .collect();
        for sim in &mut sims {
            sim.prime();
        }
        let mut headroom = vec![0.0f64; groups];
        let mut last_t = f64::NEG_INFINITY;
        for req in trace {
            let t = req.arrival_s;
            debug_assert!(t >= last_t, "lockstep needs a time-sorted trace");
            last_t = t;
            // Fence first, then pump strictly past events before `t`:
            // after this, every group's clock is < `t` and no group has
            // committed state at or beyond the injection instant.
            for sim in &mut sims {
                sim.fence(t);
                sim.pump(t);
            }
            for (g, sim) in sims.iter().enumerate() {
                headroom[g] = sim.router_headroom();
            }
            let g = router.route(req.id, &headroom);
            sims[g].inject(req);
        }
        sims.into_iter()
            .map(|mut sim| {
                sim.close_arrivals();
                sim.pump(f64::INFINITY);
                sim.report()
            })
            .collect()
    }
}

/// Step-function sum of several timelines: at every sample time in any
/// input, emit the sum of each input's most recent value at or before
/// that time (inputs are carried forward between their own samples).
/// Pool timelines all start with a t=0 sample, so the carry-forward is
/// well-defined from the origin.
fn stepwise_sum(lines: &[&Timeline]) -> Timeline {
    let mut idx = vec![0usize; lines.len()];
    let mut cur = vec![0.0f64; lines.len()];
    let mut out = Timeline::new();
    loop {
        let mut next: Option<f64> = None;
        for (i, l) in lines.iter().enumerate() {
            if let Some(&(t, _)) = l.points().get(idx[i]) {
                next = Some(next.map_or(t, |n: f64| n.min(t)));
            }
        }
        let Some(t) = next else { break };
        for (i, l) in lines.iter().enumerate() {
            while let Some(&(pt, v)) = l.points().get(idx[i]) {
                if pt <= t {
                    cur[i] = v;
                    idx[i] += 1;
                } else {
                    break;
                }
            }
        }
        out.push(t, cur.iter().sum());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl(points: &[(f64, f64)]) -> Timeline {
        let mut t = Timeline::new();
        for &(x, v) in points {
            t.push(x, v);
        }
        t
    }

    #[test]
    fn stepwise_sum_carries_values_forward() {
        let a = tl(&[(0.0, 2.0), (1.0, 3.0), (4.0, 1.0)]);
        let b = tl(&[(0.0, 4.0), (2.0, 5.0)]);
        let s = stepwise_sum(&[&a, &b]);
        assert_eq!(
            s.points(),
            &[(0.0, 6.0), (1.0, 7.0), (2.0, 8.0), (4.0, 6.0)],
            "each sample time sums the latest value of every input"
        );
        assert!(stepwise_sum(&[]).is_empty());
        let empty = Timeline::new();
        assert_eq!(stepwise_sum(&[&a, &empty]).points(), a.points());
    }

    #[test]
    fn group_config_keeps_group_zero_seed() {
        use crate::config::ModelSpec;
        use crate::workload::WorkloadKind;
        let cfg = SimConfig::paper_default(ModelSpec::llama2_7b(), WorkloadKind::ShareGpt, 1.0);
        assert_eq!(FleetSim::group_config(&cfg, 0).seed, cfg.seed);
        let s1 = FleetSim::group_config(&cfg, 1).seed;
        let s2 = FleetSim::group_config(&cfg, 2).seed;
        assert_ne!(s1, cfg.seed);
        assert_ne!(s1, s2, "groups get decorrelated RNG streams");
    }
}
