//! Discrete-event cluster simulator for the A100-scale evaluation
//! (Figs 1–3, 5–6, 9–18). See DESIGN.md §1 for why the paper's testbed is
//! simulated and §4 for the per-figure index.
//!
//! The engine-mode API ([`EngineMode`]) resolves every engine switch
//! (leap, within-run parallelism, exact costs, process-wide serial) once
//! per run from config + the `ADRENALINE_*` escape hatches;
//! [`FleetSim`] scales the single-cluster sim to N routed P/D groups
//! with prefill-pool autoscaling (EXPERIMENTS.md §Fleet).

pub mod cluster;
pub mod engine_mode;
pub mod events;
pub mod fleet;
pub mod run;

pub use cluster::{ClusterSim, SimConfig, SimReport};
pub use engine_mode::{engine_env, EngineEnv, EngineMode};
pub use fleet::{FleetReport, FleetSim};
pub use run::{
    budget_acquire, budget_release, par_config, parallel_map, parallel_map_capped, run_e2e_with,
    run_ratio_sweep_with, E2eConfig, E2ePoint, ExecMode, ParallelismConfig, PoolTask, WorkerPool,
};
