//! Discrete-event cluster simulator for the A100-scale evaluation
//! (Figs 1–3, 5–6, 9–18). See DESIGN.md §1 for why the paper's testbed is
//! simulated and §4 for the per-figure index.

pub mod cluster;
pub mod events;
pub mod run;

pub use cluster::{ClusterSim, SimConfig, SimReport};
pub use run::{
    budget_acquire, budget_release, par_config, parallel_map, parallel_map_capped, run_e2e,
    run_e2e_serial, run_ratio_sweep, run_ratio_sweep_serial, E2eConfig, E2ePoint,
    ParallelismConfig, PoolTask, WorkerPool,
};
