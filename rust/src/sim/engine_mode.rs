//! The engine-mode API (ISSUE 8): one typed struct answering "which
//! simulator engines are on for this run", resolved **once** at run
//! start from `ServingConfig` plus the `ADRENALINE_*` escape-hatch
//! environment variables.
//!
//! Before this module the four escape hatches — `ADRENALINE_NO_LEAP`,
//! `ADRENALINE_NO_PAR`, `ADRENALINE_EXACT_COSTS`, `ADRENALINE_SERIAL` —
//! were each read at their own call site with the precedence rule
//! (env forces the hatch regardless of config) re-implemented inline.
//! Now [`EngineEnv::from_process_env`] is the **only** code site that
//! reads them (grep-enforced in CI's lint job), and
//! [`EngineMode::resolve`] is the only place the env-vs-config
//! precedence lives. `ClusterSim` resolves its mode in its constructor;
//! `parallel_map`'s process-wide serial switch reads [`engine_env`].
//!
//! Every hatch keeps its exact pre-redesign meaning, so the bit-identity
//! suites (`step_leap`, `par_run`, `faults`) pin the refactor.

use crate::config::ServingConfig;
use std::sync::OnceLock;

/// Snapshot of the `ADRENALINE_*` engine escape hatches. Plain data so
/// tests can resolve modes from synthetic environments without touching
/// the process env.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineEnv {
    /// `ADRENALINE_NO_LEAP=1`: force the per-step decode reference path.
    pub no_leap: bool,
    /// `ADRENALINE_NO_PAR=1`: force inline (single-thread) epoch pricing.
    pub no_par: bool,
    /// `ADRENALINE_EXACT_COSTS=1`: force exact (pre-bucketing) step costs.
    pub exact_costs: bool,
    /// `ADRENALINE_SERIAL=1`: force every `parallel_map` sweep serial
    /// (which also implies `no_par` inside a run).
    pub serial: bool,
}

impl EngineEnv {
    /// Read the process environment. The **single** `ADRENALINE_*`
    /// engine-mode read site in the codebase — add no others.
    pub fn from_process_env() -> Self {
        let on = |key: &str| std::env::var(key).map_or(false, |v| v == "1");
        EngineEnv {
            no_leap: on("ADRENALINE_NO_LEAP"),
            no_par: on("ADRENALINE_NO_PAR"),
            exact_costs: on("ADRENALINE_EXACT_COSTS"),
            serial: on("ADRENALINE_SERIAL"),
        }
    }
}

/// The process-wide [`EngineEnv`] snapshot, read once. Sweeps and tests
/// within one process see a stable answer even if the environment
/// mutates mid-run (mirrors the old `par_config` OnceLock semantics).
pub fn engine_env() -> &'static EngineEnv {
    static ENV: OnceLock<EngineEnv> = OnceLock::new();
    ENV.get_or_init(EngineEnv::from_process_env)
}

/// Which engines a simulator run drives, fully resolved — consumers
/// never look at `ServingConfig::{no_leap,no_par,exact_costs}` or the
/// environment again after construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineMode {
    /// Steady-state decode leaping (default on).
    pub leap: bool,
    /// Within-run parallel epoch pricing (default on).
    pub par: bool,
    /// Exact (unbucketed) step costs instead of the executable grid.
    pub exact_costs: bool,
    /// Process-wide serial sweeps (`parallel_map` runs inline).
    pub serial: bool,
}

impl EngineMode {
    /// The one env-vs-config precedence rule: each env hatch *forces*
    /// its engine off (or exact costs on) regardless of config; config
    /// alone can do the same per run. `serial` comes only from the env
    /// (it is a process property, not a per-run one) and implies `par`
    /// off, exactly like the old `par_config().serial` check inside the
    /// run loop.
    pub fn resolve(cfg: &ServingConfig, env: &EngineEnv) -> Self {
        let serial = env.serial;
        EngineMode {
            leap: !(cfg.no_leap || env.no_leap),
            par: !(cfg.no_par || env.no_par || serial),
            exact_costs: cfg.exact_costs || env.exact_costs,
            serial,
        }
    }

    /// Resolve against the process environment snapshot.
    pub fn from_config(cfg: &ServingConfig) -> Self {
        Self::resolve(cfg, engine_env())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_enable_both_engines() {
        let m = EngineMode::resolve(&ServingConfig::default(), &EngineEnv::default());
        assert!(m.leap && m.par);
        assert!(!m.exact_costs && !m.serial);
    }

    #[test]
    fn config_knobs_disable_per_run() {
        let cfg = ServingConfig {
            no_leap: true,
            no_par: true,
            exact_costs: true,
            ..Default::default()
        };
        let m = EngineMode::resolve(&cfg, &EngineEnv::default());
        assert!(!m.leap && !m.par && m.exact_costs && !m.serial);
    }

    #[test]
    fn env_forces_regardless_of_config() {
        // Config says "engines on"; every env hatch must still win.
        let cfg = ServingConfig::default();
        let m = EngineMode::resolve(
            &cfg,
            &EngineEnv { no_leap: true, no_par: true, exact_costs: true, serial: false },
        );
        assert!(!m.leap && !m.par && m.exact_costs);
    }

    #[test]
    fn serial_implies_no_par_but_not_no_leap() {
        let m = EngineMode::resolve(
            &ServingConfig::default(),
            &EngineEnv { serial: true, ..Default::default() },
        );
        assert!(m.serial && !m.par, "serial sweeps must also run epochs inline");
        assert!(m.leap, "serial does not touch the leap engine");
    }

    #[test]
    fn env_and_config_compose_independently() {
        // no_leap from config + no_par from env: each hatch acts alone.
        let cfg = ServingConfig { no_leap: true, ..Default::default() };
        let m = EngineMode::resolve(&cfg, &EngineEnv { no_par: true, ..Default::default() });
        assert!(!m.leap && !m.par && !m.exact_costs && !m.serial);
    }

    #[test]
    fn from_config_matches_resolve_on_process_env() {
        let cfg = ServingConfig::default();
        assert_eq!(EngineMode::from_config(&cfg), EngineMode::resolve(&cfg, engine_env()));
    }
}
