//! Discrete-event simulation of a PD-disaggregated serving cluster on
//! A100-class hardware — the testbed substitute for the paper's §4
//! evaluation (DESIGN.md §1).
//!
//! Fidelity choices, mapped to the paper:
//!
//! * **Phases.** Requests route through the proxy (Algorithm 1 decides
//!   offloading at admission), queue for prefill, prefill at roofline
//!   speed (SM-partition slowdown when an attention executor is
//!   reserved/active), transfer KV to the decode instance over NVLink
//!   (local requests only — offloaded KV stays colocated with the
//!   executor), then decode step-by-step under continuous batching.
//! * **Decode step time.** `non_attention(batch)` + `max(local attention,
//!   remote attention + per-layer sync)`: the paper's overlap model
//!   (Fig 8b). Remote attention runs on the executor's SM share with the
//!   superlinear-bandwidth curve (Fig 9).
//! * **Memory.** Decode KV pool and per-prefill-instance executor pools
//!   sized from HBM budgets (overridable via
//!   `ServingConfig::{decode,executor}_kv_capacity_tokens` for exhaustion
//!   tests); exhaustion causes LIFO preemption with recompute (vLLM
//!   semantics), the effect behind the OpenThoughts TPOT spikes
//!   (Figs 13/14).
//! * **Dispatch gating.** A prompt is only dispatched to prefill when its
//!   KV has a home (decode pool for local, executor pool for offloaded) —
//!   queueing at high rate is what blows up vLLM's TTFT in Fig 11a.
//! * **Faults.** `ServingConfig::fault` (default `None` → structurally
//!   inert: no fault state, events, or RNG draws exist) arms scripted
//!   and/or seeded-stochastic instance crashes, transient KV-transfer
//!   failures (exponential backoff, recompute fallback), and executor
//!   straggler windows — the failure domain attention disaggregation
//!   creates (an offloaded request's KV lives in a *prefill* instance's
//!   executor HBM). See the fault-plane section below and
//!   `rust/tests/faults.rs`.
//!
//! # Hot path (EXPERIMENTS.md §Perf)
//!
//! The per-step path is allocation-free and rescans nothing:
//!
//! * requests live in a dense slab (`Vec<SimReq>` indexed by request id —
//!   the trace generator hands out dense ids);
//! * running sets remove by swap-remove via a back-pointer (`run_slot`),
//!   with LIFO preemption order preserved through `admit_seq`;
//! * each decode instance keeps incremental aggregates (local/remote
//!   context-token sums and row counts) so pricing a step is O(1) in
//!   the batch size (O(n_prefill) for the remote max);
//! * all step-time math lives in the [`CostModel`] cost plane: memoized
//!   decode and prefill roofline tables, routed (by default) through the
//!   2-D executable-bucket grid so every step pays the padded rows real
//!   graph capture executes (§3.2.2). `ServingConfig::exact_costs` or
//!   `ADRENALINE_EXACT_COSTS=1` selects the exact pre-bucketing model;
//! * steady-state decode steps *leap*: between irregular events the
//!   batch composition is frozen, so runs of clean steps commit inline
//!   (O(1) scalar work per step plus one O(batch) bulk flush per leap)
//!   and only the first interesting step is scheduled as an event — see
//!   [`ClusterSim::maybe_start_step`]. `ServingConfig::no_leap` or
//!   `ADRENALINE_NO_LEAP=1` keeps the bit-identical per-step reference;
//! * passes where **several** instances start a step run the within-run
//!   parallel epoch engine instead: every starter's step series is
//!   priced concurrently on a persistent worker pool (per-instance
//!   clones of the cost plane — memo back-fills are value-transparent)
//!   and committed through a deterministic merge that replays side
//!   effects in exact serial event order, so the report stays
//!   bit-identical to the `ADRENALINE_NO_PAR=1` inline path *and* to
//!   the per-step reference — see [`ClusterSim::run_epoch`] and
//!   `rust/tests/par_run.rs`.

use std::collections::VecDeque;

use crate::config::{
    AutoscaleConfig, ClusterSpec, FaultConfig, FaultKind, ModelSpec, ServingConfig,
};
use crate::coordinator::{BucketPair, OffloadBounds, Proxy, RebalanceController, RebalanceMode};
use crate::kv::{BlockAllocator, KvPool};
use crate::gpu_model::{
    BTpotEstimator, CostMode, CostModel, DecodeStepCost, DutyCycleEstimator, HbmUsage,
    InterferenceModel, Roofline, PREFILL_BW_FRAC,
};
use crate::metrics::{LatencyStats, MetricsRecorder, StableWindow, Timeline};
use crate::util::rng::Rng;
use crate::workload::{ArrivalPattern, Request, RequestId, TraceGenerator, WorkloadKind};

use super::engine_mode::EngineMode;
use super::events::EventQueue;
use super::run::{PoolTask, WorkerPool};

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub cluster: ClusterSpec,
    pub model: ModelSpec,
    pub serving: ServingConfig,
    pub workload: WorkloadKind,
    /// Arrival-process shape (Poisson by default; bursty/diurnal for the
    /// rebalancer scenarios — EXPERIMENTS.md §Scenarios).
    pub arrivals: ArrivalPattern,
    /// Mean request rate, req/s.
    pub rate: f64,
    /// Trace duration, seconds (drain continues afterwards).
    pub duration_s: f64,
    pub seed: u64,
    /// Per-layer decode↔executor synchronization overhead (the residual
    /// after graph-based launch batching; §3.2.2).
    pub sync_overhead_s: f64,
    /// Extra CPU launch overhead per decode step when the executable
    /// grid / CUDA-graph analogue is disabled (ablation; §3.2.2 measures
    /// ~0.76 ms/layer wasted without graphs).
    pub eager_launch_overhead_s: f64,
}

impl SimConfig {
    pub fn paper_default(model: ModelSpec, workload: WorkloadKind, rate: f64) -> Self {
        SimConfig {
            cluster: ClusterSpec::paper_default(),
            model,
            serving: ServingConfig::default(),
            workload,
            arrivals: ArrivalPattern::Poisson,
            rate,
            duration_s: 300.0,
            seed: 42,
            // ~15 µs per layer of channel+merge overhead with graphs on.
            sync_overhead_s: 15e-6,
            eager_launch_overhead_s: 0.0,
        }
    }

    pub fn baseline(model: ModelSpec, workload: WorkloadKind, rate: f64) -> Self {
        SimConfig {
            serving: ServingConfig::baseline(),
            ..Self::paper_default(model, workload, rate)
        }
    }

    /// §3.3.2 online stage: derive the attention executor's SM share from
    /// the offline prefill profile — the minimal prefill reservation that
    /// keeps `avg_prompt`-token prompts within the TTFT SLO, executor gets
    /// the complement (capped at 0.5: the executor never starves prefill
    /// past the Fig 10 sweet spot).
    pub fn with_adaptive_partition(mut self, avg_prompt: u64) -> Self {
        use crate::gpu_model::PrefillProfile;
        // Profile on the *prefill* device — the instance class the SM
        // reservation actually runs on (same GPU as `cluster.gpu` unless
        // a heterogeneous profile overrides it).
        let profile =
            PrefillProfile::default_grid(&self.cluster.prefill_profile().gpu, &self.model);
        // Leave queueing headroom: prefill must fit in half the TTFT SLO.
        let exec = profile.executor_sm_frac(avg_prompt.max(1), self.serving.slo.ttft_s * 0.5);
        self.cluster.attn_executor_sm_frac = exec.clamp(0.05, 0.5);
        self
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    WaitingDispatch,
    Prefilling,
    Transferring,
    Decoding,
    /// KV in flight between the decode pool and an executor pool (runtime
    /// rebalancing): out of the batch until `MigrationDone`.
    Migrating,
    Done,
    /// Handed off to another fleet group via [`ClusterSim::export_pending`]
    /// (cross-group failover, ISSUE 10): this slab entry is closed — the
    /// request finishes under a fresh id in the destination group's slab.
    Exported,
}

/// Executor-pool occupancy (incl. reservations) above which the rebalancer
/// stops migrating *more* attention onto an executor — the headroom keeps
/// dispatch gating from starving on migrated KV.
const OFFLOAD_POOL_HEADROOM: f64 = 0.95;

/// Tighter executor-pool watermark for offload migrations onto an
/// instance whose controller is in burst (Reclaim) mode: its incoming
/// cohort still needs dispatch reservations.
const OFFLOAD_POOL_HEADROOM_BURST: f64 = 0.90;

/// Decode-pool occupancy cap for reclaim migrations: never trade executor
/// pressure for local preemption churn.
const RECLAIM_DECODE_POOL_GUARD: f64 = 0.9;

/// Time constant for the decayed executor duty-cycle estimate the prefill
/// interference model consumes (EXPERIMENTS.md §Scenarios): busy seconds
/// older than a few tens of seconds stop weighing on the contention
/// estimate, so a busy warm-up no longer haunts the steady state.
const DUTY_TAU_S: f64 = 10.0;

/// Sentinel for "not in any running set".
const NO_SLOT: usize = usize::MAX;

/// Salt for the fault plane's dedicated RNG stream: faults draw from
/// `seed ^ SALT`, so enabling them never perturbs the workload trace —
/// a faulted run and its fault-free control see identical arrivals.
const FAULT_RNG_SALT: u64 = 0xFA17_1A7E_D15A_57E5;

/// Upper bound on decode steps committed per leap (bounds scratch-buffer
/// growth). A leap truncated here simply continues on the next pass, so
/// the cap never changes results — only the collapse granularity of very
/// long event-free stretches (drain tails).
const MAX_LEAP_STEPS: usize = 4096;

#[derive(Debug, Clone)]
struct SimReq {
    req: Request,
    phase: Phase,
    /// Output tokens generated so far.
    generated: usize,
    /// Tokens of KV this request holds (prompt + generated, after prefill).
    kv_tokens: usize,
    offloaded: bool,
    prefill_instance: usize,
    decode_instance: usize,
    /// Re-prefill length after preemption (prompt + generated).
    effective_prompt: usize,
    preemptions: u32,
    /// Rollback generation: bumped on every preemption and fault-recovery
    /// recompute. Per-request events (`PrefillDone` / `TransferDone` /
    /// `MigrationDone` / `TransferRetry`) carry the epoch they were
    /// scheduled under and are dropped stale on mismatch — a crash can
    /// leave a dead instance's completions in the queue. Always 0 with
    /// `fault: None` and no preemption.
    epoch: u32,
    /// KV-transfer retry attempts for the in-flight transfer (fault
    /// plane; reset at each transfer start).
    transfer_attempts: u32,
    /// Position in its decode instance's `running` vec (`NO_SLOT` when not
    /// running). Back-pointer for O(1) swap-remove.
    run_slot: usize,
    /// Monotone admission stamp; preserves LIFO (newest-first) preemption
    /// order now that `running` is no longer kept in admission order.
    admit_seq: u64,
}

#[derive(Debug)]
struct PrefillInst {
    busy_until: f64,
    queue: VecDeque<RequestId>,
    /// Offloaded KV tokens resident in this instance's executor pool.
    executor_kv_tokens: usize,
    executor_kv_budget: usize,
    /// Reserved (dispatched but not yet admitted) executor tokens.
    executor_reserved: usize,
    /// Accumulated busy seconds (prefill compute).
    prefill_busy_s: f64,
    /// Accumulated executor-active seconds.
    executor_busy_s: f64,
}

#[derive(Debug)]
struct DecodeInst {
    /// Running batch (request ids). NOT in admission order — removal is
    /// swap-remove; use `SimReq::admit_seq` for LIFO scans.
    running: Vec<RequestId>,
    /// Prefilled requests waiting for KV admission.
    waiting: VecDeque<RequestId>,
    /// Paged KV pool (vLLM block tables; block granularity makes the
    /// occupancy/preemption dynamics faithful to the real allocator).
    kv: KvPool,
    /// Reserved (dispatched) tokens not yet admitted.
    reserved: usize,
    step_in_flight: bool,
    /// Step generation: bumped on a decode crash so a dead batch's queued
    /// `DecodeStepEnd` cannot clear a post-recovery step's `step_in_flight`
    /// or grant its tokens. Always 0 with `fault: None`.
    step_epoch: u32,
    /// Accumulated (flops, seconds) for compute-utilization accounting.
    flops_done: f64,
    busy_s: f64,
    // ----- incremental aggregates over `running` ------------------------
    // Kept in sync on admit / per-token append / finish / preempt so the
    // per-step timing model never rescans the batch.
    /// Local (non-offloaded) rows in the running batch.
    local_rows: u64,
    /// Sum of `kv_tokens` over local running rows.
    local_ctx: u64,
    /// Offloaded rows per prefill instance.
    remote_rows: Vec<u64>,
    /// Sum of `kv_tokens` over offloaded running rows, per prefill inst.
    remote_ctx: Vec<u64>,
}

impl DecodeInst {
    fn kv_tokens(&self) -> usize {
        self.kv.resident_tokens()
    }

    fn kv_budget(&self) -> usize {
        self.kv.total_blocks() * self.kv.block_tokens()
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival(RequestId),
    PrefillDone { inst: usize, id: RequestId, epoch: u32 },
    TransferDone { id: RequestId, epoch: u32 },
    DecodeStepEnd { inst: usize, epoch: u32 },
    /// A rebalance migration's KV transfer finished; the request rejoins
    /// its decode instance's waiting queue on the new side.
    MigrationDone { id: RequestId, epoch: u32 },
    /// Periodic rebalance-controller tick (only scheduled when
    /// `ServingConfig::rebalance` is set and offloading is enabled).
    RebalanceTick,
    /// Standalone online-bounds refresh tick — scheduled only when
    /// `ServingConfig::bounds_feedback` is set, offloading is enabled,
    /// and no rebalancer runs (with rebalancing on, refreshes ride the
    /// rebalance ticks instead of duplicating the event stream).
    BoundsRefreshTick,
    // ----- fault plane (only ever scheduled when `fault` is Some) -------
    /// An instance (or one executor's step cost, for `Straggler`) fails at
    /// this instant for `down_s` seconds. The handler pushes the matching
    /// `InstanceUp`; `stochastic` marks the MTBF/MTTR chain's events so
    /// only that chain's recoveries draw + schedule the next failure.
    InstanceDown { kind: FaultKind, inst: usize, down_s: f64, stochastic: bool },
    InstanceUp { kind: FaultKind, inst: usize, stochastic: bool },
    /// A failed KV transfer's backoff expired: redraw the attempt.
    TransferRetry { id: RequestId, epoch: u32 },
    /// Heartbeat: the proxy reconciles its health view with the sim's
    /// down-state (detection latency <= `FaultConfig::heartbeat_s`) and
    /// the health timeline samples.
    HealthTick,
    /// Prefill-pool autoscaler tick (only scheduled when
    /// `FleetConfig::autoscale` is set): assess mean queue pressure,
    /// scale the active pool up/down, progress a pending drain.
    AutoscaleTick,
    /// Fleet lockstep horizon marker (pushed by `FleetSim` before every
    /// co-simulated arrival; a no-op for the run loop). Its only job is
    /// its timestamp: while it sits at the queue head, `pump(cap)` with
    /// `cap` at its time cannot pop past it, so the leap engine's strict
    /// next-event horizon fences every leap off the upcoming injection
    /// with no new engine code.
    Fence,
}

/// Post-run report.
#[derive(Debug)]
pub struct SimReport {
    pub ttft: Option<LatencyStats>,
    pub tpot: Option<LatencyStats>,
    /// Output tokens/s over the §4.1 stable window (falls back to the
    /// whole run if no window is detected).
    pub throughput: f64,
    pub window: Option<StableWindow>,
    pub arrived: usize,
    pub finished: usize,
    pub preemptions: u64,
    /// Sum of per-request preemption counters — always equals
    /// `preemptions` (checked by the conservation tests). Fault
    /// recoveries count under `requests_recovered`, not here.
    pub req_preemptions_total: u64,
    /// Token-accounting invariant: every finished request produced exactly
    /// the tokens the recorder saw for it (and at least its `output_len`),
    /// and the global recorder total matches the per-request sums.
    pub tokens_conserved: bool,
    /// Fraction of finished requests whose attention was offloaded.
    pub offloaded_fraction: f64,
    /// Mean prefill-instance HBM capacity utilization (Fig 16).
    pub prefill_hbm_capacity_util: f64,
    /// Mean prefill-instance HBM bandwidth utilization (Fig 17a).
    pub prefill_hbm_bw_util: f64,
    /// Executor-active bandwidth utilization (Fig 18a "Attn on").
    pub executor_bw_util: f64,
    /// Executor duty cycle (fraction of wall time active).
    pub executor_duty: f64,
    /// Mean decode compute utilization (Fig 17b).
    pub decode_compute_util: f64,
    /// Fraction of finished requests whose TTFT met the SLO.
    pub ttft_slo_attainment: f64,
    /// Fraction of finished requests whose *mean* TPOT met the SLO.
    pub tpot_slo_attainment: f64,
    /// Finished requests that met BOTH SLOs — the count behind `goodput`,
    /// exposed so fleet-level accounting can pool attainment across
    /// groups with shed requests in the denominator (ISSUE 10).
    pub requests_slo_met: usize,
    /// Output tokens generated by the `requests_slo_met` requests. Feeds
    /// the fleet's offered-timeline-normalized shed-aware goodput
    /// (`FleetReport::fleet_goodput_shed_aware`), which deliberately
    /// avoids the stable window: on faulted runs a post-recovery drain
    /// burst can capture (or dilute) the window arbitrarily.
    pub slo_met_tokens: u64,
    /// Goodput: output tokens/s counting only requests that met BOTH SLOs
    /// (the DistServe-style metric; same stable window as `throughput`).
    pub goodput: f64,
    /// Timelines for Figs 2/16.
    pub decode_occupancy: Timeline,
    pub prefill_occupancy: Timeline,
    pub batch_size: Timeline,
    pub sim_end_s: f64,
    /// Discrete events processed by the run loop. Leaping (the default)
    /// collapses runs of decode-step events into single events, so this
    /// is NOT comparable across leap modes and is no longer a stable
    /// perf metric — benches/sim_throughput.rs and the CI floor gate
    /// track `steps_simulated`-based steps/s instead.
    pub events_processed: u64,
    /// Decode steps whose token grant executed (committed inline by the
    /// leap engine or popped as `DecodeStepEnd` events with a non-empty
    /// batch). Identical with leaping on or off — the leap-robust
    /// denominator for sim-perf tracking.
    pub steps_simulated: u64,
    /// True when step costs were charged at exact batch sizes (ablation /
    /// regression mode) instead of the default bucket-padded model.
    pub exact_costs: bool,
    /// Executable-grid selections performed (one per decode step in
    /// bucketed mode; 0 in exact mode).
    pub graph_selections: u64,
    /// Batch slots actually requested, summed over selections.
    pub graph_used_slots: u64,
    /// Batch slots paid to bucket padding, summed over selections.
    pub graph_padded_slots: u64,
    /// `padded / (used + padded)` — the fraction of charged batch slots
    /// wasted to bucket granularity (the §3.2.2 interval trade-off).
    pub graph_padding_overhead: f64,
    /// Selection counts per captured `(C_d, C_o)` pair (non-zero only).
    pub graph_bucket_hits: Vec<(BucketPair, u64)>,
    /// Completed runtime rebalance migrations (0 without
    /// `ServingConfig::rebalance`).
    pub migrations_total: u64,
    /// Migrations that moved attention local → offloaded.
    pub migrations_to_offload: u64,
    /// Migrations that pulled attention offloaded → local.
    pub migrations_to_local: u64,
    /// KV tokens moved across the interconnect by migrations.
    pub migration_tokens_moved: u64,
    /// Offloaded fraction of proxy-tracked requests, sampled once per
    /// rebalance tick (empty without rebalancing).
    pub offloaded_frac_timeline: Timeline,
    /// Prefill-instance-0 queue pressure (queued prompt tokens /
    /// `max_prefill_tokens`), sampled once per rebalance tick.
    pub prefill_pressure_timeline: Timeline,
    /// Requests still tracked by the proxy at sim end — 0 whenever the run
    /// drained fully (the metadata-residency invariant the rebalancer must
    /// preserve).
    pub metadata_residual: usize,
    /// Per-refresh-tick B_TPOT held by the proxy's bounds (empty without
    /// `ServingConfig::bounds_feedback`).
    pub b_tpot_timeline: Timeline,
    /// Per-refresh-tick OB (Eq 3) after the refresh (empty without
    /// `bounds_feedback`).
    pub ob_timeline: Timeline,
    /// Online bounds refreshes actually applied (`Proxy::observe_b_tpot`
    /// calls; 0 without `bounds_feedback`).
    pub bounds_refreshes: u64,
    /// Decode-step observations fed to the online B_TPOT estimator (0
    /// without `bounds_feedback`).
    pub b_tpot_observations: u64,
    /// Fresh-arrival offload decisions (C1, C2, Local) — sums to
    /// `arrived` once every request has been routed.
    pub decision_counts: (u64, u64, u64),
    /// Re-route decisions (C1, C2, Local) for requests re-admitted via the
    /// recompute path — sums to `preemptions` plus the fault plane's
    /// recompute recoveries (one re-admission per rollback).
    pub decision_counts_rerouted: (u64, u64, u64),
    // ----- fault plane (all zero / empty with `fault: None`) ------------
    /// Fault windows opened: scripted + stochastic down events and
    /// straggler windows.
    pub faults_injected: u64,
    /// Requests carried through fault recovery: crash recomputes, decode
    /// re-routes of executor-resident victims, and transfer-retry
    /// exhaustion recomputes.
    pub requests_recovered: u64,
    /// Prompt + generated tokens re-prefilled by fault recomputes.
    pub recompute_tokens_replayed: u64,
    /// KV-transfer retry attempts performed (prefill→decode + migration).
    pub transfer_retries: u64,
    /// Wall time with at least one fault window active.
    pub degraded_time_s: f64,
    /// Requests handed off to another fleet group via
    /// [`ClusterSim::export_pending`] (cross-group failover, ISSUE 10).
    /// Their slab entries stay here as `Exported`; they arrive — and
    /// finish — under fresh ids in the destination group.
    pub requests_exported: u64,
    /// Fraction of instances (prefill + decode) healthy, sampled at every
    /// `HealthTick`.
    pub health_timeline: Timeline,
    // ----- prefill-pool autoscaler (empty / zero without
    // `FleetConfig::autoscale`) ------------------------------------------
    /// Routable prefill-pool size (active, non-draining instances),
    /// sampled at t=0 and at every `AutoscaleTick`.
    pub prefill_pool_timeline: Timeline,
    /// Completed scale-up actions.
    pub scale_ups: u64,
    /// Initiated scale-down (drain) actions.
    pub scale_downs: u64,
}

/// Runtime state of the fault-injection plane (`ServingConfig::fault`).
/// Lives behind `Option` on [`ClusterSim`], so `fault: None` pays no
/// state and takes no new branches on the hot path.
struct FaultPlane {
    cfg: FaultConfig,
    /// Dedicated RNG stream (seed ^ [`FAULT_RNG_SALT`]): stochastic fault
    /// schedules and transfer-failure draws never perturb the trace.
    rng: Rng,
    /// Per-instance down depth — overlapping scripted windows nest, so a
    /// crash acts only on 0→1 and a recovery only on 1→0.
    prefill_down: Vec<u32>,
    decode_down: Vec<u32>,
    straggler_depth: Vec<u32>,
    /// Currently-open fault windows (degraded-time bookkeeping).
    active: u32,
    degraded_since: Option<f64>,
    degraded_time_s: f64,
    faults_injected: u64,
    requests_recovered: u64,
    recompute_tokens_replayed: u64,
    transfer_retries: u64,
    health_timeline: Timeline,
}

impl FaultPlane {
    fn new(cfg: FaultConfig, seed: u64, n_prefill: usize, n_decode: usize) -> Self {
        FaultPlane {
            rng: Rng::seed_from_u64(seed ^ FAULT_RNG_SALT),
            cfg,
            prefill_down: vec![0; n_prefill],
            decode_down: vec![0; n_decode],
            straggler_depth: vec![0; n_prefill],
            active: 0,
            degraded_since: None,
            degraded_time_s: 0.0,
            faults_injected: 0,
            requests_recovered: 0,
            recompute_tokens_replayed: 0,
            transfer_retries: 0,
            health_timeline: Timeline::new(),
        }
    }
}

/// Runtime state of the prefill-pool autoscaler
/// (`FleetConfig::autoscale`). Lives behind `Option` on [`ClusterSim`]
/// like the fault plane, so `autoscale: None` pays no state and takes no
/// new branches — `fleet: None` runs stay bit-identical to a simulator
/// without the subsystem.
///
/// Scaling rides the existing health machinery: an inactive or draining
/// instance is marked proxy-unhealthy, so health-aware routing masks it
/// and `OB_mem` rescales exactly as it does when a heartbeat observes a
/// crash. Drain-before-down means a victim keeps serving its queued
/// prompts and its executor-resident KV until both are gone; only then
/// does it leave the pool.
struct Scaler {
    cfg: AutoscaleConfig,
    /// Per-prefill-instance pool membership. A draining instance stays
    /// `active` (it still owns work) but is no longer routable.
    active: Vec<bool>,
    /// Instance currently draining toward deactivation, if any. One
    /// drain at a time: no other scaling action fires until it lands.
    draining: Option<usize>,
    /// Instant mean pressure first held at/above the scale-up threshold.
    over_since: Option<f64>,
    /// Instant mean pressure first held at/below the scale-down threshold.
    under_since: Option<f64>,
    last_scale_at: f64,
    pool_timeline: Timeline,
    scale_ups: u64,
    scale_downs: u64,
}

impl Scaler {
    fn new(cfg: AutoscaleConfig, n_prefill: usize) -> Self {
        let floor = (cfg.min_prefill as usize).clamp(1, n_prefill);
        let ceil = (cfg.max_prefill as usize).clamp(floor, n_prefill);
        let initial = cfg
            .initial_prefill
            .map_or(floor, |i| i as usize)
            .clamp(floor, ceil);
        Scaler {
            cfg,
            active: (0..n_prefill).map(|pi| pi < initial).collect(),
            draining: None,
            over_since: None,
            under_since: None,
            last_scale_at: 0.0,
            pool_timeline: Timeline::new(),
            scale_ups: 0,
            scale_downs: 0,
        }
    }

    /// Pool floor/ceiling in instances, clamped to the topology.
    fn floor(&self) -> usize {
        (self.cfg.min_prefill as usize).clamp(1, self.active.len())
    }

    fn ceil(&self) -> usize {
        (self.cfg.max_prefill as usize).clamp(self.floor(), self.active.len())
    }

    /// Instances in the pool (draining included — it still owns work).
    fn pool_size(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Instances the proxy may route new prompts to.
    fn routable(&self, pi: usize) -> bool {
        self.active[pi] && self.draining != Some(pi)
    }

    fn routable_count(&self) -> usize {
        (0..self.active.len()).filter(|&pi| self.routable(pi)).count()
    }
}

/// Persistent per-decode-instance pricing context for the within-run
/// parallel epoch engine ([`ClusterSim::run_epoch`]). Owns a clone of
/// the unified cost plane: memoized back-fills are value-identical to
/// the authoritative model's, straggler multipliers re-sync before each
/// epoch, and grid-selection statistics land on the clone and are
/// discarded — the merge replays them on the authoritative model for
/// exactly the steps that started. Inputs (the frozen aggregate
/// snapshot and pricing window) and outputs (the priced series) live
/// here too, so one owned value crosses the worker boundary and comes
/// back, keeping the hot path allocation-free after warm-up.
struct EpochPricer {
    costs: CostModel,
    // ----- inputs: frozen batch aggregates + pricing window -------------
    local_rows: u64,
    local_ctx: u64,
    remote_rows: Vec<u64>,
    remote_ctx: Vec<u64>,
    t0: f64,
    /// Strict event bound (the queue head at epoch open).
    stop_before: Option<f64>,
    hard_stop: f64,
    /// Clean-step horizon + 1 (the series' last step is scheduled).
    max_steps: usize,
    // ----- outputs ------------------------------------------------------
    step_costs: Vec<DecodeStepCost>,
    /// Flattened per-step executor seconds (`n_prefill` per step).
    exec: Vec<f64>,
    n_steps: usize,
    /// Committed interior end times, filled by the merge (the
    /// per-request metrics flush reuses the buffer).
    times: Vec<f64>,
}

impl EpochPricer {
    fn new(costs: &CostModel) -> EpochPricer {
        EpochPricer {
            costs: costs.clone(),
            local_rows: 0,
            local_ctx: 0,
            remote_rows: Vec::new(),
            remote_ctx: Vec::new(),
            t0: 0.0,
            stop_before: None,
            hard_stop: 0.0,
            max_steps: 1,
            step_costs: Vec::new(),
            exec: Vec::new(),
            n_steps: 0,
            times: Vec::new(),
        }
    }

    /// Price the loaded step series — the only part of an epoch that
    /// runs off the sim thread. Pure given the loaded inputs, so where
    /// it runs cannot affect the result.
    fn price(mut self) -> EpochPricer {
        self.n_steps = self.costs.decode_step_series(
            self.t0,
            self.stop_before,
            self.hard_stop,
            self.max_steps,
            self.local_rows,
            self.local_ctx,
            &self.remote_rows,
            &self.remote_ctx,
            &mut self.step_costs,
            &mut self.exec,
        );
        self
    }
}

/// One lane's cursor in the epoch merge: which lane step is in flight,
/// when it ends, and the virtual event sequence number standing in for
/// the push-order tie-break the serial reference would have given its
/// `DecodeStepEnd`. A lane is either a *starter* (an instance beginning
/// a step at the pass time) or an *absorbed* in-flight instance whose
/// already-scheduled clean step end was consumed off the queue head.
struct EpochLane {
    d: usize,
    /// Index into the epoch's lane-ordered pricer results.
    li: usize,
    /// 0 for a starter lane (lane step 0 is priced and its start is
    /// replayed at epoch open); 1 for an absorbed lane (lane step 0 is
    /// the consumed pending step — already started, end time fixed by
    /// its queue entry, only its continuation is priced). Lane step `i`
    /// maps to priced-series index `i - shift`.
    shift: usize,
    /// [`ClusterSim::epoch_horizon`] plan bound for this lane (clean
    /// steps startable from the *current* pool/row state; for an
    /// absorbed lane the consumed pending step is the first of them).
    cap: usize,
    /// Lane-step index of the in-flight step.
    i: usize,
    /// In-flight step's end time.
    t_end: f64,
    /// Virtual push sequence of the in-flight step's end event.
    seq: u64,
    /// Batch rows (frozen across the epoch's clean steps).
    rows: usize,
    /// Total lane steps (`shift` + priced series length); the last one
    /// must be scheduled, never committed inline.
    n_steps: usize,
}

/// The cluster simulator.
pub struct ClusterSim {
    cfg: SimConfig,
    /// Dense request slab indexed by `RequestId` (ids are sequential).
    reqs: Vec<SimReq>,
    prefill: Vec<PrefillInst>,
    decode: Vec<DecodeInst>,
    proxy: Proxy,
    events: EventQueue<Ev>,
    metrics: MetricsRecorder,
    decode_occupancy: Timeline,
    prefill_occupancy: Timeline,
    batch_size: Timeline,
    preemptions: u64,
    interference: InterferenceModel,
    /// The unified cost plane: memoized decode/prefill step-time tables
    /// routed through the executable-bucket grid.
    costs: CostModel,
    /// Pending arrivals not yet injected (sorted by time).
    trace: VecDeque<Request>,
    finished_offloaded: usize,
    finished_total: usize,
    /// Slab entries closed by cross-group failover (ISSUE 10): they count
    /// toward drain completion like finished ones — the destination group
    /// owns their remaining work.
    exported: usize,
    /// Monotone admission counter (LIFO preemption order).
    admit_counter: u64,
    events_processed: u64,
    steps_simulated: u64,
    /// Steady-state decode leaping enabled (the default;
    /// `ServingConfig::no_leap` / `ADRENALINE_NO_LEAP=1` selects the
    /// per-step reference path).
    leap: bool,
    /// Runtime offload rebalancer (None = static admission-time split).
    rebalancer: Option<RebalanceController>,
    /// Online B_TPOT estimator (None = offline bounds stay frozen).
    b_tpot_est: Option<BTpotEstimator>,
    /// Fault-injection plane (None = no fault state, no fault events).
    fault: Option<FaultPlane>,
    /// Prefill-pool autoscaler (None = fixed pool, no autoscale events).
    scaler: Option<Scaler>,
    /// Fleet lockstep mode: arrivals are injected by `FleetSim` rather
    /// than seeded from the trace, and periodic controllers keep ticking
    /// while the injection window is open even though the slab may
    /// momentarily look drained.
    lockstep_open: bool,
    /// The run hit its hard stop; further `pump` calls are no-ops.
    stopped: bool,
    /// Per-prefill-instance decayed executor duty estimators (the
    /// interference model's "recent duty cycle").
    duty: Vec<DutyCycleEstimator>,
    migrations_to_offload: u64,
    migrations_to_local: u64,
    migration_tokens_moved: u64,
    offloaded_frac_timeline: Timeline,
    prefill_pressure_timeline: Timeline,
    b_tpot_timeline: Timeline,
    ob_timeline: Timeline,
    bounds_refreshes: u64,
    // Reusable per-step scratch (drained and returned each step so the
    // hot path never allocates after warm-up).
    scratch_finish: Vec<RequestId>,
    scratch_overflow: Vec<RequestId>,
    scratch_batch: Vec<RequestId>,
    /// (kv_tokens, id) migration-candidate buffer (tick-time only).
    scratch_migrate: Vec<(u64, RequestId)>,
    /// Per-decode-instance OB-bound backoff flags (tick-time only).
    scratch_bounded: Vec<bool>,
    /// Leap-engine scratch: the priced step series, the flattened
    /// per-step executor times, the planned per-step block-allocation
    /// counts, and the committed steps' end times (metrics flush).
    scratch_leap_costs: Vec<DecodeStepCost>,
    scratch_leap_exec: Vec<f64>,
    scratch_leap_allocs: Vec<u32>,
    scratch_leap_times: Vec<f64>,
    // ----- within-run parallel epoch engine (§Perf) ---------------------
    /// Worker pool for epoch pricing. Created lazily at the first
    /// epoch that prices lanes (runs that never see one pay nothing) and
    /// `None` when the resolved worker target is zero or the process
    /// thread budget was exhausted — pricing then runs inline, which is
    /// also the `ADRENALINE_NO_PAR=1` reference path.
    par_pool: Option<WorkerPool>,
    /// Worker threads to request at pool creation: `par_workers` (or
    /// one per decode instance when 0 = auto) minus the sim thread
    /// itself; forced to 0 by `no_par` / `ADRENALINE_NO_PAR=1` /
    /// `ADRENALINE_SERIAL=1` / `no_leap`.
    par_workers_want: usize,
    /// Pool creation attempted (a budget-exhausted first attempt must
    /// not retry every epoch).
    par_pool_init: bool,
    /// Per-decode-instance epoch pricers, created on first use.
    epoch_pricers: Vec<Option<EpochPricer>>,
    /// Epoch scratch: starter ids in lane order, merge lanes, and the
    /// per-executor-pool row totals across all starters.
    scratch_epoch_starters: Vec<usize>,
    scratch_epoch_lanes: Vec<EpochLane>,
    scratch_epoch_rtotal: Vec<u64>,
}

impl ClusterSim {
    pub fn new(cfg: SimConfig) -> Self {
        let mut gen = TraceGenerator::new(cfg.workload, cfg.rate, cfg.seed)
            .with_arrivals(cfg.arrivals);
        let trace = gen.trace(cfg.duration_s);
        Self::with_trace(cfg, trace)
    }

    /// Build against an explicit trace instead of generating one — the
    /// fleet's pre-partition path hands each group its slice of one
    /// shared trace. Ids must be dense and sequential (the caller
    /// renumbers after partitioning); `ClusterSim::new` is exactly
    /// `with_trace` over the generated trace, so a one-group fleet is
    /// bit-identical to a bare sim.
    pub fn with_trace(cfg: SimConfig, trace: Vec<Request>) -> Self {
        let avg_seq = if trace.is_empty() {
            1024
        } else {
            (trace.iter().map(|r| r.total_tokens()).sum::<usize>() / trace.len().max(1)) as u64
        };
        Self::build(cfg, trace.into(), avg_seq, false)
    }

    /// Build an empty-trace group for fleet lockstep co-simulation:
    /// `FleetSim` injects arrivals one at a time (load-aware routing
    /// needs each group's live state at the arrival instant). `avg_seq`
    /// comes from the full shared trace so the offload bounds match a
    /// whole-trace build of the same topology.
    pub(crate) fn lockstep(cfg: SimConfig, avg_seq: u64) -> Self {
        Self::build(cfg, VecDeque::new(), avg_seq.max(1), true)
    }

    fn build(
        cfg: SimConfig,
        trace: VecDeque<Request>,
        avg_seq: u64,
        lockstep_open: bool,
    ) -> Self {
        let mut bounds =
            OffloadBounds::compute(&cfg.cluster, &cfg.model, &cfg.serving.slo, avg_seq.max(1));
        if let Some(b) = cfg.serving.b_max_override {
            bounds.b_max = b;
        }
        let mut proxy = Proxy::new(
            cfg.serving.offload,
            bounds,
            cfg.cluster.n_prefill as usize,
            cfg.cluster.n_decode as usize,
        );

        // Fault plane: validate scripted targets against this topology
        // (JSON validation cannot — it does not know the cluster) and set
        // the proxy's graceful-vs-naive mode.
        let fault = cfg.serving.fault.clone().map(|fc| {
            for f in &fc.script {
                let limit = match f.kind {
                    FaultKind::DecodeCrash => cfg.cluster.n_decode as usize,
                    FaultKind::PrefillCrash | FaultKind::Straggler => {
                        cfg.cluster.n_prefill as usize
                    }
                };
                assert!(
                    f.instance < limit,
                    "scripted {} targets instance {} but the cluster has {limit}",
                    f.kind.as_str(),
                    f.instance
                );
                // Group scoping is a fleet-layer concept: FleetSim's
                // group_config filters the script per group and rewrites
                // retained entries to `group: None` before they get here.
                assert!(
                    f.group.is_none(),
                    "scripted {} still carries a fleet group scope — run it through FleetSim",
                    f.kind.as_str()
                );
            }
            proxy.set_health_aware(fc.health_aware);
            FaultPlane::new(
                fc,
                cfg.seed,
                cfg.cluster.n_prefill as usize,
                cfg.cluster.n_decode as usize,
            )
        });

        // Every instance class prices and budgets on its own device
        // profile. The default (no `profiles` configured) resolves all
        // three to `cfg.cluster.gpu` with the executor colocated at
        // `attn_executor_sm_frac` — bit-identical to the single-GpuSpec
        // plane (pinned by `rust/tests/hetero.rs`).
        let dev_prefill = cfg.cluster.prefill_profile();
        let dev_decode = cfg.cluster.decode_profile();
        let dev_executor = cfg.cluster.executor_profile();
        let colocated = cfg.cluster.executor_is_colocated();

        let hbm_budget = HbmUsage::kv_token_budget_in(
            cfg.cluster.usable_hbm_of(&dev_decode.gpu),
            &cfg.model,
        ) as usize;
        let kv_budget = cfg.serving.decode_kv_capacity_tokens.unwrap_or(hbm_budget);
        let default_executor_budget = if colocated {
            // The executor borrows the prefill GPU's spare HBM (usable
            // minus weights and workspace, like any serving instance).
            HbmUsage::kv_token_budget_in(
                cfg.cluster.usable_hbm_of(&dev_prefill.gpu),
                &cfg.model,
            ) as usize
        } else {
            // A standalone executor device is a pure attention store: no
            // weights resident, its whole usable HBM holds KV.
            (cfg.cluster.usable_hbm_of(&dev_executor.gpu) / cfg.model.kv_bytes_per_token())
                as usize
        };
        let executor_budget = if cfg.serving.offload.is_enabled() {
            cfg.serving.executor_kv_capacity_tokens.unwrap_or(default_executor_budget)
        } else {
            0
        };

        let n_prefill = cfg.cluster.n_prefill as usize;
        let prefill = (0..n_prefill)
            .map(|_| PrefillInst {
                busy_until: 0.0,
                queue: VecDeque::new(),
                executor_kv_tokens: 0,
                executor_kv_budget: executor_budget,
                executor_reserved: 0,
                prefill_busy_s: 0.0,
                executor_busy_s: 0.0,
            })
            .collect();
        let block_tokens = cfg.serving.kv_block_tokens.max(1);
        let decode = (0..cfg.cluster.n_decode)
            .map(|_| DecodeInst {
                running: Vec::new(),
                waiting: VecDeque::new(),
                kv: KvPool::new(BlockAllocator::new(kv_budget / block_tokens, block_tokens)),
                reserved: 0,
                step_in_flight: false,
                step_epoch: 0,
                flops_done: 0.0,
                busy_s: 0.0,
                local_rows: 0,
                local_ctx: 0,
                remote_rows: vec![0; n_prefill],
                remote_ctx: vec![0; n_prefill],
            })
            .collect();

        let rl_prefill = Roofline::for_profile(&dev_prefill);
        let rl_decode = Roofline::for_profile(&dev_decode);
        let rl_executor = Roofline::for_profile(&dev_executor);
        let interference = InterferenceModel::new(cfg.cluster.attn_executor_sm_frac);

        // Engine-mode resolution happens exactly once, here: config knobs
        // plus the `ADRENALINE_*` escape hatches fold into one typed
        // answer (`EngineMode`), and nothing below ever consults the
        // environment again.
        let mode = EngineMode::from_config(&cfg.serving);

        // The cost plane: the executable-bucket grid (extended to cover
        // `max_batch` the way real capture must span the servable range)
        // plus the memoized decode/prefill roofline tables, warmed at the
        // captured capacities. Bucketed charging is the default; the exact
        // pre-bucketing model stays available for ablation/regression.
        let exact = mode.exact_costs;
        let grid = CostModel::build_grid(
            &cfg.serving.decode_buckets,
            &cfg.serving.offload_buckets,
            cfg.serving.max_batch,
        );
        // Colocation interference only exists when the executor actually
        // shares the prefill GPU; a standalone executor device leaves
        // prefill alone (the arXiv 2405.01814 deployment).
        let costs = CostModel::new(
            &rl_prefill,
            &rl_decode,
            &rl_executor,
            &cfg.model,
            grid,
            if exact { CostMode::Exact } else { CostMode::Bucketed },
            (cfg.serving.offload.is_enabled() && colocated).then_some(interference),
            cfg.sync_overhead_s,
            cfg.eager_launch_overhead_s,
        );

        // The rebalancer only makes sense with offloading on: under
        // `OffloadPolicy::Disabled` there is no executor to migrate to, so
        // the controller stays off and the sim is bit-identical to the
        // static path regardless of the `rebalance` field.
        let rebalancer = if cfg.serving.offload.is_enabled() {
            cfg.serving.rebalance.map(|rc| RebalanceController::new(rc, n_prefill))
        } else {
            None
        };

        // Like the rebalancer, bounds feedback only makes sense with
        // offloading on: under `OffloadPolicy::Disabled` no admission or
        // migration consults OB, so the estimator stays off and the sim
        // is bit-identical to the static path regardless of the
        // `bounds_feedback` field.
        let b_tpot_est = if cfg.serving.offload.is_enabled() {
            cfg.serving
                .bounds_feedback
                .map(|fb| BTpotEstimator::new(costs.grid().local_buckets(), fb.alpha))
        } else {
            None
        };
        let duty = (0..n_prefill).map(|_| DutyCycleEstimator::new(DUTY_TAU_S)).collect();

        // Prefill-pool autoscaler (`FleetConfig::autoscale`): like the
        // fault plane and the rebalancer, `None` builds no state — the
        // default `fleet: None` config is structurally inert.
        let scaler = cfg
            .serving
            .fleet
            .as_ref()
            .and_then(|f| f.autoscale)
            .map(|ac| Scaler::new(ac, n_prefill));

        // Steady-state decode leaping is the default; the per-step
        // reference path stays reachable for ablation/regression, same
        // contract shape as `exact_costs`.
        let no_leap = !mode.leap;

        // Within-run parallelism: scheduling passes on multi-decode
        // topologies price every epoch lane's step series concurrently
        // (the epoch engine; lanes = the pass's starters plus absorbed
        // pending clean step ends). `no_par` / `ADRENALINE_NO_PAR=1` /
        // the process-wide `ADRENALINE_SERIAL=1` keep the same epoch
        // code but price inline on the sim thread — the bit-identity
        // reference for `rust/tests/par_run.rs`. `par_workers` is the
        // total pricing concurrency including the sim thread (0 = one
        // per decode instance); the pool itself spawns one thread fewer
        // and is capped at the lane count that could ever use it.
        let no_par = !mode.par;
        let n_decode = cfg.cluster.n_decode as usize;
        let par_workers_want = if no_par || no_leap || n_decode < 2 {
            0
        } else {
            let total =
                if cfg.serving.par_workers > 0 { cfg.serving.par_workers } else { n_decode };
            total.min(n_decode).saturating_sub(1)
        };

        ClusterSim {
            cfg,
            reqs: Vec::new(),
            prefill,
            decode,
            proxy,
            events: EventQueue::new(),
            metrics: MetricsRecorder::new(),
            decode_occupancy: Timeline::new(),
            prefill_occupancy: Timeline::new(),
            batch_size: Timeline::new(),
            preemptions: 0,
            interference,
            costs,
            trace,
            finished_offloaded: 0,
            finished_total: 0,
            exported: 0,
            admit_counter: 0,
            events_processed: 0,
            steps_simulated: 0,
            leap: !no_leap,
            rebalancer,
            b_tpot_est,
            fault,
            scaler,
            lockstep_open,
            stopped: false,
            duty,
            migrations_to_offload: 0,
            migrations_to_local: 0,
            migration_tokens_moved: 0,
            offloaded_frac_timeline: Timeline::new(),
            prefill_pressure_timeline: Timeline::new(),
            b_tpot_timeline: Timeline::new(),
            ob_timeline: Timeline::new(),
            bounds_refreshes: 0,
            scratch_finish: Vec::new(),
            scratch_overflow: Vec::new(),
            scratch_batch: Vec::new(),
            scratch_migrate: Vec::new(),
            scratch_bounded: Vec::new(),
            scratch_leap_costs: Vec::new(),
            scratch_leap_exec: Vec::new(),
            scratch_leap_allocs: Vec::new(),
            scratch_leap_times: Vec::new(),
            par_pool: None,
            par_workers_want,
            par_pool_init: false,
            epoch_pricers: (0..n_decode).map(|_| None).collect(),
            scratch_epoch_starters: Vec::new(),
            scratch_epoch_lanes: Vec::new(),
            scratch_epoch_rtotal: Vec::new(),
        }
    }

    /// Run to completion (trace drained and all requests finished or the
    /// hard cap hit) and report.
    pub fn run(mut self) -> SimReport {
        self.prime();
        self.pump(f64::INFINITY);
        self.report()
    }

    /// Seed the request slab, arrival events, and periodic controllers.
    /// Called exactly once before the first [`ClusterSim::pump`] (`run`
    /// does both; the fleet's lockstep path primes each group itself).
    pub(crate) fn prime(&mut self) {
        // Seed the request slab and arrival events. Trace ids are dense
        // and sequential, so slab index == request id.
        self.reqs.reserve(self.trace.len());
        while let Some(req) = self.trace.pop_front() {
            let id = req.id;
            debug_assert_eq!(id as usize, self.reqs.len(), "trace ids must be dense");
            let t = req.arrival_s;
            self.reqs.push(SimReq {
                effective_prompt: req.prompt_len,
                req,
                phase: Phase::WaitingDispatch,
                generated: 0,
                kv_tokens: 0,
                offloaded: false,
                prefill_instance: 0,
                decode_instance: 0,
                preemptions: 0,
                epoch: 0,
                transfer_attempts: 0,
                run_slot: NO_SLOT,
                admit_seq: 0,
            });
            self.events.push(t, Ev::Arrival(id));
        }
        // Periodic controllers skip empty runs — except a lockstep group,
        // which starts empty by construction (arrivals are injected after
        // priming) but must still tick.
        let live = !self.reqs.is_empty() || self.lockstep_open;
        if let Some(ctl) = &self.rebalancer {
            if live {
                self.events.push(ctl.interval_s(), Ev::RebalanceTick);
            }
        } else if self.b_tpot_est.is_some() {
            // Standalone refresh ticks only when no rebalancer runs; with
            // rebalancing on, refreshes ride the rebalance ticks.
            let fb = self.cfg.serving.bounds_feedback.expect("estimator implies config");
            if live {
                self.events.push(fb.interval_s, Ev::BoundsRefreshTick);
            }
        }
        if self.scaler.is_some() && live {
            // Autoscaling rides the health plane: instances outside the
            // initial pool are masked exactly as a heartbeat-observed
            // crash would be, so routing avoids them and `OB_mem`
            // rescales through the same `Proxy::set_prefill_health`
            // path.
            self.proxy.set_health_aware(true);
            for pi in 0..self.prefill.len() {
                if !self.scaler.as_ref().expect("checked above").routable(pi) {
                    self.proxy.set_prefill_health(pi, false);
                }
            }
            let s = self.scaler.as_mut().expect("checked above");
            s.pool_timeline.push(0.0, s.routable_count() as f64);
            self.events.push(s.cfg.tick_s, Ev::AutoscaleTick);
        }
        if self.fault.is_some() && live {
            // Fault plane: scripted windows are pushed whole (each Down
            // handler schedules its own Up); stochastic chains seed one
            // first failure per instance per configured class, draw order
            // fixed (prefill class then decode, instance ascending, TTF
            // then MTTR) so schedules are seed-deterministic. Every fault
            // is an ordinary queued event, so the leap engine's strict
            // next-event horizon already fences them.
            let fc = self.fault.as_ref().expect("checked above").cfg.clone();
            for f in &fc.script {
                self.events.push(
                    f.at_s,
                    Ev::InstanceDown {
                        kind: f.kind,
                        inst: f.instance,
                        down_s: f.down_s,
                        stochastic: false,
                    },
                );
            }
            if let Some(mtbf) = fc.prefill_mtbf_s {
                for pi in 0..self.prefill.len() {
                    let rng = &mut self.fault.as_mut().expect("checked above").rng;
                    let ttf = rng.exp(1.0 / mtbf);
                    let down_s = rng.exp(1.0 / fc.prefill_mttr_s);
                    self.events.push(
                        ttf,
                        Ev::InstanceDown {
                            kind: FaultKind::PrefillCrash,
                            inst: pi,
                            down_s,
                            stochastic: true,
                        },
                    );
                }
            }
            if let Some(mtbf) = fc.decode_mtbf_s {
                for d in 0..self.decode.len() {
                    let rng = &mut self.fault.as_mut().expect("checked above").rng;
                    let ttf = rng.exp(1.0 / mtbf);
                    let down_s = rng.exp(1.0 / fc.decode_mttr_s);
                    self.events.push(
                        ttf,
                        Ev::InstanceDown {
                            kind: FaultKind::DecodeCrash,
                            inst: d,
                            down_s,
                            stochastic: true,
                        },
                    );
                }
            }
            self.events.push(fc.heartbeat_s, Ev::HealthTick);
        }
    }

    /// Process queued events with timestamps strictly before `cap`
    /// (`f64::INFINITY` = drain the queue, which is exactly the old run
    /// loop). The fleet's lockstep loop passes each arrival instant as
    /// `cap` so a group never advances past the state the cluster router
    /// is about to read. Strict `<` matters: an event at exactly `cap`
    /// ties with the injected arrival and must resolve through queue
    /// `seq` order on the next pump, not fire early here.
    pub(crate) fn pump(&mut self, cap: f64) {
        let hard_stop = self.hard_stop();
        while !self.stopped {
            match self.events.peek_time() {
                Some(t) if t < cap => {}
                _ => break,
            }
            let (t, ev) = self.events.pop().expect("peeked above");
            self.events_processed += 1;
            if t > hard_stop {
                self.stopped = true;
                break;
            }
            match ev {
                Ev::Arrival(id) => self.on_arrival(t, id),
                Ev::PrefillDone { inst, id, epoch } => self.on_prefill_done(t, inst, id, epoch),
                Ev::TransferDone { id, epoch } => self.on_transfer_done(t, id, epoch),
                Ev::DecodeStepEnd { inst, epoch } => self.on_decode_step_end(t, inst, epoch),
                Ev::MigrationDone { id, epoch } => self.on_migration_done(t, id, epoch),
                Ev::RebalanceTick => self.on_rebalance_tick(t),
                Ev::BoundsRefreshTick => self.on_bounds_refresh_tick(t),
                Ev::InstanceDown { kind, inst, down_s, stochastic } => {
                    self.on_instance_down(t, kind, inst, down_s, stochastic)
                }
                Ev::InstanceUp { kind, inst, stochastic } => {
                    self.on_instance_up(t, kind, inst, stochastic)
                }
                Ev::TransferRetry { id, epoch } => self.on_transfer_retry(t, id, epoch),
                Ev::HealthTick => self.on_health_tick(t),
                Ev::AutoscaleTick => self.on_autoscale_tick(t),
                // A lockstep horizon marker is pure timestamp: popping it
                // does nothing (the scheduling pass below still runs, as
                // it does after every event).
                Ev::Fence => {}
            }
            // Global scheduling pass after every event: dispatch, then
            // admissions for every instance, then step starts. Admissions
            // read nothing a step start writes (pricing touches duty /
            // estimator / timeline / cost state only; the leap flush
            // touches only its own instance's rows and pools), so
            // hoisting them is behavior-neutral and lets the pass count
            // how many instances are about to start: a leap is only
            // sound when its instance is the pass's SOLE starter — a
            // second same-pass starter would write pass-time-stamped
            // state (timelines, estimator observations, token series)
            // after the leap already emitted future-stamped state,
            // diverging from the reference interleaving.
            self.dispatch_prefills(t);
            for d in 0..self.decode.len() {
                self.admit_waiters(t, d);
            }
            let mut starters = 0usize;
            for d in 0..self.decode.len() {
                if !self.decode[d].step_in_flight && !self.decode[d].running.is_empty() {
                    starters += 1;
                }
            }
            let sole_starter = starters <= 1;
            if self.leap && self.decode.len() >= 2 {
                // Multiple decode instances: the within-run parallel
                // epoch engine handles the pass. It prices every
                // starter's step series concurrently, *absorbs* other
                // instances' already-scheduled clean step ends off the
                // queue head into the same epoch (without absorption the
                // next instance's pending end would fence every leap to
                // a single step and the sim would degrade to per-step),
                // and merges all side effects back in exact serial event
                // order. Passes with nothing to merge fall back to the
                // plain per-instance path inside.
                self.run_epoch(t);
            } else {
                for d in 0..self.decode.len() {
                    self.maybe_start_step(t, d, sole_starter);
                }
            }
        }
    }

    // ----- fleet lockstep surface (`sim::fleet::FleetSim`) ------------------

    /// Inject one arrival into a lockstep group. The request is
    /// renumbered onto this group's dense slab (cluster-level ids belong
    /// to the fleet; per-group metrics and routing only ever see the
    /// local id) and its arrival event queued at its arrival time.
    pub(crate) fn inject(&mut self, mut req: Request) {
        debug_assert!(self.lockstep_open, "inject requires a lockstep-built sim");
        let id = self.reqs.len() as u64;
        req.id = id;
        let t = req.arrival_s;
        self.reqs.push(SimReq {
            effective_prompt: req.prompt_len,
            req,
            phase: Phase::WaitingDispatch,
            generated: 0,
            kv_tokens: 0,
            offloaded: false,
            prefill_instance: 0,
            decode_instance: 0,
            preemptions: 0,
            epoch: 0,
            transfer_attempts: 0,
            run_slot: NO_SLOT,
            admit_seq: 0,
        });
        self.events.push(t, Ev::Arrival(id));
    }

    /// Queue a lockstep horizon marker at `t` (the next arrival's
    /// instant). Pushed *before* that arrival is injected anywhere, so
    /// every group holds an event at `t` with a `seq` smaller than the
    /// arrival's — the leap engine's strict next-event horizon therefore
    /// fences all leaps off the injection, and a step ending exactly at
    /// `t` is scheduled (never committed inline), exactly as in a
    /// whole-trace run where the arrival itself is the queued event.
    pub(crate) fn fence(&mut self, t: f64) {
        debug_assert!(self.lockstep_open, "fence requires a lockstep-built sim");
        self.events.push(t, Ev::Fence);
    }

    /// The fleet finished injecting arrivals: periodic controllers may
    /// now stop rescheduling once the slab drains.
    pub(crate) fn close_arrivals(&mut self) {
        self.lockstep_open = false;
    }

    /// Whether periodic controllers should keep ticking: requests remain
    /// neither finished nor exported, or the fleet may still inject more.
    fn more_work_expected(&self) -> bool {
        self.lockstep_open || self.finished_total + self.exported < self.reqs.len()
    }

    /// Cluster-router load signal: free KV headroom (executor pools on
    /// routable prefill instances + decode pools on up instances) minus
    /// prompt tokens still queued for dispatch anywhere in the group.
    /// Queued work counts against the group even on non-routable
    /// instances — it still consumes the group's capacity. Instances the
    /// proxy currently observes as unhealthy (crashed, draining) are
    /// masked out of the positive sums: their pools exist but cannot
    /// absorb new work right now, and counting them let a degraded group
    /// keep winning least-loaded routing (ISSUE 10 satellite; pinned by
    /// `router_headroom_masks_unhealthy_instances`).
    pub(crate) fn router_headroom(&self) -> f64 {
        let mut headroom = 0.0f64;
        for pi in 0..self.prefill.len() {
            if self.scaler_routable(pi)
                && !self.prefill_is_down(pi)
                && self.proxy.is_prefill_healthy(pi)
            {
                let p = &self.prefill[pi];
                headroom += p
                    .executor_kv_budget
                    .saturating_sub(p.executor_kv_tokens + p.executor_reserved)
                    as f64;
            }
            for &id in &self.prefill[pi].queue {
                let sr = &self.reqs[id as usize];
                if sr.phase == Phase::WaitingDispatch {
                    headroom -= sr.effective_prompt as f64;
                }
            }
        }
        for d in 0..self.decode.len() {
            if !self.decode_is_down(d) && self.proxy.is_decode_healthy(d) {
                let dec = &self.decode[d];
                headroom += dec.kv_budget().saturating_sub(dec.kv_tokens() + dec.reserved) as f64;
            }
        }
        headroom
    }

    /// True when this group cannot make forward progress on new work:
    /// every prefill instance is crashed, inactive, or draining — or
    /// every decode instance is down. Queued requests are stranded until
    /// a recovery; the fleet's cross-group failover trigger (ISSUE 10).
    /// Reads the instantaneous fault/scaler state, not the heartbeat
    /// view: failover is a control-plane action that can afford the
    /// ground truth, while per-request routing inside the group keeps
    /// its heartbeat-delayed picture.
    pub(crate) fn group_stalled(&self) -> bool {
        let prefill_dead = (0..self.prefill.len())
            .all(|pi| self.prefill_is_down(pi) || !self.scaler_routable(pi));
        let decode_dead = (0..self.decode.len()).all(|d| self.decode_is_down(d));
        prefill_dead || decode_dead
    }

    /// Observed healthy-instance fraction — the proxy's heartbeat view of
    /// this group, surfaced to the fleet health plane so failover can
    /// pick the *healthiest* surviving group (ISSUE 10).
    pub(crate) fn health_fraction(&self) -> f64 {
        let n = self.prefill.len() + self.decode.len();
        let healthy = (0..self.prefill.len())
            .filter(|&pi| self.proxy.is_prefill_healthy(pi))
            .count()
            + (0..self.decode.len()).filter(|&d| self.proxy.is_decode_healthy(d)).count();
        healthy as f64 / n.max(1) as f64
    }

    /// Cross-group failover export (ISSUE 10): close every still-queued
    /// (`WaitingDispatch`) request out of this group and return it as a
    /// fresh [`Request`] ready for [`ClusterSim::inject`] into another
    /// group, arriving at `now`. Queued requests hold no sim-side
    /// reservations (those are taken at dispatch) — only proxy routing
    /// metadata, released here exactly as a preemption releases it. The
    /// exported request carries the recompute-path token ledger forward:
    /// its prompt is the effective prompt (original prompt + tokens
    /// already generated here, i.e. what a `Proxy::route_resumed`
    /// re-admission would re-prefill) and its output length drops the
    /// tokens already generated, so the destination group's ordinary
    /// arrival path — and its `tokens_conserved` invariant — need no new
    /// cases.
    pub(crate) fn export_pending(&mut self, now: f64) -> Vec<Request> {
        debug_assert!(self.lockstep_open, "export_pending requires a lockstep-built sim");
        let mut out = Vec::new();
        for i in 0..self.reqs.len() {
            if self.reqs[i].phase != Phase::WaitingDispatch {
                continue;
            }
            let d = self.reqs[i].decode_instance;
            self.proxy.on_preempted(d, i as RequestId);
            let sr = &mut self.reqs[i];
            debug_assert_eq!(sr.effective_prompt, sr.req.prompt_len + sr.generated);
            debug_assert!(
                sr.generated < sr.req.output_len,
                "a request with all output generated would have finished"
            );
            // Strand any stale per-request events from before the export.
            sr.epoch = sr.epoch.wrapping_add(1);
            sr.phase = Phase::Exported;
            out.push(Request::new(
                0, // renumbered by the destination's inject
                now,
                sr.effective_prompt,
                sr.req.output_len - sr.generated,
            ));
            self.exported += 1;
        }
        if !out.is_empty() {
            // Drop the exported ids from the prefill queues so pressure,
            // headroom, and dispatch stop seeing them.
            let reqs = &self.reqs;
            for p in &mut self.prefill {
                p.queue.retain(|&id| reqs[id as usize].phase != Phase::Exported);
            }
        }
        out
    }

    /// Admission-control signal (ISSUE 10): the best-case queueing +
    /// prefill delay a fresh prompt of `tokens` would see in this group
    /// right now — min over dispatchable prefill instances of the
    /// instance's remaining busy tail plus one priced prefill over its
    /// queued backlog and the prompt. Infinite when no prefill instance
    /// can dispatch. An estimate, not a bound (head-of-line order and
    /// decode-side gating are not modeled), but it is monotone in prompt
    /// length — which is what gives overload shedding its
    /// largest-prompt-first degradation order. `&mut` only for the
    /// memoized prefill cost table; observable state is untouched.
    pub(crate) fn predicted_ttft(&mut self, now: f64, tokens: usize) -> f64 {
        let mut best = f64::INFINITY;
        for pi in 0..self.prefill.len() {
            if self.prefill_is_down(pi) || !self.scaler_routable(pi) {
                continue;
            }
            let mut backlog = 0u64;
            for &id in &self.prefill[pi].queue {
                let sr = &self.reqs[id as usize];
                if sr.phase == Phase::WaitingDispatch {
                    backlog += sr.effective_prompt as u64;
                }
            }
            let wait = (self.prefill[pi].busy_until - now).max(0.0);
            let cost = self.prefill_time(pi, backlog + tokens as u64);
            best = best.min(wait + cost);
        }
        best
    }

    // ----- slab access ------------------------------------------------------

    #[inline]
    fn req(&self, id: RequestId) -> &SimReq {
        &self.reqs[id as usize]
    }

    #[inline]
    fn req_mut(&mut self, id: RequestId) -> &mut SimReq {
        &mut self.reqs[id as usize]
    }

    // ----- running-set / aggregate maintenance ------------------------------

    /// Fold `sr` into its decode instance's running aggregates.
    fn agg_add(dec: &mut DecodeInst, sr: &SimReq) {
        if sr.offloaded {
            dec.remote_rows[sr.prefill_instance] += 1;
            dec.remote_ctx[sr.prefill_instance] += sr.kv_tokens as u64;
        } else {
            dec.local_rows += 1;
            dec.local_ctx += sr.kv_tokens as u64;
        }
    }

    /// Remove `sr` from its decode instance's running aggregates.
    fn agg_sub(dec: &mut DecodeInst, sr: &SimReq) {
        if sr.offloaded {
            dec.remote_rows[sr.prefill_instance] -= 1;
            dec.remote_ctx[sr.prefill_instance] -= sr.kv_tokens as u64;
        } else {
            dec.local_rows -= 1;
            dec.local_ctx -= sr.kv_tokens as u64;
        }
    }

    /// O(1) removal from the running set (swap-remove + back-pointer fix).
    fn remove_from_running(&mut self, inst: usize, id: RequestId) {
        let slot = self.reqs[id as usize].run_slot;
        debug_assert_ne!(slot, NO_SLOT, "request {id} not running");
        let dec = &mut self.decode[inst];
        debug_assert_eq!(dec.running[slot], id);
        dec.running.swap_remove(slot);
        if slot < dec.running.len() {
            let moved = dec.running[slot];
            self.reqs[moved as usize].run_slot = slot;
        }
        self.reqs[id as usize].run_slot = NO_SLOT;
    }

    /// Newest-admitted local (non-offloaded) running request on `inst` —
    /// the vLLM recompute-preemption victim. O(batch), but only runs on
    /// the (rare) KV-overflow path, never per step.
    fn newest_local_victim(&self, inst: usize) -> Option<RequestId> {
        let mut best: Option<(u64, RequestId)> = None;
        for &id in &self.decode[inst].running {
            let sr = &self.reqs[id as usize];
            if sr.offloaded {
                continue;
            }
            debug_assert!(self.decode[inst].kv.contains(id));
            if best.map_or(true, |(seq, _)| sr.admit_seq > seq) {
                best = Some((sr.admit_seq, id));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Newest-admitted offloaded request homed on prefill instance `pi`,
    /// across ALL decode instances' running sets. (The executor pool is
    /// shared by every decode instance, so an overflow caused by one
    /// instance's sequences must be resolvable regardless of which
    /// instance's step just ended.)
    fn newest_offloaded_victim(&self, pi: usize) -> Option<(usize, RequestId)> {
        let mut best: Option<(u64, usize, RequestId)> = None;
        for (d, dec) in self.decode.iter().enumerate() {
            for &id in &dec.running {
                let sr = &self.reqs[id as usize];
                if !sr.offloaded || sr.prefill_instance != pi {
                    continue;
                }
                if best.map_or(true, |(seq, _, _)| sr.admit_seq > seq) {
                    best = Some((sr.admit_seq, d, id));
                }
            }
        }
        best.map(|(_, d, id)| (d, id))
    }

    /// Debug-build invariant: the incremental aggregates match a full
    /// rescan of the running set.
    #[cfg(debug_assertions)]
    fn assert_aggregates(&self, d: usize) {
        let dec = &self.decode[d];
        let mut local_rows = 0u64;
        let mut local_ctx = 0u64;
        let mut remote_rows = vec![0u64; self.prefill.len()];
        let mut remote_ctx = vec![0u64; self.prefill.len()];
        for &id in &dec.running {
            let sr = &self.reqs[id as usize];
            debug_assert_ne!(sr.run_slot, NO_SLOT);
            if sr.offloaded {
                remote_rows[sr.prefill_instance] += 1;
                remote_ctx[sr.prefill_instance] += sr.kv_tokens as u64;
            } else {
                local_rows += 1;
                local_ctx += sr.kv_tokens as u64;
            }
        }
        assert_eq!((local_rows, local_ctx), (dec.local_rows, dec.local_ctx), "local aggregates");
        assert_eq!(remote_rows, dec.remote_rows, "remote row aggregates");
        assert_eq!(remote_ctx, dec.remote_ctx, "remote ctx aggregates");
    }

    /// Debug-build invariant: the proxy's per-request `used_token` stays in
    /// lock-step with the sim's own `kv_tokens` for every running request.
    /// A fresh request carries a +1 skew (its prefill-granted first token
    /// is counted by the proxy before the KV slot is appended); a request
    /// re-admitted after preemption resumes with the two exactly equal.
    /// The preemption re-route undercount (ISSUE 4) violated this: the
    /// proxy restarted at the bare prompt length while `kv_tokens` resumed
    /// at `prompt + generated`. The fault plane's recovery paths are held
    /// to the same contract: a decode-crash re-route re-admits at exactly
    /// `kv_tokens` ([`Proxy::reroute_decode`]), and a recompute recovery
    /// re-routes at `effective_prompt` just like the preemption path —
    /// `rust/tests/faults.rs` runs crash schedules with these checks armed.
    #[cfg(debug_assertions)]
    fn assert_proxy_tokens(&self, d: usize) {
        let meta = self.proxy.metadata(d);
        for &id in &self.decode[d].running {
            let sr = &self.reqs[id as usize];
            let used = meta
                .used_token_of(id)
                .expect("running request must be proxy-tracked");
            assert!(
                used == sr.kv_tokens || used == sr.kv_tokens + 1,
                "proxy used_token {used} out of sync with kv_tokens {} for request {id} \
                 (preemptions={})",
                sr.kv_tokens,
                sr.preemptions
            );
        }
    }

    // ----- event handlers ---------------------------------------------------

    fn on_arrival(&mut self, t: f64, id: RequestId) {
        self.metrics.on_arrival(id, t);
        let route = self.proxy.route(&self.reqs[id as usize].req);
        let sr = self.req_mut(id);
        sr.offloaded = route.offload.offloaded();
        sr.prefill_instance = route.prefill_instance;
        sr.decode_instance = route.decode_instance;
        self.prefill[route.prefill_instance].queue.push_back(id);
    }

    fn on_prefill_done(&mut self, t: f64, inst: usize, id: RequestId, epoch: u32) {
        if epoch != self.req(id).epoch {
            return; // stale: the request rolled back after this was scheduled
        }
        // First token exists as soon as prefill completes.
        let was_preempted = self.req(id).preemptions > 0;
        if !was_preempted || self.req(id).generated == 0 {
            if self.metrics.request(id).and_then(|r| r.first_token_s).is_none() {
                self.metrics.on_first_token(id, t);
                let sr = self.req_mut(id);
                sr.generated = 1;
                let d = sr.decode_instance;
                self.proxy.on_token(d, id);
            }
        }
        let sr = &mut self.reqs[id as usize];
        sr.kv_tokens = sr.effective_prompt;
        if sr.offloaded {
            // KV stays on this instance (executor pool): reservation
            // becomes residency, no transfer.
            let kv = sr.kv_tokens;
            let d = sr.decode_instance;
            sr.phase = Phase::Decoding;
            let p = &mut self.prefill[inst];
            p.executor_reserved = p.executor_reserved.saturating_sub(kv);
            p.executor_kv_tokens += kv;
            self.decode[d].waiting.push_back(id);
            self.record_prefill_occupancy(t);
        } else {
            // NVLink transfer to the decode instance (cost plane;
            // bit-identical to the old inline bytes/bandwidth formula).
            sr.phase = Phase::Transferring;
            sr.transfer_attempts = 0;
            let kv = sr.kv_tokens as u64;
            let epoch = sr.epoch;
            if self.transfer_fails() {
                // Failure detected immediately; the retry fires after the
                // first backoff (fault plane only — the draw above is
                // `false` without one).
                let delay = self.transfer_backoff(0);
                self.events.push(t + delay, Ev::TransferRetry { id, epoch });
            } else {
                let xfer = self.costs.kv_transfer_time(kv);
                self.events.push(t + xfer, Ev::TransferDone { id, epoch });
            }
        }
    }

    fn on_transfer_done(&mut self, t: f64, id: RequestId, epoch: u32) {
        let _ = t;
        let sr = self.req_mut(id);
        if epoch != sr.epoch {
            return; // stale: the request rolled back after this was scheduled
        }
        debug_assert_eq!(sr.phase, Phase::Transferring);
        sr.phase = Phase::Decoding;
        let d = sr.decode_instance;
        self.decode[d].waiting.push_back(id);
    }

    fn on_decode_step_end(&mut self, t: f64, inst: usize, epoch: u32) {
        if epoch != self.decode[inst].step_epoch {
            // A crash invalidated the batch this step was priced over;
            // dropping the event keeps a stale completion from clearing a
            // post-recovery step's in-flight flag or granting its tokens.
            return;
        }
        self.decode[inst].step_in_flight = false;
        if self.decode[inst].running.is_empty() {
            return;
        }
        self.steps_simulated += 1;

        // Reusable scratch: no allocation after warm-up.
        let mut to_finish = std::mem::take(&mut self.scratch_finish);
        let mut overflow = std::mem::take(&mut self.scratch_overflow);
        debug_assert!(to_finish.is_empty() && overflow.is_empty());

        // Every running request gains one token. `running` is not mutated
        // inside this loop (finishes and preemptions are deferred), so we
        // iterate by index instead of cloning the batch.
        let n = self.decode[inst].running.len();
        for i in 0..n {
            let id = self.decode[inst].running[i];
            let sr = &mut self.reqs[id as usize];
            sr.generated += 1;
            sr.kv_tokens += 1;
            if sr.offloaded {
                let pi = sr.prefill_instance;
                self.decode[inst].remote_ctx[pi] += 1;
                self.prefill[pi].executor_kv_tokens += 1;
            } else {
                self.decode[inst].local_ctx += 1;
                // Paged append: a failed block allocation marks this
                // sequence for the preemption pass below (vLLM appends the
                // token after evicting a victim; we evict-then-retry at
                // the same position via recompute, which is equivalent in
                // token accounting).
                if self.decode[inst].kv.append_token(id).is_err() {
                    overflow.push(id);
                }
            }
            self.metrics.on_token(id, t);
            self.proxy.on_token(inst, id);
            if sr.generated >= sr.req.output_len {
                to_finish.push(id);
            }
        }

        // Retire finished requests.
        for &id in &to_finish {
            self.finish(t, inst, id);
        }

        // Preempt (LIFO, newest first) until every overflowed append fits.
        for &id in &overflow {
            if self.reqs[id as usize].run_slot == NO_SLOT {
                continue; // finished or already preempted this step
            }
            loop {
                match self.newest_local_victim(inst) {
                    Some(v) if v == id => {
                        // The overflowing sequence is itself the newest:
                        // preempt it (its token accounting rolls back via
                        // recompute).
                        self.preempt(t, inst, v);
                        break;
                    }
                    Some(v) => {
                        self.preempt(t, inst, v);
                        if self.decode[inst].kv.append_token(id).is_ok() {
                            break;
                        }
                    }
                    None => break,
                }
            }
        }

        // Executor pools can also overflow (offloaded requests growing).
        // Victims are drawn from ALL decode instances' running sets: the
        // pool is shared, and an oversubscription caused by another
        // instance's sequences must not persist until that instance
        // happens to end a step.
        for pi in 0..self.prefill.len() {
            while self.prefill[pi].executor_kv_tokens > self.prefill[pi].executor_kv_budget {
                match self.newest_offloaded_victim(pi) {
                    Some((d, v)) => self.preempt(t, d, v),
                    None => break,
                }
            }
        }

        // Return the scratch buffers for the next step.
        to_finish.clear();
        overflow.clear();
        self.scratch_finish = to_finish;
        self.scratch_overflow = overflow;

        self.record_decode_occupancy(t, inst);
    }

    // ----- runtime offload rebalancing (§3.4.2 extended) --------------------
    //
    // A feedback controller in the coordinator makes the offloaded share
    // *dynamic*: the admission-time split of Algorithm 1 is kept, and once
    // per tick the controller compares each prefill instance's observed
    // load (queued prompt tokens, executor-pool occupancy) against the
    // `OffloadBounds` headroom and migrates running decode requests
    // between local and offloaded attention:
    //
    // * **Offload more** whenever no executor is choking (any tick
    //   without a reclaim): running local requests migrate onto the
    //   least-occupied executor (largest KV first) until the OB bound
    //   binds or the pool loses its dispatch headroom — 95 % watermark
    //   normally, 90 % while that instance rides out a burst. This is
    //   where the throughput comes from: admission can only act on
    //   *arriving* requests, so after a trough (empty budget ⇒ local
    //   admissions) the resident set under-uses the executor until
    //   migrations correct the mix.
    // * **Reclaim ahead of / during prefill bursts**: when an instance's
    //   queue pressure crosses the hysteresis band AND its executor pool
    //   is actually blocking the head-of-line prompt's dispatch
    //   reservation, offloaded requests homed there migrate back
    //   (smallest KV first) until the blocked prompt fits. Reclaim is
    //   deliberately conditioned on a *blocked* dispatch, not on pressure
    //   alone: at saturation the pools are the throughput currency, and
    //   draining an executor pool that isn't choking anything only
    //   shrinks capacity.

    // ----- online bounds feedback (§3.4.2) ----------------------------------
    //
    // The proxy's `observe_b_tpot` hook existed since the seed but nothing
    // called it online — `OB` stayed frozen at the offline roofline seed
    // for the whole run even while the rebalancer migrated against it.
    // With `ServingConfig::bounds_feedback` set, the sim feeds every
    // decode step's (batch, wall time) and every finished request's mean
    // TPOT into a `BTpotEstimator` (EMA per `GraphCache` bucket), and once
    // per tick derives the largest batch currently meeting `slo.tpot_s`
    // and pushes it through the proxy — so `OB_comp`/`OB` track context
    // length and load, and the admission policy, the rebalancer, and the
    // migration bound check all consume the live value.

    /// Derive the current online B_TPOT and refresh the proxy's bounds.
    /// Timelines sample on every tick; the refresh itself applies only
    /// once the estimator has warmed past `min_observations`.
    fn refresh_bounds(&mut self, t: f64) {
        let Some(est) = self.b_tpot_est.as_ref() else { return };
        let fb = self.cfg.serving.bounds_feedback.expect("estimator implies config");
        if est.observations() >= fb.min_observations {
            if let Some(b) = est.b_tpot(self.cfg.serving.slo.tpot_s) {
                let b = b.clamp(1, self.cfg.serving.max_batch);
                self.proxy.observe_b_tpot(b);
                self.bounds_refreshes += 1;
            }
        }
        self.b_tpot_timeline.push(t, self.proxy.bounds().b_tpot as f64);
        self.ob_timeline.push(t, self.proxy.bounds().ob());
    }

    fn on_bounds_refresh_tick(&mut self, t: f64) {
        if self.b_tpot_est.is_none() {
            return;
        }
        self.refresh_bounds(t);
        let interval = self.cfg.serving.bounds_feedback.expect("tick implies config").interval_s;
        if self.more_work_expected() {
            self.events.push_in(interval, Ev::BoundsRefreshTick);
        }
    }

    fn on_rebalance_tick(&mut self, t: f64) {
        let Some(ctl) = self.rebalancer.as_ref() else { return };
        let interval = ctl.interval_s();
        let mut budget = ctl.max_migrations_per_interval();

        // Refresh the bounds first so this tick's migration decisions (and
        // the admissions until the next tick) run against the live OB
        // (no-op when the feedback plane is off).
        self.refresh_bounds(t);

        let max_prefill_tokens = self.cfg.serving.max_prefill_tokens.max(1);
        let mut reclaimed_any = false;
        for pi in 0..self.prefill.len() {
            let mut queued = 0usize;
            for &id in &self.prefill[pi].queue {
                let sr = &self.reqs[id as usize];
                if sr.phase == Phase::WaitingDispatch {
                    queued += sr.effective_prompt;
                }
            }
            let pressure = queued as f64 / max_prefill_tokens as f64;
            if pi == 0 {
                self.prefill_pressure_timeline.push(t, pressure);
            }
            let mode = self
                .rebalancer
                .as_mut()
                .expect("rebalancer checked above")
                .assess(pi, pressure);
            if mode == RebalanceMode::Reclaim {
                reclaimed_any |= self.reclaim_for(t, pi, &mut budget);
            }
        }
        // Reclaim and offload in the same tick would migrate against
        // ourselves; the reclaiming instance's pressure clears first.
        if !reclaimed_any && budget > 0 {
            self.offload_more(t, &mut budget);
        }
        self.offloaded_frac_timeline.push(t, self.proxy.offloaded_fraction());
        if self.more_work_expected() {
            self.events.push_in(interval, Ev::RebalanceTick);
        }
    }

    /// Reclaim attention homed on prefill instance `pi` until its blocked
    /// head-of-line prompt can reserve the executor pool. Returns whether
    /// any migration started.
    fn reclaim_for(&mut self, t: f64, pi: usize, budget: &mut usize) -> bool {
        // FCFS dispatch: only the queue head can block.
        let mut blocked_need = 0usize;
        for &id in &self.prefill[pi].queue {
            let sr = &self.reqs[id as usize];
            if sr.phase != Phase::WaitingDispatch {
                continue;
            }
            let p = &self.prefill[pi];
            if sr.offloaded
                && p.executor_kv_tokens + p.executor_reserved + sr.effective_prompt
                    > p.executor_kv_budget
            {
                blocked_need = sr.effective_prompt;
            }
            break;
        }
        if blocked_need == 0 || *budget == 0 {
            return false;
        }
        // Offloaded running requests homed on `pi`, smallest KV first
        // (cheapest transfers; frees the pool with the least capacity
        // surrendered per migration).
        let mut cands = std::mem::take(&mut self.scratch_migrate);
        cands.clear();
        for dec in &self.decode {
            for &id in &dec.running {
                let sr = &self.reqs[id as usize];
                if sr.offloaded && sr.prefill_instance == pi {
                    cands.push((sr.kv_tokens as u64, id));
                }
            }
        }
        cands.sort_unstable();
        let mut any = false;
        for &(kv, id) in &cands {
            if *budget == 0 {
                break;
            }
            {
                let p = &self.prefill[pi];
                if p.executor_kv_tokens + p.executor_reserved + blocked_need
                    <= p.executor_kv_budget
                {
                    break; // freed enough: the head fits now
                }
            }
            let kv = kv as usize;
            let d = self.reqs[id as usize].decode_instance;
            let dec = &self.decode[d];
            if (dec.kv_tokens() + dec.reserved + kv) as f64
                > dec.kv_budget() as f64 * RECLAIM_DECODE_POOL_GUARD
            {
                continue;
            }
            self.start_migration(t, id, false, pi);
            *budget -= 1;
            any = true;
        }
        cands.clear();
        self.scratch_migrate = cands;
        any
    }

    /// Migrate running local requests onto the least-occupied executor in
    /// Offload mode, largest KV first, until the OB bound or the pool
    /// headroom binds.
    fn offload_more(&mut self, t: f64, budget: &mut usize) {
        let mut cands = std::mem::take(&mut self.scratch_migrate);
        cands.clear();
        for dec in &self.decode {
            for &id in &dec.running {
                let sr = &self.reqs[id as usize];
                if !sr.offloaded {
                    cands.push((sr.kv_tokens as u64, id));
                }
            }
        }
        // Largest KV first: each migration moves the most attention load
        // and frees the most decode-pool capacity per transfer.
        cands.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        // Per-decode-instance OB backoff (see the bound-refusal comment
        // below): refusal stops that instance for this tick, not the rest.
        let mut bounded = std::mem::take(&mut self.scratch_bounded);
        bounded.clear();
        bounded.resize(self.decode.len(), false);
        for &(kv, id) in &cands {
            if *budget == 0 {
                break;
            }
            let d = self.reqs[id as usize].decode_instance;
            if bounded[d] {
                continue;
            }
            let kv = kv as usize;
            // Least-occupied executor pool. A Reclaim-mode instance can
            // still *receive* (no reclaim fired this tick, so its pool is
            // not choking dispatch) — it just keeps a thicker headroom
            // for the burst cohort in flight.
            let mut target: Option<(f64, usize)> = None;
            for pi in 0..self.prefill.len() {
                if self.prefill_is_down(pi) {
                    continue; // never migrate KV into a crashed executor pool
                }
                let p = &self.prefill[pi];
                if p.executor_kv_budget == 0 {
                    continue;
                }
                let occ = (p.executor_kv_tokens + p.executor_reserved) as f64
                    / p.executor_kv_budget as f64;
                let better = match target {
                    Some((best, _)) => occ < best,
                    None => true,
                };
                if better {
                    target = Some((occ, pi));
                }
            }
            let Some((_, pi)) = target else { break };
            {
                let ctl = self.rebalancer.as_ref().expect("tick implies rebalancer");
                let headroom = match ctl.mode(pi) {
                    RebalanceMode::Offload => OFFLOAD_POOL_HEADROOM,
                    RebalanceMode::Reclaim => OFFLOAD_POOL_HEADROOM_BURST,
                };
                let p = &self.prefill[pi];
                if (p.executor_kv_tokens + p.executor_reserved + kv) as f64
                    > p.executor_kv_budget as f64 * headroom
                {
                    continue; // a smaller candidate may still fit
                }
            }
            if !self.proxy.migration_within_bound(d, id) {
                // The OB bound is a budget over token *sums*; with
                // candidates sorted largest-first, the first refusal means
                // this instance's remaining headroom is marginal — stop
                // migrating from it this tick, exactly like Algorithm 1
                // stops admitting. (Deliberately NOT `continue` into
                // smaller candidates: packing the bound tight with many
                // small sequences measurably over-migrates past the
                // attention balance point and loses throughput.) Other
                // decode instances keep their own headroom.
                bounded[d] = true;
                if bounded.iter().all(|&b| b) {
                    break;
                }
                continue;
            }
            self.start_migration(t, id, true, pi);
            *budget -= 1;
        }
        cands.clear();
        bounded.clear();
        self.scratch_migrate = cands;
        self.scratch_bounded = bounded;
    }

    /// Begin moving a running request's attention + KV between the decode
    /// pool and executor pool `pi`. The request leaves the batch for the
    /// transfer (destination reserved up front, mirroring dispatch
    /// gating); residency converts on `MigrationDone`.
    ///
    /// Mid-step semantics (deliberate): a tick almost always lands inside
    /// a step window, so the request leaves a batch whose in-flight step
    /// was priced with its row — that step completes at full cost and the
    /// migrated request is simply absent at token-grant time. This models
    /// a migration canceling the row's in-flight work (the same
    /// work-discarding convention preemption uses, one token instead of
    /// the whole sequence) and deliberately charges the *dynamic* policy:
    /// the step cost is not refunded and the abandoned token is
    /// regenerated later. The dynamic-beats-static acceptance margin is
    /// measured with this penalty included.
    fn start_migration(&mut self, t: f64, id: RequestId, to_offload: bool, pi: usize) {
        let d = self.reqs[id as usize].decode_instance;
        debug_assert_ne!(self.reqs[id as usize].run_slot, NO_SLOT, "must be running");
        debug_assert_eq!(self.reqs[id as usize].phase, Phase::Decoding);
        Self::agg_sub(&mut self.decode[d], &self.reqs[id as usize]);
        self.remove_from_running(d, id);
        let kv = self.reqs[id as usize].kv_tokens;
        if to_offload {
            // KV leaves the decode pool now; executor residency
            // materializes when the transfer completes.
            let _ = self.decode[d].kv.release(id);
            self.prefill[pi].executor_reserved += kv;
            let sr = &mut self.reqs[id as usize];
            sr.offloaded = true;
            sr.prefill_instance = pi;
        } else {
            debug_assert_eq!(self.reqs[id as usize].prefill_instance, pi);
            self.prefill[pi].executor_kv_tokens =
                self.prefill[pi].executor_kv_tokens.saturating_sub(kv);
            self.decode[d].reserved += kv;
            self.reqs[id as usize].offloaded = false;
            self.record_prefill_occupancy(t);
        }
        {
            let sr = &mut self.reqs[id as usize];
            sr.phase = Phase::Migrating;
            sr.transfer_attempts = 0;
        }
        let _tracked = self.proxy.on_migrated(d, id, to_offload);
        debug_assert!(_tracked, "migrating request must be tracked by the proxy");
        let epoch = self.reqs[id as usize].epoch;
        if self.transfer_fails() {
            let delay = self.transfer_backoff(0);
            self.events.push(t + delay, Ev::TransferRetry { id, epoch });
        } else {
            let xfer = self.costs.kv_transfer_time(kv as u64);
            self.events.push(t + xfer, Ev::MigrationDone { id, epoch });
        }
    }

    fn on_migration_done(&mut self, t: f64, id: RequestId, epoch: u32) {
        if epoch != self.reqs[id as usize].epoch {
            return; // stale: the request rolled back after this was scheduled
        }
        let (offloaded, d, kv, pi) = {
            let sr = &self.reqs[id as usize];
            debug_assert_eq!(sr.phase, Phase::Migrating);
            (sr.offloaded, sr.decode_instance, sr.kv_tokens, sr.prefill_instance)
        };
        if offloaded {
            let p = &mut self.prefill[pi];
            p.executor_reserved = p.executor_reserved.saturating_sub(kv);
            p.executor_kv_tokens += kv;
            self.migrations_to_offload += 1;
            self.record_prefill_occupancy(t);
        } else {
            // The decode-pool reservation converts to block residency on
            // admission (`admit_waiters`), exactly like a prefill→decode
            // transfer landing.
            self.migrations_to_local += 1;
        }
        self.migration_tokens_moved += kv as u64;
        self.reqs[id as usize].phase = Phase::Decoding;
        self.decode[d].waiting.push_back(id);
    }

    // ----- fault plane ------------------------------------------------------
    //
    // Attention disaggregation creates a failure domain classical PD
    // serving does not have: an offloaded decode request's KV lives in a
    // *prefill* instance's HBM, so a prefill crash kills in-flight decode
    // requests that instance never admitted. The sim models three fault
    // kinds (`FaultConfig`): instance crash/recover (prefill or decode),
    // transient KV-transfer failure with exponential backoff + recompute
    // fallback, and an executor straggler window (slowdown factor on one
    // executor's offloaded-attention step cost). Recovery drives
    // `engine::recovery::RecoveryPlan`'s semantics at sim scale:
    // `RecomputeLocal` is `recompute_request` (the preemption/re-route
    // path, `Proxy::route_resumed` token accounting included), and
    // `KeepLocal` is the health-aware decode-crash re-route that keeps
    // executor-resident KV alive. Every fault is an ordinary queued
    // event, so PR 5's leap engine needs no new fences — the strict
    // next-event horizon already stops a leap at the next fault.

    #[inline]
    fn prefill_is_down(&self, pi: usize) -> bool {
        self.fault.as_ref().map_or(false, |f| f.prefill_down[pi] > 0)
    }

    #[inline]
    fn decode_is_down(&self, d: usize) -> bool {
        self.fault.as_ref().map_or(false, |f| f.decode_down[d] > 0)
    }

    /// Whether the autoscaler lets routing target prefill instance `pi`
    /// (always true without a scaler). Draining instances still *serve*
    /// their queues — only new placements are masked.
    #[inline]
    fn scaler_routable(&self, pi: usize) -> bool {
        self.scaler.as_ref().map_or(true, |s| s.routable(pi))
    }

    /// Autoscaler tick: finish a pending drain when the victim is idle,
    /// then act on sustained mean queue pressure (scale-up first — a
    /// backlog beats a shrink), then sample the pool timeline.
    fn on_autoscale_tick(&mut self, t: f64) {
        let Some(s) = self.scaler.as_ref() else { return };
        let ac = s.cfg;

        // A draining victim leaves the pool only when it owes nothing:
        // queue empty, prefill pipeline idle, and no executor-resident or
        // reserved KV (offloaded decodes it hosts must finish first) —
        // drain-before-down, so no request is ever dropped by scaling.
        if let Some(pi) = s.draining {
            let p = &self.prefill[pi];
            let idle = self.prefill[pi].queue.is_empty()
                && p.busy_until <= t
                && p.executor_kv_tokens == 0
                && p.executor_reserved == 0;
            if idle {
                let s = self.scaler.as_mut().expect("checked above");
                s.active[pi] = false;
                s.draining = None;
            }
        }

        // Mean queue pressure over routable instances — the rebalancer's
        // per-instance signal (queued prompt tokens / max_prefill_tokens),
        // averaged so the threshold is pool-size-invariant.
        let max_prefill_tokens = self.cfg.serving.max_prefill_tokens.max(1);
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for pi in 0..self.prefill.len() {
            if !self.scaler_routable(pi) {
                continue;
            }
            let mut queued = 0usize;
            for &id in &self.prefill[pi].queue {
                let sr = &self.reqs[id as usize];
                if sr.phase == Phase::WaitingDispatch {
                    queued += sr.effective_prompt;
                }
            }
            sum += queued as f64 / max_prefill_tokens as f64;
            n += 1;
        }
        let pressure = sum / n.max(1) as f64;

        let s = self.scaler.as_mut().expect("checked above");
        s.over_since = if pressure >= ac.scale_up_pressure {
            Some(s.over_since.unwrap_or(t))
        } else {
            None
        };
        s.under_since = if pressure <= ac.scale_down_pressure {
            Some(s.under_since.unwrap_or(t))
        } else {
            None
        };
        let sustained_up = s.over_since.is_some_and(|t0| t - t0 >= ac.sustain_s);
        let sustained_down = s.under_since.is_some_and(|t0| t - t0 >= ac.sustain_s);
        let cooled = t - s.last_scale_at >= ac.cooldown_s;
        let pool = s.pool_size();

        // One action per tick, none while a drain is pending (a drain in
        // flight is already a scaling action).
        if s.draining.is_none() && cooled {
            if sustained_up && pool < s.ceil() {
                // Activate the lowest-index inactive instance: its health
                // flips up, routing sees it immediately, and OB_mem
                // rescales up through the same path a crash recovery
                // takes.
                let pi = (0..s.active.len())
                    .find(|&pi| !s.active[pi])
                    .expect("pool below ceiling implies an inactive instance");
                s.active[pi] = true;
                s.scale_ups += 1;
                s.last_scale_at = t;
                s.over_since = None;
                let up = !self.prefill_is_down(pi);
                self.proxy.set_prefill_health(pi, up);
            } else if sustained_down && pool > s.floor() {
                // Drain the highest-index active instance — never
                // instance 0, which anchors the report's occupancy and
                // pressure timelines. Masked from routing now;
                // deactivated once idle.
                if let Some(pi) =
                    (1..s.active.len()).rev().find(|&pi| s.active[pi] && s.draining != Some(pi))
                {
                    s.draining = Some(pi);
                    s.scale_downs += 1;
                    s.last_scale_at = t;
                    s.under_since = None;
                    self.proxy.set_prefill_health(pi, false);
                }
            }
        }

        let s = self.scaler.as_mut().expect("checked above");
        s.pool_timeline.push(t, s.routable_count() as f64);
        if self.more_work_expected() {
            self.events.push_in(ac.tick_s, Ev::AutoscaleTick);
        }
    }

    /// Draw one transfer-failure Bernoulli (always `false` without a
    /// fault plane or with `transfer_fail_prob: 0` — no RNG consumed, so
    /// those runs stay bit-identical).
    fn transfer_fails(&mut self) -> bool {
        match self.fault.as_mut() {
            Some(fp) if fp.cfg.transfer_fail_prob > 0.0 => {
                fp.rng.f64() < fp.cfg.transfer_fail_prob
            }
            _ => false,
        }
    }

    /// Exponential backoff before retry `attempt` (0-based), capped.
    fn transfer_backoff(&self, attempt: u32) -> f64 {
        let fc = &self.fault.as_ref().expect("transfer failures imply a fault plane").cfg;
        (fc.transfer_backoff_s * (attempt as f64).exp2()).min(fc.transfer_backoff_cap_s)
    }

    fn on_instance_down(
        &mut self,
        t: f64,
        kind: FaultKind,
        inst: usize,
        down_s: f64,
        stochastic: bool,
    ) {
        let Some(fp) = self.fault.as_mut() else { return };
        fp.faults_injected += 1;
        if fp.active == 0 {
            fp.degraded_since = Some(t);
        }
        fp.active += 1;
        // Overlapping scripted windows nest: only the 0→1 edge acts.
        let first = match kind {
            FaultKind::PrefillCrash => {
                fp.prefill_down[inst] += 1;
                fp.prefill_down[inst] == 1
            }
            FaultKind::DecodeCrash => {
                fp.decode_down[inst] += 1;
                fp.decode_down[inst] == 1
            }
            FaultKind::Straggler => {
                fp.straggler_depth[inst] += 1;
                fp.straggler_depth[inst] == 1
            }
        };
        // The failure schedules its own recovery — scripted and
        // stochastic windows behave identically once open.
        self.events.push(t + down_s, Ev::InstanceUp { kind, inst, stochastic });
        if first {
            match kind {
                FaultKind::PrefillCrash => self.crash_prefill(t, inst),
                FaultKind::DecodeCrash => self.crash_decode(t, inst),
                FaultKind::Straggler => {
                    let factor =
                        self.fault.as_ref().expect("fault handler").cfg.straggler_factor;
                    self.costs.set_executor_slowdown(inst, factor);
                }
            }
        }
    }

    fn on_instance_up(&mut self, t: f64, kind: FaultKind, inst: usize, stochastic: bool) {
        let Some(fp) = self.fault.as_mut() else { return };
        fp.active = fp.active.saturating_sub(1);
        if fp.active == 0 {
            if let Some(since) = fp.degraded_since.take() {
                fp.degraded_time_s += t - since;
            }
        }
        let depth = match kind {
            FaultKind::PrefillCrash => {
                fp.prefill_down[inst] = fp.prefill_down[inst].saturating_sub(1);
                fp.prefill_down[inst]
            }
            FaultKind::DecodeCrash => {
                fp.decode_down[inst] = fp.decode_down[inst].saturating_sub(1);
                fp.decode_down[inst]
            }
            FaultKind::Straggler => {
                fp.straggler_depth[inst] = fp.straggler_depth[inst].saturating_sub(1);
                fp.straggler_depth[inst]
            }
        };
        if depth == 0 && matches!(kind, FaultKind::Straggler) {
            self.costs.clear_executor_slowdown(inst);
        }
        // A recovered crash needs no explicit action: dispatch, admission
        // and step starts read the depth counters and the post-event
        // scheduling pass restarts work at this very timestamp; the proxy
        // re-admits the instance at the next heartbeat.
        if stochastic && self.more_work_expected() {
            // The stochastic chain reschedules only off its own recovery
            // (never off scripted windows), and stops once the run has
            // drained — otherwise an MTBF chain would tick forever.
            let (mtbf, mttr) = {
                let fc = &self.fault.as_ref().expect("fault handler").cfg;
                match kind {
                    FaultKind::PrefillCrash => (fc.prefill_mtbf_s, fc.prefill_mttr_s),
                    FaultKind::DecodeCrash => (fc.decode_mtbf_s, fc.decode_mttr_s),
                    FaultKind::Straggler => (None, 0.0),
                }
            };
            if let Some(mtbf) = mtbf {
                let rng = &mut self.fault.as_mut().expect("fault handler").rng;
                let ttf = rng.exp(1.0 / mtbf);
                let down_s = rng.exp(1.0 / mttr);
                self.events
                    .push(t + ttf, Ev::InstanceDown { kind, inst, down_s, stochastic: true });
            }
        }
    }

    /// One failed transfer attempt's backoff expired: redraw. Gives up
    /// into recompute once `transfer_max_retries` retries have failed.
    fn on_transfer_retry(&mut self, t: f64, id: RequestId, epoch: u32) {
        if epoch != self.reqs[id as usize].epoch {
            return; // stale: the request rolled back (e.g. its endpoint crashed)
        }
        let phase = self.reqs[id as usize].phase;
        debug_assert!(matches!(phase, Phase::Transferring | Phase::Migrating));
        let max_retries = self.fault.as_ref().map_or(0, |f| f.cfg.transfer_max_retries);
        let attempts = {
            let sr = &mut self.reqs[id as usize];
            sr.transfer_attempts += 1;
            sr.transfer_attempts
        };
        if u64::from(attempts) > max_retries {
            // Retries exhausted: the link is treated as lost and the
            // request falls back to local recompute
            // (`RecoveryAction::RecomputeLocal`).
            self.recompute_request(t, id);
            return;
        }
        if let Some(fp) = self.fault.as_mut() {
            fp.transfer_retries += 1;
        }
        if self.transfer_fails() {
            let delay = self.transfer_backoff(attempts);
            self.events.push(t + delay, Ev::TransferRetry { id, epoch });
        } else {
            let xfer = self.costs.kv_transfer_time(self.reqs[id as usize].kv_tokens as u64);
            match phase {
                Phase::Transferring => {
                    self.events.push(t + xfer, Ev::TransferDone { id, epoch });
                }
                Phase::Migrating => {
                    self.events.push(t + xfer, Ev::MigrationDone { id, epoch });
                }
                _ => {}
            }
        }
    }

    /// Heartbeat: reconcile the proxy's health view with the sim's
    /// down-state (so detection latency is bounded by `heartbeat_s`, and
    /// `OB_mem` rescales at observation time, not crash time), then sample
    /// the health timeline.
    fn on_health_tick(&mut self, t: f64) {
        if self.fault.is_none() {
            return;
        }
        let (n_p, n_d) = (self.prefill.len(), self.decode.len());
        let mut healthy = 0usize;
        for pi in 0..n_p {
            // AND with the scaler's view: a heartbeat must not resurrect
            // an instance the autoscaler scaled down or is draining.
            let up = !self.prefill_is_down(pi) && self.scaler_routable(pi);
            self.proxy.set_prefill_health(pi, up);
            healthy += usize::from(up);
        }
        for d in 0..n_d {
            let up = !self.decode_is_down(d);
            self.proxy.set_decode_health(d, up);
            healthy += usize::from(up);
        }
        let frac = healthy as f64 / (n_p + n_d) as f64;
        let fp = self.fault.as_mut().expect("checked above");
        fp.health_timeline.push(t, frac);
        let hb = fp.cfg.heartbeat_s;
        if self.more_work_expected() {
            self.events.push_in(hb, Ev::HealthTick);
        }
    }

    /// A prefill instance died: its prefill pipeline and its colocated
    /// attention executor's HBM vanish together, so every request with KV
    /// or in-flight work there rolls back through the recompute path —
    /// including offloaded *decode* requests this instance never admitted,
    /// the failure domain attention disaggregation creates.
    fn crash_prefill(&mut self, t: f64, pi: usize) {
        // The mid-flight batch died with the instance (its queued
        // `PrefillDone` events go stale via the victims' epoch bumps).
        // Busy seconds pre-credited at dispatch stay credited: crashed
        // work still occupied the hardware.
        self.prefill[pi].busy_until = t;
        let mut victims: Vec<RequestId> = Vec::new(); // cold path; crashes are rare
        for (i, sr) in self.reqs.iter().enumerate() {
            let hit = match sr.phase {
                // Prefilling there, transferring out of it, or migrating
                // KV in either direction against its executor pool.
                Phase::Prefilling | Phase::Transferring | Phase::Migrating => {
                    sr.prefill_instance == pi
                }
                // The disaggregation domain: decoding elsewhere with
                // attention KV resident in this instance's executor HBM.
                Phase::Decoding => sr.offloaded && sr.prefill_instance == pi,
                Phase::WaitingDispatch | Phase::Done | Phase::Exported => false,
            };
            if hit {
                victims.push(i as RequestId);
            }
        }
        for id in victims {
            self.recompute_request(t, id);
        }
        debug_assert_eq!(
            self.prefill[pi].executor_kv_tokens, 0,
            "prefill crash must clear executor residency"
        );
        debug_assert_eq!(
            self.prefill[pi].executor_reserved, 0,
            "prefill crash must clear executor reservations"
        );
    }

    /// A decode instance died: its KV pool contents and in-flight step
    /// are lost. Local victims roll back through recompute. Offloaded
    /// victims' KV lives in executor HBM and survives the crash — in
    /// health-aware mode they re-route to a surviving decode instance
    /// with residency intact (the `RecoveryAction::KeepLocal` analogue);
    /// the naive baseline recomputes them too.
    fn crash_decode(&mut self, t: f64, d: usize) {
        // Invalidate the in-flight step (its queued end-event must not
        // grant tokens for a batch that no longer exists).
        self.decode[d].step_epoch = self.decode[d].step_epoch.wrapping_add(1);
        self.decode[d].step_in_flight = false;
        let health_aware = self.fault.as_ref().map_or(false, |f| f.cfg.health_aware);
        let mut victims: Vec<RequestId> = Vec::new(); // cold path
        for (i, sr) in self.reqs.iter().enumerate() {
            let hit = match sr.phase {
                // Running or waiting here, or KV in flight toward/against
                // this instance's pool.
                Phase::Decoding | Phase::Transferring | Phase::Migrating => {
                    sr.decode_instance == d
                }
                Phase::WaitingDispatch | Phase::Prefilling | Phase::Done | Phase::Exported => {
                    false
                }
            };
            if hit {
                victims.push(i as RequestId);
            }
        }
        for id in victims {
            let sr = &self.reqs[id as usize];
            if health_aware && sr.phase == Phase::Decoding && sr.offloaded {
                self.reroute_offloaded_victim(t, d, id);
            } else {
                self.recompute_request(t, id);
            }
        }
        #[cfg(debug_assertions)]
        {
            let dec = &self.decode[d];
            assert!(dec.running.is_empty(), "decode crash must empty the batch");
            assert_eq!(dec.kv.resident_tokens(), 0, "decode crash must clear the pool");
            // With a single decode instance, re-routed offloaded victims
            // land back in this queue and stall until recovery.
            for &w in &dec.waiting {
                assert!(
                    self.reqs[w as usize].offloaded,
                    "only re-routed offloaded victims may remain queued"
                );
            }
        }
    }

    /// Decode-crash recovery for an offloaded victim: its attention KV is
    /// resident in a live executor pool, so nothing re-prefills — the
    /// proxy moves it to a surviving decode instance and it rejoins that
    /// instance's waiting queue, phase unchanged.
    fn reroute_offloaded_victim(&mut self, t: f64, from: usize, id: RequestId) {
        let _ = t;
        if self.reqs[id as usize].run_slot != NO_SLOT {
            Self::agg_sub(&mut self.decode[from], &self.reqs[id as usize]);
            self.remove_from_running(from, id);
        } else {
            let dec = &mut self.decode[from];
            if let Some(pos) = dec.waiting.iter().position(|&w| w == id) {
                dec.waiting.remove(pos);
            }
        }
        debug_assert!(self.reqs[id as usize].offloaded);
        let kv = self.reqs[id as usize].kv_tokens;
        let to = self.proxy.reroute_decode(from, &self.reqs[id as usize].req, kv, true);
        self.reqs[id as usize].decode_instance = to;
        self.decode[to].waiting.push_back(id);
        if let Some(fp) = self.fault.as_mut() {
            fp.requests_recovered += 1;
        }
    }

    /// Roll `id` back to `WaitingDispatch` and re-admit it through the
    /// recompute path — the fault plane's `RecoveryAction::RecomputeLocal`
    /// at sim scale. Mirrors [`ClusterSim::preempt`]'s rollback shape
    /// (including `Proxy::route_resumed`'s resumed-length accounting) but
    /// must additionally release holdings for *every* phase a fault can
    /// strike in, and counts under the recovery metrics rather than the
    /// preemption counters.
    fn recompute_request(&mut self, t: f64, id: RequestId) {
        let _ = t;
        let (phase, offloaded, pi, d, kv, run_slot) = {
            let sr = &self.reqs[id as usize];
            (
                sr.phase,
                sr.offloaded,
                sr.prefill_instance,
                sr.decode_instance,
                sr.kv_tokens,
                sr.run_slot,
            )
        };
        match phase {
            Phase::Prefilling => {
                // The dispatch reservation rolls back with the dead batch.
                let need = self.reqs[id as usize].effective_prompt;
                if offloaded {
                    let p = &mut self.prefill[pi];
                    p.executor_reserved = p.executor_reserved.saturating_sub(need);
                } else {
                    let dec = &mut self.decode[d];
                    dec.reserved = dec.reserved.saturating_sub(need);
                }
            }
            Phase::Transferring => {
                // Local-only phase: the decode-side reservation (taken at
                // dispatch, `== kv_tokens` after prefill) rolls back.
                let dec = &mut self.decode[d];
                dec.reserved = dec.reserved.saturating_sub(kv);
            }
            Phase::Decoding => {
                if run_slot != NO_SLOT {
                    Self::agg_sub(&mut self.decode[d], &self.reqs[id as usize]);
                    self.remove_from_running(d, id);
                } else {
                    let dec = &mut self.decode[d];
                    if let Some(pos) = dec.waiting.iter().position(|&w| w == id) {
                        dec.waiting.remove(pos);
                    }
                }
                if offloaded {
                    let p = &mut self.prefill[pi];
                    p.executor_kv_tokens = p.executor_kv_tokens.saturating_sub(kv);
                } else if run_slot != NO_SLOT {
                    let _ = self.decode[d].kv.release(id);
                } else {
                    // Waiting local: the transfer landed but admission
                    // never converted the reservation to block residency.
                    let dec = &mut self.decode[d];
                    dec.reserved = dec.reserved.saturating_sub(kv);
                }
            }
            Phase::Migrating => {
                if offloaded {
                    // To-offload: the executor-side reservation rolls back
                    // (the decode pool already released at migration start).
                    let p = &mut self.prefill[pi];
                    p.executor_reserved = p.executor_reserved.saturating_sub(kv);
                } else {
                    // To-local: the decode-side reservation rolls back (the
                    // executor pool already released at migration start).
                    let dec = &mut self.decode[d];
                    dec.reserved = dec.reserved.saturating_sub(kv);
                }
            }
            Phase::WaitingDispatch | Phase::Done | Phase::Exported => return,
        }
        self.proxy.on_preempted(d, id);
        {
            let sr = &mut self.reqs[id as usize];
            // The epoch bump strands every event still queued for the old
            // incarnation (PrefillDone / TransferDone / MigrationDone /
            // TransferRetry).
            sr.epoch = sr.epoch.wrapping_add(1);
            sr.kv_tokens = 0;
            sr.transfer_attempts = 0;
            sr.effective_prompt = sr.req.prompt_len + sr.generated;
            sr.phase = Phase::WaitingDispatch;
        }
        let eff = self.reqs[id as usize].effective_prompt;
        let route = self.proxy.route_resumed(&self.reqs[id as usize].req, eff);
        {
            let sr = &mut self.reqs[id as usize];
            sr.offloaded = route.offload.offloaded();
            sr.prefill_instance = route.prefill_instance;
            sr.decode_instance = route.decode_instance;
        }
        self.prefill[route.prefill_instance].queue.push_back(id);
        if let Some(fp) = self.fault.as_mut() {
            fp.requests_recovered += 1;
            fp.recompute_tokens_replayed += eff as u64;
        }
    }

    // ----- actions ----------------------------------------------------------

    fn finish(&mut self, t: f64, inst: usize, id: RequestId) {
        // Feed the finished request's mean TPOT to the online bounds
        // estimator — the request-level signal that sees the scheduling /
        // recompute gaps raw step times cannot.
        if self.b_tpot_est.is_some() && self.reqs[id as usize].generated >= 2 {
            let first = self.metrics.request(id).and_then(|r| r.first_token_s);
            if let Some(first) = first {
                let gaps = (self.reqs[id as usize].generated - 1) as f64;
                self.b_tpot_est
                    .as_mut()
                    .expect("checked above")
                    .observe_request_tpot((t - first) / gaps);
            }
        }
        self.metrics.on_finished(id, t);
        self.proxy.on_finished(inst, id);
        Self::agg_sub(&mut self.decode[inst], &self.reqs[id as usize]);
        let sr = &mut self.reqs[id as usize];
        sr.phase = Phase::Done;
        self.finished_total += 1;
        if sr.offloaded {
            self.finished_offloaded += 1;
            self.prefill[sr.prefill_instance].executor_kv_tokens =
                self.prefill[sr.prefill_instance].executor_kv_tokens.saturating_sub(sr.kv_tokens);
        } else {
            let _ = self.decode[inst].kv.release(id);
        }
        sr.kv_tokens = 0;
        self.remove_from_running(inst, id);
        // Occupancy is recorded by the step-end handler *after* the
        // preemption pass — recording here would capture the transient
        // overshoot between token appends and preemption.
        self.record_prefill_occupancy(t);
    }

    fn preempt(&mut self, _t: f64, inst: usize, id: RequestId) {
        self.preemptions += 1;
        self.proxy.on_preempted(inst, id);
        Self::agg_sub(&mut self.decode[inst], &self.reqs[id as usize]);
        let sr = &mut self.reqs[id as usize];
        sr.preemptions += 1;
        // Strand any queued events for the preempted incarnation (none
        // exist on this path today — preemption only hits running decode
        // rows — but the rollback invariant is uniform with the fault
        // plane's: a rollback always bumps the epoch).
        sr.epoch = sr.epoch.wrapping_add(1);
        if sr.offloaded {
            self.prefill[sr.prefill_instance].executor_kv_tokens =
                self.prefill[sr.prefill_instance].executor_kv_tokens.saturating_sub(sr.kv_tokens);
        } else {
            let _ = self.decode[inst].kv.release(id);
        }
        sr.kv_tokens = 0;
        // Recompute path: prompt + generated becomes the new prefill.
        sr.effective_prompt = sr.req.prompt_len + sr.generated;
        sr.phase = Phase::WaitingDispatch;
        self.remove_from_running(inst, id);

        // Re-route through the proxy (offload decision may differ now).
        // The recompute path resumes at `effective_prompt` tokens, so the
        // re-admission must account that length — routing with the bare
        // prompt undercounted the OB budget by every generated token.
        let route = self
            .proxy
            .route_resumed(&self.reqs[id as usize].req, self.reqs[id as usize].effective_prompt);
        let sr = self.req_mut(id);
        sr.offloaded = route.offload.offloaded();
        sr.prefill_instance = route.prefill_instance;
        sr.decode_instance = route.decode_instance;
        self.prefill[route.prefill_instance].queue.push_back(id);
    }

    /// Dispatch queued prompts whose KV has a guaranteed home, batching
    /// prompts up to `max_prefill_tokens` into one prefill step (vLLM's
    /// token-budget prefill batching — amortizes the per-step weight pass
    /// across prompts and is what keeps TTFT flat below saturation).
    fn dispatch_prefills(&mut self, t: f64) {
        let mut batch = std::mem::take(&mut self.scratch_batch);
        for pi in 0..self.prefill.len() {
            if self.prefill[pi].busy_until > t {
                continue;
            }
            if self.prefill_is_down(pi) {
                continue; // crashed: queued prompts stall until recovery
            }
            let budget = self.cfg.serving.max_prefill_tokens;
            batch.clear();
            let mut batch_tokens = 0usize;
            loop {
                let Some(&id) = self.prefill[pi].queue.front() else { break };
                let (phase, need, offloaded, dec_inst) = {
                    let sr = &self.reqs[id as usize];
                    (sr.phase, sr.effective_prompt, sr.offloaded, sr.decode_instance)
                };
                if phase != Phase::WaitingDispatch {
                    self.prefill[pi].queue.pop_front();
                    continue;
                }
                if !batch.is_empty() && batch_tokens + need > budget {
                    break; // token budget reached
                }
                let fits = if offloaded {
                    let p = &self.prefill[pi];
                    p.executor_kv_tokens + p.executor_reserved + need <= p.executor_kv_budget
                } else {
                    let d = &self.decode[dec_inst];
                    d.kv_tokens() + d.reserved + need <= d.kv_budget()
                };
                if !fits {
                    break; // FCFS: head-of-line blocks (vLLM behavior)
                }
                let id = self.prefill[pi].queue.pop_front().unwrap();
                // Reserve the destination.
                if offloaded {
                    self.prefill[pi].executor_reserved += need;
                } else {
                    self.decode[dec_inst].reserved += need;
                }
                self.reqs[id as usize].phase = Phase::Prefilling;
                batch_tokens += need;
                batch.push(id);
            }
            if batch.is_empty() {
                continue;
            }
            // One fused prefill step over the batch's total tokens; every
            // request in the batch completes when the step does.
            let exec_time = self.prefill_time(pi, batch_tokens as u64);
            self.prefill[pi].prefill_busy_s += exec_time;
            self.duty[pi].record_prefill(t, exec_time);
            self.prefill[pi].busy_until = t + exec_time;
            for &id in &batch {
                let epoch = self.reqs[id as usize].epoch;
                self.events.push(t + exec_time, Ev::PrefillDone { inst: pi, id, epoch });
            }
        }
        batch.clear();
        self.scratch_batch = batch;
    }

    /// Admit waiting requests into the decode batch (KV already resident or
    /// reserved; admission consumes the reservation for local requests).
    fn admit_waiters(&mut self, t: f64, d: usize) {
        if self.decode_is_down(d) {
            return; // crashed: waiters (re-routed victims included) stall
        }
        let mut admitted = false;
        while let Some(&id) = self.decode[d].waiting.front() {
            if self.decode[d].running.len() >= self.cfg.serving.max_batch {
                break;
            }
            let (offloaded, need) = {
                let sr = &self.reqs[id as usize];
                (sr.offloaded, sr.kv_tokens)
            };
            if !offloaded {
                let dec = &mut self.decode[d];
                if dec.kv.admit(id, need).is_err() {
                    // Block quantization can refuse an admission whose
                    // token reservation fits; keep the reservation (the
                    // waiter retries next event) or dispatch gating would
                    // admit prompts whose KV has no home.
                    break;
                }
                // Admitted: the reservation converts to block residency.
                dec.reserved = dec.reserved.saturating_sub(need);
            }
            self.decode[d].waiting.pop_front();
            let slot = self.decode[d].running.len();
            self.decode[d].running.push(id);
            self.admit_counter += 1;
            let seq = self.admit_counter;
            {
                let sr = &mut self.reqs[id as usize];
                sr.run_slot = slot;
                sr.admit_seq = seq;
            }
            Self::agg_add(&mut self.decode[d], &self.reqs[id as usize]);
            admitted = true;
        }
        // One occupancy sample per admission pass, not per admitted
        // waiter: burst admissions used to bloat the timeline with
        // same-timestamp duplicates (the final value at `t` is the only
        // one window detection and time-weighted means can see anyway).
        if admitted {
            self.record_decode_occupancy(t, d);
        }
    }

    /// Start decode work on instance `d` — and, by default, *leap*.
    ///
    /// # Steady-state decode leaping (§Perf)
    ///
    /// Between irregular events — arrivals, `PrefillDone`,
    /// `TransferDone`, `MigrationDone`, controller ticks — a decode
    /// instance's evolution is fully deterministic: the batch composition
    /// is frozen (admissions and dispatches only become possible again
    /// through events), every step adds exactly one token per row, the
    /// ctx aggregates grow by the row counts, and the step time is a pure
    /// function of those aggregates through the memoized [`CostModel`].
    /// So instead of scheduling one `DecodeStepEnd` at a time (a heap
    /// push/pop plus an O(batch) token loop per step), this computes the
    /// clean-step horizon ([`ClusterSim::leap_horizon`]: first finish /
    /// KV-pool overflow / executor-pool overflow), prices the whole run
    /// through [`CostModel::decode_step_series`] (which also cuts the run
    /// at the next queued event and the run-loop hard stop), commits all
    /// but the last step inline — O(1) scalar work per step, one O(batch)
    /// bulk flush per leap — and schedules only the last step as a real
    /// event so the unchanged per-step handler deals with whatever makes
    /// it interesting.
    ///
    /// Bit-identity contract (`rust/tests/step_leap.rs`): the committed
    /// steps replay exactly the reference path's per-step side effects —
    /// same f64 op order per structure (step times, duty decay, busy-time
    /// accumulators, timelines, estimator EMAs) and the same integer
    /// accounting in bulk — so a leap run's `SimReport` matches the
    /// `ADRENALINE_NO_LEAP=1` reference bit for bit, except
    /// `events_processed` (collapsing events is the point).
    ///
    /// `sole_starter` is the run loop's same-pass guard: leaping is only
    /// sound when no other instance starts a step in this pass (the
    /// queued-event bound cannot see a co-starter's pushes, which happen
    /// *after* this call at the pass timestamp). With a co-starter both
    /// instances take the per-step path for this one step and leaping
    /// resumes at their next, solitary, step ends.
    fn maybe_start_step(&mut self, t: f64, d: usize, sole_starter: bool) {
        if self.decode[d].step_in_flight || self.decode[d].running.is_empty() {
            return;
        }
        if self.decode_is_down(d) {
            return; // crashed: no steps until recovery
        }
        #[cfg(debug_assertions)]
        self.assert_aggregates(d);
        #[cfg(debug_assertions)]
        self.assert_proxy_tokens(d);

        // Clean-step horizon; 0 = schedule the very next step as an
        // event, i.e. the per-step reference path.
        let max_clean = if self.leap && sole_starter { self.leap_horizon(d) } else { 0 };

        let next_event = self.events.peek_time();
        let hard_stop = self.hard_stop();
        let mut costs = std::mem::take(&mut self.scratch_leap_costs);
        let mut exec = std::mem::take(&mut self.scratch_leap_exec);
        let dec = &self.decode[d];
        debug_assert_eq!(
            dec.local_rows + dec.remote_rows.iter().sum::<u64>(),
            dec.running.len() as u64,
            "row aggregates must cover the running set"
        );
        let n_steps = self.costs.decode_step_series(
            t,
            next_event,
            hard_stop,
            max_clean + 1,
            dec.local_rows,
            dec.local_ctx,
            &dec.remote_rows,
            &dec.remote_ctx,
            &mut costs,
            &mut exec,
        );

        // Replay the per-step side effects in reference order; commit the
        // first `n_steps - 1` steps inline and schedule the last.
        let k = n_steps - 1;
        let n_prefill = self.prefill.len();
        let rows = self.decode[d].running.len();
        let mut times = std::mem::take(&mut self.scratch_leap_times);
        times.clear();
        let mut used_blocks = self.decode[d].kv.used_blocks();
        let total_blocks = self.decode[d].kv.total_blocks();
        let mut t_cur = t;
        for (i, cost) in costs.iter().enumerate() {
            for (pi, &et) in exec[i * n_prefill..(i + 1) * n_prefill].iter().enumerate() {
                if et > 0.0 {
                    self.prefill[pi].executor_busy_s += et;
                    self.duty[pi].record_executor(t_cur, et);
                }
            }
            if let Some(est) = self.b_tpot_est.as_mut() {
                // Observe the *local* sub-batch (the dimension B_TPOT is
                // defined over — Eq 2's "largest batch meeting the SLO
                // without offloading", and the one the executable grid
                // selects its local bucket on). Binning by the total row
                // count would credit mixed steps' offload speedup to pure
                // local capability and bias the derived B_TPOT high.
                est.observe_step(self.decode[d].local_rows as usize, cost.step_s);
            }
            let dec = &mut self.decode[d];
            dec.busy_s += cost.step_s;
            dec.flops_done += cost.flops;
            self.batch_size.push(t_cur, rows as f64);
            let t_end = t_cur + cost.step_s;
            if i < k {
                // Committed inline: every running row gains one token at
                // `t_end` (per-row state is bulk-flushed once below).
                self.steps_simulated += 1;
                let dec = &mut self.decode[d];
                dec.local_ctx += dec.local_rows;
                for pi in 0..n_prefill {
                    dec.remote_ctx[pi] += dec.remote_rows[pi];
                }
                self.metrics.on_step_tokens(t_end, rows as u64);
                // `record_decode_occupancy`'s instance-0 policy, replayed
                // from the planned allocation counts (the pool itself is
                // bulk-flushed only at leap end).
                if d == 0 {
                    used_blocks += self.scratch_leap_allocs[i] as usize;
                    let occ = KvPool::occupancy_of(used_blocks, total_blocks);
                    self.decode_occupancy.push(t_end, occ);
                }
                times.push(t_end);
                t_cur = t_end;
            } else {
                // The first non-clean step runs through the event loop:
                // its end may finish rows, overflow a pool, or interleave
                // with a queued event — the per-step handler owns all of
                // that, unchanged.
                self.decode[d].step_in_flight = true;
                let epoch = self.decode[d].step_epoch;
                self.events.push(t_end, Ev::DecodeStepEnd { inst: d, epoch });
            }
        }
        if k > 0 {
            self.flush_leap(d, k, &times);
            #[cfg(debug_assertions)]
            self.assert_leap_residency(d);
        }
        times.clear();
        costs.clear();
        exec.clear();
        self.scratch_leap_times = times;
        self.scratch_leap_costs = costs;
        self.scratch_leap_exec = exec;
    }

    /// Upper bound on the number of *clean* steps instance `d` can commit
    /// from the current state: steps that finish no request and overflow
    /// neither the decode KV pool nor any executor pool. (The event-queue
    /// and hard-stop time bounds are applied per priced step by
    /// [`CostModel::decode_step_series`].) Admissions and dispatches need
    /// no bound of their own: both only become possible again through
    /// events — pools monotonically fill and batches never shrink during
    /// clean steps, so a waiter or prompt blocked when the leap starts
    /// stays blocked throughout.
    fn leap_horizon(&mut self, d: usize) -> usize {
        let mut cap = MAX_LEAP_STEPS;
        {
            let dec = &self.decode[d];
            for &id in &dec.running {
                let sr = &self.reqs[id as usize];
                // The step that brings a row to `output_len` must be
                // evented (its end retires the row).
                let to_finish = sr.req.output_len.saturating_sub(sr.generated).max(1);
                cap = cap.min(to_finish - 1);
                if cap == 0 {
                    return 0;
                }
            }
            for (pi, p) in self.prefill.iter().enumerate() {
                // Offloaded rows grow their executor pool by one token
                // per step; the step whose growth crosses the budget must
                // be evented (its end runs the overflow-preemption pass).
                // A pool already over budget events immediately: the
                // per-step pass may owe victim scans for *other*
                // instances' sequences too.
                if p.executor_kv_tokens > p.executor_kv_budget {
                    return 0;
                }
                let rows = dec.remote_rows[pi] as usize;
                if rows > 0 {
                    cap = cap.min((p.executor_kv_budget - p.executor_kv_tokens) / rows);
                    if cap == 0 {
                        return 0;
                    }
                }
            }
        }
        // Decode-pool block budget: the exact per-step allocation
        // schedule (the counts also replay instance 0's occupancy
        // timeline during the leap).
        let mut allocs = std::mem::take(&mut self.scratch_leap_allocs);
        let k = self.decode[d].kv.plan_bulk_steps(cap, &mut allocs);
        self.scratch_leap_allocs = allocs;
        k
    }

    /// Apply `k` committed leap steps' per-row state in bulk: each
    /// running row gained one token at each of `times` (len `k`). The ctx
    /// aggregates were already advanced per step by the leap loop; this
    /// settles the per-row counters, the paged KV tables, the metrics
    /// series, and the proxy's `used_token` accounting — all integer
    /// math, so `k` bulk units equal `k` single-token updates exactly.
    fn flush_leap(&mut self, d: usize, k: usize, times: &[f64]) {
        debug_assert!(k > 0 && times.len() == k);
        // Validate the shared time series once per leap, not once per row
        // (every row receives the identical slice below).
        debug_assert!(times.windows(2).all(|w| w[0] <= w[1]), "leaped times must ascend");
        let n = self.decode[d].running.len();
        for slot in 0..n {
            let id = self.decode[d].running[slot];
            let offloaded = {
                let sr = &mut self.reqs[id as usize];
                sr.generated += k;
                sr.kv_tokens += k;
                sr.offloaded
            };
            if !offloaded {
                let appended = self.decode[d].kv.append_tokens(id, k);
                appended.expect("leap horizon reserves blocks for every committed step");
            }
            self.metrics.on_tokens(id, times);
            self.proxy.on_token_bulk(d, id, k);
        }
        for pi in 0..self.prefill.len() {
            let rows = self.decode[d].remote_rows[pi] as usize;
            if rows > 0 {
                self.prefill[pi].executor_kv_tokens += rows * k;
            }
        }
    }

    /// Debug-build invariant (leap path): after a flush, the incremental
    /// aggregates, the proxy's `used_token` ledger, the paged KV tables,
    /// and the executor pools' residency all match from-scratch
    /// recomputations over the request slab.
    #[cfg(debug_assertions)]
    fn assert_leap_residency(&self, d: usize) {
        self.assert_aggregates(d);
        self.assert_proxy_tokens(d);
        for &id in &self.decode[d].running {
            let sr = &self.reqs[id as usize];
            if !sr.offloaded {
                assert_eq!(
                    self.decode[d].kv.seq(id).map(|s| s.tokens),
                    Some(sr.kv_tokens),
                    "paged KV length out of lock-step for request {id}"
                );
            }
        }
        for (pi, p) in self.prefill.iter().enumerate() {
            let expect: usize = self
                .reqs
                .iter()
                .filter(|sr| {
                    sr.offloaded && sr.prefill_instance == pi && sr.phase == Phase::Decoding
                })
                .map(|sr| sr.kv_tokens)
                .sum();
            assert_eq!(
                p.executor_kv_tokens,
                expect,
                "executor pool residency out of lock-step on prefill instance {pi}"
            );
        }
    }

    // ----- within-run parallel epoch engine (§Perf) -------------------------

    /// Create the epoch worker pool on first use. One attempt only: a
    /// sim already running inside a saturated `parallel_map` sweep gets
    /// no permits and stays inline for its whole run rather than
    /// hammering the budget every epoch.
    fn ensure_par_pool(&mut self) {
        if self.par_pool_init {
            return;
        }
        self.par_pool_init = true;
        if self.par_workers_want > 0 {
            let pool = WorkerPool::new(self.par_workers_want);
            if pool.workers() > 0 {
                self.par_pool = Some(pool);
            }
        }
    }

    /// Fill the epoch horizon's shared executor-pool row totals: per
    /// prefill instance, the offloaded-row count summed over every live
    /// decode instance with rows — the superset of every lane that could
    /// join this epoch, whether as a starter or by absorption.
    /// Eligibility must not feed back into the bound it is checked
    /// against, and an instance that never becomes a lane only makes the
    /// per-lane cap smaller, never wrong.
    fn fill_epoch_rtotal(&self, r_total: &mut Vec<u64>) {
        r_total.clear();
        r_total.resize(self.prefill.len(), 0);
        for d in 0..self.decode.len() {
            if self.decode[d].running.is_empty() || self.decode_is_down(d) {
                continue;
            }
            for (pi, &r) in self.decode[d].remote_rows.iter().enumerate() {
                r_total[pi] += r;
            }
        }
    }

    /// Epoch variant of [`ClusterSim::leap_horizon`]: upper bound on the
    /// clean steps instance `d` can commit inside one epoch, counted
    /// from the current row/pool state (for an absorbed lane the
    /// consumed pending step is the first of them, so a non-zero horizon
    /// doubles as the proof that the pending grant is clean). The
    /// per-row finish bound and the decode-pool plan are identical to
    /// the leap's; the executor-pool bound divides each pool's headroom
    /// by the pool's row total across *all* live instances with rows
    /// (`r_total`), not just `d`'s own — every lane grows a shared pool
    /// concurrently during the epoch, and capping each at
    /// `headroom / total` keeps any interleaving of their committed
    /// steps within budget.
    fn epoch_horizon(&mut self, d: usize, r_total: &[u64]) -> usize {
        let mut cap = MAX_LEAP_STEPS;
        {
            let dec = &self.decode[d];
            for &id in &dec.running {
                let sr = &self.reqs[id as usize];
                let to_finish = sr.req.output_len.saturating_sub(sr.generated).max(1);
                cap = cap.min(to_finish - 1);
                if cap == 0 {
                    return 0;
                }
            }
            for (pi, p) in self.prefill.iter().enumerate() {
                if p.executor_kv_tokens > p.executor_kv_budget {
                    return 0;
                }
                if dec.remote_rows[pi] > 0 {
                    let total = r_total[pi] as usize;
                    cap = cap.min((p.executor_kv_budget - p.executor_kv_tokens) / total);
                    if cap == 0 {
                        return 0;
                    }
                }
            }
        }
        if d == 0 {
            // Instance 0's planned per-step allocation counts also
            // replay the decode-occupancy timeline during the merge.
            let mut allocs = std::mem::take(&mut self.scratch_leap_allocs);
            let k = self.decode[0].kv.plan_bulk_steps(cap, &mut allocs);
            self.scratch_leap_allocs = allocs;
            k
        } else {
            self.decode[d].kv.bulk_horizon(cap)
        }
    }

    /// Replay one step *start*'s side effects — exactly what
    /// [`ClusterSim::maybe_start_step`]'s loop does when the serial
    /// reference starts a step at `t_start`: executor busy time and duty
    /// decay (ascending partition), the B_TPOT observation, the decode
    /// instance's busy/FLOPs accumulators, and the batch-size timeline.
    fn replay_step_start(
        &mut self,
        d: usize,
        t_start: f64,
        rows: usize,
        step: DecodeStepCost,
        exec_row: &[f64],
    ) {
        for (pi, &et) in exec_row.iter().enumerate() {
            if et > 0.0 {
                self.prefill[pi].executor_busy_s += et;
                self.duty[pi].record_executor(t_start, et);
            }
        }
        if let Some(est) = self.b_tpot_est.as_mut() {
            est.observe_step(self.decode[d].local_rows as usize, step.step_s);
        }
        let dec = &mut self.decode[d];
        dec.busy_s += step.step_s;
        dec.flops_done += step.flops;
        self.batch_size.push(t_start, rows as f64);
    }

    /// Scheduling pass under the within-run parallel epoch engine
    /// (§Perf). One *epoch* spans the window from the pass time `t` to
    /// the next shared-state synchronization point — the first queued
    /// event that is anything other than a clean, strictly
    /// time-separated decode step end. Two kinds of lane join the epoch:
    ///
    /// * **starters** — instances beginning a step this pass (the serial
    ///   pass would start each and schedule one `DecodeStepEnd`);
    /// * **absorbed** in-flight instances — their already-scheduled step
    ///   ends are consumed off the queue head when provably clean (no
    ///   row finishes on the grant, no pool overflows, epoch-current)
    ///   and *strictly* earlier than every other queued event. Without
    ///   absorption, each instance's pending end would fence every other
    ///   instance's horizon to a single step and a saturated
    ///   multi-instance run would degrade to per-step event processing —
    ///   pending clean step ends are exactly the events that are *not*
    ///   synchronization points.
    ///
    /// Each lane's independent work (pricing its frozen-composition step
    /// series) runs concurrently on the persistent worker pool via
    /// per-instance [`EpochPricer`] clones of the cost plane; everything
    /// that touches shared order-sensitive state is then committed by a
    /// deterministic merge on this thread.
    ///
    /// The merge replays side effects in the exact order the serial
    /// reference produces them: virtual step-end events ordered by
    /// `(end time, push sequence)` — the event queue's own ordering,
    /// with absorbed lanes' seqs below all starters' (their real events
    /// were pushed before this pass) — with each pop replaying the ended
    /// step's effects and then the next step's start effects, precisely
    /// the reference's pop-handler-then-pass sequence. The merge stops
    /// at the first virtual event that cannot stay internal (a series'
    /// scheduled last step — a finish, a pool overflow, or a queue
    /// interleaving): the reference pops that event before every later
    /// one and its handler may write anything, so each lane's in-flight
    /// step then becomes a real `DecodeStepEnd`, pushed in
    /// virtual-sequence order to keep queue ties resolving identically
    /// (an absorbed lane that never advanced gets its consumed event
    /// re-pushed at the same instant — safe precisely because absorption
    /// required strict time separation). Per-row state committed by the
    /// replay is settled in one bulk flush per lane, and grid-selection
    /// statistics are recorded on the authoritative cost model for
    /// exactly the *newly* started steps (speculatively priced steps
    /// beyond the merge stop never count; an absorbed pending step was
    /// recorded when it originally started). The result is bit-identical
    /// to the `ADRENALINE_NO_PAR=1` inline path (same code, same thread
    /// for pricing) *and* to the `ADRENALINE_NO_LEAP=1` per-step
    /// reference (`rust/tests/par_run.rs`, `rust/tests/step_leap.rs`).
    fn run_epoch(&mut self, t: f64) {
        // -- collect the actual starters (the run-loop pass count
        //    includes crashed instances, which never start) --------------
        let mut starters = std::mem::take(&mut self.scratch_epoch_starters);
        let mut lanes = std::mem::take(&mut self.scratch_epoch_lanes);
        starters.clear();
        lanes.clear();
        for d in 0..self.decode.len() {
            if self.decode[d].step_in_flight
                || self.decode[d].running.is_empty()
                || self.decode_is_down(d)
            {
                continue;
            }
            #[cfg(debug_assertions)]
            {
                self.assert_aggregates(d);
                self.assert_proxy_tokens(d);
            }
            starters.push(d);
        }

        let hard_stop = self.hard_stop();
        let n_prefill = self.prefill.len();

        // Per-executor-pool row totals for the epoch horizon's
        // conservative shared-pool bound, filled lazily on first horizon
        // use (most passes merge nothing and should stay cheap). Empty ≡
        // not yet filled; the fill reads only state that is frozen for
        // the duration of the pass, so *when* it runs cannot change it.
        let mut r_total = std::mem::take(&mut self.scratch_epoch_rtotal);
        r_total.clear();

        // -- absorption: consume clean pending step ends off the queue
        //    head, in queue order, while each is strictly earlier than
        //    everything else queued. Eligibility is evaluated on the
        //    *current* state (preemptions or migrations since the step
        //    started already updated rows/aggregates — exactly what the
        //    reference handler would grant against at that timestamp).
        //    The prefix rule keeps this exact: once a head is refused,
        //    no later queue entry may be consumed either. ----------------
        loop {
            let (t_d, d) = match self.events.peek() {
                Some((t_d, Ev::DecodeStepEnd { inst, epoch }))
                    if *epoch == self.decode[*inst].step_epoch =>
                {
                    (t_d, *inst)
                }
                _ => break,
            };
            if t_d > hard_stop
                || self.decode_is_down(d)
                || self.decode[d].running.is_empty()
                || self.events.second_min_time().map_or(false, |s2| s2 <= t_d)
            {
                break;
            }
            debug_assert!(
                self.decode[d].step_in_flight,
                "an epoch-current pending DecodeStepEnd implies an in-flight step"
            );
            if r_total.is_empty() {
                self.fill_epoch_rtotal(&mut r_total);
            }
            // Horizon >= 1 means the pending step itself is clean: the
            // per-row finish bound, the decode-pool plan, and the
            // executor bound all count it as the first granted step.
            let cap = self.epoch_horizon(d, &r_total);
            if cap == 0 {
                break;
            }
            #[cfg(debug_assertions)]
            {
                self.assert_aggregates(d);
                self.assert_proxy_tokens(d);
            }
            let _ = self.events.pop_no_clock();
            lanes.push(EpochLane {
                d,
                li: lanes.len(),
                shift: 1,
                cap,
                i: 0,
                t_end: t_d,
                seq: lanes.len() as u64,
                rows: self.decode[d].running.len(),
                n_steps: 0,
            });
        }

        if lanes.is_empty() && starters.len() <= 1 {
            // Nothing to merge: no absorbable pending end and at most
            // one live starter. The plain path (with its own leap
            // engine) handles the pass; the starter, if any, is sole.
            starters.clear();
            self.scratch_epoch_starters = starters;
            self.scratch_epoch_lanes = lanes;
            self.scratch_epoch_rtotal = r_total;
            for d in 0..self.decode.len() {
                self.maybe_start_step(t, d, true);
            }
            return;
        }

        if r_total.is_empty() {
            self.fill_epoch_rtotal(&mut r_total);
        }

        // -- append starter lanes after the absorbed ones: the serial
        //    reference pushed every absorbed pending end before this
        //    pass, so all absorbed virtual seqs must precede the
        //    starters' ------------------------------------------------------
        for &d in starters.iter() {
            let cap = self.epoch_horizon(d, &r_total);
            lanes.push(EpochLane {
                d,
                li: lanes.len(),
                shift: 0,
                cap,
                i: 0,
                t_end: t,
                seq: lanes.len() as u64,
                rows: self.decode[d].running.len(),
                n_steps: 0,
            });
        }
        self.scratch_epoch_rtotal = r_total;

        // Lane-order instance list (indexes the priced results back into
        // the per-instance pricer cache at epoch close).
        starters.clear();
        starters.extend(lanes.iter().map(|l| l.d));

        self.ensure_par_pool();
        // The epoch's strict event bound — taken AFTER absorption, so
        // the window extends past every consumed pending end to the
        // first real synchronization point.
        let t_next = self.events.peek_time();

        // -- load each lane's pricer: horizon, frozen aggregates,
        //    pricing window, and the straggler-multiplier re-sync -------
        let mut tasks: Vec<PoolTask<EpochPricer>> = Vec::with_capacity(lanes.len());
        for lane in lanes.iter() {
            let mut pricer = match self.epoch_pricers[lane.d].take() {
                Some(p) => p,
                None => EpochPricer::new(&self.costs),
            };
            pricer.costs.sync_executor_slowdowns(&self.costs);
            let dec = &self.decode[lane.d];
            debug_assert_eq!(
                dec.local_rows + dec.remote_rows.iter().sum::<u64>(),
                dec.running.len() as u64,
                "row aggregates must cover the running set"
            );
            pricer.local_rows = dec.local_rows;
            pricer.remote_rows.clear();
            pricer.remote_rows.extend_from_slice(&dec.remote_rows);
            pricer.remote_ctx.clear();
            pricer.remote_ctx.extend_from_slice(&dec.remote_ctx);
            if lane.shift == 1 {
                // Absorbed lane: price the continuation after the
                // consumed pending step's grant (one token per row),
                // starting from that step's fixed end time.
                pricer.local_ctx = dec.local_ctx + dec.local_rows;
                for (pi, rc) in pricer.remote_ctx.iter_mut().enumerate() {
                    *rc += dec.remote_rows[pi];
                }
                pricer.t0 = lane.t_end;
            } else {
                pricer.local_ctx = dec.local_ctx;
                pricer.t0 = t;
            }
            pricer.stop_before = t_next;
            pricer.hard_stop = hard_stop;
            pricer.max_steps = lane.cap + 1 - lane.shift;
            pricer.times.clear();
            tasks.push(Box::new(move || pricer.price()));
        }

        // -- price every series: workers plus this thread, results in
        //    lane order regardless of scheduling --------------------------
        let mut priced: Vec<EpochPricer> = match &self.par_pool {
            Some(pool) => pool.run_batch(tasks),
            None => tasks.into_iter().map(|task| task()).collect(),
        };

        // -- replay the epoch-open step starts in ascending-d order (the
        //    serial pass's own starter order). Absorbed lanes' in-flight
        //    steps started before this pass — their start effects are
        //    already in the books and their end times are fixed ----------
        for lane in lanes.iter_mut() {
            let p = &priced[lane.li];
            lane.n_steps = p.n_steps + lane.shift;
            if lane.shift == 0 {
                let step = p.step_costs[0];
                lane.t_end = t + step.step_s;
                self.replay_step_start(lane.d, t, lane.rows, step, &p.exec[0..n_prefill]);
            }
        }
        let mut next_seq = lanes.len() as u64;

        // Instance 0's occupancy replay state (only lane 0 uses it).
        let total_blocks0 = self.decode[0].kv.total_blocks();
        let mut used_blocks0 = self.decode[0].kv.used_blocks();

        // -- deterministic merge --------------------------------------
        loop {
            // Global minimum (end time, virtual seq) over in-flight
            // steps; lanes ≤ n_decode, so a linear scan beats a heap.
            let mut min = 0usize;
            for j in 1..lanes.len() {
                let ord = lanes[j]
                    .t_end
                    .total_cmp(&lanes[min].t_end)
                    .then(lanes[j].seq.cmp(&lanes[min].seq));
                if ord == std::cmp::Ordering::Less {
                    min = j;
                }
            }
            if lanes[min].i + 1 >= lanes[min].n_steps {
                // The minimum is a series' scheduled last step: its end
                // may finish rows, overflow a pool, or tie with a queued
                // event, and the reference pops it before every later
                // virtual end — nothing further can be replayed inline.
                break;
            }
            let (d, li, i, shift, e, rows) = (
                lanes[min].d,
                lanes[min].li,
                lanes[min].i,
                lanes[min].shift,
                lanes[min].t_end,
                lanes[min].rows,
            );

            // End effects of lane step `i` at `e` (the reference's
            // clean-step handler): token grant bookkeeping is deferred to
            // the bulk flush; everything order-sensitive replays here.
            // (An absorbed lane's step 0 is the consumed pending step —
            // same effects, end time straight from its queue entry.)
            self.steps_simulated += 1;
            {
                let dec = &mut self.decode[d];
                dec.local_ctx += dec.local_rows;
                for pi in 0..n_prefill {
                    dec.remote_ctx[pi] += dec.remote_rows[pi];
                }
            }
            self.metrics.on_step_tokens(e, rows as u64);
            if d == 0 {
                // `record_decode_occupancy`'s instance-0 policy, replayed
                // from the planned allocation counts (the plan starts at
                // the current pool state for both lane kinds, so lane
                // step `i` always maps to `allocs[i]`).
                used_blocks0 += self.scratch_leap_allocs[i] as usize;
                let occ = KvPool::occupancy_of(used_blocks0, total_blocks0);
                self.decode_occupancy.push(e, occ);
            }
            priced[li].times.push(e);

            // Start effects of lane step `i + 1` at `e` (the reference's
            // post-handler scheduling pass). Priced-series index is the
            // lane-step index minus the absorbed shift.
            let step = priced[li].step_costs[i + 1 - shift];
            self.replay_step_start(
                d,
                e,
                rows,
                step,
                &priced[li].exec[(i + 1 - shift) * n_prefill..(i + 2 - shift) * n_prefill],
            );

            let lane = &mut lanes[min];
            lane.i += 1;
            lane.t_end = e + step.step_s;
            lane.seq = next_seq;
            next_seq += 1;
        }

        // -- epoch close: every lane's in-flight step becomes a real
        //    event, pushed in virtual-sequence order so queue ties keep
        //    resolving exactly as the reference's push order would. An
        //    absorbed lane that never advanced re-pushes its consumed
        //    pending end at the same instant — its new seq cannot flip
        //    any tie, because absorption required strict time separation
        //    from everything still queued --------------------------------
        lanes.sort_by_key(|l| l.seq);
        for lane in lanes.iter() {
            let d = lane.d;
            self.decode[d].step_in_flight = true;
            let epoch = self.decode[d].step_epoch;
            self.events.push(lane.t_end, Ev::DecodeStepEnd { inst: d, epoch });
        }

        // -- settle per-row state and replay grid statistics (integer
        //    accounting — order across instances is immaterial; keep
        //    ascending d for readability) --------------------------------
        lanes.sort_by_key(|l| l.d);
        for lane in lanes.iter() {
            let p = &priced[lane.li];
            let remote_total: u64 = p.remote_rows.iter().sum();
            // One selection per *newly started* step (interior commits
            // plus the scheduled step), matching what pricing on the
            // authoritative model would have recorded for exactly these
            // steps. An absorbed lane's pending step was recorded when it
            // originally started, so the shift subtracts it back out.
            for _ in 0..(lane.i + 1 - lane.shift) {
                self.costs.record_decode_selection(p.local_rows, remote_total);
            }
            if lane.i > 0 {
                self.flush_leap(lane.d, lane.i, &p.times);
                #[cfg(debug_assertions)]
                self.assert_leap_residency(lane.d);
            }
        }

        // -- return the pricers and scratch ----------------------------
        for (li, pricer) in priced.into_iter().enumerate() {
            self.epoch_pricers[starters[li]] = Some(pricer);
        }
        starters.clear();
        lanes.clear();
        self.scratch_epoch_starters = starters;
        self.scratch_epoch_lanes = lanes;
    }

    /// Run-loop cutoff: an event popping past this instant ends the run
    /// (and a leap never commits a step ending beyond it — the reference
    /// path would stop before granting that step's tokens).
    fn hard_stop(&self) -> f64 {
        self.cfg.duration_s * 20.0 + 3600.0
    }

    // ----- timing models ----------------------------------------------------

    fn prefill_time(&mut self, pi: usize, tokens: u64) -> f64 {
        // MPS reservation always applies; bandwidth contention applies in
        // proportion to the executor's *recent* duty cycle — an
        // exponentially-decayed estimate (`DutyCycleEstimator`, τ =
        // `DUTY_TAU_S`) rather than the old lifetime-cumulative ratio,
        // which never forgot a busy warm-up. (The cost plane skips both
        // when offloading is disabled — no executor colocated, so the
        // duty value is unused and that path stays bit-identical.)
        let duty = self.duty[pi].duty();
        self.costs.prefill_time(tokens, duty)
    }

    // ----- accounting -------------------------------------------------------

    fn record_decode_occupancy(&mut self, t: f64, d: usize) {
        if d == 0 {
            self.decode_occupancy.push(t, self.decode[d].kv.occupancy());
        }
    }

    fn record_prefill_occupancy(&mut self, t: f64) {
        // Fig 16 metric: capacity utilization of prefill instance 0.
        let m = &self.cfg.model;
        let p = &self.prefill[0];
        let used = m.weight_bytes()
            + HbmUsage::activation_workspace(m)
            + p.executor_kv_tokens as f64 * m.kv_bytes_per_token();
        let capacity = self.cfg.cluster.prefill_profile().gpu.hbm_capacity;
        self.prefill_occupancy.push(t, (used / capacity).min(1.0));
    }

    pub(crate) fn report(mut self) -> SimReport {
        let end = self.events.clock();
        self.record_prefill_occupancy(end);
        let window = StableWindow::detect(&self.decode_occupancy, &self.batch_size);
        let throughput = match window {
            Some(w) if w.duration() > 1e-9 => self.metrics.throughput_in_window(w.start, w.end),
            _ => {
                if end > 0.0 {
                    self.metrics.total_output_tokens() as f64 / end
                } else {
                    0.0
                }
            }
        };

        // Prefill-instance utilization means (instance 0), each class
        // normalized by its own device's capability.
        let pre_gpu = self.cfg.cluster.prefill_profile().gpu;
        let p0 = &self.prefill[0];
        let span = end.max(1e-9);
        let exec_bw_frac = if self.cfg.cluster.executor_is_colocated() {
            self.interference.attn_bw_cap(pre_gpu.bw_eff)
        } else {
            // Standalone executor: its achievable fraction of its own
            // device's peak bandwidth (streaming attention sustains
            // bw_eff × the Fig 9 whole-device factor).
            let dev = self.cfg.cluster.executor_profile();
            Roofline::for_profile(&dev).effective_bw() / dev.gpu.hbm_bw
        };
        let prefill_hbm_bw_util = (p0.prefill_busy_s * PREFILL_BW_FRAC
            + p0.executor_busy_s * exec_bw_frac)
            / span;
        let executor_duty = p0.executor_busy_s / span;

        let d0 = &self.decode[0];
        let decode_compute_util = if d0.busy_s > 0.0 {
            (d0.flops_done / d0.busy_s) / self.cfg.cluster.decode_profile().gpu.peak_flops
        } else {
            0.0
        };

        let prefill_hbm_capacity_util = self
            .prefill_occupancy
            .time_weighted_mean(0.0, end)
            .unwrap_or(0.0);

        // SLO attainment + goodput over finished requests, plus the
        // token-conservation invariants.
        let slo = self.cfg.serving.slo;
        let mut met_ttft = 0usize;
        let mut met_tpot = 0usize;
        let mut met_both = 0usize;
        let mut slo_met_tokens = 0u64;
        let mut finished_seen = 0usize;
        let mut req_preemptions_total = 0u64;
        let mut generated_total = 0usize;
        let mut tokens_conserved = true;
        for sr in &self.reqs {
            req_preemptions_total += sr.preemptions as u64;
            generated_total += sr.generated;
            if sr.phase != Phase::Done {
                continue;
            }
            finished_seen += 1;
            let Some(rm) = self.metrics.request(sr.req.id) else { continue };
            if rm.output_tokens() != sr.generated || sr.generated < sr.req.output_len {
                tokens_conserved = false;
            }
            let ttft_ok = rm.ttft().is_some_and(|t| t <= slo.ttft_s);
            let tpots = rm.tpot_samples();
            let tpot_ok = if tpots.is_empty() {
                true
            } else {
                tpots.iter().sum::<f64>() / tpots.len() as f64 <= slo.tpot_s
            };
            met_ttft += usize::from(ttft_ok);
            met_tpot += usize::from(tpot_ok);
            met_both += usize::from(ttft_ok && tpot_ok);
            if ttft_ok && tpot_ok {
                slo_met_tokens += sr.generated as u64;
            }
        }
        if generated_total != self.metrics.total_output_tokens() {
            tokens_conserved = false;
        }
        let frac = |n: usize| {
            if finished_seen == 0 {
                0.0
            } else {
                n as f64 / finished_seen as f64
            }
        };
        let good_frac = frac(met_both);
        let gstats = self.costs.graph_stats();
        let metadata_residual: usize = (0..self.decode.len())
            .map(|i| self.proxy.metadata(i).total_count())
            .sum();

        // Fault plane: close a still-open degraded window at sim end so
        // `degraded_time_s` covers crashes the run never recovered from.
        let (
            faults_injected,
            requests_recovered,
            recompute_tokens_replayed,
            transfer_retries,
            degraded_time_s,
            health_timeline,
        ) = match self.fault.take() {
            Some(mut fp) => {
                if let Some(since) = fp.degraded_since.take() {
                    fp.degraded_time_s += end - since;
                }
                (
                    fp.faults_injected,
                    fp.requests_recovered,
                    fp.recompute_tokens_replayed,
                    fp.transfer_retries,
                    fp.degraded_time_s,
                    fp.health_timeline,
                )
            }
            None => (0, 0, 0, 0, 0.0, Timeline::new()),
        };

        let (prefill_pool_timeline, scale_ups, scale_downs) = match self.scaler.take() {
            Some(s) => (s.pool_timeline, s.scale_ups, s.scale_downs),
            None => (Timeline::new(), 0, 0),
        };

        SimReport {
            ttft: self.metrics.ttft_stats(),
            tpot: self.metrics.tpot_stats(),
            throughput,
            window,
            arrived: self.reqs.len(),
            finished: self.finished_total,
            preemptions: self.preemptions,
            req_preemptions_total,
            tokens_conserved,
            offloaded_fraction: if self.finished_total > 0 {
                self.finished_offloaded as f64 / self.finished_total as f64
            } else {
                0.0
            },
            prefill_hbm_capacity_util,
            prefill_hbm_bw_util,
            executor_bw_util: exec_bw_frac,
            executor_duty,
            decode_compute_util,
            ttft_slo_attainment: frac(met_ttft),
            tpot_slo_attainment: frac(met_tpot),
            requests_slo_met: met_both,
            slo_met_tokens,
            goodput: throughput * good_frac,
            decode_occupancy: self.decode_occupancy,
            prefill_occupancy: self.prefill_occupancy,
            batch_size: self.batch_size,
            sim_end_s: end,
            events_processed: self.events_processed,
            steps_simulated: self.steps_simulated,
            exact_costs: self.costs.mode() == CostMode::Exact,
            graph_selections: gstats.selections,
            graph_used_slots: gstats.used_slots,
            graph_padded_slots: gstats.padded_slots,
            graph_padding_overhead: self.costs.padding_overhead(),
            graph_bucket_hits: self.costs.bucket_hits(),
            migrations_total: self.migrations_to_offload + self.migrations_to_local,
            migrations_to_offload: self.migrations_to_offload,
            migrations_to_local: self.migrations_to_local,
            migration_tokens_moved: self.migration_tokens_moved,
            offloaded_frac_timeline: self.offloaded_frac_timeline,
            prefill_pressure_timeline: self.prefill_pressure_timeline,
            metadata_residual,
            b_tpot_timeline: self.b_tpot_timeline,
            ob_timeline: self.ob_timeline,
            bounds_refreshes: self.bounds_refreshes,
            b_tpot_observations: self.b_tpot_est.as_ref().map_or(0, |e| e.observations()),
            decision_counts: self.proxy.decision_counts,
            decision_counts_rerouted: self.proxy.decision_counts_rerouted,
            faults_injected,
            requests_recovered,
            recompute_tokens_replayed,
            transfer_retries,
            degraded_time_s,
            requests_exported: self.exported as u64,
            health_timeline,
            prefill_pool_timeline,
            scale_ups,
            scale_downs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    fn quick(policy_on: bool, rate: f64, duration: f64) -> SimReport {
        let model = ModelSpec::llama2_7b();
        let mut cfg = if policy_on {
            SimConfig::paper_default(model, WorkloadKind::ShareGpt, rate)
        } else {
            SimConfig::baseline(model, WorkloadKind::ShareGpt, rate)
        };
        cfg.duration_s = duration;
        ClusterSim::new(cfg).run()
    }

    fn quick_fault(rate: f64, duration: f64, fc: crate::config::FaultConfig) -> SimReport {
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::ShareGpt, rate);
        cfg.duration_s = duration;
        cfg.serving.fault = Some(fc);
        ClusterSim::new(cfg).run()
    }

    #[test]
    fn all_requests_finish_at_low_rate() {
        let r = quick(false, 0.5, 40.0);
        assert!(r.arrived > 0);
        assert_eq!(r.finished, r.arrived, "low load must drain fully");
        assert!(r.ttft.is_some() && r.tpot.is_some());
    }

    #[test]
    fn offloading_happens_under_load_aware_policy() {
        let r = quick(true, 2.0, 60.0);
        assert!(r.offloaded_fraction > 0.05, "offloaded {}", r.offloaded_fraction);
        assert!(r.executor_duty > 0.0);
    }

    #[test]
    fn baseline_never_offloads() {
        let r = quick(false, 2.0, 40.0);
        assert_eq!(r.offloaded_fraction, 0.0);
        assert_eq!(r.executor_duty, 0.0);
    }

    /// Saturating ShareGPT rate for this testbed. The paper's testbed
    /// saturates near 4 req/s; our roofline decode steps are faster than
    /// the authors' measured stack, so the decode pool fills at a higher
    /// rate — the crossover shape is what must match, not the absolute
    /// rate (see EXPERIMENTS.md).
    const SATURATING_RATE: f64 = 24.0;

    #[test]
    fn adrenaline_beats_baseline_throughput_at_high_rate() {
        // The headline claim (Fig 11d): at saturating rates Adrenaline
        // sustains higher output-token throughput.
        let base = quick(false, SATURATING_RATE, 120.0);
        let adre = quick(true, SATURATING_RATE, 120.0);
        assert!(
            adre.throughput > base.throughput * 1.1,
            "adrenaline {} vs baseline {}",
            adre.throughput,
            base.throughput
        );
    }

    #[test]
    fn prefill_capacity_util_improves_with_offloading() {
        let base = quick(false, SATURATING_RATE, 120.0);
        let adre = quick(true, SATURATING_RATE, 120.0);
        assert!(
            adre.prefill_hbm_capacity_util > base.prefill_hbm_capacity_util * 1.3,
            "adre {} base {}",
            adre.prefill_hbm_capacity_util,
            base.prefill_hbm_capacity_util
        );
    }

    #[test]
    fn tokens_conserved() {
        let r = quick(true, 1.0, 30.0);
        // Every finished request produced exactly its output_len tokens;
        // total output tokens >= finished (each got >= 1).
        assert!(r.finished > 0);
        assert!(r.tpot.map(|t| t.count).unwrap_or(0) > 0);
        assert!(r.tokens_conserved);
        assert_eq!(r.preemptions, r.req_preemptions_total);
    }

    #[test]
    fn bucketed_costs_are_default_and_record_padding() {
        let r = quick(true, 2.0, 40.0);
        assert!(!r.exact_costs, "bucketed charging is the default");
        assert!(r.graph_selections > 0, "every decode step selects a pair");
        assert!(r.graph_used_slots > 0);
        assert!(r.graph_padded_slots > 0, "real batches rarely land on buckets");
        assert!((0.0..1.0).contains(&r.graph_padding_overhead));
        assert!(!r.graph_bucket_hits.is_empty());
        assert_eq!(
            r.graph_bucket_hits.iter().map(|&(_, n)| n).sum::<u64>(),
            r.graph_selections,
            "hit histogram must account for every selection"
        );
    }

    #[test]
    fn exact_cost_switch_bypasses_the_grid() {
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::ShareGpt, 2.0);
        cfg.duration_s = 40.0;
        cfg.serving.exact_costs = true;
        let r = ClusterSim::new(cfg).run();
        assert!(r.exact_costs);
        assert_eq!(r.graph_selections, 0);
        assert_eq!(r.graph_padded_slots, 0);
        assert_eq!(r.graph_padding_overhead, 0.0);
        assert!(r.graph_bucket_hits.is_empty());
        assert!(r.finished > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = quick(true, 1.5, 30.0);
        let b = quick(true, 1.5, 30.0);
        assert_eq!(a.finished, b.finished);
        assert!((a.throughput - b.throughput).abs() < 1e-9);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.steps_simulated, b.steps_simulated);
    }

    #[test]
    fn leaping_collapses_step_events_and_counts_steps() {
        // Leaping is default-on; the per-step reference schedules one
        // event per decode step. Both count the same simulated steps
        // (the leap-robust perf denominator), but the leap run folds
        // clean steps into far fewer events.
        let model = ModelSpec::llama2_7b();
        let mk = |no_leap: bool| {
            let mut cfg = SimConfig::baseline(model, WorkloadKind::ShareGpt, 1.0);
            cfg.duration_s = 20.0;
            cfg.serving.no_leap = no_leap;
            ClusterSim::new(cfg).run()
        };
        let leap = mk(false);
        let refr = mk(true);
        assert!(leap.steps_simulated > 0);
        assert_eq!(leap.steps_simulated, refr.steps_simulated);
        assert_eq!(leap.finished, refr.finished);
        // Reference: at least one event per arrival and one per step.
        assert!(refr.events_processed as usize > refr.arrived);
        assert!(refr.events_processed >= refr.steps_simulated);
        // Leap: clean steps no longer cost events (unless the env switch
        // forces the reference path process-wide, when the counts tie).
        if crate::sim::engine_env().no_leap {
            assert_eq!(leap.events_processed, refr.events_processed);
        } else {
            assert!(
                leap.events_processed < refr.events_processed,
                "leap {} vs reference {} events",
                leap.events_processed,
                refr.events_processed
            );
        }
    }

    #[test]
    fn tiny_kv_pools_force_preemption_and_conserve_tokens() {
        // Shrunk decode + executor pools (the exhaustion path): preemption
        // churn must not corrupt token accounting or the aggregates (the
        // debug-build aggregate invariant runs on every step here).
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::OpenThoughts, 1.0);
        cfg.duration_s = 20.0;
        cfg.serving.decode_kv_capacity_tokens = Some(16 * 1024);
        cfg.serving.executor_kv_capacity_tokens = Some(16 * 1024);
        let r = ClusterSim::new(cfg).run();
        assert!(r.preemptions > 0, "tiny pools must preempt");
        assert!(r.tokens_conserved, "token accounting must survive preemption churn");
        assert_eq!(r.preemptions, r.req_preemptions_total);
        assert!(r.finished > 0);
    }

    #[test]
    fn fleet_config_without_autoscale_is_structurally_inert() {
        // `fleet: Some(..)` with `autoscale: None` must build no scaler,
        // schedule no autoscale events, and leave the physics untouched —
        // the per-group half of the fleet:None inertness contract
        // (rust/tests/fleet.rs pins the FleetSim half).
        use crate::config::FleetConfig;
        let model = ModelSpec::llama2_7b();
        let mk = |fleet: Option<FleetConfig>| {
            let mut cfg = SimConfig::paper_default(model, WorkloadKind::ShareGpt, 2.0);
            cfg.duration_s = 20.0;
            cfg.serving.fleet = fleet;
            ClusterSim::new(cfg).run()
        };
        let off = mk(None);
        let on = mk(Some(FleetConfig::default()));
        assert_eq!(off.finished, on.finished);
        assert_eq!(off.steps_simulated, on.steps_simulated);
        assert_eq!(off.events_processed, on.events_processed);
        assert_eq!(off.throughput.to_bits(), on.throughput.to_bits());
        assert_eq!(off.goodput.to_bits(), on.goodput.to_bits());
        for r in [&off, &on] {
            assert!(r.prefill_pool_timeline.is_empty());
            assert_eq!((r.scale_ups, r.scale_downs), (0, 0));
        }
    }

    #[test]
    fn static_runs_never_migrate() {
        // Without `ServingConfig::rebalance` there are no ticks, no
        // migrations, and the new observability stays empty — the
        // bit-identity contract's structural half (rust/tests/rebalance.rs
        // pins the behavioral half).
        for policy_on in [true, false] {
            let r = quick(policy_on, 2.0, 40.0);
            assert_eq!(r.migrations_total, 0);
            assert_eq!(r.migrations_to_offload, 0);
            assert_eq!(r.migrations_to_local, 0);
            assert_eq!(r.migration_tokens_moved, 0);
            assert!(r.offloaded_frac_timeline.is_empty());
            assert!(r.prefill_pressure_timeline.is_empty());
        }
    }

    #[test]
    fn no_feedback_means_no_observation_hooks() {
        // Without `bounds_feedback` (the default) the estimator does not
        // exist: no observations, no refreshes, empty timelines — the
        // structural half of the ISSUE 4 bit-identity contract
        // (rust/tests/bounds_feedback.rs pins the behavioral half).
        for policy_on in [true, false] {
            let r = quick(policy_on, 2.0, 40.0);
            assert_eq!(r.bounds_refreshes, 0);
            assert_eq!(r.b_tpot_observations, 0);
            assert!(r.b_tpot_timeline.is_empty());
            assert!(r.ob_timeline.is_empty());
        }
    }

    #[test]
    fn disabled_policy_ignores_bounds_feedback_config() {
        // Feedback on top of OffloadPolicy::Disabled must not invent a
        // control plane: nothing consults OB, so nothing observes.
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::baseline(model, WorkloadKind::ShareGpt, 2.0);
        cfg.duration_s = 30.0;
        cfg.serving.bounds_feedback = Some(crate::config::BoundsFeedbackConfig::default());
        let r = ClusterSim::new(cfg).run();
        assert_eq!(r.bounds_refreshes, 0);
        assert_eq!(r.b_tpot_observations, 0);
        assert!(r.b_tpot_timeline.is_empty());
        assert!(r.ob_timeline.is_empty());
    }

    #[test]
    fn decision_counts_track_arrivals_and_reroutes() {
        // Tiny pools force preemptions: fresh-arrival decisions must sum
        // to arrivals and re-route decisions to preemptions — the counters
        // used to conflate the two, inflating C1/C2/Local per preemption.
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::OpenThoughts, 1.0);
        cfg.duration_s = 20.0;
        cfg.serving.decode_kv_capacity_tokens = Some(16 * 1024);
        cfg.serving.executor_kv_capacity_tokens = Some(16 * 1024);
        let r = ClusterSim::new(cfg).run();
        assert!(r.preemptions > 0, "tiny pools must preempt");
        let fresh = r.decision_counts.0 + r.decision_counts.1 + r.decision_counts.2;
        assert_eq!(fresh as usize, r.arrived, "one fresh decision per arrival");
        let re = r.decision_counts_rerouted;
        assert_eq!(re.0 + re.1 + re.2, r.preemptions, "one re-route per preemption");
    }

    #[test]
    fn disabled_policy_ignores_rebalance_config() {
        // Rebalancing on top of OffloadPolicy::Disabled must not invent an
        // executor: no ticks run, nothing offloads.
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::baseline(model, WorkloadKind::ShareGpt, 2.0);
        cfg.duration_s = 30.0;
        cfg.serving.rebalance = Some(crate::config::RebalanceConfig::default());
        let r = ClusterSim::new(cfg).run();
        assert_eq!(r.migrations_total, 0);
        assert_eq!(r.offloaded_fraction, 0.0);
        assert!(r.prefill_pressure_timeline.is_empty());
    }

    #[test]
    fn rebalancing_run_samples_timelines_and_conserves() {
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::ShareGpt, 8.0);
        cfg.duration_s = 30.0;
        cfg.arrivals = ArrivalPattern::Bursty { period_s: 10.0, duty: 0.25, mult: 3.0 };
        cfg.serving.rebalance = Some(crate::config::RebalanceConfig::default());
        let r = ClusterSim::new(cfg).run();
        assert!(r.finished > 0);
        assert!(r.tokens_conserved, "migrations must not corrupt token accounting");
        assert_eq!(r.preemptions, r.req_preemptions_total);
        // One pressure + one fraction sample per tick, aligned.
        assert!(!r.prefill_pressure_timeline.is_empty());
        assert_eq!(
            r.prefill_pressure_timeline.len(),
            r.offloaded_frac_timeline.len(),
            "tick samples must stay aligned"
        );
        // Every request finished => the proxy metadata fully drained.
        if r.finished == r.arrived {
            assert_eq!(r.metadata_residual, 0);
        }
    }

    #[test]
    fn shared_executor_pool_drains_across_decode_instances() {
        // Two decode instances feeding one prefill instance's executor
        // pool: an overflow must be resolvable from either instance's
        // step-end (the cross-instance victim scan).
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::OpenThoughts, 2.0);
        cfg.duration_s = 20.0;
        cfg.cluster.n_decode = 2;
        cfg.serving.executor_kv_capacity_tokens = Some(8 * 1024);
        let r = ClusterSim::new(cfg).run();
        assert!(r.finished > 0);
        assert!(r.tokens_conserved);
        assert_eq!(r.preemptions, r.req_preemptions_total);
    }

    #[test]
    fn fault_none_reports_zero_fault_metrics() {
        let r = quick(true, 1.0, 30.0);
        assert_eq!(r.faults_injected, 0);
        assert_eq!(r.requests_recovered, 0);
        assert_eq!(r.recompute_tokens_replayed, 0);
        assert_eq!(r.transfer_retries, 0);
        assert_eq!(r.degraded_time_s, 0.0);
        assert!(r.health_timeline.is_empty());
    }

    #[test]
    fn scripted_prefill_crash_recovers_every_request() {
        use crate::config::{FaultConfig, FaultKind, ScriptedFault};
        // Crash prefill 0 mid-run with a survivor available: the offloaded
        // residents it carried must re-prefill via the recompute path and
        // the run must still drain completely with exact token accounting.
        let fc = FaultConfig {
            script: vec![ScriptedFault {
                kind: FaultKind::PrefillCrash,
                instance: 0,
                at_s: 10.0,
                down_s: 8.0,
                group: None,
            }],
            ..FaultConfig::default()
        };
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::ShareGpt, 1.0);
        cfg.duration_s = 40.0;
        cfg.cluster.n_prefill = 2;
        cfg.serving.fault = Some(fc);
        let r = ClusterSim::new(cfg).run();
        assert_eq!(r.finished, r.arrived, "no request may be lost to a crash");
        assert!(r.tokens_conserved);
        assert_eq!(r.faults_injected, 1);
        assert!(r.degraded_time_s >= 8.0 - 1e-9, "window spans the scripted down_s");
        assert!(!r.health_timeline.is_empty());
        let dipped = r.health_timeline.min_value().unwrap_or(1.0) < 1.0;
        assert!(dipped, "heartbeats must observe the crash window");
        // Crash recoveries are NOT preemptions: the rerouted decision sum
        // covers preemptions plus recompute recoveries.
        assert_eq!(r.preemptions, r.req_preemptions_total);
        let re = r.decision_counts_rerouted;
        assert!(re.0 + re.1 + re.2 >= r.preemptions);
    }

    #[test]
    fn scripted_decode_crash_drains_with_two_instances() {
        use crate::config::{FaultConfig, FaultKind, ScriptedFault};
        let fc = FaultConfig {
            script: vec![ScriptedFault {
                kind: FaultKind::DecodeCrash,
                instance: 0,
                at_s: 10.0,
                down_s: 6.0,
                group: None,
            }],
            ..FaultConfig::default()
        };
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::ShareGpt, 1.0);
        cfg.duration_s = 40.0;
        cfg.cluster.n_decode = 2;
        cfg.serving.fault = Some(fc);
        let r = ClusterSim::new(cfg).run();
        assert_eq!(r.finished, r.arrived, "survivor must absorb the victims");
        assert!(r.tokens_conserved);
        assert_eq!(r.faults_injected, 1);
        assert!(r.requests_recovered > 0, "the crash must have struck live work");
    }

    #[test]
    fn router_headroom_masks_unhealthy_instances() {
        // ISSUE 10 satellite: an instance the proxy observes as unhealthy
        // (crashed, draining) must not contribute KV headroom to the
        // cluster router's load signal — a degraded group otherwise keeps
        // winning least-loaded routing on capacity it cannot serve.
        let model = ModelSpec::llama2_7b();
        let mut cfg = SimConfig::paper_default(model, WorkloadKind::ShareGpt, 1.0);
        cfg.cluster.n_prefill = 2;
        cfg.cluster.n_decode = 2;
        cfg.serving.fault = Some(crate::config::FaultConfig::default());
        let mut sim = ClusterSim::lockstep(cfg, 1024);
        sim.prime();
        let full = sim.router_headroom();
        sim.proxy.set_prefill_health(1, false);
        let lost_exec = sim.prefill[1].executor_kv_budget as f64;
        assert!(lost_exec > 0.0, "the offload-enabled default carries executor pools");
        assert_eq!(
            (full - sim.router_headroom()).to_bits(),
            lost_exec.to_bits(),
            "an unhealthy prefill instance's executor pool leaves the sum exactly"
        );
        sim.proxy.set_decode_health(1, false);
        let lost_dec = sim.decode[1].kv_budget() as f64;
        assert_eq!(
            (full - sim.router_headroom()).to_bits(),
            (lost_exec + lost_dec).to_bits(),
            "an unhealthy decode instance's KV pool leaves the sum too"
        );
        // Recovery restores the full signal.
        sim.proxy.set_prefill_health(1, true);
        sim.proxy.set_decode_health(1, true);
        assert_eq!(sim.router_headroom().to_bits(), full.to_bits());
    }

    #[test]
    fn transfer_failures_retry_and_still_drain() {
        use crate::config::FaultConfig;
        let fc = FaultConfig {
            transfer_fail_prob: 0.5,
            transfer_max_retries: 20,
            ..FaultConfig::default()
        };
        let r = quick_fault(1.0, 40.0, fc);
        assert_eq!(r.finished, r.arrived);
        assert!(r.tokens_conserved);
        assert!(r.transfer_retries > 0, "p=0.5 over a 40 s run must retry");
        assert_eq!(r.faults_injected, 0, "link flaps are not instance faults");
    }

    #[test]
    fn straggler_window_degrades_but_conserves() {
        use crate::config::{FaultConfig, FaultKind, ScriptedFault};
        let fc = FaultConfig {
            script: vec![ScriptedFault {
                kind: FaultKind::Straggler,
                instance: 0,
                at_s: 5.0,
                down_s: 10.0,
                group: None,
            }],
            straggler_factor: 4.0,
            ..FaultConfig::default()
        };
        let r = quick_fault(2.0, 40.0, fc);
        assert!(r.finished > 0);
        assert!(r.tokens_conserved);
        assert_eq!(r.faults_injected, 1);
        assert!((r.degraded_time_s - 10.0).abs() < 1e-6);
        assert_eq!(r.requests_recovered, 0, "a straggler slows, it does not kill");
    }

    #[test]
    fn stochastic_fault_schedule_is_seed_deterministic() {
        use crate::config::FaultConfig;
        let fc = FaultConfig {
            prefill_mtbf_s: Some(15.0),
            prefill_mttr_s: 3.0,
            decode_mtbf_s: Some(20.0),
            decode_mttr_s: 3.0,
            ..FaultConfig::default()
        };
        let a = quick_fault(1.0, 40.0, fc.clone());
        let b = quick_fault(1.0, 40.0, fc);
        assert_eq!(a.faults_injected, b.faults_injected);
        assert_eq!(a.requests_recovered, b.requests_recovered);
        assert_eq!(a.events_processed, b.events_processed);
        assert!((a.degraded_time_s - b.degraded_time_s).abs() < 1e-12);
        assert!(a.faults_injected > 0, "MTBF 15 s over 40 s must fire");
        assert_eq!(a.finished, a.arrived, "no request may be lost");
        assert!(a.tokens_conserved);
    }
}
