//! Discrete-event simulation of a PD-disaggregated serving cluster on
//! A100-class hardware — the testbed substitute for the paper's §4
//! evaluation (DESIGN.md §1).
//!
//! Fidelity choices, mapped to the paper:
//!
//! * **Phases.** Requests route through the proxy (Algorithm 1 decides
//!   offloading at admission), queue for prefill, prefill at roofline
//!   speed (SM-partition slowdown when an attention executor is
//!   reserved/active), transfer KV to the decode instance over NVLink
//!   (local requests only — offloaded KV stays colocated with the
//!   executor), then decode step-by-step under continuous batching.
//! * **Decode step time.** `non_attention(batch)` + `max(local attention,
//!   remote attention + per-layer sync)`: the paper's overlap model
//!   (Fig 8b). Remote attention runs on the executor's SM share with the
//!   superlinear-bandwidth curve (Fig 9).
//! * **Memory.** Decode KV pool and per-prefill-instance executor pools
//!   sized from HBM budgets; exhaustion causes LIFO preemption with
//!   recompute (vLLM semantics), the effect behind the OpenThoughts TPOT
//!   spikes (Figs 13/14).
//! * **Dispatch gating.** A prompt is only dispatched to prefill when its
//!   KV has a home (decode pool for local, executor pool for offloaded) —
//!   queueing at high rate is what blows up vLLM's TTFT in Fig 11a.

use std::collections::{HashMap, VecDeque};

use crate::config::{ClusterSpec, ModelSpec, ServingConfig};
use crate::coordinator::{OffloadBounds, Proxy};
use crate::kv::{BlockAllocator, KvPool};
use crate::gpu_model::{
    DecodeKernelTimes, HbmUsage, InterferenceModel, KernelCost, PrefillKernelTimes, Roofline,
};
use crate::metrics::{LatencyStats, MetricsRecorder, StableWindow, Timeline};
use crate::workload::{Request, RequestId, TraceGenerator, WorkloadKind};

use super::events::EventQueue;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub cluster: ClusterSpec,
    pub model: ModelSpec,
    pub serving: ServingConfig,
    pub workload: WorkloadKind,
    /// Mean request rate, req/s.
    pub rate: f64,
    /// Trace duration, seconds (drain continues afterwards).
    pub duration_s: f64,
    pub seed: u64,
    /// Per-layer decode↔executor synchronization overhead (the residual
    /// after graph-based launch batching; §3.2.2).
    pub sync_overhead_s: f64,
    /// Extra CPU launch overhead per decode step when the executable
    /// grid / CUDA-graph analogue is disabled (ablation; §3.2.2 measures
    /// ~0.76 ms/layer wasted without graphs).
    pub eager_launch_overhead_s: f64,
}

impl SimConfig {
    pub fn paper_default(model: ModelSpec, workload: WorkloadKind, rate: f64) -> Self {
        SimConfig {
            cluster: ClusterSpec::paper_default(),
            model,
            serving: ServingConfig::default(),
            workload,
            rate,
            duration_s: 300.0,
            seed: 42,
            // ~15 µs per layer of channel+merge overhead with graphs on.
            sync_overhead_s: 15e-6,
            eager_launch_overhead_s: 0.0,
        }
    }

    pub fn baseline(model: ModelSpec, workload: WorkloadKind, rate: f64) -> Self {
        SimConfig {
            serving: ServingConfig::baseline(),
            ..Self::paper_default(model, workload, rate)
        }
    }

    /// §3.3.2 online stage: derive the attention executor's SM share from
    /// the offline prefill profile — the minimal prefill reservation that
    /// keeps `avg_prompt`-token prompts within the TTFT SLO, executor gets
    /// the complement (capped at 0.5: the executor never starves prefill
    /// past the Fig 10 sweet spot).
    pub fn with_adaptive_partition(mut self, avg_prompt: u64) -> Self {
        use crate::gpu_model::PrefillProfile;
        let profile = PrefillProfile::default_grid(&self.cluster.gpu, &self.model);
        // Leave queueing headroom: prefill must fit in half the TTFT SLO.
        let exec = profile.executor_sm_frac(avg_prompt.max(1), self.serving.slo.ttft_s * 0.5);
        self.cluster.attn_executor_sm_frac = exec.clamp(0.05, 0.5);
        self
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    WaitingDispatch,
    Prefilling,
    Transferring,
    Decoding,
    Done,
}

#[derive(Debug, Clone)]
struct SimReq {
    req: Request,
    phase: Phase,
    /// Output tokens generated so far.
    generated: usize,
    /// Tokens of KV this request holds (prompt + generated, after prefill).
    kv_tokens: usize,
    offloaded: bool,
    prefill_instance: usize,
    decode_instance: usize,
    /// Re-prefill length after preemption (prompt + generated).
    effective_prompt: usize,
    preemptions: u32,
}

#[derive(Debug)]
struct PrefillInst {
    busy_until: f64,
    queue: VecDeque<RequestId>,
    /// Offloaded KV tokens resident in this instance's executor pool.
    executor_kv_tokens: usize,
    executor_kv_budget: usize,
    /// Reserved (dispatched but not yet admitted) executor tokens.
    executor_reserved: usize,
    /// Accumulated busy seconds (prefill compute).
    prefill_busy_s: f64,
    /// Accumulated executor-active seconds.
    executor_busy_s: f64,
}

#[derive(Debug)]
struct DecodeInst {
    /// Running batch (request ids).
    running: Vec<RequestId>,
    /// Prefilled requests waiting for KV admission.
    waiting: VecDeque<RequestId>,
    /// Paged KV pool (vLLM block tables; block granularity makes the
    /// occupancy/preemption dynamics faithful to the real allocator).
    kv: KvPool,
    /// Reserved (dispatched) tokens not yet admitted.
    reserved: usize,
    step_in_flight: bool,
    /// Accumulated (flops, seconds) for compute-utilization accounting.
    flops_done: f64,
    busy_s: f64,
}

impl DecodeInst {
    fn kv_tokens(&self) -> usize {
        self.kv.resident_tokens()
    }

    fn kv_budget(&self) -> usize {
        self.kv.total_blocks() * self.kv.block_tokens()
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival(RequestId),
    PrefillDone { inst: usize, id: RequestId },
    TransferDone { id: RequestId },
    DecodeStepEnd { inst: usize },
}

/// Post-run report.
#[derive(Debug)]
pub struct SimReport {
    pub ttft: Option<LatencyStats>,
    pub tpot: Option<LatencyStats>,
    /// Output tokens/s over the §4.1 stable window (falls back to the
    /// whole run if no window is detected).
    pub throughput: f64,
    pub window: Option<StableWindow>,
    pub arrived: usize,
    pub finished: usize,
    pub preemptions: u64,
    /// Fraction of finished requests whose attention was offloaded.
    pub offloaded_fraction: f64,
    /// Mean prefill-instance HBM capacity utilization (Fig 16).
    pub prefill_hbm_capacity_util: f64,
    /// Mean prefill-instance HBM bandwidth utilization (Fig 17a).
    pub prefill_hbm_bw_util: f64,
    /// Executor-active bandwidth utilization (Fig 18a "Attn on").
    pub executor_bw_util: f64,
    /// Executor duty cycle (fraction of wall time active).
    pub executor_duty: f64,
    /// Mean decode compute utilization (Fig 17b).
    pub decode_compute_util: f64,
    /// Fraction of finished requests whose TTFT met the SLO.
    pub ttft_slo_attainment: f64,
    /// Fraction of finished requests whose *mean* TPOT met the SLO.
    pub tpot_slo_attainment: f64,
    /// Goodput: output tokens/s counting only requests that met BOTH SLOs
    /// (the DistServe-style metric; same stable window as `throughput`).
    pub goodput: f64,
    /// Timelines for Figs 2/16.
    pub decode_occupancy: Timeline,
    pub prefill_occupancy: Timeline,
    pub batch_size: Timeline,
    pub sim_end_s: f64,
}

/// The cluster simulator.
pub struct ClusterSim {
    cfg: SimConfig,
    reqs: HashMap<RequestId, SimReq>,
    prefill: Vec<PrefillInst>,
    decode: Vec<DecodeInst>,
    proxy: Proxy,
    events: EventQueue<Ev>,
    metrics: MetricsRecorder,
    decode_occupancy: Timeline,
    prefill_occupancy: Timeline,
    batch_size: Timeline,
    preemptions: u64,
    rl_whole: Roofline,
    rl_executor: Roofline,
    interference: InterferenceModel,
    /// Pending arrivals not yet injected (sorted by time).
    trace: VecDeque<Request>,
    finished_offloaded: usize,
    finished_total: usize,
}

impl ClusterSim {
    pub fn new(cfg: SimConfig) -> Self {
        let mut gen = TraceGenerator::new(cfg.workload, cfg.rate, cfg.seed);
        let trace: VecDeque<Request> = gen.trace(cfg.duration_s).into();

        let avg_seq = if trace.is_empty() {
            1024
        } else {
            (trace.iter().map(|r| r.total_tokens()).sum::<usize>() / trace.len().max(1)) as u64
        };
        let mut bounds =
            OffloadBounds::compute(&cfg.cluster, &cfg.model, &cfg.serving.slo, avg_seq.max(1));
        if let Some(b) = cfg.serving.b_max_override {
            bounds.b_max = b;
        }
        let proxy = Proxy::new(
            cfg.serving.offload,
            bounds,
            cfg.cluster.n_prefill as usize,
            cfg.cluster.n_decode as usize,
        );

        let kv_budget = HbmUsage::kv_token_budget(&cfg.cluster, &cfg.model) as usize;
        let executor_budget = if cfg.serving.offload.is_enabled() { kv_budget } else { 0 };

        let prefill = (0..cfg.cluster.n_prefill)
            .map(|_| PrefillInst {
                busy_until: 0.0,
                queue: VecDeque::new(),
                executor_kv_tokens: 0,
                executor_kv_budget: executor_budget,
                executor_reserved: 0,
                prefill_busy_s: 0.0,
                executor_busy_s: 0.0,
            })
            .collect();
        let block_tokens = cfg.serving.kv_block_tokens.max(1);
        let decode = (0..cfg.cluster.n_decode)
            .map(|_| DecodeInst {
                running: Vec::new(),
                waiting: VecDeque::new(),
                kv: KvPool::new(BlockAllocator::new(kv_budget / block_tokens, block_tokens)),
                reserved: 0,
                step_in_flight: false,
                flops_done: 0.0,
                busy_s: 0.0,
            })
            .collect();

        let rl_whole = Roofline::whole(cfg.cluster.gpu);
        let interference = InterferenceModel::new(cfg.cluster.attn_executor_sm_frac);
        let rl_executor = Roofline::partition(
            cfg.cluster.gpu,
            cfg.cluster.attn_executor_sm_frac.max(1e-3),
        );

        ClusterSim {
            cfg,
            reqs: HashMap::new(),
            prefill,
            decode,
            proxy,
            events: EventQueue::new(),
            metrics: MetricsRecorder::new(),
            decode_occupancy: Timeline::new(),
            prefill_occupancy: Timeline::new(),
            batch_size: Timeline::new(),
            preemptions: 0,
            rl_whole,
            rl_executor,
            interference,
            trace,
            finished_offloaded: 0,
            finished_total: 0,
        }
    }

    /// Run to completion (trace drained and all requests finished or the
    /// hard cap hit) and report.
    pub fn run(mut self) -> SimReport {
        // Seed arrival events.
        let arrivals: Vec<(f64, RequestId)> =
            self.trace.iter().map(|r| (r.arrival_s, r.id)).collect();
        for (t, _) in &arrivals {
            let req = self.trace.pop_front().unwrap();
            let id = req.id;
            self.reqs.insert(
                id,
                SimReq {
                    effective_prompt: req.prompt_len,
                    req,
                    phase: Phase::WaitingDispatch,
                    generated: 0,
                    kv_tokens: 0,
                    offloaded: false,
                    prefill_instance: 0,
                    decode_instance: 0,
                    preemptions: 0,
                },
            );
            self.events.push(*t, Ev::Arrival(id));
        }

        let hard_stop = self.cfg.duration_s * 20.0 + 3600.0;
        while let Some((t, ev)) = self.events.pop() {
            if t > hard_stop {
                break;
            }
            match ev {
                Ev::Arrival(id) => self.on_arrival(t, id),
                Ev::PrefillDone { inst, id } => self.on_prefill_done(t, inst, id),
                Ev::TransferDone { id } => self.on_transfer_done(t, id),
                Ev::DecodeStepEnd { inst } => self.on_decode_step_end(t, inst),
            }
            // Global scheduling pass after every event.
            self.dispatch_prefills(t);
            for d in 0..self.decode.len() {
                self.admit_waiters(t, d);
                self.maybe_start_step(t, d);
            }
        }
        self.report()
    }

    // ----- event handlers ---------------------------------------------------

    fn on_arrival(&mut self, t: f64, id: RequestId) {
        self.metrics.on_arrival(id, t);
        let (route, prompt_len) = {
            let sr = &self.reqs[&id];
            (self.proxy.route(&sr.req), sr.req.prompt_len)
        };
        let _ = prompt_len;
        let sr = self.reqs.get_mut(&id).unwrap();
        sr.offloaded = route.offload.offloaded();
        sr.prefill_instance = route.prefill_instance;
        sr.decode_instance = route.decode_instance;
        self.prefill[route.prefill_instance].queue.push_back(id);
    }

    fn on_prefill_done(&mut self, t: f64, inst: usize, id: RequestId) {
        // First token exists as soon as prefill completes.
        let was_preempted = self.reqs[&id].preemptions > 0;
        if !was_preempted || self.reqs[&id].generated == 0 {
            if self.metrics.request(id).and_then(|r| r.first_token_s).is_none() {
                self.metrics.on_first_token(id, t);
                let sr = self.reqs.get_mut(&id).unwrap();
                sr.generated = 1;
                self.proxy.on_token(sr.decode_instance, id);
            }
        }
        let sr = self.reqs.get_mut(&id).unwrap();
        sr.kv_tokens = sr.effective_prompt;
        if sr.offloaded {
            // KV stays on this instance (executor pool): reservation
            // becomes residency, no transfer.
            let p = &mut self.prefill[inst];
            p.executor_reserved = p.executor_reserved.saturating_sub(sr.kv_tokens);
            p.executor_kv_tokens += sr.kv_tokens;
            sr.phase = Phase::Decoding;
            let d = sr.decode_instance;
            self.decode[d].waiting.push_back(id);
            self.record_prefill_occupancy(t);
        } else {
            // NVLink transfer to the decode instance.
            sr.phase = Phase::Transferring;
            let bytes = sr.kv_tokens as f64 * self.cfg.model.kv_bytes_per_token();
            let xfer = bytes / self.cfg.cluster.gpu.interconnect_bw;
            self.events.push(t + xfer, Ev::TransferDone { id });
        }
    }

    fn on_transfer_done(&mut self, t: f64, id: RequestId) {
        let _ = t;
        let sr = self.reqs.get_mut(&id).unwrap();
        sr.phase = Phase::Decoding;
        let d = sr.decode_instance;
        self.decode[d].waiting.push_back(id);
    }

    fn on_decode_step_end(&mut self, t: f64, inst: usize) {
        self.decode[inst].step_in_flight = false;
        let running = self.decode[inst].running.clone();
        if running.is_empty() {
            return;
        }

        // Every running request gains one token.
        let mut to_finish = Vec::new();
        let mut overflow = Vec::new();
        let mut executor_appends: HashMap<usize, usize> = HashMap::new();
        for &id in &running {
            let sr = self.reqs.get_mut(&id).unwrap();
            sr.generated += 1;
            sr.kv_tokens += 1;
            if sr.offloaded {
                *executor_appends.entry(sr.prefill_instance).or_insert(0) += 1;
            } else {
                // Paged append: a failed block allocation marks this
                // sequence for the preemption pass below (vLLM appends the
                // token after evicting a victim; we evict-then-retry at
                // the same position via recompute, which is equivalent in
                // token accounting).
                if self.decode[inst].kv.append_token(id).is_err() {
                    overflow.push(id);
                }
            }
            self.metrics.on_token(id, t);
            self.proxy.on_token(inst, id);
            if sr.generated >= sr.req.output_len {
                to_finish.push(id);
            }
        }
        for (pi, n) in executor_appends {
            self.prefill[pi].executor_kv_tokens += n;
        }

        // Retire finished requests.
        for id in to_finish {
            self.finish(t, inst, id);
        }

        // Preempt (LIFO, newest first) until every overflowed append fits.
        for id in overflow {
            if !self.decode[inst].running.contains(&id) {
                continue; // finished this step
            }
            loop {
                let victim = self.decode[inst]
                    .running
                    .iter()
                    .rev()
                    .copied()
                    .find(|v| !self.reqs[v].offloaded && self.decode[inst].kv.contains(*v));
                match victim {
                    Some(v) if v == id => {
                        // The overflowing sequence is itself the newest:
                        // preempt it (its token accounting rolls back via
                        // recompute).
                        self.preempt(t, inst, v);
                        break;
                    }
                    Some(v) => {
                        self.preempt(t, inst, v);
                        if self.decode[inst].kv.append_token(id).is_ok() {
                            break;
                        }
                    }
                    None => break,
                }
            }
        }
        // Executor pools can also overflow (offloaded requests growing).
        for pi in 0..self.prefill.len() {
            while self.prefill[pi].executor_kv_tokens > self.prefill[pi].executor_kv_budget {
                let victim = self.decode[inst]
                    .running
                    .iter()
                    .rev()
                    .copied()
                    .find(|id| self.reqs[id].offloaded && self.reqs[id].prefill_instance == pi);
                match victim {
                    Some(v) => self.preempt(t, inst, v),
                    None => break,
                }
            }
        }

        self.record_decode_occupancy(t, inst);
    }

    // ----- actions ----------------------------------------------------------

    fn finish(&mut self, t: f64, inst: usize, id: RequestId) {
        self.metrics.on_finished(id, t);
        self.proxy.on_finished(inst, id);
        let sr = self.reqs.get_mut(&id).unwrap();
        sr.phase = Phase::Done;
        self.finished_total += 1;
        if sr.offloaded {
            self.finished_offloaded += 1;
            self.prefill[sr.prefill_instance].executor_kv_tokens =
                self.prefill[sr.prefill_instance].executor_kv_tokens.saturating_sub(sr.kv_tokens);
        } else {
            let _ = self.decode[inst].kv.release(id);
        }
        sr.kv_tokens = 0;
        self.decode[inst].running.retain(|&r| r != id);
        // Occupancy is recorded by the step-end handler *after* the
        // preemption pass — recording here would capture the transient
        // overshoot between token appends and preemption.
        self.record_prefill_occupancy(t);
    }

    fn preempt(&mut self, _t: f64, inst: usize, id: RequestId) {
        self.preemptions += 1;
        self.proxy.on_preempted(inst, id);
        let sr = self.reqs.get_mut(&id).unwrap();
        sr.preemptions += 1;
        if sr.offloaded {
            self.prefill[sr.prefill_instance].executor_kv_tokens =
                self.prefill[sr.prefill_instance].executor_kv_tokens.saturating_sub(sr.kv_tokens);
        } else {
            let _ = self.decode[inst].kv.release(id);
        }
        sr.kv_tokens = 0;
        // Recompute path: prompt + generated becomes the new prefill.
        sr.effective_prompt = sr.req.prompt_len + sr.generated;
        sr.phase = Phase::WaitingDispatch;
        self.decode[inst].running.retain(|&r| r != id);

        // Re-route through the proxy (offload decision may differ now).
        let (route, _) = {
            let sr = &self.reqs[&id];
            (self.proxy.route(&sr.req), 0)
        };
        let sr = self.reqs.get_mut(&id).unwrap();
        sr.offloaded = route.offload.offloaded();
        sr.prefill_instance = route.prefill_instance;
        sr.decode_instance = route.decode_instance;
        self.prefill[route.prefill_instance].queue.push_back(id);
    }

    /// Dispatch queued prompts whose KV has a guaranteed home.
    /// Dispatch queued prompts whose KV has a guaranteed home, batching
    /// prompts up to `max_prefill_tokens` into one prefill step (vLLM's
    /// token-budget prefill batching — amortizes the per-step weight pass
    /// across prompts and is what keeps TTFT flat below saturation).
    fn dispatch_prefills(&mut self, t: f64) {
        for pi in 0..self.prefill.len() {
            if self.prefill[pi].busy_until > t {
                continue;
            }
            let budget = self.cfg.serving.max_prefill_tokens;
            let mut batch: Vec<RequestId> = Vec::new();
            let mut batch_tokens = 0usize;
            loop {
                let Some(&id) = self.prefill[pi].queue.front() else { break };
                let sr = &self.reqs[&id];
                if sr.phase != Phase::WaitingDispatch {
                    self.prefill[pi].queue.pop_front();
                    continue;
                }
                let need = sr.effective_prompt;
                if !batch.is_empty() && batch_tokens + need > budget {
                    break; // token budget reached
                }
                let fits = if sr.offloaded {
                    let p = &self.prefill[pi];
                    p.executor_kv_tokens + p.executor_reserved + need <= p.executor_kv_budget
                } else {
                    let d = &self.decode[sr.decode_instance];
                    d.kv_tokens() + d.reserved + need <= d.kv_budget()
                };
                if !fits {
                    break; // FCFS: head-of-line blocks (vLLM behavior)
                }
                let id = self.prefill[pi].queue.pop_front().unwrap();
                // Reserve the destination.
                if sr.offloaded {
                    self.prefill[pi].executor_reserved += need;
                } else {
                    let d = self.reqs[&id].decode_instance;
                    self.decode[d].reserved += need;
                }
                self.reqs.get_mut(&id).unwrap().phase = Phase::Prefilling;
                batch_tokens += need;
                batch.push(id);
            }
            if batch.is_empty() {
                continue;
            }
            // One fused prefill step over the batch's total tokens; every
            // request in the batch completes when the step does.
            let exec_time = self.prefill_time(pi, batch_tokens as u64);
            self.prefill[pi].prefill_busy_s += exec_time;
            self.prefill[pi].busy_until = t + exec_time;
            for id in batch {
                self.events.push(t + exec_time, Ev::PrefillDone { inst: pi, id });
            }
        }
    }

    /// Admit waiting requests into the decode batch (KV already resident or
    /// reserved; admission consumes the reservation for local requests).
    fn admit_waiters(&mut self, t: f64, d: usize) {
        while let Some(&id) = self.decode[d].waiting.front() {
            if self.decode[d].running.len() >= self.cfg.serving.max_batch {
                break;
            }
            let sr = &self.reqs[&id];
            if !sr.offloaded {
                let need = sr.kv_tokens;
                let dec = &mut self.decode[d];
                // The reservation covers it; convert to block residency.
                dec.reserved = dec.reserved.saturating_sub(need);
                if dec.kv.admit(id, need).is_err() {
                    break;
                }
            }
            self.decode[d].waiting.pop_front();
            self.decode[d].running.push(id);
            self.record_decode_occupancy(t, d);
        }
    }

    fn maybe_start_step(&mut self, t: f64, d: usize) {
        if self.decode[d].step_in_flight || self.decode[d].running.is_empty() {
            return;
        }
        let (step, flops) = self.decode_step_time(d);
        let dec = &mut self.decode[d];
        dec.step_in_flight = true;
        dec.busy_s += step;
        dec.flops_done += flops;
        self.batch_size.push(t, self.decode[d].running.len() as f64);
        self.events.push(t + step, Ev::DecodeStepEnd { inst: d });
    }

    // ----- timing models ----------------------------------------------------

    fn prefill_time(&mut self, pi: usize, tokens: u64) -> f64 {
        let base = PrefillKernelTimes::compute(&self.rl_whole, &self.cfg.model, tokens).total();
        if !self.cfg.serving.offload.is_enabled() {
            return base;
        }
        // MPS reservation always applies; bandwidth contention applies in
        // proportion to the executor's recent duty cycle.
        let duty = {
            let p = &self.prefill[pi];
            if p.prefill_busy_s + p.executor_busy_s > 0.0 {
                (p.executor_busy_s / (p.prefill_busy_s + p.executor_busy_s)).min(1.0)
            } else {
                0.0
            }
        };
        let prefill_bw_frac = 0.25; // Fig 1a: prefill's own bandwidth draw
        let attn_bw = self.interference.attn_bw_cap(self.cfg.cluster.gpu.bw_eff);
        let idle = self.interference.prefill_slowdown_idle();
        let active = self.interference.prefill_slowdown_active(prefill_bw_frac, attn_bw);
        base * (idle * (1.0 - duty) + active * duty)
    }

    /// One decode step for instance `d`: returns (seconds, flops).
    fn decode_step_time(&mut self, d: usize) -> (f64, f64) {
        let model = self.cfg.model;
        let mut local_ctx = 0u64;
        let mut remote_ctx: HashMap<usize, u64> = HashMap::new();
        let mut b_total = 0u64;
        for &id in &self.decode[d].running {
            let sr = &self.reqs[&id];
            b_total += 1;
            if sr.offloaded {
                *remote_ctx.entry(sr.prefill_instance).or_insert(0) += sr.kv_tokens as u64 + 1;
            } else {
                local_ctx += sr.kv_tokens as u64 + 1;
            }
        }

        let times = DecodeKernelTimes::compute(&self.rl_whole, &model, b_total, 1);
        let non_attn = times.non_attention();
        let local_attn = if local_ctx > 0 {
            self.rl_whole.time(KernelCost::new(
                model.decode_attn_flops(local_ctx),
                model.decode_attn_bytes(local_ctx),
            ))
        } else {
            0.0
        };
        // Remote attention on each involved executor partition, in parallel.
        let mut remote_attn: f64 = 0.0;
        for (&pi, &ctx) in &remote_ctx {
            let t = self.rl_executor.time(KernelCost::new(
                model.decode_attn_flops(ctx),
                model.decode_attn_bytes(ctx),
            ));
            self.prefill[pi].executor_busy_s += t;
            remote_attn = remote_attn.max(t);
        }
        if !remote_ctx.is_empty() {
            remote_attn += self.cfg.sync_overhead_s * model.n_layers as f64;
        }

        let step = non_attn
            + local_attn.max(remote_attn)
            + self.cfg.eager_launch_overhead_s;
        let flops = model.decode_step_flops(b_total, local_ctx + remote_ctx.values().sum::<u64>());
        (step, flops)
    }

    // ----- accounting -------------------------------------------------------

    fn record_decode_occupancy(&mut self, t: f64, d: usize) {
        if d == 0 {
            self.decode_occupancy.push(t, self.decode[d].kv.occupancy());
        }
    }

    fn record_prefill_occupancy(&mut self, t: f64) {
        // Fig 16 metric: capacity utilization of prefill instance 0.
        let m = &self.cfg.model;
        let p = &self.prefill[0];
        let used = m.weight_bytes()
            + HbmUsage::activation_workspace(m)
            + p.executor_kv_tokens as f64 * m.kv_bytes_per_token();
        self.prefill_occupancy.push(t, (used / self.cfg.cluster.gpu.hbm_capacity).min(1.0));
    }

    fn report(mut self) -> SimReport {
        let end = self.events.clock();
        self.record_prefill_occupancy(end);
        let window = StableWindow::detect(&self.decode_occupancy, &self.batch_size);
        let throughput = match window {
            Some(w) if w.duration() > 1e-9 => self.metrics.throughput_in_window(w.start, w.end),
            _ => {
                if end > 0.0 {
                    self.metrics.total_output_tokens() as f64 / end
                } else {
                    0.0
                }
            }
        };

        // Prefill-instance utilization means (instance 0).
        let gpu = self.cfg.cluster.gpu;
        let p0 = &self.prefill[0];
        let span = end.max(1e-9);
        let prefill_bw_frac = 0.25;
        let exec_bw_frac = self.interference.attn_bw_cap(gpu.bw_eff);
        let prefill_hbm_bw_util = (p0.prefill_busy_s * prefill_bw_frac
            + p0.executor_busy_s * exec_bw_frac)
            / span;
        let executor_duty = p0.executor_busy_s / span;

        let d0 = &self.decode[0];
        let decode_compute_util = if d0.busy_s > 0.0 {
            (d0.flops_done / d0.busy_s) / gpu.peak_flops
        } else {
            0.0
        };

        let prefill_hbm_capacity_util = self
            .prefill_occupancy
            .time_weighted_mean(0.0, end)
            .unwrap_or(0.0);

        // SLO attainment + goodput over finished requests.
        let slo = self.cfg.serving.slo;
        let mut met_ttft = 0usize;
        let mut met_tpot = 0usize;
        let mut met_both = 0usize;
        let mut finished_seen = 0usize;
        for sr in self.reqs.values() {
            if sr.phase != Phase::Done {
                continue;
            }
            finished_seen += 1;
            let Some(rm) = self.metrics.request(sr.req.id) else { continue };
            let ttft_ok = rm.ttft().is_some_and(|t| t <= slo.ttft_s);
            let tpots = rm.tpot_samples();
            let tpot_ok = if tpots.is_empty() {
                true
            } else {
                tpots.iter().sum::<f64>() / tpots.len() as f64 <= slo.tpot_s
            };
            met_ttft += usize::from(ttft_ok);
            met_tpot += usize::from(tpot_ok);
            met_both += usize::from(ttft_ok && tpot_ok);
        }
        let frac = |n: usize| {
            if finished_seen == 0 {
                0.0
            } else {
                n as f64 / finished_seen as f64
            }
        };
        let good_frac = frac(met_both);

        SimReport {
            ttft: self.metrics.ttft_stats(),
            tpot: self.metrics.tpot_stats(),
            throughput,
            window,
            arrived: self.reqs.len(),
            finished: self.finished_total,
            preemptions: self.preemptions,
            offloaded_fraction: if self.finished_total > 0 {
                self.finished_offloaded as f64 / self.finished_total as f64
            } else {
                0.0
            },
            prefill_hbm_capacity_util,
            prefill_hbm_bw_util,
            executor_bw_util: exec_bw_frac,
            executor_duty,
            decode_compute_util,
            ttft_slo_attainment: frac(met_ttft),
            tpot_slo_attainment: frac(met_tpot),
            goodput: throughput * good_frac,
            decode_occupancy: self.decode_occupancy,
            prefill_occupancy: self.prefill_occupancy,
            batch_size: self.batch_size,
            sim_end_s: end,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    fn quick(policy_on: bool, rate: f64, duration: f64) -> SimReport {
        let model = ModelSpec::llama2_7b();
        let mut cfg = if policy_on {
            SimConfig::paper_default(model, WorkloadKind::ShareGpt, rate)
        } else {
            SimConfig::baseline(model, WorkloadKind::ShareGpt, rate)
        };
        cfg.duration_s = duration;
        ClusterSim::new(cfg).run()
    }

    #[test]
    fn all_requests_finish_at_low_rate() {
        let r = quick(false, 0.5, 40.0);
        assert!(r.arrived > 0);
        assert_eq!(r.finished, r.arrived, "low load must drain fully");
        assert!(r.ttft.is_some() && r.tpot.is_some());
    }

    #[test]
    fn offloading_happens_under_load_aware_policy() {
        let r = quick(true, 2.0, 60.0);
        assert!(r.offloaded_fraction > 0.05, "offloaded {}", r.offloaded_fraction);
        assert!(r.executor_duty > 0.0);
    }

    #[test]
    fn baseline_never_offloads() {
        let r = quick(false, 2.0, 40.0);
        assert_eq!(r.offloaded_fraction, 0.0);
        assert_eq!(r.executor_duty, 0.0);
    }

    /// Saturating ShareGPT rate for this testbed. The paper's testbed
    /// saturates near 4 req/s; our roofline decode steps are faster than
    /// the authors' measured stack, so the decode pool fills at a higher
    /// rate — the crossover shape is what must match, not the absolute
    /// rate (see EXPERIMENTS.md).
    const SATURATING_RATE: f64 = 24.0;

    #[test]
    fn adrenaline_beats_baseline_throughput_at_high_rate() {
        // The headline claim (Fig 11d): at saturating rates Adrenaline
        // sustains higher output-token throughput.
        let base = quick(false, SATURATING_RATE, 120.0);
        let adre = quick(true, SATURATING_RATE, 120.0);
        assert!(
            adre.throughput > base.throughput * 1.1,
            "adrenaline {} vs baseline {}",
            adre.throughput,
            base.throughput
        );
    }

    #[test]
    fn prefill_capacity_util_improves_with_offloading() {
        let base = quick(false, SATURATING_RATE, 120.0);
        let adre = quick(true, SATURATING_RATE, 120.0);
        assert!(
            adre.prefill_hbm_capacity_util > base.prefill_hbm_capacity_util * 1.3,
            "adre {} base {}",
            adre.prefill_hbm_capacity_util,
            base.prefill_hbm_capacity_util
        );
    }

    #[test]
    fn tokens_conserved() {
        let r = quick(true, 1.0, 30.0);
        // Every finished request produced exactly its output_len tokens;
        // total output tokens >= finished (each got >= 1).
        assert!(r.finished > 0);
        assert!(r.tpot.map(|t| t.count).unwrap_or(0) > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = quick(true, 1.5, 30.0);
        let b = quick(true, 1.5, 30.0);
        assert_eq!(a.finished, b.finished);
        assert!((a.throughput - b.throughput).abs() < 1e-9);
    }
}
