//! Typed discrete-event queue (min-heap over f64 timestamps).
//!
//! Everything the simulator does flows through this queue — including the
//! fault plane's crash/recovery/retry/heartbeat events, which are ordinary
//! entries with no special priority: insertion-order tie-breaking makes a
//! crash landing at the same instant as a step end or transfer resolve in
//! one deterministic order, and the decode leap engine's strict
//! before-[`EventQueue::peek_time`] horizon fences leaps off upcoming
//! faults with no extra machinery.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: (time, seq) with reversed ordering for a min-heap; `seq`
/// breaks ties deterministically (insertion order).
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap. `total_cmp` is total over all f64s, so the
        // heap can never panic mid-sift: non-finite timestamps are rejected
        // with a clear message at the `push` call site instead (the only
        // place a bad timestamp can enter).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-time event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
    clock: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, clock: 0.0 }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulation time (the timestamp of the last popped event).
    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `t` (must be finite — NaN and
    /// infinities are rejected HERE, at the call site, rather than
    /// surfacing as a comparison failure deep inside the heap — and must
    /// not precede the clock).
    pub fn push(&mut self, t: f64, event: E) {
        assert!(
            t.is_finite(),
            "event time must be finite, got {t} (clock={}): a NaN/inf timestamp \
             means an upstream timing model produced garbage",
            self.clock
        );
        assert!(
            t >= self.clock - 1e-12,
            "cannot schedule into the past: t={t} clock={}",
            self.clock
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time: t, seq, event });
    }

    /// Schedule `event` `delay` seconds after the current clock — the
    /// common pattern for transfer completions and periodic controller
    /// ticks. `delay` must be non-negative and finite (checked by `push`).
    pub fn push_in(&mut self, delay: f64, event: E) {
        debug_assert!(delay >= 0.0, "push_in takes a non-negative delay, got {delay}");
        self.push(self.clock + delay, event);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.heap.pop()?;
        self.clock = e.time;
        Some((e.time, e.event))
    }

    /// Earliest scheduled time without popping — the decode leap
    /// engine's horizon probe. A leap may only commit steps ending
    /// *strictly before* this instant: an event at exactly a step's end
    /// was pushed earlier, so it holds a smaller tie-breaking `seq` and
    /// the reference run pops it before that step's end.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, "first");
        q.push(1.0, "second");
        q.push(1.0, "third");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["first", "second", "third"]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.push(1.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.clock(), t1);
        q.push(2.0, ()); // after clock=1, fine
        let mut prev = t1;
        while let Some((t, _)) = q.pop() {
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn push_in_schedules_relative_to_clock() {
        let mut q = EventQueue::new();
        q.push(2.0, "a");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 2.0);
        q.push_in(1.5, "b");
        q.push_in(0.5, "c");
        let order: Vec<(f64, &str)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(2.5, "c"), (3.5, "b")]);
    }

    #[test]
    #[should_panic]
    fn scheduling_into_past_panics() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push(1.0, ());
    }

    #[test]
    #[should_panic(expected = "event time must be finite")]
    fn nan_timestamp_panics_at_push() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "event time must be finite")]
    fn infinite_timestamp_panics_at_push() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, ());
    }

    #[test]
    fn peek_time_tracks_the_head_without_popping() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(3.0, "late");
        q.push(1.0, "early");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.len(), 2, "peeking must not pop");
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (1.0, "early"));
        assert_eq!(q.peek_time(), Some(3.0));
        // Ties: peek reports the shared time; pops still resolve in push
        // order (the property the leap engine's strict bound relies on).
        q.push(3.0, "later-pushed");
        assert_eq!(q.peek_time(), Some(3.0));
        assert_eq!(q.pop().unwrap().1, "late");
        assert_eq!(q.pop().unwrap().1, "later-pushed");
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn property_always_sorted() {
        crate::util::prop::check("event_queue_sorted", 50, |rng| {
            let mut q = EventQueue::new();
            for i in 0..100u64 {
                q.push(rng.f64() * 1000.0, i);
            }
            let mut prev = f64::NEG_INFINITY;
            while let Some((t, _)) = q.pop() {
                assert!(t >= prev);
                prev = t;
            }
        });
    }
}
