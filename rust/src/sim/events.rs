//! Typed discrete-event queue (min-heap over f64 timestamps).
//!
//! Everything the simulator does flows through this queue — including the
//! fault plane's crash/recovery/retry/heartbeat events, which are ordinary
//! entries with no special priority: insertion-order tie-breaking makes a
//! crash landing at the same instant as a step end or transfer resolve in
//! one deterministic order, and the decode leap engine's strict
//! before-[`EventQueue::peek_time`] horizon fences leaps off upcoming
//! faults with no extra machinery.
//!
//! The heap is hand-rolled over a `Vec` rather than `std::collections::
//! BinaryHeap` for one reason: the epoch-absorption engine
//! (`ClusterSim::run_epoch`) needs [`EventQueue::second_min_time`] — the
//! would-be head after removing the current head — to prove a pending
//! decode step end is *strictly* time-separated from every other queued
//! event before consuming it into an epoch. In a binary min-heap the
//! second-smallest entry is always one of the root's two children, so the
//! probe is O(1); `BinaryHeap` hides its layout. Pop order is a total
//! order on `(time, seq)`, so any correct heap — std's or this one — pops
//! the exact same sequence; determinism does not depend on the layout.

use std::cmp::Ordering;

/// Heap entry: ordered by `(time, seq)` ascending; `seq` breaks ties
/// deterministically (insertion order).
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

/// Deterministic min-time event queue.
pub struct EventQueue<E> {
    heap: Vec<Entry<E>>,
    next_seq: u64,
    clock: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue { heap: Vec::new(), next_seq: 0, clock: 0.0 }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Strict `(time, seq)` order. `total_cmp` is total over all f64s, so
    /// the heap can never panic mid-sift: non-finite timestamps are
    /// rejected with a clear message at the `push` call site instead (the
    /// only place a bad timestamp can enter).
    fn before(a: &Entry<E>, b: &Entry<E>) -> bool {
        a.time.total_cmp(&b.time).then_with(|| a.seq.cmp(&b.seq)) == Ordering::Less
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::before(&self.heap[i], &self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let len = self.heap.len();
        loop {
            let mut smallest = i;
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            if l < len && Self::before(&self.heap[l], &self.heap[smallest]) {
                smallest = l;
            }
            if r < len && Self::before(&self.heap[r], &self.heap[smallest]) {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }

    /// Current simulation time (the timestamp of the last popped event).
    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `t` (must be finite — NaN and
    /// infinities are rejected HERE, at the call site, rather than
    /// surfacing as a comparison failure deep inside the heap — and must
    /// not precede the clock).
    pub fn push(&mut self, t: f64, event: E) {
        assert!(
            t.is_finite(),
            "event time must be finite, got {t} (clock={}): a NaN/inf timestamp \
             means an upstream timing model produced garbage",
            self.clock
        );
        assert!(
            t >= self.clock - 1e-12,
            "cannot schedule into the past: t={t} clock={}",
            self.clock
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time: t, seq, event });
        self.sift_up(self.heap.len() - 1);
    }

    /// Schedule `event` `delay` seconds after the current clock — the
    /// common pattern for transfer completions and periodic controller
    /// ticks. `delay` must be non-negative and finite (checked by `push`).
    pub fn push_in(&mut self, delay: f64, event: E) {
        debug_assert!(delay >= 0.0, "push_in takes a non-negative delay, got {delay}");
        self.push(self.clock + delay, event);
    }

    fn pop_entry(&mut self) -> Option<Entry<E>> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let e = self.heap.pop();
        self.sift_down(0);
        e
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.pop_entry()?;
        self.clock = e.time;
        Some((e.time, e.event))
    }

    /// Pop the earliest event WITHOUT advancing the clock — the epoch
    /// engine's absorption primitive. A consumed pending step end is
    /// replayed inside the epoch merge at its own timestamp, but the
    /// merge may also close lanes whose scheduled ends land *earlier*
    /// than the absorbed time; leaving the clock at the pass time keeps
    /// those pushes valid. The run loop's own pops restore the clock's
    /// monotone march (everything left in the queue fires later than
    /// every absorbed event, by the absorption loop's prefix rule).
    pub fn pop_no_clock(&mut self) -> Option<(f64, E)> {
        let e = self.pop_entry()?;
        Some((e.time, e.event))
    }

    /// Earliest scheduled time without popping — the decode leap
    /// engine's horizon probe. A leap may only commit steps ending
    /// *strictly before* this instant: an event at exactly a step's end
    /// was pushed earlier, so it holds a smaller tie-breaking `seq` and
    /// the reference run pops it before that step's end.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.first().map(|e| e.time)
    }

    /// Earliest event (time + borrowed payload) without popping — the
    /// epoch absorption loop's eligibility probe.
    pub fn peek(&self) -> Option<(f64, &E)> {
        self.heap.first().map(|e| (e.time, &e.event))
    }

    /// Timestamp of the entry that would become the head if the current
    /// head were popped. In a binary min-heap the second-smallest entry
    /// is always one of the root's children, so this is O(1). The epoch
    /// absorption loop consumes the head only when this is *strictly*
    /// later than the head's time: an exact tie means the serial
    /// reference interleaves another handler at the same instant, and
    /// re-pushing an unconsumed absorbed event would flip the `seq`
    /// tie-break.
    pub fn second_min_time(&self) -> Option<f64> {
        match (self.heap.get(1), self.heap.get(2)) {
            (Some(a), Some(b)) => Some(if Self::before(b, a) { b.time } else { a.time }),
            (Some(a), None) => Some(a.time),
            (None, _) => None,
        }
    }

    /// Epoch-horizon probe for the within-run parallel engine: true iff a
    /// queued event would pop at or before an epoch-internal step ending
    /// at `t`. An event at *exactly* `t` was pushed before the epoch
    /// opened, so it holds a smaller tie-breaking `seq` and the serial
    /// reference pops it first — the step must become a scheduled event,
    /// not an inline replay. Packaged here so call sites cannot get the
    /// tie direction wrong.
    pub fn fires_at_or_before(&self, t: f64) -> bool {
        self.peek_time().map_or(false, |head| head <= t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, "first");
        q.push(1.0, "second");
        q.push(1.0, "third");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["first", "second", "third"]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.push(1.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.clock(), t1);
        q.push(2.0, ()); // after clock=1, fine
        let mut prev = t1;
        while let Some((t, _)) = q.pop() {
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn push_in_schedules_relative_to_clock() {
        let mut q = EventQueue::new();
        q.push(2.0, "a");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 2.0);
        q.push_in(1.5, "b");
        q.push_in(0.5, "c");
        let order: Vec<(f64, &str)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(2.5, "c"), (3.5, "b")]);
    }

    #[test]
    #[should_panic]
    fn scheduling_into_past_panics() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        q.pop();
        q.push(1.0, ());
    }

    #[test]
    #[should_panic(expected = "event time must be finite")]
    fn nan_timestamp_panics_at_push() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "event time must be finite")]
    fn infinite_timestamp_panics_at_push() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, ());
    }

    #[test]
    fn peek_time_tracks_the_head_without_popping() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(3.0, "late");
        q.push(1.0, "early");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.len(), 2, "peeking must not pop");
        let (t, e) = q.pop().unwrap();
        assert_eq!((t, e), (1.0, "early"));
        assert_eq!(q.peek_time(), Some(3.0));
        // Ties: peek reports the shared time; pops still resolve in push
        // order (the property the leap engine's strict bound relies on).
        q.push(3.0, "later-pushed");
        assert_eq!(q.peek_time(), Some(3.0));
        assert_eq!(q.pop().unwrap().1, "late");
        assert_eq!(q.pop().unwrap().1, "later-pushed");
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn peek_exposes_the_head_event() {
        let mut q = EventQueue::new();
        assert!(q.peek().is_none());
        q.push(2.0, "b");
        q.push(1.0, "a");
        let (t, e) = q.peek().unwrap();
        assert_eq!((t, *e), (1.0, "a"));
        assert_eq!(q.len(), 2, "peeking must not pop");
    }

    #[test]
    fn fires_at_or_before_is_inclusive() {
        let mut q = EventQueue::new();
        assert!(!q.fires_at_or_before(1.0), "empty queue never fires");
        q.push(2.0, ());
        assert!(!q.fires_at_or_before(1.5));
        assert!(q.fires_at_or_before(2.0), "a tie means the queued event pops first");
        assert!(q.fires_at_or_before(3.0));
    }

    #[test]
    fn second_min_time_tracks_the_would_be_head() {
        let mut q = EventQueue::new();
        assert_eq!(q.second_min_time(), None);
        q.push(5.0, "only");
        assert_eq!(q.second_min_time(), None, "a single entry has no runner-up");
        q.push(3.0, "head");
        assert_eq!(q.peek_time(), Some(3.0));
        assert_eq!(q.second_min_time(), Some(5.0));
        q.push(4.0, "middle");
        assert_eq!(q.second_min_time(), Some(4.0));
        // An exact tie with the head is reported (the absorption loop
        // treats it as "not strictly separated" and stops).
        q.push(3.0, "tied");
        assert_eq!(q.second_min_time(), Some(3.0));
        // And it stays consistent with actual pop order all the way down.
        while q.len() >= 2 {
            let second = q.second_min_time().unwrap();
            q.pop();
            assert_eq!(q.peek_time(), Some(second));
        }
    }

    #[test]
    fn pop_no_clock_leaves_the_clock_alone() {
        let mut q = EventQueue::new();
        q.push(2.0, "pass");
        q.push(4.0, "absorbed");
        q.push(9.0, "later");
        let (t, _) = q.pop().unwrap();
        assert_eq!((t, q.clock()), (2.0, 2.0));
        let (t, e) = q.pop_no_clock().unwrap();
        assert_eq!((t, e), (4.0, "absorbed"));
        assert_eq!(q.clock(), 2.0, "absorption must not advance the clock");
        // A lane closing earlier than the absorbed time stays schedulable.
        q.push(3.0, "close-push");
        assert_eq!(q.pop().unwrap(), (3.0, "close-push"));
        assert_eq!(q.pop().unwrap(), (9.0, "later"));
    }

    #[test]
    fn property_always_sorted() {
        crate::util::prop::check("event_queue_sorted", 50, |rng| {
            let mut q = EventQueue::new();
            for i in 0..100u64 {
                q.push(rng.f64() * 1000.0, i);
            }
            let mut prev = f64::NEG_INFINITY;
            while let Some((t, _)) = q.pop() {
                assert!(t >= prev);
                prev = t;
            }
        });
    }

    #[test]
    fn property_second_min_matches_pop_order() {
        crate::util::prop::check("event_queue_second_min", 50, |rng| {
            let mut q = EventQueue::new();
            for i in 0..64u64 {
                // Coarse grid so exact ties actually occur.
                q.push((rng.range_usize(0, 16) as f64) * 0.5, i);
            }
            while q.len() >= 2 {
                let second = q.second_min_time().unwrap();
                q.pop();
                assert_eq!(q.peek_time(), Some(second));
            }
        });
    }
}
