//! Regenerate the data series behind every figure in the paper's
//! evaluation (DESIGN.md §4 maps each to its modules).
//!
//! Usage: `figures [fig1|fig2|fig3|fig5|fig6|fig9|fig10|fig11|fig12|
//!                  fig13|fig14|fig15|fig16|fig17|fig18|launch|scaling|all]`
//!
//! Output rows are stable and grep-able:
//!     figure=ID series=NAME x=X y=Y
//! so `figures all | tee figures.txt` is the full evaluation dump.
//! Simulated panels run at this testbed's saturating rates — see
//! EXPERIMENTS.md for the paper-vs-measured mapping.
//!
//! Sweep points (rate sweeps, ratio sweeps, the fig16/launch/scaling
//! panels) run one seed-deterministic simulation per core and print in
//! the same order — and with bit-identical values — as the serial
//! drivers. Set `ADRENALINE_SERIAL=1` to force serial execution.

use adrenaline::config::{ClusterSpec, GpuSpec, ModelSpec, SloConfig};
use adrenaline::coordinator::OffloadBounds;
use adrenaline::gpu_model::{
    bw_frac_of_sm_frac, prefill_slowdown, DecodeKernelTimes, HbmUsage, KernelKind, PhaseKernels,
    PrefillKernelTimes, Roofline,
};
use adrenaline::sim::{
    parallel_map, run_e2e, run_ratio_sweep, ClusterSim, E2eConfig, SimConfig, SimReport,
};
use adrenaline::util::bench::figure_row;
use adrenaline::workload::WorkloadKind;

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    let all = which == "all";
    if all || which == "fig1" {
        fig1();
    }
    if all || which == "fig2" {
        fig2();
    }
    if all || which == "fig3" {
        fig3();
    }
    if all || which == "fig5" {
        fig5();
    }
    if all || which == "fig6" {
        fig6();
    }
    if all || which == "fig9" {
        fig9();
    }
    if all || which == "fig10" {
        fig10();
    }
    if all || which == "fig11" {
        e2e("fig11", scaled(E2eConfig::fig11()));
    }
    if all || which == "fig12" {
        e2e("fig12", scaled(E2eConfig::fig12()));
    }
    if all || which == "fig13" {
        e2e("fig13", E2eConfig::fig13());
    }
    if all || which == "fig14" {
        e2e("fig14", E2eConfig::fig14());
    }
    if all || which == "fig15" {
        fig15();
    }
    if all || which == "fig16" {
        fig16();
    }
    if all || which == "fig17" {
        fig17();
    }
    if all || which == "fig18" {
        fig18();
    }
    if all || which == "launch" {
        launch();
    }
    if all || which == "scaling" {
        scaling();
    }
}

/// ShareGPT panels run at this testbed's saturating rates (the paper's
/// stack saturates near 4 req/s; our roofline decode is faster, so the
/// crossover lands at higher absolute rates — shape over absolutes).
fn scaled(mut cfg: E2eConfig) -> E2eConfig {
    cfg.rates = vec![8.0, 12.0, 16.0, 20.0, 24.0, 28.0];
    cfg.duration_s = 120.0;
    cfg
}

fn setup() -> (Roofline, ModelSpec) {
    (Roofline::whole(GpuSpec::a100_80g()), ModelSpec::llama2_7b())
}

/// Fig 1: (a) prefill HBM-bw utilization vs prompt length; (b) decode
/// compute utilization vs batch size.
fn fig1() {
    let (rl, m) = setup();
    let pk = PhaseKernels::new(m);
    for p in [256u64, 512, 1024, 2048, 4096] {
        let mut cost = pk.prefill_cost(KernelKind::QkvProj, p);
        for k in [KernelKind::Attention, KernelKind::OutProj, KernelKind::Ffn] {
            cost = cost.add(&pk.prefill_cost(k, p));
        }
        figure_row("fig1a", "prefill_hbm_bw_util", p as f64, rl.bw_utilization(cost));
    }
    for b in [1u64, 8, 16, 32, 64, 80, 128] {
        let ctx = b * 1024;
        let mut cost = pk.decode_cost(KernelKind::QkvProj, b, ctx);
        for k in [KernelKind::Attention, KernelKind::OutProj, KernelKind::Ffn] {
            cost = cost.add(&pk.decode_cost(k, b, ctx));
        }
        figure_row("fig1b", "decode_compute_util", b as f64, rl.compute_utilization(cost));
    }
}

/// Fig 2: HBM capacity utilization of prefill vs decode instances.
fn fig2() {
    let c = ClusterSpec::paper_default();
    let m = ModelSpec::llama2_7b();
    let prefill = HbmUsage::for_instance(&c, &m, 0);
    figure_row("fig2", "prefill_capacity_util", 0.0, prefill.utilization());
    let budget = HbmUsage::kv_token_budget(&c, &m);
    let decode = HbmUsage::for_instance(&c, &m, budget);
    figure_row("fig2", "decode_capacity_util", 0.0, decode.utilization());
    figure_row("fig2", "decode_kv_share", 0.0, decode.kv_share());
}

/// Fig 3: decode attention share of layer time vs batch (seq 1K).
fn fig3() {
    let (rl, m) = setup();
    for b in [1u64, 8, 16, 32, 48, 64, 80, 96, 128] {
        let t = DecodeKernelTimes::compute(&rl, &m, b, b * 1024);
        figure_row("fig3", "attention_share", b as f64, t.attention_share());
    }
}

/// Fig 5: prefill per-kernel compute & bandwidth utilization vs prompt len.
fn fig5() {
    let (rl, m) = setup();
    let pk = PhaseKernels::new(m);
    for p in [256u64, 1024, 4096] {
        for k in KernelKind::ALL {
            let cost = pk.prefill_cost(k, p);
            figure_row(
                "fig5a",
                &format!("{}_compute", k.name()),
                p as f64,
                rl.compute_utilization(cost),
            );
            figure_row("fig5b", &format!("{}_bw", k.name()), p as f64, rl.bw_utilization(cost));
        }
    }
}

/// Fig 6: decode per-kernel compute & bandwidth utilization vs batch.
fn fig6() {
    let (rl, m) = setup();
    let pk = PhaseKernels::new(m);
    for b in [8u64, 32, 80, 128] {
        let ctx = b * 1024;
        for k in KernelKind::ALL {
            let cost = pk.decode_cost(k, b, ctx);
            figure_row(
                "fig6a",
                &format!("{}_compute", k.name()),
                b as f64,
                rl.compute_utilization(cost),
            );
            figure_row("fig6b", &format!("{}_bw", k.name()), b as f64, rl.bw_utilization(cost));
        }
    }
}

/// Fig 9: attention-kernel bandwidth vs SM fraction (superlinear).
fn fig9() {
    for i in 1..=10 {
        let s = i as f64 / 10.0;
        figure_row("fig9", "bw_frac", s, bw_frac_of_sm_frac(s));
    }
    figure_row("fig9", "bw_frac_anchor", 0.2, bw_frac_of_sm_frac(0.2));
}

/// Fig 10: normalized prefill throughput vs SM fraction (sublinear).
fn fig10() {
    let (rl, m) = setup();
    for p in [1024u64, 4096] {
        let base = PrefillKernelTimes::compute(&rl, &m, p).total();
        for i in 2..=10 {
            let s = i as f64 / 10.0;
            let t = base * prefill_slowdown(s);
            figure_row("fig10", &format!("norm_tput_p{p}"), s, base / t);
        }
    }
}

/// Figs 11–14: TTFT / TPOT / P99 TPOT / throughput vs request rate for
/// both systems.
fn e2e(fig: &str, cfg: E2eConfig) {
    for p in run_e2e(&cfg) {
        figure_row(&format!("{fig}a"), &format!("{}_ttft_s", p.system), p.rate, p.ttft_mean_s);
        figure_row(&format!("{fig}b"), &format!("{}_tpot_s", p.system), p.rate, p.tpot_mean_s);
        figure_row(
            &format!("{fig}c"),
            &format!("{}_p99_tpot_s", p.system),
            p.rate,
            p.tpot_p99_s,
        );
        figure_row(
            &format!("{fig}d"),
            &format!("{}_tput_tok_s", p.system),
            p.rate,
            p.throughput_tok_s,
        );
        figure_row(
            &format!("{fig}x"),
            &format!("{}_preemptions", p.system),
            p.rate,
            p.preemptions as f64,
        );
    }
}

/// Fig 15: E2E performance vs (fixed) offload ratio.
fn fig15() {
    let pts = run_ratio_sweep(
        ModelSpec::llama2_7b(),
        WorkloadKind::ShareGpt,
        24.0,
        &[0.0, 0.2, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9],
        120.0,
    );
    for (ratio, r) in &pts {
        figure_row("fig15", "tput_tok_s", *ratio, r.throughput);
        figure_row("fig15", "tpot_s", *ratio, r.tpot.map(|s| s.mean).unwrap_or(f64::NAN));
        figure_row("fig15", "ttft_s", *ratio, r.ttft.map(|s| s.mean).unwrap_or(f64::NAN));
    }
}

/// Fig 16: prefill-instance HBM capacity over the run.
fn fig16() {
    let systems = [("vllm", false), ("adrenaline", true)];
    let reports: Vec<SimReport> = parallel_map(systems.len(), |i| {
        let m = ModelSpec::llama2_7b();
        let mut cfg = if systems[i].1 {
            SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0)
        } else {
            SimConfig::baseline(m, WorkloadKind::ShareGpt, 24.0)
        };
        cfg.duration_s = 120.0;
        ClusterSim::new(cfg).run()
    });
    for ((name, _), r) in systems.iter().zip(&reports) {
        let pts = r.prefill_occupancy.points();
        let stride = (pts.len() / 20).max(1);
        for (t, v) in pts.iter().step_by(stride) {
            figure_row("fig16", &format!("{name}_capacity_util"), *t, *v);
        }
        figure_row("fig16", &format!("{name}_mean"), 0.0, r.prefill_hbm_capacity_util);
    }
}

/// Fig 17: prefill bandwidth & decode compute utilization vs offload ratio,
/// both models.
fn fig17() {
    for m in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b()] {
        let rate = if m.name == "llama2-7b" { 24.0 } else { 16.0 };
        let pts = run_ratio_sweep(m, WorkloadKind::ShareGpt, rate, &[0.0, 0.4, 0.6, 0.8], 120.0);
        for (ratio, r) in &pts {
            figure_row(
                "fig17a",
                &format!("{}_prefill_bw_util", m.name),
                *ratio,
                r.prefill_hbm_bw_util,
            );
            figure_row(
                "fig17b",
                &format!("{}_decode_compute_util", m.name),
                *ratio,
                r.decode_compute_util,
            );
        }
    }
}

/// Fig 18: (a) prefill bandwidth with executor on/off + duty cycle;
/// (b) non-attention kernel compute growth vs offload ratio.
fn fig18() {
    let m = ModelSpec::llama2_7b();
    let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0);
    cfg.duration_s = 120.0;
    let r = ClusterSim::new(cfg).run();
    figure_row("fig18a", "attn_on_bw_util", 0.0, r.executor_bw_util);
    figure_row("fig18a", "attn_off_bw_util", 0.0, 0.25); // prefill-only draw (Fig 1a)
    figure_row("fig18a", "executor_duty", 0.0, r.executor_duty);

    // (b) per-kernel decode compute at growing total batch (the effect of
    // offload ratios 0 / 0.4 / 0.8 on the non-attention kernels).
    let (rl, m) = setup();
    let pk = PhaseKernels::new(m);
    let b_local = 92u64; // B_TPOT-scale local batch
    for ratio in [0.0f64, 0.4, 0.8] {
        let b_total = (b_local as f64 * (1.0 + ratio)) as u64;
        for k in [KernelKind::QkvProj, KernelKind::OutProj, KernelKind::Ffn] {
            let cost = pk.decode_cost(k, b_total, b_total * 1024);
            figure_row(
                "fig18b",
                &format!("{}_compute_util", k.name()),
                ratio,
                rl.compute_utilization(cost),
            );
        }
    }
}

/// §3.2.2 ablation: decode TPOT with and without the executable-grid
/// (CUDA-graph analogue) launch batching, plus the computed offload bounds.
fn launch() {
    let m = ModelSpec::llama2_7b();
    let variants = [("graphed", 0.0), ("eager", 0.76e-3 * 32.0)];
    let reports: Vec<SimReport> = parallel_map(variants.len(), |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 16.0);
        cfg.duration_s = 60.0;
        cfg.eager_launch_overhead_s = variants[i].1;
        ClusterSim::new(cfg).run()
    });
    for ((name, _), r) in variants.iter().zip(&reports) {
        figure_row(
            "launch",
            &format!("{name}_tpot_s"),
            0.0,
            r.tpot.map(|s| s.mean).unwrap_or(f64::NAN),
        );
        figure_row("launch", &format!("{name}_tput"), 0.0, r.throughput);
    }
    let b = OffloadBounds::compute(
        &ClusterSpec::paper_default(),
        &ModelSpec::llama2_7b(),
        &SloConfig::default(),
        1024,
    );
    figure_row("launch", "ob_mem", 0.0, b.ob_mem);
    figure_row("launch", "ob", 0.0, b.ob());
}

/// §3.4.2 flexibility: prefill-pool scaling. Eq 1's OB_mem is linear in
/// n (prefill instances per decode instance); more executors ⇒ more
/// offload capacity ⇒ higher saturated throughput.
fn scaling() {
    let m = ModelSpec::llama2_7b();
    let sizes = [1u32, 2, 3];
    let reports: Vec<SimReport> = parallel_map(sizes.len(), |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 28.0);
        cfg.duration_s = 120.0;
        cfg.cluster.n_prefill = sizes[i];
        ClusterSim::new(cfg).run()
    });
    for (&n, r) in sizes.iter().zip(&reports) {
        figure_row("scaling", "tput_tok_s", n as f64, r.throughput);
        figure_row("scaling", "offloaded_fraction", n as f64, r.offloaded_fraction);
        figure_row("scaling", "ttft_s", n as f64, r.ttft.map(|s| s.mean).unwrap_or(f64::NAN));
    }
}
