//! Regenerate the data series behind every figure in the paper's
//! evaluation (DESIGN.md §4 maps each to its modules).
//!
//! Usage: `figures [fig1|fig2|fig3|fig5|fig6|fig9|fig10|fig11|fig12|
//!                  fig13|fig14|fig15|fig16|fig17|fig18|launch|scaling|
//!                  rebalance|buckets|feedback|faults|fleet|fleet_faults|
//!                  hetero|all]`
//!
//! Output rows are stable and grep-able:
//!     figure=ID series=NAME x=X y=Y
//! so `figures all | tee figures.txt` is the full evaluation dump.
//! Simulated panels run at this testbed's saturating rates — see
//! EXPERIMENTS.md for the paper-vs-measured mapping.
//!
//! Parallelism happens at two levels, both through [`parallel_map`]:
//! `figures all` fans the figure *groups* themselves out (each group
//! buffers its rows and the buffers print in the fixed group order), and
//! the sweep-driven groups fan their sweep points out again internally.
//! Both levels (plus any within-run epoch workers the sims spawn) draw
//! from one process-wide thread budget, so nested fan-out stays near the
//! core count on any host instead of groups × cores. Every simulation is
//! seed-deterministic, so the output is bit-identical to a serial run.
//! Set `ADRENALINE_SERIAL=1` to force serial execution at every level.
//!
//! Simulated step costs default to the bucket-padded model (the 2-D
//! executable grid, §3.2.2); set `ADRENALINE_EXACT_COSTS=1` to reproduce
//! the exact-cost ablation.

use adrenaline::config::{
    AutoscaleConfig, BoundsFeedbackConfig, ClusterSpec, FaultConfig, FaultKind, FleetConfig,
    GpuSpec, ModelSpec, OverloadConfig, RebalanceConfig, RouterPolicy, ScriptedFault, SloConfig,
};
use adrenaline::coordinator::OffloadBounds;
use adrenaline::gpu_model::{
    bw_frac_of_sm_frac, prefill_slowdown, DecodeKernelTimes, HbmUsage, KernelKind, PhaseKernels,
    PrefillKernelTimes, Roofline,
};
use adrenaline::sim::{
    parallel_map, run_e2e_with, run_ratio_sweep_with, ClusterSim, E2eConfig, ExecMode, FleetReport,
    FleetSim, SimConfig, SimReport,
};
use adrenaline::util::bench::figure_row_str;
use adrenaline::workload::{ArrivalPattern, WorkloadKind};

/// The figure groups, in output order. Each writes its rows into a
/// buffer so `all` can run groups concurrently.
const GROUPS: &[(&str, fn(&mut String))] = &[
    ("fig1", fig1),
    ("fig2", fig2),
    ("fig3", fig3),
    ("fig5", fig5),
    ("fig6", fig6),
    ("fig9", fig9),
    ("fig10", fig10),
    ("fig11", fig11),
    ("fig12", fig12),
    ("fig13", fig13),
    ("fig14", fig14),
    ("fig15", fig15),
    ("fig16", fig16),
    ("fig17", fig17),
    ("fig18", fig18),
    ("launch", launch),
    ("scaling", scaling),
    ("rebalance", rebalance),
    ("buckets", buckets),
    ("feedback", feedback),
    ("faults", faults),
    ("fleet", fleet),
    ("fleet_faults", fleet_faults),
    ("hetero", hetero),
];

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    let selected: Vec<&(&str, fn(&mut String))> =
        GROUPS.iter().filter(|(name, _)| which == "all" || *name == which).collect();
    if selected.is_empty() {
        eprintln!("unknown figure `{which}`; valid groups:");
        eprintln!("  all {}", GROUPS.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(" "));
        std::process::exit(2);
    }
    // The sweep-driven groups fan out again internally; the process-wide
    // thread budget inside `parallel_map` keeps total live threads near
    // the core count no matter how the levels nest, so the group level
    // needs no ad-hoc cap (it previously hard-coded 2).
    let outputs = parallel_map(selected.len(), |i| {
        let mut out = String::new();
        (selected[i].1)(&mut out);
        out
    });
    for out in outputs {
        print!("{out}");
    }
}

/// Buffered variant of `figure_row` (same format, printed later in
/// group order).
fn row(out: &mut String, figure: &str, series: &str, x: f64, y: f64) {
    out.push_str(&figure_row_str(figure, series, x, y));
    out.push('\n');
}

/// ShareGPT panels run at this testbed's saturating rates (the paper's
/// stack saturates near 4 req/s; our roofline decode is faster, so the
/// crossover lands at higher absolute rates — shape over absolutes).
fn scaled(mut cfg: E2eConfig) -> E2eConfig {
    cfg.rates = vec![8.0, 12.0, 16.0, 20.0, 24.0, 28.0];
    cfg.duration_s = 120.0;
    cfg
}

fn setup() -> (Roofline, ModelSpec) {
    (Roofline::whole(GpuSpec::a100_80g()), ModelSpec::llama2_7b())
}

/// Fig 1: (a) prefill HBM-bw utilization vs prompt length; (b) decode
/// compute utilization vs batch size.
fn fig1(out: &mut String) {
    let (rl, m) = setup();
    let pk = PhaseKernels::new(m);
    for p in [256u64, 512, 1024, 2048, 4096] {
        let mut cost = pk.prefill_cost(KernelKind::QkvProj, p);
        for k in [KernelKind::Attention, KernelKind::OutProj, KernelKind::Ffn] {
            cost = cost.add(&pk.prefill_cost(k, p));
        }
        row(out, "fig1a", "prefill_hbm_bw_util", p as f64, rl.bw_utilization(cost));
    }
    for b in [1u64, 8, 16, 32, 64, 80, 128] {
        let ctx = b * 1024;
        let mut cost = pk.decode_cost(KernelKind::QkvProj, b, ctx);
        for k in [KernelKind::Attention, KernelKind::OutProj, KernelKind::Ffn] {
            cost = cost.add(&pk.decode_cost(k, b, ctx));
        }
        row(out, "fig1b", "decode_compute_util", b as f64, rl.compute_utilization(cost));
    }
}

/// Fig 2: HBM capacity utilization of prefill vs decode instances.
fn fig2(out: &mut String) {
    let c = ClusterSpec::paper_default();
    let m = ModelSpec::llama2_7b();
    let prefill = HbmUsage::for_instance(&c, &m, 0);
    row(out, "fig2", "prefill_capacity_util", 0.0, prefill.utilization());
    let budget = HbmUsage::kv_token_budget(&c, &m);
    let decode = HbmUsage::for_instance(&c, &m, budget);
    row(out, "fig2", "decode_capacity_util", 0.0, decode.utilization());
    row(out, "fig2", "decode_kv_share", 0.0, decode.kv_share());
}

/// Fig 3: decode attention share of layer time vs batch (seq 1K).
fn fig3(out: &mut String) {
    let (rl, m) = setup();
    for b in [1u64, 8, 16, 32, 48, 64, 80, 96, 128] {
        let t = DecodeKernelTimes::compute(&rl, &m, b, b * 1024);
        row(out, "fig3", "attention_share", b as f64, t.attention_share());
    }
}

/// Fig 5: prefill per-kernel compute & bandwidth utilization vs prompt len.
fn fig5(out: &mut String) {
    let (rl, m) = setup();
    let pk = PhaseKernels::new(m);
    for p in [256u64, 1024, 4096] {
        for k in KernelKind::ALL {
            let cost = pk.prefill_cost(k, p);
            row(
                out,
                "fig5a",
                &format!("{}_compute", k.name()),
                p as f64,
                rl.compute_utilization(cost),
            );
            row(out, "fig5b", &format!("{}_bw", k.name()), p as f64, rl.bw_utilization(cost));
        }
    }
}

/// Fig 6: decode per-kernel compute & bandwidth utilization vs batch.
fn fig6(out: &mut String) {
    let (rl, m) = setup();
    let pk = PhaseKernels::new(m);
    for b in [8u64, 32, 80, 128] {
        let ctx = b * 1024;
        for k in KernelKind::ALL {
            let cost = pk.decode_cost(k, b, ctx);
            row(
                out,
                "fig6a",
                &format!("{}_compute", k.name()),
                b as f64,
                rl.compute_utilization(cost),
            );
            row(out, "fig6b", &format!("{}_bw", k.name()), b as f64, rl.bw_utilization(cost));
        }
    }
}

/// Fig 9: attention-kernel bandwidth vs SM fraction (superlinear).
fn fig9(out: &mut String) {
    for i in 1..=10 {
        let s = i as f64 / 10.0;
        row(out, "fig9", "bw_frac", s, bw_frac_of_sm_frac(s));
    }
    row(out, "fig9", "bw_frac_anchor", 0.2, bw_frac_of_sm_frac(0.2));
}

/// Fig 10: normalized prefill throughput vs SM fraction (sublinear).
fn fig10(out: &mut String) {
    let (rl, m) = setup();
    for p in [1024u64, 4096] {
        let base = PrefillKernelTimes::compute(&rl, &m, p).total();
        for i in 2..=10 {
            let s = i as f64 / 10.0;
            let t = base * prefill_slowdown(s);
            row(out, "fig10", &format!("norm_tput_p{p}"), s, base / t);
        }
    }
}

fn fig11(out: &mut String) {
    e2e(out, "fig11", scaled(E2eConfig::fig11()));
}

fn fig12(out: &mut String) {
    e2e(out, "fig12", scaled(E2eConfig::fig12()));
}

fn fig13(out: &mut String) {
    e2e(out, "fig13", E2eConfig::fig13());
}

fn fig14(out: &mut String) {
    e2e(out, "fig14", E2eConfig::fig14());
}

/// Figs 11–14: TTFT / TPOT / P99 TPOT / throughput vs request rate for
/// both systems.
fn e2e(out: &mut String, fig: &str, cfg: E2eConfig) {
    for p in run_e2e_with(&cfg, ExecMode::Parallel) {
        row(out, &format!("{fig}a"), &format!("{}_ttft_s", p.system), p.rate, p.ttft_mean_s);
        row(out, &format!("{fig}b"), &format!("{}_tpot_s", p.system), p.rate, p.tpot_mean_s);
        row(
            out,
            &format!("{fig}c"),
            &format!("{}_p99_tpot_s", p.system),
            p.rate,
            p.tpot_p99_s,
        );
        row(
            out,
            &format!("{fig}d"),
            &format!("{}_tput_tok_s", p.system),
            p.rate,
            p.throughput_tok_s,
        );
        row(
            out,
            &format!("{fig}x"),
            &format!("{}_preemptions", p.system),
            p.rate,
            p.preemptions as f64,
        );
    }
}

/// Fig 15: E2E performance vs (fixed) offload ratio.
fn fig15(out: &mut String) {
    let pts = run_ratio_sweep_with(
        ModelSpec::llama2_7b(),
        WorkloadKind::ShareGpt,
        24.0,
        &[0.0, 0.2, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9],
        120.0,
        ExecMode::Parallel,
    );
    for (ratio, r) in &pts {
        row(out, "fig15", "tput_tok_s", *ratio, r.throughput);
        row(out, "fig15", "tpot_s", *ratio, r.tpot.map(|s| s.mean).unwrap_or(f64::NAN));
        row(out, "fig15", "ttft_s", *ratio, r.ttft.map(|s| s.mean).unwrap_or(f64::NAN));
    }
}

/// Fig 16: prefill-instance HBM capacity over the run.
fn fig16(out: &mut String) {
    let systems = [("vllm", false), ("adrenaline", true)];
    let reports: Vec<SimReport> = parallel_map(systems.len(), |i| {
        let m = ModelSpec::llama2_7b();
        let mut cfg = if systems[i].1 {
            SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0)
        } else {
            SimConfig::baseline(m, WorkloadKind::ShareGpt, 24.0)
        };
        cfg.duration_s = 120.0;
        ClusterSim::new(cfg).run()
    });
    for ((name, _), r) in systems.iter().zip(&reports) {
        let pts = r.prefill_occupancy.points();
        let stride = (pts.len() / 20).max(1);
        for (t, v) in pts.iter().step_by(stride) {
            row(out, "fig16", &format!("{name}_capacity_util"), *t, *v);
        }
        row(out, "fig16", &format!("{name}_mean"), 0.0, r.prefill_hbm_capacity_util);
    }
}

/// Fig 17: prefill bandwidth & decode compute utilization vs offload ratio,
/// both models.
fn fig17(out: &mut String) {
    for m in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b()] {
        let rate = if m.name == "llama2-7b" { 24.0 } else { 16.0 };
        let pts = run_ratio_sweep_with(
            m,
            WorkloadKind::ShareGpt,
            rate,
            &[0.0, 0.4, 0.6, 0.8],
            120.0,
            ExecMode::Parallel,
        );
        for (ratio, r) in &pts {
            row(
                out,
                "fig17a",
                &format!("{}_prefill_bw_util", m.name),
                *ratio,
                r.prefill_hbm_bw_util,
            );
            row(
                out,
                "fig17b",
                &format!("{}_decode_compute_util", m.name),
                *ratio,
                r.decode_compute_util,
            );
        }
    }
}

/// Fig 18: (a) prefill bandwidth with executor on/off + duty cycle;
/// (b) non-attention kernel compute growth vs offload ratio.
fn fig18(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0);
    cfg.duration_s = 120.0;
    let r = ClusterSim::new(cfg).run();
    row(out, "fig18a", "attn_on_bw_util", 0.0, r.executor_bw_util);
    row(out, "fig18a", "attn_off_bw_util", 0.0, 0.25); // prefill-only draw (Fig 1a)
    row(out, "fig18a", "executor_duty", 0.0, r.executor_duty);

    // (b) per-kernel decode compute at growing total batch (the effect of
    // offload ratios 0 / 0.4 / 0.8 on the non-attention kernels).
    let (rl, m) = setup();
    let pk = PhaseKernels::new(m);
    let b_local = 92u64; // B_TPOT-scale local batch
    for ratio in [0.0f64, 0.4, 0.8] {
        let b_total = (b_local as f64 * (1.0 + ratio)) as u64;
        for k in [KernelKind::QkvProj, KernelKind::OutProj, KernelKind::Ffn] {
            let cost = pk.decode_cost(k, b_total, b_total * 1024);
            row(
                out,
                "fig18b",
                &format!("{}_compute_util", k.name()),
                ratio,
                rl.compute_utilization(cost),
            );
        }
    }
}

/// §3.2.2 ablation: decode TPOT with and without the executable-grid
/// (CUDA-graph analogue) launch batching, the grid's padding overhead,
/// plus the computed offload bounds.
fn launch(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let variants = [("graphed", 0.0), ("eager", 0.76e-3 * 32.0)];
    let reports: Vec<SimReport> = parallel_map(variants.len(), |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 16.0);
        cfg.duration_s = 60.0;
        cfg.eager_launch_overhead_s = variants[i].1;
        ClusterSim::new(cfg).run()
    });
    for ((name, _), r) in variants.iter().zip(&reports) {
        row(
            out,
            "launch",
            &format!("{name}_tpot_s"),
            0.0,
            r.tpot.map(|s| s.mean).unwrap_or(f64::NAN),
        );
        row(out, "launch", &format!("{name}_tput"), 0.0, r.throughput);
        row(
            out,
            "launch",
            &format!("{name}_padding_overhead"),
            0.0,
            r.graph_padding_overhead,
        );
    }
    let b = OffloadBounds::compute(
        &ClusterSpec::paper_default(),
        &ModelSpec::llama2_7b(),
        &SloConfig::default(),
        1024,
    );
    row(out, "launch", "ob_mem", 0.0, b.ob_mem);
    row(out, "launch", "ob", 0.0, b.ob());
}

/// Runtime offload rebalancing under bursty traffic (ISSUE 3 /
/// EXPERIMENTS.md §Scenarios): static admission-time `LoadAware` vs the
/// dynamic rebalancer on the same 3x-burst trace, plus the dynamic run's
/// per-tick prefill-pressure and offloaded-fraction timelines — the
/// tracking chart (fraction climbs with the admission wave each burst,
/// and migrations keep it at the OB bound through the troughs).
fn rebalance(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let pattern = ArrivalPattern::Bursty { period_s: 30.0, duty: 0.25, mult: 3.0 };
    let variants: [(&str, Option<RebalanceConfig>); 2] =
        [("static", None), ("dynamic", Some(RebalanceConfig::default()))];
    let reports: Vec<SimReport> = parallel_map(variants.len(), |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0);
        cfg.duration_s = 120.0;
        cfg.arrivals = pattern;
        cfg.serving.rebalance = variants[i].1;
        ClusterSim::new(cfg).run()
    });
    for ((name, _), r) in variants.iter().zip(&reports) {
        row(out, "rebalance", &format!("{name}_tput_tok_s"), 0.0, r.throughput);
        row(out, "rebalance", &format!("{name}_goodput_tok_s"), 0.0, r.goodput);
        row(
            out,
            "rebalance",
            &format!("{name}_ttft_s"),
            0.0,
            r.ttft.map(|s| s.mean).unwrap_or(f64::NAN),
        );
        row(
            out,
            "rebalance",
            &format!("{name}_tpot_p99_s"),
            0.0,
            r.tpot.map(|s| s.p99).unwrap_or(f64::NAN),
        );
        row(out, "rebalance", &format!("{name}_offloaded_fraction"), 0.0, r.offloaded_fraction);
        row(out, "rebalance", &format!("{name}_migrations"), 0.0, r.migrations_total as f64);
        row(
            out,
            "rebalance",
            &format!("{name}_migration_tokens"),
            0.0,
            r.migration_tokens_moved as f64,
        );
    }
    // The dynamic run's tick timelines (strided to ~60 chart points).
    let dynamic = &reports[1];
    for (series, tl) in [
        ("pressure", &dynamic.prefill_pressure_timeline),
        ("offloaded_frac", &dynamic.offloaded_frac_timeline),
    ] {
        let pts = tl.points();
        let stride = (pts.len() / 60).max(1);
        for (t, v) in pts.iter().step_by(stride) {
            row(out, "rebalance", series, *t, *v);
        }
    }
}

/// Online bounds feedback (ISSUE 4 / EXPERIMENTS.md §Scenarios): static
/// offline `OB` vs the online B_TPOT feedback loop on the PR 3
/// non-stationary traces. Rows per (trace, mode): throughput, goodput,
/// TPOT-SLO attainment, mean/P99 TPOT, and refresh counters, plus the
/// online runs' per-tick `b_tpot` / `ob` timelines — the tracking chart
/// (the offline seed is one horizontal line; the online bound moves with
/// context length and load).
fn feedback(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let traces: [(&str, ArrivalPattern, f64); 2] = [
        ("bursty", ArrivalPattern::Bursty { period_s: 30.0, duty: 0.25, mult: 3.0 }, 24.0),
        ("diurnal", ArrivalPattern::Diurnal { period_s: 40.0, depth: 0.8 }, 12.0),
    ];
    let modes: [(&str, Option<BoundsFeedbackConfig>); 2] =
        [("static", None), ("online", Some(BoundsFeedbackConfig::default()))];
    let reports: Vec<SimReport> = parallel_map(traces.len() * modes.len(), |i| {
        let (_, pattern, rate) = traces[i / modes.len()];
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
        cfg.duration_s = 120.0;
        cfg.arrivals = pattern;
        // Two prefill instances: Eq 1's OB_mem doubles, so the compute
        // bound (Eq 2) binds and online B_TPOT movement translates into
        // OB movement (at n=1 OB_mem binds and the loop is observational
        // — EXPERIMENTS.md §Scenarios).
        cfg.cluster.n_prefill = 2;
        cfg.serving.bounds_feedback = modes[i % modes.len()].1;
        ClusterSim::new(cfg).run()
    });
    for (i, r) in reports.iter().enumerate() {
        let trace = traces[i / modes.len()].0;
        let mode = modes[i % modes.len()].0;
        let s = |name: &str| format!("{trace}_{mode}_{name}");
        row(out, "feedback", &s("tput_tok_s"), 0.0, r.throughput);
        row(out, "feedback", &s("goodput_tok_s"), 0.0, r.goodput);
        row(out, "feedback", &s("tpot_slo_attainment"), 0.0, r.tpot_slo_attainment);
        row(
            out,
            "feedback",
            &s("tpot_s"),
            0.0,
            r.tpot.map(|t| t.mean).unwrap_or(f64::NAN),
        );
        row(
            out,
            "feedback",
            &s("tpot_p99_s"),
            0.0,
            r.tpot.map(|t| t.p99).unwrap_or(f64::NAN),
        );
        row(out, "feedback", &s("bounds_refreshes"), 0.0, r.bounds_refreshes as f64);
        row(
            out,
            "feedback",
            &s("b_tpot_observations"),
            0.0,
            r.b_tpot_observations as f64,
        );
        // The online runs' tracking timelines (strided to ~60 points).
        if mode == "online" {
            for (series, tl) in [("b_tpot", &r.b_tpot_timeline), ("ob", &r.ob_timeline)] {
                let pts = tl.points();
                let stride = (pts.len() / 60).max(1);
                for (t, v) in pts.iter().step_by(stride) {
                    row(out, "feedback", &format!("{trace}_{series}"), *t, *v);
                }
            }
        }
    }
}

/// End-to-end bucket-granularity sweep (the ROADMAP follow-on to PR 2):
/// the same saturated ShareGPT run under coarser/finer executable grids,
/// charting the padding-overhead vs grid-size frontier that
/// BENCH_graph_bucket.json tracks microscopically — now with the
/// throughput cost attached. `exact` is the zero-padding reference
/// (ADRENALINE_EXACT_COSTS ablation path).
fn buckets(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let grids: &[(&str, &[usize])] = &[
        ("coarse2", &[1, 2]),
        ("pow2_8", &[1, 2, 4, 8]),
        ("pow2_32", &[1, 2, 4, 8, 16, 32]),
        ("dense16", &[1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 14, 16]),
    ];
    let reports: Vec<SimReport> = parallel_map(grids.len() + 1, |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 24.0);
        cfg.duration_s = 60.0;
        if i < grids.len() {
            cfg.serving.decode_buckets = grids[i].1.to_vec();
            cfg.serving.offload_buckets = grids[i].1.to_vec();
        } else {
            cfg.serving.exact_costs = true;
        }
        ClusterSim::new(cfg).run()
    });
    for (i, r) in reports.iter().enumerate() {
        let name = if i < grids.len() { grids[i].0 } else { "exact" };
        let grid_size = if i < grids.len() { grids[i].1.len() as f64 } else { 0.0 };
        row(out, "buckets", &format!("{name}_grid_capacities"), grid_size, r.throughput);
        row(out, "buckets", &format!("{name}_tput_tok_s"), 0.0, r.throughput);
        row(
            out,
            "buckets",
            &format!("{name}_padding_overhead"),
            0.0,
            r.graph_padding_overhead,
        );
        row(
            out,
            "buckets",
            &format!("{name}_tpot_s"),
            0.0,
            r.tpot.map(|s| s.mean).unwrap_or(f64::NAN),
        );
    }
}

/// Fault plane (ISSUE 6 / EXPERIMENTS.md §Faults): (a) throughput /
/// goodput / recovery counters vs stochastic crash MTBF, health-aware
/// "graceful" degraded routing against the naive fail-and-recompute
/// baseline; (b) a scripted prefill-crash run's health-fraction
/// timeline — the dip at the crash, the heartbeat-latency recovery edge,
/// and the recompute wave the counters attribute to it.
fn faults(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let mtbfs = [20.0f64, 40.0, 80.0];
    let modes: [(&str, bool); 2] = [("naive", false), ("graceful", true)];
    let reports: Vec<SimReport> = parallel_map(mtbfs.len() * modes.len(), |i| {
        let mtbf = mtbfs[i / modes.len()];
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 20.0);
        cfg.duration_s = 120.0;
        // Two instances per class so a crash leaves survivors to degrade
        // onto; single-instance crashes only measure the recompute stall.
        cfg.cluster.n_prefill = 2;
        cfg.cluster.n_decode = 2;
        cfg.serving.fault = Some(FaultConfig {
            prefill_mtbf_s: Some(mtbf),
            prefill_mttr_s: 4.0,
            decode_mtbf_s: Some(2.0 * mtbf),
            decode_mttr_s: 4.0,
            health_aware: modes[i % modes.len()].1,
            ..FaultConfig::default()
        });
        ClusterSim::new(cfg).run()
    });
    for (i, r) in reports.iter().enumerate() {
        let mtbf = mtbfs[i / modes.len()];
        let mode = modes[i % modes.len()].0;
        row(out, "faults", &format!("{mode}_tput_tok_s"), mtbf, r.throughput);
        row(out, "faults", &format!("{mode}_goodput_tok_s"), mtbf, r.goodput);
        row(
            out,
            "faults",
            &format!("{mode}_requests_recovered"),
            mtbf,
            r.requests_recovered as f64,
        );
        row(
            out,
            "faults",
            &format!("{mode}_recompute_tokens"),
            mtbf,
            r.recompute_tokens_replayed as f64,
        );
        row(out, "faults", &format!("{mode}_degraded_time_s"), mtbf, r.degraded_time_s);
    }

    // (b) One scripted prefill crash mid-run: the health timeline is the
    // recovery chart (strided to ~60 points like the other timelines).
    let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 20.0);
    cfg.duration_s = 120.0;
    cfg.cluster.n_prefill = 2;
    cfg.serving.fault = Some(FaultConfig {
        script: vec![ScriptedFault {
            kind: FaultKind::PrefillCrash,
            instance: 0,
            at_s: 40.0,
            down_s: 10.0,
            group: None,
        }],
        ..FaultConfig::default()
    });
    let r = ClusterSim::new(cfg).run();
    let pts = r.health_timeline.points();
    let stride = (pts.len() / 60).max(1);
    for (t, v) in pts.iter().step_by(stride) {
        row(out, "faults", "crash_health_frac", *t, *v);
    }
    row(out, "faults", "crash_requests_recovered", 0.0, r.requests_recovered as f64);
    row(out, "faults", "crash_recompute_tokens", 0.0, r.recompute_tokens_replayed as f64);
    row(out, "faults", "crash_degraded_time_s", 0.0, r.degraded_time_s);
}

/// §3.4.2 flexibility: prefill-pool scaling. Eq 1's OB_mem is linear in
/// n (prefill instances per decode instance); more executors ⇒ more
/// offload capacity ⇒ higher saturated throughput.
fn scaling(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let sizes = [1u32, 2, 3];
    let reports: Vec<SimReport> = parallel_map(sizes.len(), |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 28.0);
        cfg.duration_s = 120.0;
        cfg.cluster.n_prefill = sizes[i];
        ClusterSim::new(cfg).run()
    });
    for (&n, r) in sizes.iter().zip(&reports) {
        row(out, "scaling", "tput_tok_s", n as f64, r.throughput);
        row(out, "scaling", "offloaded_fraction", n as f64, r.offloaded_fraction);
        row(out, "scaling", "ttft_s", n as f64, r.ttft.map(|s| s.mean).unwrap_or(f64::NAN));
    }
}

/// Fleet layer (ISSUE 8 / EXPERIMENTS.md §Fleet): (a) the three cluster
/// router policies on a saturated 4-group diurnal fleet — least-loaded's
/// live-headroom placement beats round-robin's blind striping on fleet
/// goodput (the acceptance gate) — with per-group routing counts; (b)
/// fleet-size scaling at a per-group-constant rate; (c) a 4-group
/// autoscaled fleet's routable prefill-pool timeline tracking the
/// diurnal wave, plus its goodput against the same fleet pinned at the
/// pool ceiling (the capacity the autoscaler trades against).
fn fleet(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let diurnal = ArrivalPattern::Diurnal { period_s: 40.0, depth: 0.8 };

    // (a) Router-policy shootout: 4 groups, one shared diurnal trace at
    // 4x the single-group saturating rate.
    let policies =
        [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::SessionSticky];
    let reports: Vec<FleetReport> = parallel_map(policies.len(), |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 64.0);
        cfg.duration_s = 120.0;
        cfg.arrivals = diurnal;
        cfg.serving.fleet =
            Some(FleetConfig { groups: 4, router: policies[i], ..FleetConfig::default() });
        FleetSim::new(cfg).run()
    });
    for (p, r) in policies.iter().zip(&reports) {
        let name = p.name();
        row(out, "fleet", &format!("{name}_tput_tok_s"), 0.0, r.fleet_throughput);
        row(out, "fleet", &format!("{name}_goodput_tok_s"), 0.0, r.fleet_goodput);
        row(
            out,
            "fleet",
            &format!("{name}_ttft_s"),
            0.0,
            r.fleet_ttft.map(|s| s.mean).unwrap_or(f64::NAN),
        );
        row(
            out,
            "fleet",
            &format!("{name}_tpot_p99_s"),
            0.0,
            r.fleet_tpot.map(|s| s.p99).unwrap_or(f64::NAN),
        );
        for (g, n) in r.router_decisions.iter().enumerate() {
            row(out, "fleet", &format!("{name}_routed"), g as f64, *n as f64);
        }
    }

    // (b) Fleet-size scaling: per-group rate held constant, so ideal
    // scaling is linear fleet throughput in the group count.
    let sizes = [1u32, 2, 4];
    let scale_reports: Vec<FleetReport> = parallel_map(sizes.len(), |i| {
        let g = sizes[i];
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 16.0 * g as f64);
        cfg.duration_s = 120.0;
        cfg.arrivals = diurnal;
        cfg.serving.fleet = Some(FleetConfig { groups: g, ..FleetConfig::default() });
        FleetSim::new(cfg).run()
    });
    for (&g, r) in sizes.iter().zip(&scale_reports) {
        row(out, "fleet", "size_tput_tok_s", g as f64, r.fleet_throughput);
        row(out, "fleet", "size_goodput_tok_s", g as f64, r.fleet_goodput);
    }

    // (c) Autoscaler tracking: 3 prefills per group, pool floor 1 —
    // the pool timeline should ride the diurnal wave (grow into peaks,
    // drain through troughs). The fixed-ceiling twin run prices the
    // capacity the autoscaler gives back.
    let autoscaled: Vec<FleetReport> = parallel_map(2, |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 64.0);
        cfg.duration_s = 120.0;
        cfg.arrivals = diurnal;
        cfg.cluster.n_prefill = 3;
        let autoscale = if i == 0 {
            Some(AutoscaleConfig { min_prefill: 1, max_prefill: 3, ..AutoscaleConfig::default() })
        } else {
            None // fixed at the full pool (the ceiling)
        };
        cfg.serving.fleet = Some(FleetConfig { groups: 4, autoscale, ..FleetConfig::default() });
        FleetSim::new(cfg).run()
    });
    let (auto, fixed) = (&autoscaled[0], &autoscaled[1]);
    row(out, "fleet", "autoscale_goodput_tok_s", 0.0, auto.fleet_goodput);
    row(out, "fleet", "fixed_pool_goodput_tok_s", 0.0, fixed.fleet_goodput);
    row(out, "fleet", "autoscale_scale_events", 0.0, auto.scale_events as f64);
    let pts = auto.fleet_size_timeline.points();
    let stride = (pts.len() / 60).max(1);
    for (t, v) in pts.iter().step_by(stride) {
        row(out, "fleet", "pool_size", *t, *v);
    }
}

/// Fleet fault tolerance (ISSUE 10 / EXPERIMENTS.md §Fleet-faults):
/// (a) graceful (health-aware routing + failover + admission control)
/// vs naive goodput under a scripted group-0 prefill crash, per router
/// policy, with the failover/reroute/shed counters behind the gap;
/// (b) the graceful round-robin run's per-group availability timelines
/// (the crash and recovery edges as the router sees them); (c) the
/// overload admission-control sweep — a tight TTFT budget against a
/// rising offered rate trades shed requests for SLO attainment on the
/// admitted ones.
fn fleet_faults(out: &mut String) {
    let m = ModelSpec::llama2_7b();
    let crash = |health_aware: bool| FaultConfig {
        script: vec![ScriptedFault {
            kind: FaultKind::PrefillCrash,
            instance: 0,
            at_s: 10.0,
            down_s: 60.0,
            group: Some(0),
        }],
        health_aware,
        ..FaultConfig::default()
    };

    // (a) Graceful vs naive under a group-0 crash, all three policies.
    let policies =
        [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::SessionSticky];
    let jobs: Vec<(usize, bool)> =
        policies.iter().enumerate().flat_map(|(p, _)| [(p, false), (p, true)]).collect();
    let reports: Vec<FleetReport> = parallel_map(jobs.len(), |i| {
        let (p, graceful) = jobs[i];
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, 12.0);
        cfg.duration_s = 40.0;
        cfg.serving.fault = Some(crash(graceful));
        cfg.serving.fleet = Some(FleetConfig {
            groups: 2,
            router: policies[p],
            overload: graceful.then(OverloadConfig::default),
            ..FleetConfig::default()
        });
        FleetSim::new(cfg).run()
    });
    for (i, r) in reports.iter().enumerate() {
        let (p, graceful) = jobs[i];
        let name = policies[p].name();
        let mode = if graceful { "graceful" } else { "naive" };
        let series = |metric: &str| format!("{name}_{mode}_{metric}");
        row(out, "fleet_faults", &series("goodput_shed_aware"), 0.0, r.fleet_goodput_shed_aware);
        row(out, "fleet_faults", &series("slo_attainment"), 0.0, r.fleet_slo_attainment);
        row(out, "fleet_faults", &series("shed"), 0.0, r.requests_shed as f64);
        row(out, "fleet_faults", &series("failed_over"), 0.0, r.requests_failed_over as f64);
        row(out, "fleet_faults", &series("reroutes"), 0.0, r.router_reroutes as f64);
    }

    // (b) Availability timelines from the graceful round-robin run: the
    // points are change-edges, so no stride is needed.
    let rr_graceful = &reports[1];
    for (g, tl) in rr_graceful.availability.iter().enumerate() {
        for (t, v) in tl.points() {
            row(out, "fleet_faults", &format!("rr_graceful_avail_g{g}"), *t, *v);
        }
    }

    // (c) Overload admission control: a tight TTFT budget on a healthy
    // 2-group least-loaded fleet, offered rate swept past saturation.
    let rates = [16.0, 32.0, 48.0];
    let ov_reports: Vec<FleetReport> = parallel_map(rates.len(), |i| {
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rates[i]);
        cfg.duration_s = 30.0;
        cfg.serving.fleet = Some(FleetConfig {
            groups: 2,
            router: RouterPolicy::LeastLoaded,
            overload: Some(OverloadConfig { ttft_budget_s: 0.25, ..OverloadConfig::default() }),
            ..FleetConfig::default()
        });
        FleetSim::new(cfg).run()
    });
    for (&rate, r) in rates.iter().zip(&ov_reports) {
        row(out, "fleet_faults", "overload_shed", rate, r.requests_shed as f64);
        row(out, "fleet_faults", "overload_retries", rate, r.retries as f64);
        row(out, "fleet_faults", "overload_slo_attainment", rate, r.fleet_slo_attainment);
        row(out, "fleet_faults", "overload_goodput_shed_aware", rate, r.fleet_goodput_shed_aware);
    }
}

/// Relative street-price units for the equal-hardware-cost comparison in
/// the `hetero` group: A100-80G = 1.0 by definition; an H20-class
/// memory-rich part trades at very roughly 0.45 of an A100 (compute is
/// cut ~4x while HBM capacity/bandwidth grow — the pricing asymmetry
/// arXiv 2405.01814 exploits). The absolute ratio is informational; the
/// per-cost series just needs a fixed, documented normalization.
const A100_COST_UNITS: f64 = 1.0;
const H20_COST_UNITS: f64 = 0.45;

/// Heterogeneous device profiles (ISSUE 9 / EXPERIMENTS.md
/// §Heterogeneous): three ways to buy attention capacity, compared at
/// their actual hardware cost:
///
/// * `homogeneous` — the paper's deployment: 1 prefill + 1 decode A100,
///   executor colocated on prefill SMs (2.0 A100 units);
/// * `hetero_offload` — the same A100 pair plus a standalone memory-rich
///   H20-class executor holding the offloaded KV (2.45 units);
/// * `intra_split` — one A100 statically split 45 % prefill / 55 %
///   decode SMs, no offload (Nexus-style, 1.0 unit).
///
/// Per-scenario rows: throughput, goodput and throughput *per cost unit*
/// over a rate sweep, plus each scenario's Eq 1 OB_mem and cost.
fn hetero(out: &mut String) {
    use adrenaline::config::{DeviceProfile, DeviceProfiles, DeviceRole};

    let m = ModelSpec::llama2_7b();
    let a100 = GpuSpec::a100_80g();
    let offload_profiles = DeviceProfiles {
        executor: Some(DeviceProfile::whole(GpuSpec::h20_96g(), DeviceRole::Executor)),
        ..DeviceProfiles::default()
    };
    let split_profiles = DeviceProfiles {
        prefill: Some(DeviceProfile::partitioned(a100, DeviceRole::Prefill, 0.45)),
        decode: Some(DeviceProfile::partitioned(a100, DeviceRole::Decode, 0.55)),
        executor: None,
    };
    let scenarios: [(&str, Option<DeviceProfiles>, bool, f64); 3] = [
        ("homogeneous", None, true, 2.0 * A100_COST_UNITS),
        ("hetero_offload", Some(offload_profiles), true, 2.0 * A100_COST_UNITS + H20_COST_UNITS),
        ("intra_split", Some(split_profiles), false, A100_COST_UNITS),
    ];

    let rates = [8.0, 16.0, 24.0];
    let jobs: Vec<(usize, f64)> =
        scenarios.iter().enumerate().flat_map(|(s, _)| rates.map(|r| (s, r))).collect();
    let reports: Vec<SimReport> = parallel_map(jobs.len(), |i| {
        let (s, rate) = jobs[i];
        let (_, profiles, offload, _) = scenarios[s];
        let mut cfg = SimConfig::paper_default(m, WorkloadKind::ShareGpt, rate);
        cfg.duration_s = 60.0;
        cfg.cluster.profiles = profiles;
        if !offload {
            cfg.serving.offload = adrenaline::config::OffloadPolicy::Disabled;
        }
        ClusterSim::new(cfg).run()
    });

    for (i, r) in reports.iter().enumerate() {
        let (s, rate) = jobs[i];
        let (name, _, _, cost) = scenarios[s];
        row(out, "hetero", &format!("{name}_tput_tok_s"), rate, r.throughput);
        row(out, "hetero", &format!("{name}_goodput_tok_s"), rate, r.goodput);
        row(out, "hetero", &format!("{name}_tput_per_cost"), rate, r.throughput / cost);
        row(
            out,
            "hetero",
            &format!("{name}_ttft_s"),
            rate,
            r.ttft.map(|s| s.mean).unwrap_or(f64::NAN),
        );
    }

    // Static per-scenario context: the cost normalization and Eq 1's
    // memory-side offload bound on each scenario's cluster.
    for (name, profiles, _, cost) in scenarios {
        let mut cluster = ClusterSpec::paper_default();
        cluster.profiles = profiles;
        row(out, "hetero", &format!("{name}_cost_units"), 0.0, cost);
        row(out, "hetero", &format!("{name}_ob_mem"), 0.0, OffloadBounds::ob_mem(&cluster, &m));
    }
}
