//! Transformer dimension tables and derived per-kernel cost inputs.
//!
//! The FLOP/byte formulas here are the single source of truth for the
//! roofline model ([`crate::gpu_model`]) and for the figure harnesses; they
//! follow the standard decomposition of a Llama-style decoder layer into
//! the four kernels the paper profiles (Figs 5/6): QKV projection,
//! attention, output projection, FFN.

/// Bytes per element for the serving dtype (paper: fp16).
pub const DTYPE_BYTES_F16: f64 = 2.0;
/// Bytes per element for the CPU-path tiny model (f32).
pub const DTYPE_BYTES_F32: f64 = 4.0;

/// Model architecture dimensions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub vocab_size: u64,
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub head_dim: u64,
    pub ffn_hidden: u64,
    pub max_seq_len: u64,
    /// Bytes per parameter / activation element (2 = fp16, 4 = f32).
    pub dtype_bytes: f64,
}

impl ModelSpec {
    /// The tiny CPU-path model. MUST match python/compile/model.py::TINY and
    /// artifacts/manifest.json (checked at runtime by the artifact loader).
    pub const fn tiny() -> Self {
        ModelSpec {
            name: "tiny",
            vocab_size: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            head_dim: 16,
            ffn_hidden: 128,
            max_seq_len: 128,
            dtype_bytes: DTYPE_BYTES_F32,
        }
    }

    /// Llama-2 7B (fp16) — the paper's primary evaluation model.
    pub const fn llama2_7b() -> Self {
        ModelSpec {
            name: "llama2-7b",
            vocab_size: 32000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            head_dim: 128,
            ffn_hidden: 11008,
            max_seq_len: 4096,
            dtype_bytes: DTYPE_BYTES_F16,
        }
    }

    /// Llama-2 13B (fp16).
    pub const fn llama2_13b() -> Self {
        ModelSpec {
            name: "llama2-13b",
            vocab_size: 32000,
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            head_dim: 128,
            ffn_hidden: 13824,
            max_seq_len: 4096,
            dtype_bytes: DTYPE_BYTES_F16,
        }
    }

    /// Total parameter count (Llama architecture, tied-embedding variant for
    /// the tiny model; untied lm_head for 7B/13B — matches published counts
    /// to within ~1%).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model;
        let f = self.ffn_hidden;
        let per_layer = 4 * d * d          // wq wk wv wo
            + 3 * d * f                    // gate up down
            + 2 * d; // two RMSNorm gains
        let embed = self.vocab_size * d;
        let head = if self.name == "tiny" { 0 } else { self.vocab_size * d };
        embed + head + self.n_layers * per_layer + d
    }

    /// Bytes of HBM the weights occupy.
    pub fn weight_bytes(&self) -> f64 {
        self.param_count() as f64 * self.dtype_bytes
    }

    /// KV-cache bytes per token (all layers, K + V).
    pub fn kv_bytes_per_token(&self) -> f64 {
        // MHA: per layer K and V each hold d_model elements per token.
        (2 * self.n_layers * self.n_heads * self.head_dim) as f64 * self.dtype_bytes
    }

    // ----- per-kernel FLOP / HBM-byte counts, decode step -----------------
    // One decode step over a batch of `b` requests whose context lengths sum
    // to `ctx_total` tokens. All counts are whole-model (× n_layers).

    /// QKV projection: GEMM [b, d] x [d, 3d].
    pub fn decode_qkv_flops(&self, b: u64) -> f64 {
        (2 * b * self.d_model * 3 * self.d_model * self.n_layers) as f64
    }
    pub fn decode_qkv_bytes(&self, b: u64) -> f64 {
        // Weight-dominated: 3·d² weights per layer + activations.
        ((3 * self.d_model * self.d_model + 4 * b * self.d_model) * self.n_layers) as f64
            * self.dtype_bytes
    }

    /// Decode attention: q·K^T and p·V over the whole context.
    pub fn decode_attn_flops(&self, ctx_total: u64) -> f64 {
        (4 * ctx_total * self.d_model * self.n_layers) as f64
    }
    /// The KV-cache read is the attention kernel's (dominant) traffic.
    pub fn decode_attn_bytes(&self, ctx_total: u64) -> f64 {
        ctx_total as f64 * self.kv_bytes_per_token()
    }

    /// Output projection: GEMM [b, d] x [d, d].
    pub fn decode_oproj_flops(&self, b: u64) -> f64 {
        (2 * b * self.d_model * self.d_model * self.n_layers) as f64
    }
    pub fn decode_oproj_bytes(&self, b: u64) -> f64 {
        ((self.d_model * self.d_model + 2 * b * self.d_model) * self.n_layers) as f64
            * self.dtype_bytes
    }

    /// SwiGLU FFN: three GEMMs [b, d] x [d, f] / [f, d].
    pub fn decode_ffn_flops(&self, b: u64) -> f64 {
        (2 * b * 3 * self.d_model * self.ffn_hidden * self.n_layers) as f64
    }
    pub fn decode_ffn_bytes(&self, b: u64) -> f64 {
        ((3 * self.d_model * self.ffn_hidden + 2 * b * (self.d_model + self.ffn_hidden))
            * self.n_layers) as f64
            * self.dtype_bytes
    }

    /// LM head (+ final norm): GEMM [b, d] x [d, V]. Charged once, not per
    /// layer.
    pub fn decode_head_flops(&self, b: u64) -> f64 {
        (2 * b * self.d_model * self.vocab_size) as f64
    }
    pub fn decode_head_bytes(&self, b: u64) -> f64 {
        (self.d_model * self.vocab_size + b * self.vocab_size) as f64 * self.dtype_bytes
    }

    /// Whole decode step (all kernels).
    pub fn decode_step_flops(&self, b: u64, ctx_total: u64) -> f64 {
        self.decode_qkv_flops(b)
            + self.decode_attn_flops(ctx_total)
            + self.decode_oproj_flops(b)
            + self.decode_ffn_flops(b)
            + self.decode_head_flops(b)
    }
    pub fn decode_step_bytes(&self, b: u64, ctx_total: u64) -> f64 {
        self.decode_qkv_bytes(b)
            + self.decode_attn_bytes(ctx_total)
            + self.decode_oproj_bytes(b)
            + self.decode_ffn_bytes(b)
            + self.decode_head_bytes(b)
    }

    // ----- prefill (prompt of p tokens, batch folded into p) --------------

    pub fn prefill_qkv_flops(&self, p: u64) -> f64 {
        self.decode_qkv_flops(p)
    }
    /// Prefill causal attention: ~p²·d MACs per layer (causal halves it).
    pub fn prefill_attn_flops(&self, p: u64) -> f64 {
        (2 * p * p * self.d_model * self.n_layers) as f64
    }
    pub fn prefill_attn_bytes(&self, p: u64) -> f64 {
        // Flash attention streams K/V once per q-block; approximate one full
        // KV pass plus q/o traffic.
        (p as f64 * self.kv_bytes_per_token())
            + (2 * p * self.d_model * self.n_layers) as f64 * self.dtype_bytes
    }
    pub fn prefill_oproj_flops(&self, p: u64) -> f64 {
        self.decode_oproj_flops(p)
    }
    pub fn prefill_ffn_flops(&self, p: u64) -> f64 {
        self.decode_ffn_flops(p)
    }

    /// Total prefill FLOPs for a prompt of `p` tokens (the standard ≈2·N·p
    /// plus quadratic attention).
    pub fn prefill_flops(&self, p: u64) -> f64 {
        self.prefill_qkv_flops(p)
            + self.prefill_attn_flops(p)
            + self.prefill_oproj_flops(p)
            + self.prefill_ffn_flops(p)
            + self.decode_head_flops(1)
    }

    /// HBM traffic of a prefill: one weights pass (compute-bound ⇒ weights
    /// are re-read per layer, activations stay resident) plus KV writes.
    pub fn prefill_bytes(&self, p: u64) -> f64 {
        self.weight_bytes() + p as f64 * self.kv_bytes_per_token()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published() {
        // Llama-2 7B: 6.74e9 params; 13B: 13.0e9. Allow 2%.
        let p7 = ModelSpec::llama2_7b().param_count() as f64;
        assert!((p7 - 6.74e9).abs() / 6.74e9 < 0.02, "7B params = {p7:.3e}");
        let p13 = ModelSpec::llama2_13b().param_count() as f64;
        assert!((p13 - 13.0e9).abs() / 13.0e9 < 0.02, "13B params = {p13:.3e}");
    }

    #[test]
    fn kv_bytes_per_token_7b() {
        // Published: 0.5 MiB/token for Llama-2 7B fp16.
        let kv = ModelSpec::llama2_7b().kv_bytes_per_token();
        assert_eq!(kv, 2.0 * 32.0 * 4096.0 * 2.0);
        assert!((kv - 524288.0).abs() < 1.0);
    }

    #[test]
    fn tiny_matches_manifest_dims() {
        let t = ModelSpec::tiny();
        assert_eq!(t.d_model, t.n_heads * t.head_dim);
        assert_eq!(t.max_seq_len, 128);
        assert_eq!(t.n_layers, 2);
    }

    #[test]
    fn decode_attn_dominates_bytes_at_long_context() {
        // The paper's Fig 3 premise: attention's KV read dominates decode
        // traffic as batch·seq grows.
        let m = ModelSpec::llama2_7b();
        let b = 80;
        let ctx = b * 1024;
        let attn = m.decode_attn_bytes(ctx);
        let rest = m.decode_step_bytes(b, ctx) - attn;
        assert!(attn > 2.0 * rest, "attn={attn:.3e} rest={rest:.3e}");
    }

    #[test]
    fn prefill_flops_scale_quadratically_eventually() {
        let m = ModelSpec::llama2_7b();
        let f1 = m.prefill_flops(1024);
        let f2 = m.prefill_flops(2048);
        // Doubling p more than doubles FLOPs (linear + quadratic terms).
        assert!(f2 > 2.0 * f1);
        assert!(f2 < 4.0 * f1);
    }

    #[test]
    fn decode_step_flops_monotone_in_batch() {
        let m = ModelSpec::llama2_13b();
        let mut prev = 0.0;
        for b in [1u64, 2, 8, 32, 128] {
            let f = m.decode_step_flops(b, b * 512);
            assert!(f > prev);
            prev = f;
        }
    }

    #[test]
    fn weight_bytes_fit_a100() {
        assert!(ModelSpec::llama2_7b().weight_bytes() < 80e9 * 0.2);
        assert!(ModelSpec::llama2_13b().weight_bytes() < 80e9 * 0.4);
    }
}
